"""Roofline report: three terms per (arch x shape) on the 16x16 mesh.

Sources (see EXPERIMENTS.md §Roofline for the methodology note):
  * compute term   — exact global HLO FLOPs from the unrolled cost pass
    (launch_results/cost/*.json; XLA counts while bodies once, so the
    production scanned lowering cannot be used for totals — validated
    in tests/test_dryrun.py) divided by chips x peak;
  * memory term    — analytic minimum HBM traffic (params, caches,
    activations; formulas below), the fusion-realistic bound.  The
    unfused HLO bytes from the cost pass are reported as the upper
    bracket;
  * collective term — analytic wire bytes of the sharding schedule
    (megatron TP all-reduces, DP grad reduction, ZeRO RS/AG, EP
    all-to-all, paged gathers), cross-checked against the collective-op
    inventory parsed from the compiled 256-dev HLO (dryrun/*.json).

Hardware: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI
per chip. chips=256 (single pod; the pod axis is pure DP on top).
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from repro.configs import ARCHS
from repro.configs.base import SHAPES
from repro.launch.shapes import LONG_KNN_CFG
# The ANN scan-stage HBM model lives with the serving stats schema so
# benchmark reports (bench_fused) and live serving snapshots
# (repro.obs.snapshot_all) use identical accounting; re-exported here
# because this module owns the repo's HBM-traffic bookkeeping.
from repro.obs.stats import scan_traffic_model  # noqa: F401

PEAK = 197e12
HBM = 819e9
ICI = 50e9
CHIPS = 256
TP = 16           # model axis
DP = 16           # data axis
BF16 = 2
F32 = 4

ROOT = os.path.join(os.path.dirname(__file__), "..", "launch_results")


def _param_counts(cfg) -> Dict[str, float]:
    """#params by group: dense (always active), expert (MoE), embed table."""
    from repro.models.transformer import ParamSpec, param_specs
    import jax
    dense = expert = embed = 0
    def walk(tree, in_moe=False):
        nonlocal dense, expert, embed
        for k, v in tree.items():
            if isinstance(v, dict):
                walk(v, in_moe or k == "moe")
            elif isinstance(v, ParamSpec):
                n = float(np.prod(v.shape))
                if k == "embed":
                    embed += n
                elif in_moe and k in ("w_gate", "w_up", "w_down") \
                        and len(v.shape) == 4:
                    expert += n
                else:
                    dense += n
    walk(param_specs(cfg))
    return {"dense": dense, "expert": expert, "embed": embed}


def model_flops(arch: str, shape: str, **_) -> float:
    """'Useful' FLOPs: 6*N_active*T train / 2*N_active*T inference,
    plus exact-attention (or SSD / retrieval) context terms."""
    cfg = ARCHS[arch]
    info = SHAPES[shape]
    b, s = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    pc = _param_counts(cfg)
    n_active = pc["dense"] + pc["expert"] * (
        cfg.moe_top_k / cfg.moe_experts if cfg.moe_experts else 0.0)
    n_attn_layers = sum(m == "attn" for m, _ in cfg.slot_kinds()) \
        * cfg.n_periods
    hd, h = cfg.hd, cfg.n_heads

    if kind == "train":
        t = b * s
        attn = 6 * n_attn_layers * (2 * t * s * h * hd) / 2  # causal half
        return 6 * n_active * t + attn
    if kind == "prefill":
        t = b * s
        attn = 2 * n_attn_layers * (2 * t * s * h * hd) / 2
        return 2 * n_active * t + attn
    if kind == "decode":
        attn = n_attn_layers * (2 * 2 * b * s * cfg.n_kv_heads
                                * (h // cfg.n_kv_heads) * hd)
        return 2 * n_active * b + attn
    # long_decode
    if cfg.attn_every == 0:   # rairs_knn: retrieved subset, not full S
        kc = LONG_KNN_CFG
        keys = kc.nprobe * kc.max_blocks_per_list * kc.block + kc.window
        attn = n_attn_layers * (2 * 2 * b * keys * h * hd)
        return 2 * n_active * b + attn
    attn = n_attn_layers * (2 * 2 * b * s * h * hd)
    return 2 * n_active * b + attn


def analytic_bytes(arch: str, shape: str, tp: int = TP, dp: int = DP,
                   kv_bytes: int = BF16, knn_cfg=None) -> float:
    """Min HBM traffic per device per step (fusion-ideal)."""
    global TP, DP
    TP_, DP_ = TP, DP
    cfg = ARCHS[arch]
    info = SHAPES[shape]
    b, s = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    pc = _param_counts(cfg)
    n_total = pc["dense"] + pc["expert"] + pc["embed"]
    p_local = n_total / tp              # TP-sharded weights
    act_bytes_tok = cfg.d_model * cfg.n_layers * 12 * BF16  # ~6 rw tensors

    if kind == "train":
        accum = 8
        tok_local = b * s / dp
        # fwd+bwd param reads per microbatch (remat ~3x) + grad write/read
        w = accum * 3 * p_local * F32 + 4 * p_local * F32
        opt = 6 * p_local * F32 / dp   # ZeRO-1 moments
        acts = tok_local * act_bytes_tok
        return w + opt + acts
    if kind == "prefill":
        tok_local = b * s / dp
        return p_local * BF16 + tok_local * act_bytes_tok / 6
    if kind == "decode":
        n_attn_layers = sum(m == "attn" for m, _ in cfg.slot_kinds()) \
            * cfg.n_periods
        kv = (2 * n_attn_layers * (b / dp) * s
              * cfg.n_kv_heads * cfg.hd / tp * kv_bytes)
        ssm_layers = cfg.n_layers - n_attn_layers
        ssm = (2 * ssm_layers * (b / dp) * cfg.ssm_heads
               * cfg.ssm_head_dim * cfg.ssm_state * F32) if ssm_layers else 0
        return p_local * BF16 + kv + ssm
    # long_decode
    if cfg.attn_every == 0:
        kc = knn_cfg or LONG_KNN_CFG
        n_attn_layers = cfg.n_layers
        gathered = (2 * n_attn_layers * cfg.n_kv_heads * kc.nprobe
                    * kc.max_blocks_per_list * kc.block * cfg.hd * kv_bytes
                    / CHIPS)
        cent = n_attn_layers * cfg.n_kv_heads * kc.nlist * cfg.hd * F32 \
            / CHIPS
        return p_local * BF16 + gathered + cent
    n_attn_layers = sum(m == "attn" for m, _ in cfg.slot_kinds()) \
        * cfg.n_periods
    kv = 2 * n_attn_layers * b * s * cfg.n_kv_heads * cfg.hd * BF16 / CHIPS
    ssm_layers = cfg.n_layers - n_attn_layers
    ssm = 2 * ssm_layers * b * cfg.ssm_heads * cfg.ssm_head_dim \
        * cfg.ssm_state * F32
    return p_local * BF16 + kv + ssm


def analytic_collective_bytes(arch: str, shape: str, tp: int = TP,
                              dp: int = DP, grad_bytes: int = F32,
                              kv_bytes: int = BF16, knn_cfg=None) -> float:
    """Wire bytes per device per step under the declared schedule."""
    cfg = ARCHS[arch]
    info = SHAPES[shape]
    b, s = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    pc = _param_counts(cfg)
    n_total = pc["dense"] + pc["expert"] + pc["embed"]
    d = cfg.d_model
    L = cfg.n_layers
    n_attn = sum(m == "attn" for m, _ in cfg.slot_kinds()) * cfg.n_periods
    n_moe = sum(ml == "moe" for _, ml in cfg.slot_kinds()) * cfg.n_periods

    if kind == "train":
        tok_local = b * s / dp
        # megatron TP: 2 all-reduce / layer fwd + 2 bwd, ring: 2x payload
        tpb = L * 4 * (tok_local * d * BF16) * 2 * (tp - 1) / tp
        # DP grad all-reduce (ring 2x) in f32 over TP-sharded grads
        dpg = 2 * (n_total / tp) * grad_bytes * (dp - 1) / dp * 2
        # EP all-to-all: top_k dispatch+combine per moe layer
        ep = n_moe * 2 * (tok_local * d * BF16) * (cfg.moe_top_k or 0)
        return tpb + dpg + ep
    if kind == "prefill":
        tok_local = b * s / dp
        tpb = L * 2 * (tok_local * d * BF16) * 2 * (tp - 1) / tp
        ep = n_moe * 2 * (tok_local * d * BF16) * (cfg.moe_top_k or 0)
        return tpb + ep
    if kind == "decode":
        tok_local = b / dp
        tpb = L * 2 * (tok_local * d * BF16) * 2 * (tp - 1) / tp
        ep = n_moe * 2 * (tok_local * d * BF16) * (cfg.moe_top_k or 0)
        return tpb + ep
    # long_decode, b=1 replicated activations; paged gathers cross-device
    if cfg.attn_every == 0:
        kc = knn_cfg or LONG_KNN_CFG
        gathered = (2 * cfg.n_layers * cfg.n_kv_heads * kc.nprobe
                    * kc.max_blocks_per_list * kc.block * cfg.hd * kv_bytes)
        # blocks sharded over data: (DP-1)/DP of gathered bytes cross links
        return gathered * (dp - 1) / dp / dp + L * 2 * d * BF16 * 2
    return L * 2 * d * BF16 * 2   # TP all-reduces on a single token


def load_results():
    cost, dry = {}, {}
    cdir = os.path.join(ROOT, "cost")
    ddir = os.path.join(ROOT, "dryrun")
    for fn in os.listdir(cdir):
        r = json.load(open(os.path.join(cdir, fn)))
        cost[(r["arch"], r["shape"])] = r
    for fn in os.listdir(ddir):
        r = json.load(open(os.path.join(ddir, fn)))
        if r.get("status") == "skipped":
            continue
        dry[(r["arch"], r["shape"], r["multi_pod"])] = r
    return cost, dry


def roofline_row(arch: str, shape: str, cost, dry) -> Optional[dict]:
    c = cost.get((arch, shape))
    if c is None or c.get("status") != "ok":
        return None
    d1 = dry.get((arch, shape, False), {})
    flops = c["flops"]
    t_comp = flops / (CHIPS * PEAK)
    abytes = analytic_bytes(arch, shape)
    t_mem = abytes / HBM
    cbytes = analytic_collective_bytes(arch, shape)
    t_coll = cbytes / ICI
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(arch, shape)
    total = max(t_comp, t_mem, t_coll)
    return {
        "arch": arch, "shape": shape,
        "hlo_flops": flops,
        "model_flops": mf,
        "useful_ratio": mf / flops,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bottleneck": dom[0],
        "roofline_frac": t_comp / total,   # fraction of peak if bound there
        "hlo_unfused_bytes": c.get("bytes_accessed"),
        "collectives_in_hlo": sorted(
            (d1.get("collective_bytes") or {}).keys()),
        "compile_s_pod1": d1.get("compile_s"),
    }


def report(out_path: Optional[str] = None):
    cost, dry = load_results()
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            r = roofline_row(arch, shape, cost, dry)
            if r:
                rows.append(r)
    lines = ["| arch | shape | HLO FLOPs | useful | compute s | memory s |"
             " collective s | bound | roofline |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['hlo_flops']:.3e} "
            f"| {r['useful_ratio']:.2f} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| {r['bottleneck']} | {r['roofline_frac']:.2f} |")
    text = "\n".join(lines)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1, default=str)
    return rows, text


if __name__ == "__main__":
    rows, text = report(os.path.join(ROOT, "roofline.json"))
    print(text)
