"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (see DESIGN.md §6).  Prints
``name,us_per_call,derived`` CSV; raw rows go to benchmarks/results/.
``--full`` widens datasets/queries; ``--only fig8`` runs one bench.

The engine bench additionally writes a machine-readable
``BENCH_engine.json`` at the repo root (recall / QPS / DCO per
exec-mode x nprobe config, plus searcher compile-cache stats) so the
perf trajectory is tracked across PRs instead of only printed.  The
stream bench does the same with ``BENCH_stream.json`` (append
throughput delta-path vs legacy rebuild, layout-build count — must be
0 on the delta path —, compaction cost, recall under churn), and the
distributed bench with ``BENCH_dist.json`` (recall / QPS / DCO of
``ShardedIndex`` sessions vs device count for both exec modes; sweep
wider by setting ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
before the run), and the fused scan->top-k bench with
``BENCH_fused.json`` (modeled scan-stage HBM traffic fused vs unfused
plus QPS per exec mode — the CI ``kernel-smoke`` guard), and the
gateway serving bench with ``BENCH_serve.json`` (deadline-batched vs
per-request throughput and p50/p99 latency per open-loop offered load
point — the CI ``gateway-smoke`` guard), and the stage-trace bench
with ``BENCH_trace.json`` (per-stage wall-time/DCO breakdown from
tracer spans with >= 95% dispatch-time attribution asserted,
single-host and sharded — the stage-attributed view of the
BENCH_dist.json multi-device cliff; DESIGN.md §11), and the two-tier
quantization-ladder bench with ``BENCH_refine.json`` (backend x
refine_factor x nprobe sweep: recall and the weighted total-ops model
vs single-tier, rf=1 bitwise-parity count, and the frontier config —
the CI ``refine-smoke`` guard; DESIGN.md §12), and the
overload-resilience bench with ``BENCH_overload.json`` (unbounded vs
bounded-admission vs degradation-ladder serving at 0.5/1/2x the
measured saturating load: typed shed/deadline accounting, answered
recall vs the documented floor, ladder engagement — the CI
``chaos-smoke`` guard; DESIGN.md §13).

``benchmarks/check_regression.py`` consumes the committed BENCH_*.json
files and gates CI on machine-checkable invariants (never wall-clock).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from . import suite

BENCH_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_engine.json")
STREAM_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_stream.json")
DIST_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_dist.json")
PLAN_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_plan.json")
FUSED_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fused.json")
SERVE_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serve.json")
TRACE_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_trace.json")
REFINE_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_refine.json")
OVERLOAD_JSON_DEFAULT = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_overload.json")
BENCH_JSON_SCHEMA_VERSION = 1
STREAM_JSON_SCHEMA_VERSION = 1
DIST_JSON_SCHEMA_VERSION = 1
PLAN_JSON_SCHEMA_VERSION = 1
FUSED_JSON_SCHEMA_VERSION = 1
SERVE_JSON_SCHEMA_VERSION = 1
TRACE_JSON_SCHEMA_VERSION = 1
REFINE_JSON_SCHEMA_VERSION = 1
OVERLOAD_JSON_SCHEMA_VERSION = 1


def _write_summary_json(label: str, schema_version: int, body: dict,
                        dataset: str, path: str) -> None:
    """Shared writer for every committed BENCH_*.json (one format:
    schema_version + dataset + bench body, trailing newline)."""
    payload = {
        "schema_version": schema_version,
        "dataset": dataset,
        **body,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")
    sys.stderr.write(f"[{label} json -> {os.path.abspath(path)}]\n")


def write_bench_json(engine_out: dict, dataset: str, path: str) -> None:
    """Flatten the exec-mode sweep into per-config rows and persist."""
    configs = []
    for mode in ("paged", "grouped"):
        for row in engine_out.get(mode, ()):
            configs.append({
                "config": f"{mode}/nprobe{row['nprobe']}",
                "exec_mode": mode,
                "nprobe": row["nprobe"],
                "recall": row["recall"],
                "qps": row["qps"],
                "dco": row["dco"],
            })
    _write_summary_json("bench", BENCH_JSON_SCHEMA_VERSION, {
        "id_mismatch_points": engine_out.get("id_mismatch_points"),
        "searcher": engine_out.get("searcher", {}),
        "configs": configs,
    }, dataset, path)


def write_stream_json(stream_out: dict, dataset: str, path: str) -> None:
    """Persist the streaming bench (append/compact/churn) summary."""
    _write_summary_json("stream", STREAM_JSON_SCHEMA_VERSION, stream_out,
                        dataset, path)


def write_dist_json(dist_out: dict, dataset: str, path: str) -> None:
    """Persist the distributed scaling bench summary."""
    import jax
    _write_summary_json("dist", DIST_JSON_SCHEMA_VERSION, {
        "devices_available": len(jax.devices()),
        "platform": jax.devices()[0].platform,
        **dist_out,
    }, dataset, path)


def write_plan_json(plan_out: dict, dataset: str, path: str) -> None:
    """Persist the locality-aware planning bench (union sizes, plan-cache
    hit rates, clustered-vs-paged QPS, delta-routing cost)."""
    _write_summary_json("plan", PLAN_JSON_SCHEMA_VERSION, plan_out,
                        dataset, path)


def write_fused_json(fused_out: dict, dataset: str, path: str) -> None:
    """Persist the fused scan->top-k bench (modeled scan-stage HBM
    traffic fused vs unfused + QPS per exec mode)."""
    _write_summary_json("fused", FUSED_JSON_SCHEMA_VERSION, fused_out,
                        dataset, path)


def write_serve_json(serve_out: dict, dataset: str, path: str) -> None:
    """Persist the gateway serving bench (deadline-batched vs
    per-request throughput + p50/p99 per offered load point)."""
    _write_summary_json("serve", SERVE_JSON_SCHEMA_VERSION, serve_out,
                        dataset, path)


def write_trace_json(trace_out: dict, dataset: str, path: str) -> None:
    """Persist the stage-trace bench (per-stage time/DCO breakdown and
    attribution, single-host + sharded — DESIGN.md §11)."""
    import jax
    _write_summary_json("trace", TRACE_JSON_SCHEMA_VERSION, {
        "devices_available": len(jax.devices()),
        "platform": jax.devices()[0].platform,
        **trace_out,
    }, dataset, path)


def write_refine_json(refine_out: dict, dataset: str, path: str) -> None:
    """Persist the two-tier quantization-ladder bench (backend x
    refine_factor x nprobe sweep: recall vs modeled total-ops reduction
    against single-tier, plus the rf=1 bitwise-parity count)."""
    _write_summary_json("refine", REFINE_JSON_SCHEMA_VERSION, refine_out,
                        dataset, path)


def write_overload_json(overload_out: dict, dataset: str, path: str) -> None:
    """Persist the overload-resilience bench (bounded admission vs
    unbounded at 0.5/1/2x saturating load: typed shed/deadline
    accounting, degradation-ladder engagement, answered recall vs the
    documented floor — DESIGN.md §13)."""
    _write_summary_json("overload", OVERLOAD_JSON_SCHEMA_VERSION,
                        overload_out, dataset, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--bench-json", type=str, default=BENCH_JSON_DEFAULT,
                    help="where the engine bench writes its machine-readable "
                         "summary ('' disables)")
    ap.add_argument("--stream-json", type=str, default=STREAM_JSON_DEFAULT,
                    help="where the stream bench writes its machine-readable "
                         "summary ('' disables)")
    ap.add_argument("--dist-json", type=str, default=DIST_JSON_DEFAULT,
                    help="where the distributed bench writes its machine-"
                         "readable summary ('' disables)")
    ap.add_argument("--plan-json", type=str, default=PLAN_JSON_DEFAULT,
                    help="where the planning bench writes its machine-"
                         "readable summary ('' disables)")
    ap.add_argument("--fused-json", type=str, default=FUSED_JSON_DEFAULT,
                    help="where the fused scan->top-k bench writes its "
                         "machine-readable summary ('' disables)")
    ap.add_argument("--serve-json", type=str, default=SERVE_JSON_DEFAULT,
                    help="where the gateway serving bench writes its "
                         "machine-readable summary ('' disables)")
    ap.add_argument("--trace-json", type=str, default=TRACE_JSON_DEFAULT,
                    help="where the stage-trace bench writes its machine-"
                         "readable summary ('' disables)")
    ap.add_argument("--refine-json", type=str, default=REFINE_JSON_DEFAULT,
                    help="where the quantization-ladder bench writes its "
                         "machine-readable summary ('' disables)")
    ap.add_argument("--overload-json", type=str,
                    default=OVERLOAD_JSON_DEFAULT,
                    help="where the overload-resilience bench writes its "
                         "machine-readable summary ('' disables)")
    ap.add_argument("--bench-dataset", type=str, default="sift1m",
                    help="dataset for the engine/stream benches and their "
                         "BENCH_*.json files")
    args = ap.parse_args()

    benches = _bench_list(args)
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            out = fn()
            if name == "engine_modes" and args.bench_json:
                write_bench_json(out, args.bench_dataset, args.bench_json)
            if name == "stream" and args.stream_json:
                write_stream_json(out, args.bench_dataset, args.stream_json)
            if name == "dist" and args.dist_json:
                write_dist_json(out, args.bench_dataset, args.dist_json)
            if name == "plan" and args.plan_json:
                write_plan_json(out, args.bench_dataset, args.plan_json)
            if name == "fused" and args.fused_json:
                write_fused_json(out, args.bench_dataset, args.fused_json)
            if name == "serve" and args.serve_json:
                write_serve_json(out, args.bench_dataset, args.serve_json)
            if name == "trace" and args.trace_json:
                write_trace_json(out, args.bench_dataset, args.trace_json)
            if name == "refine" and args.refine_json:
                write_refine_json(out, args.bench_dataset, args.refine_json)
            if name == "overload" and args.overload_json:
                write_overload_json(out, args.bench_dataset,
                                    args.overload_json)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},NaN,FAILED")
        sys.stderr.write(f"[bench {name}: {time.perf_counter()-t0:.1f}s]\n")
    if failures:
        sys.exit(1)


def _bench_list(args):
    main_sets = ("sift1m", "msong", "gist", "openai") if args.full \
        else ("sift1m",)
    return [
        ("fig5", lambda: suite.bench_cells()),
        ("fig7_k10", lambda: suite.bench_recall_curves(main_sets, k=10,
                                                       quick=not args.full)),
        ("fig7_k1", lambda: suite.bench_recall_curves(("sift1m",), k=1,
                                                      quick=True)),
        ("fig8", lambda: suite.bench_nprobe()),
        ("fig9", lambda: suite.bench_cdf()),
        ("fig10", lambda: suite.bench_top100()),
        ("fig11", lambda: suite.bench_latency()),
        ("fig12", lambda: suite.bench_insert_delete()),
        ("fig13a", lambda: suite.bench_ablation()),
        ("table4", lambda: suite.bench_memory(
            main_sets if args.full else ("sift1m",))),
        ("fig14", lambda: suite.bench_multi_assign()),
        ("fig15a", lambda: suite.bench_lambda()),
        ("fig15b", lambda: suite.bench_ncands()),
        ("fig16", lambda: suite.bench_block_size()),
        ("fig17", lambda: suite.bench_seil_soar()),
        ("table3", lambda: suite.bench_match_table(
            main_sets if args.full else ("sift1m",))),
        ("engine_modes",
         lambda: suite.bench_exec_modes(dataset=args.bench_dataset)),
        ("stream", lambda: suite.bench_stream(dataset=args.bench_dataset)),
        ("plan", lambda: suite.bench_plan(dataset=args.bench_dataset)),
        ("dist", lambda: suite.bench_dist(dataset=args.bench_dataset)),
        ("fused", lambda: suite.bench_fused(dataset=args.bench_dataset)),
        ("serve", lambda: suite.bench_serve(dataset=args.bench_dataset)),
        ("trace", lambda: suite.bench_trace(dataset=args.bench_dataset)),
        ("refine", lambda: suite.bench_refine(dataset=args.bench_dataset)),
        ("overload",
         lambda: suite.bench_overload(dataset=args.bench_dataset)),
        ("kernels", lambda: suite.bench_kernels()),
    ]


if __name__ == "__main__":
    main()
