"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (see DESIGN.md §6).  Prints
``name,us_per_call,derived`` CSV; raw rows go to benchmarks/results/.
``--full`` widens datasets/queries; ``--only fig8`` runs one bench.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import suite


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()

    main_sets = ("sift1m", "msong", "gist", "openai") if args.full \
        else ("sift1m",)
    benches = [
        ("fig5", lambda: suite.bench_cells()),
        ("fig7_k10", lambda: suite.bench_recall_curves(main_sets, k=10,
                                                       quick=not args.full)),
        ("fig7_k1", lambda: suite.bench_recall_curves(("sift1m",), k=1,
                                                      quick=True)),
        ("fig8", lambda: suite.bench_nprobe()),
        ("fig9", lambda: suite.bench_cdf()),
        ("fig10", lambda: suite.bench_top100()),
        ("fig11", lambda: suite.bench_latency()),
        ("fig12", lambda: suite.bench_insert_delete()),
        ("fig13a", lambda: suite.bench_ablation()),
        ("table4", lambda: suite.bench_memory(
            main_sets if args.full else ("sift1m",))),
        ("fig14", lambda: suite.bench_multi_assign()),
        ("fig15a", lambda: suite.bench_lambda()),
        ("fig15b", lambda: suite.bench_ncands()),
        ("fig16", lambda: suite.bench_block_size()),
        ("fig17", lambda: suite.bench_seil_soar()),
        ("table3", lambda: suite.bench_match_table(
            main_sets if args.full else ("sift1m",))),
        ("engine_modes", lambda: suite.bench_exec_modes()),
        ("kernels", lambda: suite.bench_kernels()),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},NaN,FAILED")
        sys.stderr.write(f"[bench {name}: {time.perf_counter()-t0:.1f}s]\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
