"""Perf-regression gate over the committed BENCH_*.json summaries.

``PYTHONPATH=src python -m benchmarks.check_regression``            # all
``... check_regression plan=/tmp/BENCH_plan_unit.json trace=...``   # some

Each committed benchmark summary carries machine-checkable invariants
— per-stage DCO splits, union-cut ratios, plan reuse rates, modeled
HBM traffic reductions, id-parity counts, stage-time attribution —
that hold at ANY scale and on ANY machine.  This gate asserts those,
and deliberately never a wall-clock number: CI runners are noisy, but
"the fused scan writes >= 4x fewer bytes", "the traced dispatch
returned identical ids", and "the clustered tile union is a strict cut
of the batch union" are exact at unit scale and at sift1m alike.

CI smoke jobs run a unit-scale bench into a temp file and gate it with
``kind=/path.json``; with no arguments the gate re-validates every
committed repo-root baseline, so a PR that regenerates a BENCH_*.json
with a regressed invariant fails even if no smoke re-runs that bench.

Pure stdlib on purpose (no jax, no repro import): the gate must be
runnable before, after, and regardless of the accelerator stack.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Callable, Dict

_REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
_SCHEMA_EXPECTED = {"engine": 1, "stream": 1, "dist": 1, "plan": 1,
                    "fused": 1, "serve": 1, "trace": 1, "refine": 1,
                    "overload": 1}


class Gate:
    """Collects named invariant checks; remembers every failure."""

    def __init__(self):
        self.checks = 0
        self.failures = []

    def check(self, ok: bool, label: str, detail: str = "") -> None:
        self.checks += 1
        if ok:
            print(f"  ok   {label}")
        else:
            self.failures.append(f"{label}: {detail}" if detail else label)
            print(f"  FAIL {label}  {detail}")


def _schema(g: Gate, kind: str, d: dict) -> None:
    want = _SCHEMA_EXPECTED[kind]
    g.check(d.get("schema_version") == want,
            f"{kind}.schema_version == {want}",
            f"got {d.get('schema_version')!r}")


def check_engine(g: Gate, d: dict) -> None:
    g.check(d.get("id_mismatch_points") == 0,
            "engine: exec modes agree on ids at every config",
            f"id_mismatch_points={d.get('id_mismatch_points')}")
    g.check(all(0.0 <= c["recall"] <= 1.0 and c["dco"] > 0
                for c in d.get("configs", [])),
            "engine: every config has sane recall and nonzero DCO")


def check_stream(g: Gate, d: dict) -> None:
    g.check(d.get("delta_layout_builds") == 0,
            "stream: delta appends never rebuild the layout",
            f"delta_layout_builds={d.get('delta_layout_builds')}")
    g.check(d.get("append_speedup", 0) > 1.0,
            "stream: delta append beats legacy rebuild",
            f"append_speedup={d.get('append_speedup')}")
    g.check(d.get("recall_post_compact", 0) >=
            d.get("recall_churn", 1) - 0.02,
            "stream: compaction does not lose recall",
            f"churn={d.get('recall_churn')} "
            f"post_compact={d.get('recall_post_compact')}")


def check_dist(g: Gate, d: dict) -> None:
    g.check(d.get("one_dev_id_mismatch_points") == 0,
            "dist: 1-device sharded session matches plain searcher bitwise",
            f"one_dev_id_mismatch_points="
            f"{d.get('one_dev_id_mismatch_points')}")
    by_mode = {}
    for c in d.get("configs", []):
        by_mode.setdefault(c["exec_mode"], []).append(c["dco"])
    # shard-count padding moves a few blocks between shards, so DCO
    # drifts a fraction of a percent — but it must never *scale* with
    # device count (work moves across the mesh, it does not grow)
    g.check(all(max(dcos) / min(dcos) < 1.05
                for dcos in by_mode.values() if dcos),
            "dist: total DCO stays flat across device counts",
            f"dco spread={ {m: (min(v), max(v)) for m, v in by_mode.items()} }")


def check_plan(g: Gate, d: dict) -> None:
    g.check(d.get("id_mismatch_points") == 0,
            "plan: clustered/planned scans agree with paged ids",
            f"id_mismatch_points={d.get('id_mismatch_points')}")
    for name, s in d.get("streams", {}).items():
        g.check(s.get("union_reduction", 0) > 1.0,
                f"plan[{name}]: tile union is a strict cut of the "
                f"batch union",
                f"union_reduction={s.get('union_reduction')}")
        p = s.get("plan", {})
        tiles = p.get("tiles", 0)
        reuse = (p.get("hits", 0) + p.get("extends", 0)) / tiles \
            if tiles else 0.0
        g.check(p.get("hits", 0) + p.get("extends", 0) +
                p.get("misses", 0) == tiles,
                f"plan[{name}]: hit/extend/miss partition the tiles",
                f"plan={p}")
        g.check(reuse > 0.0,
                f"plan[{name}]: plan cache reuses at least one tile",
                f"reuse_rate={reuse:.3f}")
    dr = d.get("delta_routing", {})
    g.check(dr.get("dco_reduction", 0) > 1.0,
            "plan: routed delta scan cuts delta DCO vs exhaustive",
            f"dco_reduction={dr.get('dco_reduction')}")


def check_fused(g: Gate, d: dict) -> None:
    m = d.get("modeled_bytes_per_query", {})
    g.check(m.get("write_reduction_x", 0) >= 4.0,
            "fused: modeled scan-stage HBM write reduction >= 4x",
            f"write_reduction_x={m.get('write_reduction_x')}")
    g.check(m.get("roundtrip_reduction_x", 0) >= 4.0,
            "fused: modeled scan/finalize roundtrip reduction >= 4x",
            f"roundtrip_reduction_x={m.get('roundtrip_reduction_x')}")
    g.check(m.get("fused_scan_write", 1) < m.get("unfused_scan_write", 0),
            "fused: fused write strictly below unfused")
    g.check(all(row.get("ids_equal") for row in d.get("modes", [])),
            "fused: fused top-k returns identical ids in every exec mode",
            f"modes={[r.get('ids_equal') for r in d.get('modes', [])]}")


def check_serve(g: Gate, d: dict) -> None:
    errs = sum(pt["batched"].get("errors", 1) +
               pt["per_request"].get("errors", 1)
               for pt in d.get("points", []))
    g.check(errs == 0, "serve: no request failed or timed out",
            f"errors={errs}")
    g.check(d.get("batched", {}).get("batch_fill", 0) > 1.0,
            "serve: the deadline batcher actually coalesces",
            f"batch_fill={d.get('batched', {}).get('batch_fill')}")
    g.check(max((pt.get("speedup", 0) for pt in d.get("points", [])),
                default=0) >= 2.0,
            "serve: batched >= 2x per-request at some offered load",
            f"speedups="
            f"{[round(pt.get('speedup', 0), 2) for pt in d.get('points', [])]}")


def check_trace(g: Gate, d: dict) -> None:
    g.check(d.get("traced_id_mismatch_points") == 0,
            "trace: traced dispatch returns bitwise-identical ids",
            f"traced_id_mismatch_points="
            f"{d.get('traced_id_mismatch_points')}")
    floor = d.get("min_attribution", 0.95)
    for c in d.get("configs", []):
        g.check(c.get("stage_attribution", 0) >= floor,
                f"trace[{c.get('config')}]: stage spans attribute >= "
                f"{floor:.0%} of dispatch time",
                f"stage_attribution={c.get('stage_attribution')}")
        g.check(c.get("fences", 0) > 0 and bool(c.get("dco_per_stage")),
                f"trace[{c.get('config')}]: device fences + per-stage "
                f"DCO recorded")
    m = d.get("hbm_model", {}).get("bytes_per_query", {})
    g.check(m.get("write_reduction_x", 0) >= 4.0,
            "trace: session HBM model matches the fused-bench floor",
            f"write_reduction_x={m.get('write_reduction_x')}")


def check_refine(g: Gate, d: dict) -> None:
    g.check(d.get("rf1_id_mismatch_points") == 0,
            "refine: refine_factor=1 is bitwise-identical to single-tier",
            f"rf1_id_mismatch_points={d.get('rf1_id_mismatch_points')}")
    configs = d.get("configs", [])
    # the sweep deliberately includes losing operating points (large
    # refine factors overshoot), so per-config checks are structural:
    # tier-1 must scan a strictly narrower plane than the full codes
    g.check(bool(configs) and all(
        0.0 <= c["recall"] <= 1.0
        and c["m_compact"] < c["m_full"]
        and 0 < c["tier1_ops"] < c["single_tier_ops"]
        for c in configs),
            "refine: every config scans a strictly narrower tier-1 plane")
    # the headline claim of the ladder, exact on any machine: on the
    # iso-recall frontier, some two-tier config must match the best
    # single-tier recall (within the summary's tolerance) at >= 2x
    # fewer modeled total ops than that single-tier point spends
    # (sift1m holds the committed claim; smoke scales run a looser
    # floor — at D=32 the compact plane is only 2-4x narrower)
    floor = 2.0 if d.get("dataset") == "sift1m" else 1.2
    tol = d.get("tolerance", 0.005)
    fr = d.get("frontier")
    g.check(fr is not None
            and fr.get("total_ops_reduction_x", 0) >= floor
            and fr.get("recall_drop", 1) <= tol
            and fr.get("total_ops", 0) > 0
            and abs(fr.get("target_single_tier_ops", 0)
                    - fr.get("total_ops_reduction_x", 0)
                    * fr.get("total_ops", 1)) < 1.0,
            f"refine: iso-recall frontier >= {floor}x total-ops "
            f"reduction within {tol:.3f} of the best single-tier recall",
            f"frontier={fr}")


def check_overload(g: Gate, d: dict) -> None:
    modes = d.get("modes", {})
    # every submission accounted for with a result or a *typed* error —
    # the no-silent-drops contract, exact on any machine at any scale
    for mode, md in sorted(modes.items()):
        for pt in md.get("points", []):
            total = (pt["n_ok"] + pt["shed"] + pt["deadline_failed"]
                     + pt["closed"] + pt["errors"])
            g.check(total == pt["n_requests"],
                    f"overload[{mode}/x{pt.get('load_factor')}]: every "
                    f"request resolves typed",
                    f"ok+shed+deadline+closed+errors={total} "
                    f"!= n_requests={pt['n_requests']}")
            g.check(pt["errors"] == 0,
                    f"overload[{mode}/x{pt.get('load_factor')}]: zero "
                    f"untyped failures", f"errors={pt['errors']}")
    # shed fraction monotone in offered load for the bounded modes; the
    # plain bounded queue must actually shed at top load (the degrade
    # mode may legitimately absorb it all — that is what the ladder is
    # for — so only engagement is asserted there, below)
    for mode in ("shed", "degrade"):
        pts = modes.get(mode, {}).get("points", [])
        fr = [pt["shed"] / pt["n_requests"] for pt in pts] or [0.0]
        g.check(all(b >= a - 0.01 for a, b in zip(fr, fr[1:])),
                f"overload[{mode}]: shed fraction monotone in offered "
                f"load", f"shed_fractions={[round(f, 3) for f in fr]}")
        if mode == "shed":
            g.check(fr[-1] > 0.0,
                    f"overload[{mode}]: top offered load actually sheds",
                    f"shed_fractions={[round(f, 3) for f in fr]}")
    g.check(all(pt["shed"] == 0
                for pt in modes.get("unbounded", {}).get("points", [])),
            "overload[unbounded]: the unbounded gateway never sheds")
    # degradation has a documented price: answered recall stays above
    # the floor at every load point, ladder fully engaged or not
    floor = d.get("recall_floor", 0.0)
    want_floor = 0.4 if d.get("dataset") == "sift1m" else 0.2
    recalls = [pt["recall"] for pt in modes.get("degrade", {})
               .get("points", []) if pt["n_ok"]]
    g.check(floor >= want_floor,
            f"overload: documented recall floor >= {want_floor}",
            f"recall_floor={floor}")
    g.check(bool(recalls) and min(recalls) >= floor,
            "overload[degrade]: answered recall above the documented "
            "floor at every load point",
            f"recalls={[round(r, 3) for r in recalls]} floor={floor}")
    g.check(bool(d.get("ladder_engaged")),
            "overload[degrade]: the quality ladder engaged at top load",
            f"counters={modes.get('degrade', {}).get('counters')}")


_CHECKERS: Dict[str, Callable[[Gate, dict], None]] = {
    "engine": check_engine, "stream": check_stream, "dist": check_dist,
    "plan": check_plan, "fused": check_fused, "serve": check_serve,
    "trace": check_trace, "refine": check_refine,
    "overload": check_overload,
}


def run(targets: Dict[str, str]) -> int:
    g = Gate()
    for kind, path in sorted(targets.items()):
        print(f"[{kind}] {path}")
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, ValueError) as e:
            g.checks += 1
            g.failures.append(f"{kind}: unreadable {path}: {e}")
            print(f"  FAIL unreadable: {e}")
            continue
        _schema(g, kind, d)
        _CHECKERS[kind](g, d)
    print(f"{g.checks} invariant checks, {len(g.failures)} failure(s)")
    for f in g.failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    return 1 if g.failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate machine-checkable BENCH_*.json invariants "
                    "(never wall-clock).")
    ap.add_argument("targets", nargs="*", metavar="KIND=PATH",
                    help="bench summaries to gate, e.g. "
                         "plan=/tmp/BENCH_plan_unit.json; with no "
                         "targets, validates every committed repo-root "
                         "BENCH_*.json baseline")
    args = ap.parse_args(argv)
    if args.targets:
        targets = {}
        for t in args.targets:
            kind, sep, path = t.partition("=")
            if not sep or kind not in _CHECKERS:
                ap.error(f"target {t!r} is not KIND=PATH with KIND in "
                         f"{sorted(_CHECKERS)}")
            targets[kind] = path
    else:
        targets = {k: p for k in _CHECKERS
                   if os.path.exists(p := os.path.join(_REPO,
                                                       f"BENCH_{k}.json"))}
        missing = sorted(set(_CHECKERS) - set(targets))
        if missing:
            print(f"(no committed baseline yet for: {', '.join(missing)})")
    return run(targets)


if __name__ == "__main__":
    raise SystemExit(main())
