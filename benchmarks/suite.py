"""One benchmark per paper table/figure (see DESIGN.md §6 for the map).

Each ``bench_*`` function emits ``name,us_per_call,derived`` CSV rows and
saves raw rows to benchmarks/results/*.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (IndexConfig, build_index, dco_summary, insert_batch,
                        per_query_recall, recall_at_k)
from repro.core.assign import candidate_lists, rair_assign
from repro.core.seil import cell_stats, vectors_in_large_cells

from .common import (NPROBES, at_recall, curve, emit, get_context, qps_at,
                     save_json, timed_search)

# paper-name -> (strategy, seil) presets
SOLUTIONS = {
    "IVFPQfs": ("single", False),
    "NaiveRA": ("naive", False),
    "SOARL2": ("soar", False),
    "RAIR": ("rair", False),
    "SRAIR": ("srair", False),
    "RAIRS": ("rair", True),
    "SRAIRS": ("srair", True),
}


def bench_recall_curves(datasets=("sift1m",), k=10, quick=True):
    """Fig 7a/7b/7c: recall-QPS and recall-DCO across solutions."""
    out = {}
    names = ("IVFPQfs", "NaiveRA", "SOARL2", "SRAIRS", "RAIRS") if quick \
        else tuple(SOLUTIONS)
    for ds in datasets:
        ctx = get_context(ds, n_queries=500 if quick else None)
        for name in names:
            strat, seil = SOLUTIONS[name]
            rows = curve(ctx, ctx.index(strat, seil), k=k)
            out[f"{ds}/{name}"] = rows
        target = 0.99 if k == 1 else 0.9
        base = at_recall(out[f"{ds}/IVFPQfs"], target, "dco")
        ours = at_recall(out[f"{ds}/RAIRS"], target, "dco")
        dr = (base / ours) if (base and ours) else float("nan")
        # wall-clock speedup at the target-recall operating point (blocked
        # deployment path, matched-recall nprobes)
        pb = at_recall(out[f"{ds}/IVFPQfs"], target, "nprobe")
        pr = at_recall(out[f"{ds}/RAIRS"], target, "nprobe")
        if pb and pr:
            usb = qps_at(ctx, ctx.index("single", False),
                         nprobe=max(1, round(pb)), k=k)
            usr = qps_at(ctx, ctx.index("rair", True),
                         nprobe=max(1, round(pr)), k=k)
            qr = usb / usr
        else:
            qr = float("nan")
        emit(f"fig7_recall_curves/{ds}/k{k}", 0.0,
             f"dco_speedup@{target}={dr:.3f}x qps_speedup@{target}={qr:.3f}x")
    save_json(f"fig7_recall_curves_k{k}", out)
    return out


def bench_nprobe(dataset="sift1m"):
    """Fig 8: recall vs nprobe — RAIRS reaches target recall at ~half the
    nprobe of single assignment."""
    ctx = get_context(dataset, n_queries=500)
    out = {}
    for name in ("IVFPQfs", "NaiveRA", "RAIRS", "SRAIRS"):
        strat, seil = SOLUTIONS[name]
        rows = curve(ctx, ctx.index(strat, seil), k=10)
        out[name] = [{"nprobe": r["nprobe"], "recall": r["recall"]}
                     for r in rows]
    # nprobe (interpolated) to hit recall 0.9
    def probe_at(name):
        return at_recall([{"recall": r["recall"], "nprobe": r["nprobe"]}
                          for r in out[name]], 0.9, "nprobe")
    pb, pr = probe_at("IVFPQfs"), probe_at("RAIRS")
    ratio = (pr / pb) if (pb and pr) else float("nan")
    emit("fig8_nprobe", 0.0, f"nprobe_ratio_RAIRS/IVFPQfs@0.9={ratio:.3f}")
    save_json("fig8_nprobe", out)
    return out


def bench_cdf(dataset="sift1m"):
    """Fig 9: per-query recall and DCO CDFs at matched ~0.9 recall."""
    from repro.core.dense import dense_search
    ctx = get_context(dataset, n_queries=1000)
    out = {}
    for name, probe in (("IVFPQfs", 16), ("RAIRS", 8)):
        strat, seil = SOLUTIONS[name]
        res = dense_search(ctx.index(strat, seil), ctx.q, k=10,
                           nprobe=probe)
        rec = per_query_recall(res.ids, ctx.gt(10))
        dco = np.asarray(res.approx_dco) + np.asarray(res.refine_dco)
        out[name] = {
            "recall_mean": float(rec.mean()),
            "recall_p10": float(np.percentile(rec, 10)),
            "frac_recall_ge_0.8": float((rec >= 0.8).mean()),
            "dco_mean": float(dco.mean()),
            "dco_p99": float(np.percentile(dco, 99)),
            "dco_p99_over_mean": float(np.percentile(dco, 99) / dco.mean()),
        }
    emit("fig9_cdf", 0.0,
         f"rairs_p99/mean={out['RAIRS']['dco_p99_over_mean']:.2f} "
         f"dco_mean_ratio={out['RAIRS']['dco_mean']/out['IVFPQfs']['dco_mean']:.3f}")
    save_json("fig9_cdf", out)
    return out


def bench_top100(dataset="sift1m"):
    """Fig 10: top-100 queries (K_FACTOR=4 per paper §6.1)."""
    ctx = get_context(dataset, n_queries=300)
    out = {}
    for name in ("IVFPQfs", "NaiveRA", "SOARL2", "RAIRS"):
        strat, seil = SOLUTIONS[name]
        out[name] = curve(ctx, ctx.index(strat, seil), k=100, k_factor=4,
                          nprobes=(4, 8, 16, 32, 64))
    b = at_recall(out["IVFPQfs"], 0.9, "dco")
    r = at_recall(out["RAIRS"], 0.9, "dco")
    emit("fig10_top100", 0.0,
         f"dco_speedup@0.9={(b / r) if (b and r) else float('nan'):.3f}x")
    save_json("fig10_top100", out)
    return out


def bench_latency(dataset="sift1m"):
    """Fig 11: one-query-at-a-time latency (B=1, no batch amortization)."""
    ctx = get_context(dataset, n_queries=64)
    out = {}
    probes = {"IVFPQfs": 16, "NaiveRA": 16, "SRAIRS": 8, "RAIRS": 8}
    for name in ("IVFPQfs", "NaiveRA", "SRAIRS", "RAIRS"):
        strat, seil = SOLUTIONS[name]
        idx = ctx.index(strat, seil)
        res, us = timed_search(idx, ctx.q, k=10, nprobe=probes[name], chunk=1)
        out[name] = {"us_per_query": us,
                     "recall": recall_at_k(res.ids, ctx.gt(10))}
    emit("fig11_latency", out["RAIRS"]["us_per_query"],
         f"latency_ratio_vs_IVFPQfs="
         f"{out['RAIRS']['us_per_query']/out['IVFPQfs']['us_per_query']:.3f}")
    save_json("fig11_latency", out)
    return out


def bench_insert_delete(dataset="sift1m"):
    """Fig 12: insertion/deletion throughput, RAIRS vs IVFPQfs — both
    routed through the streaming subsystem (core/stream/): inserts land
    in the delta segment via the `insert_batch` compat wrapper, deletes
    flip tombstone bits (the old layout-level `seil.delete_ids` path is
    measurement-only and left consistency-incoherent by design)."""
    ctx = get_context(dataset)
    n = ctx.x.shape[0]
    n0 = int(n * 0.8)
    batch = (n - n0) // 5
    out = {}
    for name in ("IVFPQfs", "RAIRS"):
        strat, seil = SOLUTIONS[name]
        cfg = IndexConfig(nlist=ctx.nlist, strategy=strat, seil=seil,
                          metric=ctx.metric)
        idx = build_index(jax.random.PRNGKey(0), ctx.x[:n0], cfg,
                          centroids=ctx.centroids, codebook=ctx.codebook)
        t0 = time.perf_counter()
        for b in range(5):
            s = n0 + b * batch
            idx = insert_batch(idx, ctx.x[s:s + batch])  # -> StreamingIndex
        t_ins = time.perf_counter() - t0
        rng = np.random.default_rng(0)
        victims = rng.choice(idx.n_total, size=5 * batch, replace=False)
        t0 = time.perf_counter()
        for b in range(5):
            idx.delete(victims[b * batch:(b + 1) * batch])
        t_del = time.perf_counter() - t0
        out[name] = {"insert_vec_per_s": 5 * batch / t_ins,
                     "delete_vec_per_s": 5 * batch / t_del}
    rel_i = out["RAIRS"]["insert_vec_per_s"] / out["IVFPQfs"]["insert_vec_per_s"]
    rel_d = out["RAIRS"]["delete_vec_per_s"] / out["IVFPQfs"]["delete_vec_per_s"]
    emit("fig12_insert_delete", 0.0,
         f"insert_rel={rel_i:.3f} delete_rel={rel_d:.3f}")
    save_json("fig12_insert_delete", out)
    return out


def bench_stream(dataset="sift1m", batches=8):
    """Streaming-subsystem bench (-> BENCH_stream.json): append
    throughput through the delta path vs the legacy pooled full-layout
    rebuild, deletion throughput, compaction cost, and recall under
    churn vs a brute-force oracle over the surviving corpus."""
    import repro.core.index as index_mod
    from repro.core import StreamingIndex, build_seil_call_count
    from repro.core.seil import build_seil

    ctx = get_context(dataset, n_queries=200)
    n = ctx.x.shape[0]
    n0 = int(n * 0.8)
    batch = max(1, (n - n0) // batches)
    cfg = IndexConfig(nlist=ctx.nlist, strategy="rair", seil=True,
                      metric=ctx.metric)
    idx = build_index(jax.random.PRNGKey(0), ctx.x[:n0], cfg,
                      centroids=ctx.centroids, codebook=ctx.codebook)

    # legacy baseline: one pooled re-add, i.e. what insert_batch did per
    # call before the delta path (assign+encode the batch, then rebuild
    # the whole SEIL layout from pooled items)
    xb = ctx.x[n0:n0 + batch]
    t0 = time.perf_counter()
    a_new = index_mod.compute_assignments(xb, idx.centroids, cfg)
    c_new = np.asarray(index_mod.pq_encode(idx.codebook, xb))
    all_a = np.concatenate([idx.assigns, a_new], axis=0)
    all_c = np.concatenate([idx.codes, c_new], axis=0)
    build_seil(all_a, all_c, np.arange(all_a.shape[0], dtype=np.int32),
               cfg.nlist, block=cfg.block, shared=True, code_bits=cfg.nbits)
    rebuild_vps = batch / (time.perf_counter() - t0)

    stream = StreamingIndex(idx)
    layout_calls0 = build_seil_call_count()
    t0 = time.perf_counter()
    inserted = 0
    for b in range(batches):
        s = n0 + b * batch
        inserted += len(stream.insert(ctx.x[s:s + batch]))
    delta_vps = inserted / (time.perf_counter() - t0)
    delta_layout_builds = build_seil_call_count() - layout_calls0

    rng = np.random.default_rng(0)
    victims = rng.choice(stream.n_total, size=max(1, stream.n_total // 10),
                         replace=False)
    t0 = time.perf_counter()
    deleted = stream.delete(victims)
    delete_vps = deleted / (time.perf_counter() - t0)

    from repro.core import ground_truth
    live = stream.live_ids()
    gt = live[ground_truth(stream.live_vectors(), ctx.q, 10,
                           metric=ctx.metric)]
    r = stream.search(ctx.q, k=10, nprobe=16)
    recall_churn = recall_at_k(np.asarray(r.ids), gt)

    info = stream.compact()
    gt2 = stream.live_ids()[ground_truth(stream.live_vectors(), ctx.q, 10,
                                         metric=ctx.metric)]
    r2 = stream.search(ctx.q, k=10, nprobe=16)
    recall_post = recall_at_k(np.asarray(r2.ids), gt2)

    out = {
        "n_base": n0, "append_batch": batch, "append_batches": batches,
        "append_vec_per_s_delta": delta_vps,
        "append_vec_per_s_rebuild": rebuild_vps,
        "append_speedup": delta_vps / rebuild_vps,
        "delta_layout_builds": int(delta_layout_builds),  # must be 0
        "delete_vec_per_s": delete_vps,
        "deleted": int(deleted),
        "compact_seconds": info["seconds"],
        "compact_layout_seconds": info["layout_seconds"],
        "recall_churn": recall_churn,
        "recall_post_compact": recall_post,
        "n_live": stream.n_live,
        "searcher": stream.searcher_stats(),
    }
    emit("stream", 0.0,
         f"append_speedup={out['append_speedup']:.1f}x "
         f"layout_builds={delta_layout_builds} "
         f"recall_churn={recall_churn:.3f} "
         f"recall_post_compact={recall_post:.3f}")
    save_json("stream", out)
    return out


def bench_dist(dataset="sift1m", k=10, nprobe=16,
               exec_modes=("paged", "grouped")):
    """Distributed scaling bench (-> BENCH_dist.json): recall / QPS /
    DCO of ``ShardedIndex`` sessions vs device count, both exec modes.

    Device counts sweep the powers of two up to ``len(jax.devices())``
    — on a stock CPU host that is just ndev=1 (the parity point, still
    asserted bitwise vs the plain Searcher); run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a
    scaling curve.  QPS on a virtual-device CPU mesh measures overhead
    trends, not TPU throughput (see DESIGN.md §4)."""
    from jax.sharding import Mesh

    from repro.core import SearchParams

    ctx = get_context(dataset, n_queries=256)
    idx = ctx.index("rair", True)
    gt = ctx.gt(k)
    devs = jax.devices()
    ndevs = [n for n in (1, 2, 4, 8, 16) if n <= len(devs)]
    max_scan = idx.default_max_scan(nprobe)
    params0 = SearchParams(k=k, nprobe=nprobe, max_scan=max_scan,
                           batch_buckets=(64,))
    rows, mismatches = [], 0
    for nd in ndevs:
        mesh = Mesh(np.asarray(devs[:nd]), ("data",))
        sharded = idx.shard(mesh)
        for mode in exec_modes:
            import dataclasses as _dc
            searcher = sharded.searcher(_dc.replace(params0, exec_mode=mode))
            searcher(ctx.q[:64]).ids.block_until_ready()   # compile
            t0 = time.perf_counter()
            outs = [jax.tree.map(np.asarray, searcher(ctx.q[s:s + 64]))
                    for s in range(0, ctx.q.shape[0], 64)]
            dt = time.perf_counter() - t0
            res = jax.tree.map(lambda *a: np.concatenate(a, 0), *outs)
            if nd == 1:
                ref = idx.searcher(
                    _dc.replace(params0, exec_mode=mode))(ctx.q)
                if not np.array_equal(np.asarray(ref.ids), res.ids):
                    mismatches += 1
            rows.append({
                "ndev": nd, "exec_mode": mode,
                "recall": recall_at_k(res.ids, gt),
                "qps": ctx.q.shape[0] / dt,
                "us_per_query": dt / ctx.q.shape[0] * 1e6,
                "dco": dco_summary(res)["total_dco"],
            })
            emit(f"dist/{dataset}/ndev{nd}/{mode}",
                 rows[-1]["us_per_query"],
                 f"recall={rows[-1]['recall']:.4f} "
                 f"qps={rows[-1]['qps']:.0f} dco={rows[-1]['dco']:.0f}")
    out = {"ndev_swept": ndevs, "nprobe": nprobe,
           "one_dev_id_mismatch_points": mismatches, "configs": rows}
    emit(f"dist/{dataset}/parity", 0.0,
         f"one_dev_id_mismatch_points={mismatches}")
    save_json("dist_scaling", out)
    assert mismatches == 0, \
        "1-device ShardedIndex must match the plain Searcher bitwise"
    return out


def _dco_at(ctx, name, target=0.9, k=10, **over):
    strat, seil = SOLUTIONS[name]
    rows = curve(ctx, ctx.index(strat, seil, **over), k=k)
    return at_recall(rows, target, "approx_dco")


def bench_ablation(dataset="sift1m"):
    """Fig 13a: DCO at ~target recall for NaiveRA/SRAIR/RAIR x (SEIL on/off)."""
    ctx = get_context(dataset, n_queries=500)
    out = {}
    for base, strat in (("NaiveRA", "naive"), ("SRAIR", "srair"),
                        ("RAIR", "rair")):
        for seil in (False, True):
            rows = curve(ctx, ctx.index(strat, seil), k=10)
            out[f"{base}{'+SEIL' if seil else ''}"] = {
                "dco@0.9": at_recall(rows, 0.9, "approx_dco"),
                "rows": rows,
            }
    try:
        gain = 1 - (out["RAIR+SEIL"]["dco@0.9"] / out["RAIR"]["dco@0.9"])
    except TypeError:
        gain = float("nan")
    emit("fig13a_ablation", 0.0, f"seil_dco_cut_on_RAIR={gain:.3%}")
    save_json("fig13a_ablation", out)
    return out


def bench_memory(datasets=("sift1m", "msong", "gist")):
    """Table 4 / Fig 13b: IVF-PQ module memory across solutions."""
    out = {}
    for ds in datasets:
        ctx = get_context(ds)
        row = {}
        for name in ("IVFPQfs", "NaiveRA", "RAIR", "RAIRS"):
            strat, seil = SOLUTIONS[name]
            idx = ctx.index(strat, seil)
            row[name] = idx.stats.logical_bytes
        strat, seil = SOLUTIONS["NaiveRA"]
        idx = ctx.index("naive", True)
        row["NaiveRA+SEIL"] = idx.stats.logical_bytes
        out[ds] = row
        emit(f"table4_memory/{ds}", 0.0,
             f"rairs/naive={row['RAIRS']/row['NaiveRA']:.3f} "
             f"naive+seil/naive={row['NaiveRA+SEIL']/row['NaiveRA']:.3f}")
    save_json("table4_memory", out)
    return out


def bench_multi_assign(dataset="sift1m"):
    """Fig 14: aggr functions for 3-assignment; m in {1,2,3,4} (strict,
    SEIL off per paper)."""
    ctx = get_context(dataset, n_queries=300)
    out = {}
    for aggr in ("max", "min", "avg"):
        rows = curve(ctx, ctx.index("srair", False, multi_m=3, aggr=aggr),
                     k=10, nprobes=(2, 4, 8, 16, 32))
        out[f"aggr_{aggr}"] = {"dco@0.9": at_recall(rows, 0.9, "approx_dco"),
                               "rows": rows}
    for m, name in ((1, "IVFPQfs"), (2, "SRAIR")):
        strat, seil = SOLUTIONS[name]
        rows = curve(ctx, ctx.index(strat, seil), k=10,
                     nprobes=(2, 4, 8, 16, 32))
        out[f"m{m}"] = {"dco@0.9": at_recall(rows, 0.9, "approx_dco"),
                        "rows": rows}
    for m in (3, 4):
        rows = curve(ctx, ctx.index("srair", False, multi_m=m, aggr="max"),
                     k=10, nprobes=(2, 4, 8, 16, 32))
        out[f"m{m}"] = {"dco@0.9": at_recall(rows, 0.9, "approx_dco"),
                        "rows": rows}
    d = {k: v["dco@0.9"] for k, v in out.items()}
    emit("fig14_multi_assign", 0.0,
         " ".join(f"{k}={v:.0f}" if v else f"{k}=NA" for k, v in d.items()))
    save_json("fig14_multi_assign", out)
    return out


def bench_lambda(dataset="sift1m"):
    """Fig 15a: lambda sweep for RAIRS."""
    ctx = get_context(dataset, n_queries=300)
    out = {}
    for lam in (0.0, 0.25, 0.5, 0.75, 1.0):
        rows = curve(ctx, ctx.index("rair", True, lam=lam), k=10,
                     nprobes=(2, 4, 8, 16, 32))
        out[f"lam{lam}"] = {"dco@0.9": at_recall(rows, 0.9, "approx_dco"),
                            "rows": rows}
    d = {k: v["dco@0.9"] for k, v in out.items()}
    emit("fig15a_lambda", 0.0,
         " ".join(f"{k}={v:.0f}" if v else f"{k}=NA" for k, v in d.items()))
    save_json("fig15a_lambda", out)
    return out


def bench_ncands(dataset="sift1m", lam=0.5):
    """Fig 15b: CDF of the true AIR-argmin rank among distance-sorted lists."""
    ctx = get_context(dataset)
    x = ctx.x[:20000]
    cid, cd2 = candidate_lists(x, ctx.centroids, ctx.nlist)
    c = ctx.centroids[cid]
    r = c - x[:, None, :]
    loss = cd2 + lam * jnp.einsum("nd,ncd->nc", r[:, 0], r)
    true_rank = np.asarray(jnp.argmin(loss, axis=1))
    cdf = {f"rank<={t}": float((true_rank <= t).mean())
           for t in (1, 2, 5, 10, 20, 50)}
    emit("fig15b_ncands", 0.0, f"rank<=10={cdf['rank<=10']:.4f}")
    save_json("fig15b_ncands", cdf)
    return cdf


def bench_block_size(dataset="sift1m"):
    """Fig 16: block-size sweep — misc fraction grows, SEIL saving shrinks."""
    from repro.core.dense import dense_search
    ctx = get_context(dataset, n_queries=300)
    out = {}
    for blk in (16, 32, 64, 128):
        idx = ctx.index("rair", True, block=blk)
        misc_frac = idx.stats.n_misc_items / max(idx.stats.n_items_stored, 1)
        res = dense_search(idx, ctx.q, k=10, nprobe=16)
        out[f"blk{blk}"] = {
            "misc_item_frac": misc_frac,
            "large_cell_frac": vectors_in_large_cells(idx.assigns, blk),
            "dco@nprobe16": dco_summary(res)["approx_dco"],
        }
    emit("fig16_block_size", 0.0,
         " ".join(f"blk{b}_misc={out[f'blk{b}']['misc_item_frac']:.3f}"
                  for b in (16, 32, 64, 128)))
    save_json("fig16_block_size", out)
    return out


def bench_seil_soar(dataset="t2i"):
    """Fig 17: SEIL applied to SOAR under inner product."""
    ctx = get_context(dataset, n_queries=500)
    out = {}
    for seil in (False, True):
        rows = curve(ctx, ctx.index("soar", seil), k=10,
                     nprobes=(2, 4, 8, 16, 32))
        out[f"SOAR{'+SEIL' if seil else ''}"] = rows
    b = at_recall(out["SOAR"], 0.7, "approx_dco")
    s = at_recall(out["SOAR+SEIL"], 0.7, "approx_dco")
    emit("fig17_seil_soar", 0.0,
         f"seil_dco_cut={1 - (s / b) if (b and s) else float('nan'):.3%}")
    save_json("fig17_seil_soar", out)
    return out


def bench_match_table(datasets=("sift1m", "msong", "gist")):
    """Table 3: %% of vectors with identical 2nd choice under SOARL2 vs AIR."""
    out = {}
    for ds in datasets:
        ctx = get_context(ds)
        x = ctx.x[:30000]
        a_air = np.asarray(rair_assign(x, ctx.centroids, metric="air",
                                       strict=True))
        a_soar = np.asarray(rair_assign(x, ctx.centroids, metric="soar",
                                        strict=True))
        match = float((a_air == a_soar).all(axis=1).mean())
        out[ds] = match
        emit(f"table3_match/{ds}", 0.0, f"match={match:.4f}")
    save_json("table3_match", out)
    return out


def bench_cells(dataset="sift1m"):
    """Fig 5: cell-size skew after redundant assignment."""
    ctx = get_context(dataset)
    idx = ctx.index("rair", True)
    sizes = cell_stats(idx.assigns)["cell_sizes"]
    out = {
        "n_cells": int(len(sizes)),
        "frac_vectors_in_large_cells": vectors_in_large_cells(idx.assigns),
        "max_cell": int(sizes.max()),
        "p99_cell": float(np.percentile(sizes, 99)),
    }
    emit("fig5_cells", 0.0,
         f"large_cell_frac={out['frac_vectors_in_large_cells']:.3f} "
         f"max_cell={out['max_cell']}")
    save_json("fig5_cells", out)
    return out


def bench_exec_modes(dataset="sift1m", k=10, nprobes=(4, 8, 16, 32)):
    """Engine exec-mode study (paper §5.3): recall vs QPS for per-query
    paged scanning vs list-major grouped (batch-union) execution of the
    same RAIRS index.  Also asserts result equivalence at every point —
    the modes differ only in memory-access schedule, never in output."""
    ctx = get_context(dataset, n_queries=256)
    idx = ctx.index("rair", True)
    gt = ctx.gt(k)
    out = {"paged": [], "grouped": []}
    mismatches = 0
    for nprobe in nprobes:
        per_mode = {}
        for mode in ("paged", "grouped"):
            res, us = timed_search(idx, ctx.q, k=k, nprobe=nprobe,
                                   chunk=64, exec_mode=mode)
            per_mode[mode] = res
            out[mode].append({
                "nprobe": nprobe,
                "recall": recall_at_k(res.ids, gt),
                "qps": 1e6 / us,
                "us_per_query": us,
                "dco": dco_summary(res)["total_dco"],
            })
        if not np.array_equal(per_mode["paged"].ids, per_mode["grouped"].ids):
            mismatches += 1
    rows_p, rows_g = out["paged"], out["grouped"]
    for rp, rg in zip(rows_p, rows_g):
        emit(f"engine_exec_modes/{dataset}/nprobe{rp['nprobe']}",
             rp["us_per_query"],
             f"paged_qps={rp['qps']:.0f} grouped_qps={rg['qps']:.0f} "
             f"recall={rp['recall']:.4f} "
             f"grouped/paged_qps={rg['qps'] / rp['qps']:.3f}")
    emit(f"engine_exec_modes/{dataset}/equivalence", 0.0,
         f"id_mismatch_points={mismatches}")
    out["id_mismatch_points"] = mismatches
    # compile-cache accounting across every session the sweep created
    out["searcher"] = idx.searcher_stats()
    save_json("engine_exec_modes", out)
    assert mismatches == 0, "grouped mode must return identical ids"
    return out


def _query_streams(ctx, batch, n_batches, seed=0, hot=16, zipf_a=1.1,
                   jitter=0.02):
    """Two serving traces of `n_batches` x `batch` queries over the
    context's query pool: ``uniform`` draws iid, ``zipf`` draws from a
    `hot`-query pool with Zipf(a) popularity — the cache-hot
    steady-state traffic the locality-aware planner targets (think the
    head of a search-query distribution: a small set of hot queries
    dominating each serving batch).  Every draw gets small Gaussian
    jitter so batches are near-duplicates, not exact repeats."""
    rng = np.random.default_rng(seed)
    pool = np.asarray(ctx.q)
    scale = float(pool.std()) * jitter
    h = min(hot, pool.shape[0])
    p = 1.0 / np.arange(1, h + 1) ** zipf_a
    p /= p.sum()
    streams = {"uniform": [], "zipf": []}
    for _ in range(n_batches):
        for name, picks in (
                ("uniform", rng.integers(0, pool.shape[0], batch)),
                ("zipf", rng.choice(h, batch, p=p))):
            q = pool[picks] + rng.normal(0.0, scale, (batch, pool.shape[1]))
            streams[name].append(jnp.asarray(q, jnp.float32))
    return streams


def _union_sizes(idx, qb, nprobe, query_tile):
    """(batch-wide union live, mean per-tile union live) for one batch —
    plan-only, no scan, so QPS timings stay uncontaminated."""
    from repro.core import plan_blocks, select_lists
    from repro.core.engine import (cluster_order, fit_tile,
                                   tables_from_arrays)
    selection = select_lists(qb, idx.centroids, nprobe=nprobe,
                             metric=idx.config.metric)
    plan = plan_blocks(tables_from_arrays(idx.arrays), selection,
                       max_scan=idx.default_max_scan(nprobe))
    blocks, valid = np.asarray(plan.blocks), np.asarray(plan.valid)
    batch_live = len(np.unique(blocks[valid]))
    perm = np.asarray(cluster_order(selection.sel))
    qt = fit_tile(qb.shape[0], query_tile)
    t = qb.shape[0] // qt
    pb = blocks[perm].reshape(t, qt, -1)
    pv = valid[perm].reshape(t, qt, -1)
    tiles = [len(np.unique(pb[i][pv[i]])) for i in range(t)]
    return batch_live, float(np.mean(tiles))


def bench_plan(dataset="sift1m", k=10, nprobe=16, batch=256, n_batches=12,
               query_tile=16):
    """Locality-aware planning bench (-> BENCH_plan.json): per-tile vs
    batch-wide union sizes, incremental plan-cache hit rates, and QPS of
    paged / grouped (batch union) / clustered (+plan reuse) on a
    Zipf-skewed and a uniform query stream, plus routed-vs-exhaustive
    delta scan cost once the delta outgrows ``nlist * block``.

    Asserts the optimization's core claims so CI's ``plan-smoke`` step
    guards them at toy scale: clustered tile unions at least 2x smaller
    than the batch-wide union on the skewed stream, a majority plan-cache
    hit rate at steady state, and bitwise-identical results across
    modes."""
    import dataclasses as _dc

    from repro.core import SearchParams, Searcher, StreamingIndex

    nlist = 64 if dataset.startswith("unit") else 256
    ctx = get_context(dataset, nlist=nlist)
    idx = ctx.index("rair", True)
    streams = _query_streams(ctx, batch, n_batches)
    out = {"nlist": nlist, "batch": batch, "n_batches": n_batches,
           "nprobe": nprobe, "query_tile": query_tile, "streams": {}}
    mismatches = 0
    for stream_name, batches in streams.items():
        row = {}
        # union geometry (plan-only, over the first few batches)
        sizes = [_union_sizes(idx, qb, nprobe, query_tile)
                 for qb in batches[:4]]
        row["batch_union_live_mean"] = float(np.mean([s[0] for s in sizes]))
        row["tile_union_live_mean"] = float(np.mean([s[1] for s in sizes]))
        row["union_reduction"] = (row["batch_union_live_mean"]
                                  / max(row["tile_union_live_mean"], 1.0))
        # QPS per mode (fresh session per mode; compile excluded).  The
        # batch-wide-union grouped baseline is stateless and an order of
        # magnitude slower on the CPU oracle (that is the point of
        # clustering) — timing a prefix of the stream suffices.
        results = {}
        for mode, reuse in (("paged", False), ("grouped", False),
                            ("clustered", True)):
            params = SearchParams(k=k, nprobe=nprobe, exec_mode=mode,
                                  plan_reuse=reuse, query_tile=query_tile,
                                  batch_buckets=(batch,))
            timed = batches if mode != "grouped" else batches[:4]
            # fresh session per (stream, mode): the index-level session
            # cache is keyed by params and would carry one stream's plan
            # cache — and its settled scan widths — into the other
            # stream's measurement
            searcher = Searcher(idx, params)
            # warmup/compile; the reuse path gets a second untimed batch
            # so the plan cache and its width bucket settle before the
            # clock starts (compile is excluded from every mode's timing)
            for qb in (timed[:2] if reuse else timed[:1]):
                searcher(qb).ids.block_until_ready()
            t0 = time.perf_counter()
            last = None
            for qb in timed:
                last = searcher(qb)
            last.ids.block_until_ready()
            dt = time.perf_counter() - t0
            row[f"{mode}_qps"] = len(timed) * batch / dt
            # equivalence checked on a common batch (untimed)
            results[mode] = np.asarray(searcher(batches[0]).ids)
            if reuse:
                row["plan"] = searcher.compile_stats()["plan"]
        row["clustered_over_paged_qps"] = (row["clustered_qps"]
                                           / row["paged_qps"])
        if not (np.array_equal(results["paged"], results["grouped"])
                and np.array_equal(results["paged"], results["clustered"])):
            mismatches += 1
        out["streams"][stream_name] = row
        emit(f"plan/{dataset}/{stream_name}", 1e6 / row["clustered_qps"],
             f"union_cut={row['union_reduction']:.2f}x "
             f"hit_rate={row['plan']['hit_rate']:.2f} "
             f"clustered/paged_qps={row['clustered_over_paged_qps']:.3f}")

    # -- routed delta scans: DCO/QPS once delta > nlist * block ----------
    # The "routed" stream pins delta_route_min=0 so the comparison runs
    # at any corpus scale; ``auto_would_route`` records whether the
    # default nlist*block threshold fires for this delta size (it does
    # at sift1m scale — the committed benchmark's operating point).
    n = ctx.x.shape[0]
    n0 = int(n * 0.8)
    cfg = IndexConfig(nlist=nlist, strategy="rair", seil=True,
                      metric=ctx.metric, delta_route_min=0)
    base = build_index(jax.random.PRNGKey(0), ctx.x[:n0], cfg,
                       centroids=ctx.centroids, codebook=ctx.codebook)
    base_ex = _dc.replace(base, config=_dc.replace(
        cfg, delta_route_min=10 ** 9))
    routed, exhaust = StreamingIndex(base), StreamingIndex(base_ex)
    routed.insert(ctx.x[n0:])
    exhaust.insert(ctx.x[n0:])
    qd = streams["zipf"][0]
    drow = {"threshold_auto": nlist * cfg.block,
            "delta_rows": n - n0,
            "delta_capacity": routed._delta.capacity,
            "routed_active": routed.delta_routed,
            "auto_would_route": routed._delta.capacity > nlist * cfg.block}
    for name, st in (("exhaustive", exhaust), ("routed", routed)):
        sess = st.searcher(SearchParams(k=k, nprobe=nprobe,
                                        batch_buckets=(batch,)))
        sess(qd).ids.block_until_ready()
        t0 = time.perf_counter()
        r = sess(qd)
        r.ids.block_until_ready()
        drow[f"qps_{name}"] = batch / (time.perf_counter() - t0)
        drow[f"dco_{name}"] = float(np.asarray(r.approx_dco).mean()
                                    + np.asarray(r.refine_dco).mean())
    drow["dco_reduction"] = drow["dco_exhaustive"] / drow["dco_routed"]
    out["delta_routing"] = drow
    emit(f"plan/{dataset}/delta_routing", 0.0,
         f"routed={drow['routed_active']} "
         f"dco_cut={drow['dco_reduction']:.2f}x "
         f"qps_routed/exhaustive="
         f"{drow['qps_routed'] / drow['qps_exhaustive']:.2f}")

    out["id_mismatch_points"] = mismatches
    save_json("plan", out)
    zrow = out["streams"]["zipf"]
    assert mismatches == 0, "exec modes must return identical ids"
    # toy corpora cap the batch union at their tiny block store, which
    # flattens the ratio; the full >= 2x bar applies at bench scale
    min_cut = 1.2 if dataset.startswith("unit") else 2.0
    assert zrow["union_reduction"] >= min_cut, \
        f"clustered unions should be >= {min_cut}x tighter on the skewed " \
        f"stream (got {zrow['union_reduction']:.2f}x)"
    assert zrow["plan"]["hit_rate"] > 0.5, \
        f"steady-state plan-cache hit rate should exceed 50% " \
        f"(got {zrow['plan']['hit_rate']:.2f})"
    assert drow["dco_reduction"] > 1.0, "routing must cut delta DCO"
    return out


def bench_kernels():
    """Kernel microbench: jnp oracle vs Pallas path on one workload.
    (CPU interpret-mode timing is NOT TPU perf — roofline covers that.)"""
    from repro.kernels.ops import pq_scan_paged
    from repro.kernels.ref import pq_scan_paged_ref
    key = jax.random.PRNGKey(0)
    b, m, kk, tb, blk, s = 8, 64, 16, 512, 32, 64
    k1, k2, k3 = jax.random.split(key, 3)
    lut = jax.random.normal(k1, (b, m, kk), jnp.float32)
    codes = jax.random.randint(k2, (tb, blk, m), 0, kk).astype(jnp.uint8)
    idx = jax.random.randint(k3, (b, s), 0, tb, jnp.int32)
    out = {}
    for name, fn in (("jnp_ref", pq_scan_paged_ref),
                     ("pallas_interpret", pq_scan_paged)):
        fn(lut, codes, idx).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fn(lut, codes, idx).block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        out[name] = us
        emit(f"kernel_pq_scan/{name}", us,
             f"items={b * s * blk} us_per_item={us / (b * s * blk):.3f}")
    save_json("kernel_pq_scan", out)
    return out


def bench_fused(dataset="sift1m", k=10, nprobe=16, chunk=64,
                exec_modes=("paged", "grouped", "clustered")):
    """Fused scan->top-k bench (-> BENCH_fused.json): modeled scan-stage
    HBM traffic and wall-clock QPS, fused vs unfused, per exec mode.

    Traffic model (roofline.py accounting style — analytic minimum
    bytes the scan stage exchanges with HBM around the scan/finalize
    boundary, per query):

      unfused: the scan materializes the full (S, BLK) candidate stream
        for finalize to re-read — ``S*BLK`` candidates x 8 B
        (f32 distance + i32 id), written once and read once;
      fused:   only the top-``fetch`` accumulator leaves the scan —
        ``fetch`` candidates x 12 B written (f32 distance + i32 flat
        position + i32 id), 8 B of which finalize reads back.

    On-TPU the fused kernel additionally keeps the accumulator VMEM-
    resident across the whole scan grid; this model counts only the
    boundary traffic, which is what shrinks.  Asserts the modeled write
    reduction >= 4x (the CI ``kernel-smoke`` guard) and fused==unfused
    result ids at every operating point.
    """
    from repro.core import SearchParams
    from repro.core.search import finalize_fetch

    ctx = get_context(dataset, n_queries=256)
    idx = ctx.index("rair", True)
    gt = ctx.gt(k)
    max_scan = idx.default_max_scan(nprobe)
    blk = idx.arrays.block_codes.shape[1]
    fetch = finalize_fetch(k * 10, idx.result_oversample,
                           idx.needs_result_dedup)
    fetch = min(fetch, max_scan * blk)
    scan_width = max_scan * blk

    from .roofline import scan_traffic_model
    out = {
        "k": k, "nprobe": nprobe, "max_scan": max_scan, "block": blk,
        "fetch": fetch, "scan_width": scan_width,
        "modeled_bytes_per_query": scan_traffic_model(
            scan_width=scan_width, fetch=fetch),
        "modes": [],
    }

    def run(exec_mode, fused):
        p = SearchParams(k=k, nprobe=nprobe, exec_mode=exec_mode,
                         fused_topk=fused,
                         batch_buckets=(min(chunk, ctx.q.shape[0]),))
        searcher = idx.searcher(p)
        nq = ctx.q.shape[0]
        searcher(ctx.q[:chunk]).ids.block_until_ready()  # warmup/compile
        t0 = time.perf_counter()
        outs = [jax.tree.map(np.asarray, searcher(ctx.q[s:s + chunk]))
                for s in range(0, nq, chunk)]
        us = (time.perf_counter() - t0) / nq * 1e6
        return jax.tree.map(lambda *a: np.concatenate(a, 0), *outs), us

    mismatches = 0
    for mode in exec_modes:
        base, us_b = run(mode, False)
        fused, us_f = run(mode, True)
        equal = bool(np.array_equal(base.ids, fused.ids))
        mismatches += not equal
        row = {
            "exec_mode": mode,
            "unfused_qps": 1e6 / us_b,
            "fused_qps": 1e6 / us_f,
            "fused_over_unfused_qps": us_b / us_f,
            "recall": recall_at_k(fused.ids, gt),
            "ids_equal": equal,
        }
        out["modes"].append(row)
        emit(f"fused_topk/{dataset}/{mode}", us_f,
             f"fused_qps={row['fused_qps']:.0f} "
             f"unfused_qps={row['unfused_qps']:.0f} "
             f"ratio={row['fused_over_unfused_qps']:.3f} "
             f"recall={row['recall']:.4f} ids_equal={equal}")
    red = out["modeled_bytes_per_query"]["write_reduction_x"]
    emit(f"fused_topk/{dataset}/hbm_model", 0.0,
         f"scan_width={scan_width} fetch={fetch} write_reduction={red:.1f}x")
    save_json("fused_topk", out)
    assert mismatches == 0, "fused path must return identical ids"
    assert red >= 4.0, (
        f"modeled scan-stage HBM write reduction {red:.1f}x < 4x — "
        f"fetch={fetch} grew relative to the scan width {scan_width}")
    return out


def bench_refine(dataset="sift1m", k=10, nprobes=(8, 16, 32),
                 backends=("pq4", "binary"), refine_factors=(2, 4, 8),
                 chunk=64):
    """Two-tier quantization ladder bench (-> BENCH_refine.json):
    backend x refine_factor x nprobe sweep against the single-tier
    baseline (DESIGN.md §12).

    Reports, per operating point, measured recall@k and the weighted
    total-ops model — tier-1 LUT lookups (scan_width x m_compact) plus
    tier-2 exact dims (bigk_eff x D) against the single-tier cost
    (scan_width x m_full + bigk x D).  The accounting comes from
    ``session_traffic_model`` so serving snapshots, this bench, and the
    ``check_regression`` gate can never disagree.  Also asserts the
    refine_factor=1 degenerate ladder returns bitwise-identical results
    (the acceptance guarantee that enabling the subsystem cannot change
    answers until it is actually asked to trade).

    The committed sift1m baseline is gated on the iso-recall frontier:
    some two-tier config must reach within 0.5% absolute recall@10 of
    the best single-tier operating point at >= 2x fewer modeled total
    ops than that point spends.
    """
    import dataclasses

    from repro.core import RefineParams, SearchParams
    from repro.obs.stats import session_traffic_model

    ctx = get_context(dataset, n_queries=256)
    idx = ctx.index("rair", True)
    gt = ctx.gt(k)
    nprobes = tuple(p for p in nprobes if p <= ctx.nlist)
    # sift1m holds the committed-baseline claim; smoke scales loosen it
    # (at D=32 the compact plane is only 2-4x narrower than full)
    tolerance = 0.005 if dataset == "sift1m" else 0.03

    def run(params):
        searcher = idx.searcher(params)
        nq = ctx.q.shape[0]
        searcher(ctx.q[:chunk]).ids.block_until_ready()  # warmup/compile
        t0 = time.perf_counter()
        outs = [jax.tree.map(np.asarray, searcher(ctx.q[s:s + chunk]))
                for s in range(0, nq, chunk)]
        us = (time.perf_counter() - t0) / nq * 1e6
        merged = jax.tree.map(lambda *a: np.concatenate(a, 0), *outs)
        return merged, us, searcher

    out = {"k": k, "tolerance": tolerance, "baselines": [], "configs": [],
           "rf1_id_mismatch_points": 0}
    base_by_nprobe = {}
    for nprobe in nprobes:
        p0 = SearchParams(k=k, nprobe=nprobe,
                          batch_buckets=(min(chunk, ctx.q.shape[0]),))
        res0, us0, _ = run(p0)
        r0 = recall_at_k(res0.ids, gt)
        base_by_nprobe[nprobe] = r0
        out["baselines"].append({"nprobe": nprobe, "recall": r0,
                                 "qps": 1e6 / us0})
        # degenerate ladder: rf=1 must be bitwise the single-tier path
        res1, _, _ = run(dataclasses.replace(
            p0, refine=RefineParams(plane=backends[0], refine_factor=1)))
        if not (np.array_equal(res0.ids, res1.ids)
                and np.array_equal(res0.dists, res1.dists)):
            out["rf1_id_mismatch_points"] += 1
        for backend in backends:
            for rf in refine_factors:
                p2 = dataclasses.replace(
                    p0, refine=RefineParams(plane=backend, refine_factor=rf))
                res2, us2, s2 = run(p2)
                model = session_traffic_model(s2)["refine"]
                row = {
                    "backend": backend, "refine_factor": rf,
                    "nprobe": nprobe,
                    "recall": recall_at_k(res2.ids, gt),
                    "qps": 1e6 / us2,
                    "m_compact": model["m_compact"],
                    "m_full": model["m_full"],
                    "tier1_ops": model["tier1_ops"],
                    "tier2_ops": model["tier2_ops"],
                    "total_ops": model["total_ops"],
                    "single_tier_ops": model["single_tier_ops"],
                    "total_ops_reduction_x": model["total_ops_reduction_x"],
                }
                row["recall_drop"] = r0 - row["recall"]
                out["configs"].append(row)
                emit(f"refine/{dataset}/{backend}/rf{rf}/nprobe{nprobe}",
                     us2,
                     f"recall={row['recall']:.4f} (drop "
                     f"{row['recall_drop']:+.4f}) "
                     f"ops_reduction={row['total_ops_reduction_x']:.2f}x "
                     f"qps={row['qps']:.0f}")
    # iso-recall frontier (the paper's own methodology — recall-vs-cost
    # curves, not same-knob points): the target is the best single-tier
    # recall anywhere in the sweep, and the frontier is the cheapest
    # two-tier config within `tolerance` of it; the claimed reduction is
    # against the single-tier ops AT that target operating point
    ops_by_nprobe = {c["nprobe"]: c["single_tier_ops"]
                     for c in out["configs"]}
    for b in out["baselines"]:
        b["single_tier_ops"] = ops_by_nprobe[b["nprobe"]]
    best = max(out["baselines"], key=lambda b: b["recall"])
    eligible = [c for c in out["configs"]
                if c["recall"] >= best["recall"] - tolerance]
    if eligible:
        fr = dict(min(eligible, key=lambda c: c["total_ops"]))
        fr["target_recall"] = best["recall"]
        fr["target_nprobe"] = best["nprobe"]
        fr["target_single_tier_ops"] = best["single_tier_ops"]
        fr["recall_drop"] = best["recall"] - fr["recall"]
        fr["total_ops_reduction_x"] = \
            best["single_tier_ops"] / fr["total_ops"]
        out["frontier"] = fr
        emit(f"refine/{dataset}/frontier", 0.0,
             f"{fr['backend']}/rf{fr['refine_factor']}/nprobe"
             f"{fr['nprobe']} reduction={fr['total_ops_reduction_x']:.2f}x "
             f"vs single-tier nprobe{fr['target_nprobe']} "
             f"drop={fr['recall_drop']:+.4f}")
    save_json("refine", out)
    assert out["rf1_id_mismatch_points"] == 0, \
        "refine_factor=1 must be bitwise-identical to single-tier"
    return out


def bench_trace(dataset="sift1m", k=10, nprobe=16, chunk=64,
                min_attribution=0.95):
    """Engine-deep trace bench (-> BENCH_trace.json): per-stage wall
    time and DCO from tracer spans (DESIGN.md §11), single-host and
    sharded.

    For each config — the plain single-host Searcher and a
    ``ShardedIndex`` session at ndev=1 and ndev=len(jax.devices()) —
    the same query stream runs untraced (the reference) and then traced
    with stage-boundary fencing, asserting bitwise-identical ids
    (fencing changes when the host observes values, never the values)
    and that >= ``min_attribution`` of end-to-end dispatch wall time
    lands in named ``stage.*`` spans.  Under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` this commits
    the first stage-attributed breakdown of the BENCH_dist.json
    multi-device QPS cliff: the per-shard scan and the gather/merge
    tail separately timed per dispatch.
    """
    from jax.sharding import Mesh

    from repro import obs
    from repro.core import SearchParams

    ctx = get_context(dataset, n_queries=256)
    idx = ctx.index("rair", True)
    max_scan = idx.default_max_scan(nprobe)
    params = SearchParams(k=k, nprobe=nprobe, max_scan=max_scan,
                          batch_buckets=(chunk,))
    devs = jax.devices()
    sessions = [("host", 0, idx.searcher(params))]
    for nd in sorted({1, len(devs)}):
        mesh = Mesh(np.asarray(devs[:nd]), ("data",))
        sessions.append(
            (f"sharded_ndev{nd}", nd, idx.shard(mesh).searcher(params)))

    def run_all(searcher):
        t0 = time.perf_counter()
        outs = [jax.tree.map(np.asarray, searcher(ctx.q[s:s + chunk]))
                for s in range(0, ctx.q.shape[0], chunk)]
        us = (time.perf_counter() - t0) / ctx.q.shape[0] * 1e6
        return jax.tree.map(lambda *a: np.concatenate(a, 0), *outs), us

    rows, mismatches = [], 0
    for name, nd, searcher in sessions:
        run_all(searcher)                       # compile the untraced path
        ref, us_ref = run_all(searcher)
        with obs.trace():
            run_all(searcher)                   # compile the traced stages
        with obs.trace() as tr:
            res, us_tr = run_all(searcher)
        mismatches += not np.array_equal(ref.ids, res.ids)
        trace = obs.snapshot_all(searcher=searcher, tracer=tr)["trace"]
        summary = tr.stage_summary()
        disp_s = summary["searcher.dispatch"]["total_s"]
        stages = {
            n: {"count": v["count"], "total_ms": v["total_s"] * 1e3,
                "share_of_dispatch": v["total_s"] / disp_s,
                **({"counters": v["counters"]} if v["counters"] else {})}
            for n, v in sorted(summary.items()) if n.startswith("stage.")}
        rows.append({
            "config": name, "ndev": nd,
            "stage_attribution": trace["stage_attribution"],
            "us_per_query_untraced": us_ref,
            "us_per_query_traced": us_tr,
            "traced_over_untraced": us_tr / us_ref,
            "fences": trace["fences"],
            "stages": stages,
            "dco_per_stage": trace.get("dco", {}),
        })
        emit(f"trace/{dataset}/{name}", us_tr,
             f"attribution={rows[-1]['stage_attribution']:.4f} "
             f"stages={len(stages)} fences={trace['fences']} "
             f"traced_overhead={us_tr / us_ref:.2f}x")
    out = {"k": k, "nprobe": nprobe, "max_scan": max_scan, "chunk": chunk,
           "min_attribution": min_attribution,
           "traced_id_mismatch_points": mismatches,
           "hbm_model": obs.session_traffic_model(sessions[0][2]),
           "configs": rows}
    save_json("trace_stages", out)
    assert mismatches == 0, \
        "traced dispatch must return bitwise-identical ids"
    bad = [(r["config"], r["stage_attribution"]) for r in rows
           if r["stage_attribution"] < min_attribution]
    assert not bad, (
        f"stage spans attribute < {min_attribution:.0%} of dispatch wall "
        f"time at {bad} — unattributed host work crept into dispatch")
    return out


def bench_serve(dataset="sift1m", k=10, nprobe=4, max_scan=16,
                load_factors=(1.5, 20.0), n_requests=384,
                max_batch=32, max_delay_ms=2.0):
    """Async gateway serving bench (-> BENCH_serve.json): the same
    open-loop Poisson arrival stream served two ways — through the
    deadline-batched gateway (requests coalesced into compiled batch
    buckets) and per-request (``max_batch=1``: identical queue and
    sessions, every dispatch carries one query) — with p50/p99 latency
    at each offered load point.

    The serving config is latency-budgeted (small nprobe, capped
    ``max_scan`` block budget) — the operating point a front-end
    actually serves, and the regime where per-dispatch overhead is
    worth amortizing.  Offered loads are calibrated to the machine: a
    back-to-back warmup
    run measures the per-request sustainable throughput, and each load
    point offers ``load_factor`` times that rate.  Below 1.0 both paths
    keep up and coalescing (by design) buys nothing; above it the
    per-request path saturates while the batched gateway keeps
    absorbing the stream — the regime a serving front-end exists for.

    Asserts the gateway's core claim so CI's ``gateway-smoke`` step
    fails loudly if coalescing regresses: at the highest offered load
    the batched gateway sustains >= 2x the per-request throughput."""
    from repro.gateway import Gateway, GatewayConfig, run_open_loop

    ctx = get_context(dataset, n_queries=256)
    idx = ctx.index("rair", True)
    q = np.asarray(ctx.q)
    modes = {
        "batched": GatewayConfig(max_delay_ms=max_delay_ms,
                                 max_batch=max_batch),
        "per_request": GatewayConfig(max_delay_ms=0.0, max_batch=1,
                                     admission="fifo"),
    }
    # calibrate: per-request capacity under back-to-back arrivals
    with Gateway(idx, k=k, nprobe=nprobe, max_scan=max_scan,
                 config=modes["per_request"]) as gw:
        cal = run_open_loop(gw, q, 1e6, max(n_requests // 3, 32), seed=99)
    per_req_cap = cal["achieved_qps"]
    offered = tuple(f * per_req_cap for f in load_factors)
    emit(f"serve_gateway/{dataset}/calibration", 0.0,
         f"per_request_capacity={per_req_cap:.0f}qps "
         f"offered={[f'{o:.0f}' for o in offered]}")

    runs = {}
    for mode, cfg in modes.items():
        with Gateway(idx, k=k, nprobe=nprobe, max_scan=max_scan,
                     config=cfg) as gw:
            rows = [run_open_loop(gw, q, qps, n_requests, seed=i)
                    for i, qps in enumerate(offered)]
            tel = gw.stats()["telemetry"]
        runs[mode] = {"points": rows,
                      "batch_fill": tel["batch_fill"],
                      "bucket_fill": tel["bucket_fill"],
                      "counters": tel["counters"]}

    points = []
    for i, qps in enumerate(offered):
        b = runs["batched"]["points"][i]
        p = runs["per_request"]["points"][i]
        speedup = b["achieved_qps"] / max(p["achieved_qps"], 1e-9)
        points.append({"offered_qps": qps, "speedup": speedup,
                       "batched": b, "per_request": p})
        emit(f"serve_gateway/{dataset}/qps{qps:g}", 0.0,
             f"batched={b['achieved_qps']:.0f} "
             f"per_request={p['achieved_qps']:.0f} "
             f"speedup={speedup:.2f}x "
             f"p50={b['p50_ms']:.1f}ms p99={b['p99_ms']:.1f}ms "
             f"mean_batch={b['mean_batch']:.1f}")
    out = {"k": k, "nprobe": nprobe, "max_scan": max_scan,
           "max_batch": max_batch,
           "max_delay_ms": max_delay_ms, "n_requests": n_requests,
           "per_request_capacity_qps": per_req_cap,
           "load_factors": list(load_factors),
           "points": points,
           "batched": {m: runs["batched"][m] for m in
                       ("batch_fill", "bucket_fill", "counters")},
           "per_request": {m: runs["per_request"][m] for m in
                           ("batch_fill", "bucket_fill", "counters")}}
    save_json("serve_gateway", out)
    errs = sum(pt["batched"]["errors"] + pt["per_request"]["errors"]
               for pt in points)
    assert errs == 0, f"{errs} gateway requests failed or timed out"
    top = max(pt["speedup"] for pt in points)
    assert top >= 2.0, (
        f"deadline-batched gateway only {top:.2f}x per-request dispatch "
        f"at its best offered load point — coalescing regressed")
    return out


def bench_overload(dataset="sift1m", k=10, nprobe=8, max_scan=16,
                   load_factors=(0.5, 1.0, 2.0), n_requests=512,
                   max_batch=32, max_delay_ms=2.0, max_queue=64,
                   recall_floor=None):
    """Overload-resilience bench (-> BENCH_overload.json, DESIGN.md
    §13): the same open-loop Poisson stream at 0.5x / 1x / 2x the
    *measured* saturating throughput, served three ways —

      unbounded   today's default: no admission bound, queueing delay
                  grows without limit past saturation
      shed        bounded queue (``max_queue``), reject policy: excess
                  arrivals fail fast with ``Overloaded``
      degrade     bounded queue + the quality ladder: under sustained
                  pressure the gateway steps down a pre-compiled
                  reduced-effort ``SearchParams`` rung instead of (or
                  before) shedding, and steps back up when load recedes

    Each point carries a full typed accounting (ok / shed / deadline /
    closed / untyped) plus recall@k of every answered query against the
    offline ground truth — degradation has a *price*, and the bench
    publishes it next to the latency it buys.  The regression gate
    asserts the machine-independent invariants: nothing dropped without
    a typed error, shed fraction monotone in offered load, the
    unbounded mode never sheds, answered recall above the documented
    floor, and the ladder actually engaging at top load — never a
    wall-clock number.
    """
    from repro.core import SearchParams
    from repro.gateway import (Gateway, GatewayConfig, degrade_ladder,
                               run_open_loop)

    ctx = get_context(dataset, n_queries=256)
    idx = ctx.index("rair", True)
    q = np.asarray(ctx.q)
    gt = np.asarray(ctx.gt(k))
    if recall_floor is None:
        # documented floors (DESIGN.md §13): the deepest ladder rung
        # (nprobe/4, max_scan/4) stays above these on answered queries
        # (the level-0 operating point itself is latency-budgeted:
        # nprobe=8/max_scan=16 sits near 0.48 recall@10 on sift1m)
        recall_floor = 0.4 if dataset == "sift1m" else 0.2
    params = SearchParams(k=k, nprobe=nprobe, max_scan=max_scan)
    ladder = degrade_ladder(params, levels=2)
    modes = {
        "unbounded": GatewayConfig(max_delay_ms=max_delay_ms,
                                   max_batch=max_batch),
        "shed": GatewayConfig(max_delay_ms=max_delay_ms,
                              max_batch=max_batch,
                              max_queue=max_queue, overload="reject"),
        "degrade": GatewayConfig(max_delay_ms=max_delay_ms,
                                 max_batch=max_batch,
                                 max_queue=max_queue, overload="reject",
                                 degrade=ladder[1:], degrade_hold=2),
    }
    # calibrate: saturating throughput of the (batched) serving config.
    # One search first so session creation + width warmup compile
    # outside the measured window — calibrating against cold-compile
    # wall time understates capacity and the "2x" sweep never overloads
    with Gateway(idx, params, config=modes["unbounded"]) as gw:
        gw.search(q[0])
        cal = run_open_loop(gw, q, 1e6, max(n_requests // 3, 32), seed=99)
    sat_qps = cal["achieved_qps"]
    offered = tuple(f * sat_qps for f in load_factors)
    emit(f"overload/{dataset}/calibration", 0.0,
         f"saturating={sat_qps:.0f}qps "
         f"offered={[f'{o:.0f}' for o in offered]}")

    out_modes = {}
    for mode, cfg in modes.items():
        points = []
        with Gateway(idx, params, config=cfg) as gw:
            gw.search(q[0])       # compile outside the measured points
            for i, qps in enumerate(offered):
                pt = run_open_loop(gw, q, qps, n_requests, seed=i,
                                   collect=True)
                ids = pt.pop("ok_ids")
                qi = pt.pop("ok_query_idx")
                pt["load_factor"] = load_factors[i]
                pt["recall"] = (float(per_query_recall(
                    ids, gt[qi]).mean()) if len(qi) else 0.0)
                points.append(pt)
                emit(f"overload/{dataset}/{mode}/x{load_factors[i]:g}", 0.0,
                     f"ok={pt['n_ok']} shed={pt['shed']} "
                     f"recall={pt['recall']:.3f} "
                     f"p99={pt['p99_ms']:.1f}ms levels={pt['levels']}")
            tel = gw.stats()["telemetry"]
        out_modes[mode] = {"points": points, "counters": tel["counters"]}

    top = len(offered) - 1
    p99_u = out_modes["unbounded"]["points"][top]["p99_ms"]
    p99_d = out_modes["degrade"]["points"][top]["p99_ms"]
    out = {"k": k, "nprobe": nprobe, "max_scan": max_scan,
           "max_batch": max_batch, "max_delay_ms": max_delay_ms,
           "max_queue": max_queue, "n_requests": n_requests,
           "saturating_qps": sat_qps,
           "load_factors": list(load_factors),
           "ladder": [{"nprobe": p.nprobe, "max_scan": p.max_scan}
                      for p in ladder],
           "recall_floor": recall_floor,
           "ladder_engaged": out_modes["degrade"]["counters"].get(
               "degrade_steps_down", 0) >= 1,
           "p99_top_load_degrade_over_unbounded": p99_d / max(p99_u, 1e-9),
           "modes": out_modes}
    save_json("overload", out)
    emit(f"overload/{dataset}/summary", 0.0,
         f"p99@2x degrade/unbounded="
         f"{out['p99_top_load_degrade_over_unbounded']:.3f} "
         f"ladder_engaged={out['ladder_engaged']}")
    return out
