"""Shared benchmark machinery: cached contexts, curve runner, CSV/JSON out."""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (IndexConfig, RairsIndex, SearchParams, build_index,
                        dco_summary, ground_truth, per_query_recall,
                        recall_at_k)
from repro.data import make_dataset

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
NPROBES = (1, 2, 4, 8, 16, 32, 64)

_CTX_CACHE: Dict[Tuple[str, int], "BenchContext"] = {}


@dataclasses.dataclass
class BenchContext:
    name: str
    x: jnp.ndarray
    q: jnp.ndarray
    metric: str
    nlist: int
    centroids: jnp.ndarray
    codebook: object
    _gt: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    _idx: Dict[tuple, RairsIndex] = dataclasses.field(default_factory=dict)

    def gt(self, k: int) -> np.ndarray:
        if k not in self._gt:
            self._gt[k] = ground_truth(self.x, self.q, k, metric=self.metric)
        return self._gt[k]

    def index(self, strategy: str, seil: bool, **over) -> RairsIndex:
        key = (strategy, seil, tuple(sorted(over.items())))
        if key not in self._idx:
            cfg = IndexConfig(nlist=self.nlist, strategy=strategy, seil=seil,
                              metric=self.metric, **over)
            self._idx[key] = build_index(
                jax.random.PRNGKey(0), self.x, cfg,
                centroids=self.centroids, codebook=self.codebook)
        return self._idx[key]


def get_context(dataset: str, nlist: int = 256, n_queries: Optional[int] = None
                ) -> BenchContext:
    ckey = (dataset, nlist)
    if ckey in _CTX_CACHE:
        ctx = _CTX_CACHE[ckey]
    else:
        x, q, spec = make_dataset(dataset)
        cfg = IndexConfig(nlist=nlist, metric=spec.metric)
        base = build_index(jax.random.PRNGKey(0), x, cfg)
        ctx = BenchContext(name=dataset, x=x, q=q, metric=spec.metric,
                           nlist=nlist, centroids=base.centroids,
                           codebook=base.codebook)
        ctx._idx[("rair", True, ())] = base
        _CTX_CACHE[ckey] = ctx
    if n_queries is not None and n_queries < ctx.q.shape[0]:
        return dataclasses.replace(
            ctx, q=ctx.q[:n_queries],
            _gt={k: v[:n_queries] for k, v in ctx._gt.items()},
            _idx=ctx._idx)
    return ctx


def timed_search(idx: RairsIndex, q, *, k, nprobe, k_factor=10,
                 chunk: int = 256, repeats: int = 1,
                 exec_mode: str = "paged"):
    """Run chunked search through a compiled searcher session; returns
    (merged result arrays, us_per_query).  The session pads short tail
    chunks to the single `chunk`-sized bucket, so the whole sweep runs
    on one cached executable (compile excluded from the timing)."""
    nq = q.shape[0]
    first = min(chunk, nq)
    searcher = idx.searcher(SearchParams(
        k=k, nprobe=nprobe, k_factor=k_factor, exec_mode=exec_mode,
        batch_buckets=(first,)))
    searcher(q[:first]).ids.block_until_ready()   # warmup/compile
    t0 = time.perf_counter()
    outs = []
    for _ in range(repeats):
        outs = [jax.tree.map(np.asarray, searcher(q[s:s + chunk]))
                for s in range(0, nq, chunk)]
    dt = (time.perf_counter() - t0) / repeats
    merged = jax.tree.map(lambda *a: np.concatenate(a, 0), *outs)
    return merged, dt / nq * 1e6


def curve(ctx: BenchContext, idx: RairsIndex, *, k: int = 10,
          k_factor: int = 10, nprobes=NPROBES) -> List[dict]:
    """Recall/DCO curve via the dense scoring path (== blocked path; the
    GEMM is shared across the nprobe sweep).  Wall-clock QPS is measured
    separately at operating points (see qps_at) — the paper itself switches
    to DCO after Fig. 7 because QPS is run-to-run noisy."""
    from repro.core.dense import dense_search_multi
    gt = ctx.gt(k)
    probes = tuple(p for p in nprobes if p <= ctx.nlist)
    results = dense_search_multi(idx, ctx.q, nprobes=probes, k=k,
                                 k_factor=k_factor)
    rows = []
    for p, res in zip(probes, results):
        s = dco_summary(res)
        rows.append({
            "nprobe": p,
            "recall": recall_at_k(res.ids, gt),
            "dco": s["total_dco"],
            "approx_dco": s["approx_dco"],
        })
    return rows


def qps_at(ctx: BenchContext, idx: RairsIndex, *, nprobe: int, k: int = 10,
           k_factor: int = 10, nq: int = 64) -> float:
    """us/query of the deployment (blocked) path at one operating point."""
    q = ctx.q[:nq]
    _, us = timed_search(idx, q, k=k, nprobe=nprobe, k_factor=k_factor,
                         chunk=32)
    return us


def at_recall(rows: List[dict], target: float, field: str) -> Optional[float]:
    """Linear interpolation of `field` at the target recall, walking the
    curve in nprobe order (monotone-envelope: first crossing wins)."""
    rows = sorted(rows, key=lambda r: r.get("nprobe", r[field]))
    prev = None
    for r in rows:
        if r["recall"] >= target:
            if prev is None or r["recall"] <= prev["recall"]:
                return float(r[field])
            w = (target - prev["recall"]) / (r["recall"] - prev["recall"])
            return float(prev[field] + w * (r[field] - prev[field]))
        if prev is None or r["recall"] > prev["recall"]:
            prev = r
    return None  # target unreachable


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def save_json(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
