"""Quickstart: build a RAIRS index, open a compiled searcher session,
persist the index, and see why RAIR+SEIL win.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.core import (IndexConfig, SearchParams, build_index, dco_summary,
                        ground_truth, load_index, recall_at_k, save_index,
                        vectors_in_large_cells)
from repro.data import make_dataset

# 1. a SIFT-like corpus (clustered, low intrinsic dimension)
x, queries, spec = make_dataset("unit")
gt = ground_truth(x, queries, k=10)

# 2. the paper's index: RAIR (AIR metric) redundant assignment + SEIL lists
index = build_index(jax.random.PRNGKey(0), x,
                    IndexConfig(nlist=64, strategy="rair", seil=True))
print(f"cells: {vectors_in_large_cells(index.assigns):.0%} of vectors live "
      f"in shared cells >= 1 block (the skew SEIL exploits)")

# 3. open a compiled searcher session (params validated + resolved once,
#    executables cached per batch-size bucket) and compare against the
#    single-assignment baseline at equal nprobe
params = SearchParams(k=10, nprobe=6)
baseline = build_index(jax.random.PRNGKey(0), x,
                       IndexConfig(nlist=64, strategy="single"),
                       centroids=index.centroids, codebook=index.codebook)
for name, idx in [("IVFPQfs (single)", baseline), ("RAIRS", index)]:
    searcher = idx.searcher(params)
    res = searcher(queries)
    rec = recall_at_k(np.asarray(res.ids), gt)
    s = dco_summary(res)
    print(f"{name:18s} nprobe=6: recall@10={rec:.3f} "
          f"distance-computations/query={s['total_dco']:.0f}")

# 4. sessions absorb varying batch sizes without retracing: every batch
#    pads to a cached bucket executable (watch the compile counters)
searcher = index.searcher(params)
for bs in (200, 64, 100, 200):
    searcher(queries[:bs])
print(f"session stats after mixed batches: {searcher.compile_stats()}")

# 5. persistence: save/load round-trips the whole index (config, centroids,
#    codebook, SEIL arrays, cached codes) — no re-training on restart
with tempfile.TemporaryDirectory() as td:
    bundle = os.path.join(td, "rairs_unit.npz")
    save_index(index, bundle)
    restored = load_index(bundle)
    res2 = restored.searcher(params)(queries)
    assert np.array_equal(np.asarray(res.ids), np.asarray(res2.ids))
    print(f"save/load round-trip: identical results "
          f"({os.path.getsize(bundle) / 1e6:.1f}MB bundle)")

# 6. the same search through the Pallas TPU kernel path (interpret on CPU)
kp = SearchParams(k=10, nprobe=6, use_kernel=True)
res_k = index.searcher(kp)(queries[:8])
res_j = index.searcher(params)(queries[:8])
assert np.array_equal(np.asarray(res_k.ids), np.asarray(res_j.ids))
print("pallas pq_scan kernel path == jnp path (8 queries checked)")

# 7. fused scan->top-k: the scan stage emits only the bigK*oversample
#    candidates finalize actually selects, instead of round-tripping the
#    full (S, BLK) score tensor through HBM; with use_kernel=True the
#    selection runs inside the Pallas kernel as a VMEM-resident bitonic
#    accumulator.  Results are bitwise identical either way (DESIGN.md §9)
fp = SearchParams(k=10, nprobe=6, use_kernel=True, fused_topk=True)
res_f = index.searcher(fp)(queries[:8])
assert np.array_equal(np.asarray(res_f.ids), np.asarray(res_k.ids))
print("fused scan->top-k path == unfused path (8 queries checked)")

# 8. a mesh is a deployment detail: shard the index and serve through the
#    *same* session API (1-device mesh here; bitwise-identical results —
#    on a real pod only the mesh constructor changes)
mesh = jax.make_mesh((len(jax.devices()),), ("data",))
sharded = index.shard(mesh)
res_m = sharded.searcher(params)(queries[:64])
assert np.array_equal(np.asarray(res_m.ids), np.asarray(res.ids[:64]))
print(f"sharded ({sharded.ndev}-device) session == single-host session; "
      f"stats: {sharded.searcher_stats()}")

# 9. steady-state serving with the locality-aware planner: clustered
#    execution buckets each batch by probed-list overlap (per-tile block
#    unions) and plan_reuse carries those unions across adjacent batches
#    — watch the plan-cache hit rate climb while results stay bitwise
#    identical to the paged scan
rng = np.random.default_rng(0)
hot = np.asarray(queries[:16])                    # a skewed "hot query" pool
serving = index.searcher(SearchParams(k=10, nprobe=6, exec_mode="clustered",
                                      plan_reuse=True))
for step in range(5):                             # the serving loop
    batch = hot[rng.integers(0, len(hot), 64)] + \
        rng.normal(0, 0.01, (64, hot.shape[1])).astype(np.float32)
    res_c = serving(batch)
    assert np.array_equal(
        np.asarray(res_c.ids),
        np.asarray(index.search(batch, k=10, nprobe=6).ids))
plan = serving.compile_stats()["plan"]
print(f"steady-state plan cache after 5 batches: "
      f"hit_rate={plan['hit_rate']:.0%} "
      f"tile_union~{plan['mean_union_live']:.0f} blocks "
      f"(scan width {plan['mean_width']:.0f})")

# 10. serving *traffic* instead of batches: the async gateway coalesces
#     single-query submissions into the same compiled buckets (flush on
#     a 2ms deadline or a full bucket) and keeps first-class telemetry —
#     batch_fill > 1 is the whole point (DESIGN.md §10).  On a
#     StreamingIndex, gw.compact_async() folds a new epoch in the
#     background and installs it between batches: zero downtime, and the
#     external ids in responses stay valid across the swap.
from repro.gateway import Gateway, GatewayConfig

with Gateway(index, params,
             config=GatewayConfig(max_delay_ms=2.0, max_batch=32)) as gw:
    pending = [gw.submit(q) for q in np.asarray(queries[:64])]
    answers = [p.result(timeout=30.0) for p in pending]
    assert np.array_equal(np.asarray(answers[0].ids), np.asarray(res.ids[0]))
    snap = gw.stats()["telemetry"]
    print(f"gateway: {len(answers)} requests coalesced into "
          f"{snap['counters']['batches']} dispatches "
          f"(batch_fill={snap['batch_fill']:.1f}, "
          f"p99={snap['latency']['p99_ms']:.1f}ms)")

# 11. x-ray the dispatch: with a tracer active every engine stage is a
#     fenced span (device time + per-stage DCO); off, tracing costs
#     literally nothing and results are bitwise identical either way.
#     write_trace emits Chrome/Perfetto trace-event JSON — drop it on
#     ui.perfetto.dev — and snapshot_all unifies session, gateway, HBM-
#     model, and per-stage trace stats in one dict (DESIGN.md §11)
from repro import obs

searcher = index.searcher(params)
ref = searcher(queries[:64])
with obs.trace():
    searcher(queries[:64])              # first traced call compiles stages
with obs.trace() as tr:
    traced = searcher(queries[:64])
assert np.array_equal(np.asarray(traced.ids), np.asarray(ref.ids))
trace_path = os.path.join(tempfile.mkdtemp(), "quickstart_trace.json")
obs.write_trace(tr, trace_path)
snap = obs.snapshot_all(searcher=searcher, tracer=tr)
stages = {n.removeprefix("stage."): f"{v['mean_ms']:.2f}ms"
          for n, v in sorted(tr.stage_summary().items())
          if n.startswith("stage.")}
print(f"traced dispatch == untraced (64 queries); per-stage {stages}; "
      f"attribution={snap['trace']['stage_attribution']:.0%} -> "
      f"{trace_path}")

# 12. the two-tier quantization ladder (DESIGN.md §12): tier-1 scans a
#     compact code plane (here coarse 4-bit PQ) keeping a widened
#     bigK * refine_factor survivor set, tier-2 re-ranks the survivors
#     exactly — same engine, same sessions, just cheaper scanning.
#     refine_factor=1 degenerates to the single-tier program *bitwise*;
#     snapshot_all reports the modeled tier split
from repro.core import RefineParams

two_tier = index.searcher(SearchParams(
    k=10, nprobe=6, refine=RefineParams(plane="pq4", refine_factor=4)))
res_2t = two_tier(queries)
model = obs.snapshot_all(searcher=two_tier)["hbm_model"]["refine"]
res_rf1 = index.searcher(SearchParams(
    k=10, nprobe=6, refine=RefineParams(plane="pq4", refine_factor=1)))(queries)
assert np.array_equal(np.asarray(res_rf1.ids),
                      np.asarray(index.searcher(params)(queries).ids))
print(f"two-tier pq4/rf4: recall@10="
      f"{recall_at_k(np.asarray(res_2t.ids), gt):.3f} "
      f"(single-tier {recall_at_k(np.asarray(res.ids), gt):.3f}); "
      f"tier-1 scans {model['m_compact']} of {model['m_full']} "
      f"subquantizers -> modeled total-ops "
      f"{model['total_ops_reduction_x']:.2f}x cheaper; rf=1 == single-tier")

# 13. overload resilience (DESIGN.md §13): the same gateway, now with a
#     bounded queue.  Unbounded, a burst past capacity just queues (and
#     p99 grows with the backlog); bounded with overload="reject", the
#     excess fails *fast and typed* — submit returns an already-failed
#     handle carrying Overloaded, so every request resolves either way.
#     Add degrade= (a pre-compiled reduced-effort ladder) and sustained
#     pressure steps quality down instead of shedding, stepping back up
#     when the burst passes — each answer is tagged with the level that
#     served it.
from repro.gateway import Overloaded, degrade_ladder

burst = np.asarray(queries[:192])
with Gateway(index, params,
             config=GatewayConfig(max_delay_ms=2.0, max_batch=32)) as gw:
    answered = [gw.submit(q).result(30.0) for q in burst]   # all served
with Gateway(index, params,
             config=GatewayConfig(max_delay_ms=2.0, max_batch=32,
                                  max_queue=16, overload="reject",
                                  degrade=degrade_ladder(params)[1:],
                                  degrade_hold=1)) as gw:
    pending = [gw.submit(q) for q in burst]
    ok, shed = [], 0
    for p in pending:
        try:
            ok.append(p.result(30.0))
        except Overloaded:
            shed += 1
    levels = sorted({r.level for r in ok})
print(f"overload: unbounded served {len(answered)}/{len(burst)}; "
      f"bounded served {len(ok)} + shed {shed} typed "
      f"(quality levels used: {levels}) — nothing dropped silently")
