"""Quickstart: build a RAIRS index, search it, and see why RAIR+SEIL win.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (IndexConfig, build_index, dco_summary, ground_truth,
                        recall_at_k, vectors_in_large_cells)
from repro.data import make_dataset

# 1. a SIFT-like corpus (clustered, low intrinsic dimension)
x, queries, spec = make_dataset("unit")
gt = ground_truth(x, queries, k=10)

# 2. the paper's index: RAIR (AIR metric) redundant assignment + SEIL lists
index = build_index(jax.random.PRNGKey(0), x,
                    IndexConfig(nlist=64, strategy="rair", seil=True))
print(f"cells: {vectors_in_large_cells(index.assigns):.0%} of vectors live "
      f"in shared cells >= 1 block (the skew SEIL exploits)")

# 3. search; compare against the single-assignment baseline at equal nprobe
baseline = build_index(jax.random.PRNGKey(0), x,
                       IndexConfig(nlist=64, strategy="single"),
                       centroids=index.centroids, codebook=index.codebook)
for name, idx in [("IVFPQfs (single)", baseline), ("RAIRS", index)]:
    res = idx.search(queries, k=10, nprobe=6)
    rec = recall_at_k(np.asarray(res.ids), gt)
    s = dco_summary(res)
    print(f"{name:18s} nprobe=6: recall@10={rec:.3f} "
          f"distance-computations/query={s['total_dco']:.0f}")

# 4. the same search through the Pallas TPU kernel path (interpret on CPU)
res_k = index.search(queries[:8], k=10, nprobe=6, use_kernel=True)
res_j = index.search(queries[:8], k=10, nprobe=6, use_kernel=False)
assert np.array_equal(np.asarray(res_k.ids), np.asarray(res_j.ids))
print("pallas pq_scan kernel path == jnp path (8 queries checked)")
