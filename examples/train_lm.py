"""End-to-end driver: train a ~100M-param qwen3-style model for a few
hundred steps on synthetic data, with checkpoint/resume.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults sized for the 1-core CPU container: ~35M params)
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.dist.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.models.transformer import init_params
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        ARCHS["qwen3-8b"], name="qwen3-example",
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=32, d_ff=args.d_model * 3, vocab=4096,
        flash_chunk=128, ce_chunk=64)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))))
    print(f"model: {cfg.name} {n_params / 1e6:.1f}M params")

    tcfg = TrainConfig(accum=2, optim=AdamWConfig(lr=1e-3, warmup_steps=20,
                                                  total_steps=args.steps))
    step = jax.jit(make_train_step(cfg, tcfg))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    start = 0
    if latest_step(args.ckpt):
        r = restore_checkpoint(args.ckpt, {"params": params, "opt": opt})
        params, opt = r["params"], r["opt"]
        start = int(opt.step)
        print(f"resumed at step {start}")

    # synthetic data with learnable structure (Zipf bigram chains)
    def batch_at(i):
        key = jax.random.PRNGKey(1000 + i)
        k1, k2 = jax.random.split(key)
        starts = jax.random.categorical(
            k1, np.log(1.0 / np.arange(1, cfg.vocab + 1) ** 1.3)[None, :]
            .repeat(args.batch, 0), shape=(args.batch,))
        ramp = (starts[:, None] + 7 * jax.numpy.arange(args.seq)[None, :]) \
            % cfg.vocab
        return {"tokens": ramp.astype("int32"),
                "labels": jax.numpy.roll(ramp, -1, axis=1).astype("int32")}

    t0 = time.perf_counter()
    for i in range(start, args.steps):
        params, opt, m = step(params, opt, batch_at(i))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):7.4f} "
                  f"lr {float(m['lr']):.2e}", flush=True)
        if (i + 1) % 50 == 0:
            save_checkpoint(args.ckpt, i + 1, {"params": params, "opt": opt})
    dt = time.perf_counter() - t0
    tok = (args.steps - start) * args.batch * args.seq
    print(f"trained {args.steps - start} steps, {tok / dt:.0f} tokens/s; "
          f"final loss {float(m['loss']):.4f} (predictable chains => "
          f"loss should fall well below ln(vocab)={np.log(cfg.vocab):.2f})")


if __name__ == "__main__":
    main()
