"""RAIRS-kNN paged attention: the paper's index serving a long KV cache.

Clusters the keys of a synthetic attention cache with k-means, assigns
them redundantly with the AIR metric (RAIR), packs shared cells once
(SEIL), then answers decode-step queries by probing top-nprobe lists —
and shows the recall of true top-attention keys vs probe count.

Run: PYTHONPATH=src python examples/long_context_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.retrieval import (KnnAttnConfig, build_knn_cache,
                                    rairs_attention_decode)

key = jax.random.PRNGKey(0)
b, s, kvh, hd, h = 1, 2048, 2, 32, 4

# a cache with cluster structure (bursty topics along the sequence)
topics = jax.random.normal(key, (16, kvh, hd))
topic_of = (jnp.arange(s) // 128) % 16
keys = topics[topic_of] + 0.3 * jax.random.normal(
    jax.random.PRNGKey(1), (s, kvh, hd))
keys = keys[None]
vals = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd))
q = (topics[5][None, None].repeat(h // kvh, 2).reshape(1, 1, h, hd)
     + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (1, 1, h, hd)))

# exact attention reference
qg = np.asarray(q)[:, 0].reshape(b, kvh, h // kvh, hd)
sc = np.einsum("bgrd,bsgd->bgrs", qg / np.sqrt(hd), np.asarray(keys))
p = np.exp(sc - sc.max(-1, keepdims=True)); p /= p.sum(-1, keepdims=True)
ref = np.einsum("bgrs,bsgd->bgrd", p, np.asarray(vals)).reshape(1, 1, h, hd)

print(f"cache: {s} keys/head; exact attention mass is concentrated: "
      f"top-128 keys hold {np.sort(p, -1)[..., -128:].sum(-1).mean():.0%}")

for nprobe in (1, 2, 4, 8, 16):
    kcfg = KnnAttnConfig(nlist=16, nprobe=nprobe, block=64,
                         max_blocks_per_list=48, window=32)
    cache = build_knn_cache(np.asarray(keys), np.asarray(vals), kcfg)
    out = rairs_attention_decode(q, cache, jnp.array([s]), kcfg)
    err = float(np.abs(np.asarray(out, np.float32) - ref).max()
                / np.abs(ref).max())
    print(f"nprobe={nprobe:2d}: attention output rel-err vs exact "
          f"{err:8.2e}  (scans ~{nprobe}/{kcfg.nlist} of the cache)")
print("RAIR assigns boundary keys to a second list, so low-nprobe probes "
      "still cover queries far from their list centroid (paper Fig. 2).")
