import os
import sys

# Tests and benches must see the real single-device CPU backend; only
# launch/dryrun.py sets xla_force_host_platform_device_count (see spec).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.core import IndexConfig, build_index, ground_truth
from repro.data import make_dataset


@pytest.fixture(scope="session")
def unit_data():
    x, q, spec = make_dataset("unit")
    gt10 = ground_truth(x, q, 10)
    return x, q, gt10


@pytest.fixture(scope="session")
def rairs_index(unit_data):
    x, _, _ = unit_data
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                      kmeans_iters=8, pq_iters=6)
    return build_index(jax.random.PRNGKey(0), x, cfg)


@pytest.fixture(scope="session")
def shared_trained(unit_data):
    """centroids+codebook trained once and shared across strategy builds."""
    x, _, _ = unit_data
    cfg = IndexConfig(nlist=64, kmeans_iters=8, pq_iters=6)
    idx = build_index(jax.random.PRNGKey(0), x, cfg)
    return idx.centroids, idx.codebook
