"""PQ and k-means substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import assign_nearest, kmeans_fit, pairwise_sq_l2
from repro.core.pq import (pq_adc, pq_decode, pq_encode, pq_lut, pq_lut_ip,
                           pq_train)


def test_pairwise_matches_numpy():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (50, 16))
    c = jax.random.normal(jax.random.PRNGKey(1), (7, 16))
    got = np.asarray(pairwise_sq_l2(x, c))
    ref = ((np.asarray(x)[:, None] - np.asarray(c)[None]) ** 2).sum(-1)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_kmeans_reduces_inertia():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2000, 8))
    c0 = x[:16]
    c = kmeans_fit(key, x, 16, iters=10)
    def inertia(cc):
        return float(pairwise_sq_l2(x, cc).min(axis=1).sum())
    assert inertia(c) < inertia(c0)


def test_kmeans_chunked_equals_unchunked():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1000, 8))
    c = jax.random.normal(jax.random.PRNGKey(4), (13, 8))
    a1 = np.asarray(assign_nearest(x, c, chunk=64))
    a2 = np.asarray(assign_nearest(x, c, chunk=10 ** 6))
    assert np.array_equal(a1, a2)


def test_pq_adc_identity():
    """by_residual=False ADC: sum_m ||q_m - c_code||^2 == ||q - decode||^2."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (512, 32))
    cb = pq_train(jax.random.PRNGKey(6), x, m=16, iters=8)
    codes = pq_encode(cb, x[:64])
    q = jax.random.normal(jax.random.PRNGKey(7), (4, 32))
    lut = pq_lut(cb, q)
    dec = pq_decode(cb, codes)
    for i in range(4):
        adc = np.asarray(pq_adc(lut[i], codes))
        exact = np.asarray(((dec - q[i]) ** 2).sum(-1))
        np.testing.assert_allclose(adc, exact, rtol=1e-4, atol=1e-4)


def test_pq_quantization_error_below_variance():
    key = jax.random.PRNGKey(8)
    x = jax.random.normal(key, (2048, 32))
    cb = pq_train(jax.random.PRNGKey(9), x, m=16, iters=10)
    rec = pq_decode(cb, pq_encode(cb, x))
    mse = float(jnp.mean((rec - x) ** 2))
    assert mse < float(jnp.var(x)) * 0.6


def test_pq_adc_correlates_with_true_distance(unit_data):
    x, q, _ = unit_data
    cb = pq_train(jax.random.PRNGKey(10), x, m=x.shape[1] // 2, iters=8)
    codes = pq_encode(cb, x[:2000])
    lut = pq_lut(cb, q[:1])
    adc = np.asarray(pq_adc(lut[0], codes))
    true = np.asarray(((x[:2000] - q[0]) ** 2).sum(-1))
    corr = np.corrcoef(adc, true)[0, 1]
    assert corr > 0.95, corr


def test_pq_lut_ip_sign():
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (256, 16))
    cb = pq_train(jax.random.PRNGKey(12), x, m=8, iters=6)
    q = x[:3]
    lut = pq_lut_ip(cb, q)
    codes = pq_encode(cb, x[:100])
    dec = pq_decode(cb, codes)
    for i in range(3):
        adc = np.asarray(pq_adc(lut[i], codes))
        ip = -np.asarray(dec @ q[i])
        np.testing.assert_allclose(adc, ip, rtol=1e-4, atol=1e-4)
