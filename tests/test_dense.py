"""Dense (GEMM) scoring path must be exactly equivalent to the blocked
SEIL scan: same DCO accounting, same candidate sets, same final ids."""
import jax
import numpy as np
import pytest

from repro.core import IndexConfig, build_index
from repro.core.dense import dense_search, dense_search_multi


@pytest.mark.parametrize("strategy,seil", [
    ("single", False), ("naive", False), ("rair", False),
    ("rair", True), ("srair", True),
])
def test_dense_equals_blocked(unit_data, shared_trained, strategy, seil):
    x, q, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy=strategy, seil=seil)
    idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                      codebook=cb)
    qs = q[:24]
    for nprobe in (3, 9):
        rb = idx.search(qs, k=10, nprobe=nprobe, max_scan=100000)
        rd = dense_search(idx, qs, nprobe=nprobe, k=10)
        assert np.asarray(rb.dropped_blocks).max() == 0
        np.testing.assert_array_equal(np.asarray(rb.approx_dco),
                                      np.asarray(rd.approx_dco))
        np.testing.assert_array_equal(np.asarray(rb.refine_dco),
                                      np.asarray(rd.refine_dco))
        gb, gd = np.asarray(rb.ids), np.asarray(rd.ids)
        for i in range(len(qs)):
            a, b = set(gb[i][gb[i] >= 0].tolist()), set(gd[i][gd[i] >= 0].tolist())
            assert len(a ^ b) <= 2, (i, a ^ b)   # tie-boundary tolerance


def test_dense_multi_matches_single(rairs_index, unit_data):
    _, q, _ = unit_data
    qs = q[:16]
    multi = dense_search_multi(rairs_index, qs, nprobes=(2, 8), k=10)
    for p, r in zip((2, 8), multi):
        single = dense_search(rairs_index, qs, nprobe=p, k=10)
        np.testing.assert_array_equal(np.asarray(r.ids),
                                      np.asarray(single.ids))
        np.testing.assert_array_equal(np.asarray(r.approx_dco),
                                      np.asarray(single.approx_dco))
