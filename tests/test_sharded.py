"""ShardedIndex sessions — the unified distributed path (DESIGN.md §4).

Run single-device in the tier-1 suite (where the 1-device mesh must be
*bitwise* identical to the plain ``Searcher``) and again on an
8-virtual-device CPU mesh in CI
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), where
multi-shard merges may reorder top-k ties but sorted (dist, id) pairs
and every DCO counter must still match.
"""
import jax
import numpy as np
import pytest

from repro.core import (IndexConfig, SearchParams, ShardedIndex,
                        StaleSessionError, build_index, distributed_search,
                        recall_at_k)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


@pytest.fixture(scope="module")
def dup_index(unit_data, shared_trained):
    """A duplicated (no-SEIL) layout: exercises the result-dedup merge."""
    x, _, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="srair", seil=False,
                      kmeans_iters=8, pq_iters=6)
    return build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                       codebook=cb)


def assert_results_match(res_local, res_sharded, ndev: int):
    """Bitwise on one device; up to top-k tie reordering on a mesh."""
    if ndev == 1:
        for name in res_local._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res_local, name)),
                np.asarray(getattr(res_sharded, name)), err_msg=name)
        return
    dl, ds = np.asarray(res_local.dists), np.asarray(res_sharded.dists)
    np.testing.assert_allclose(np.sort(dl, 1), np.sort(ds, 1), rtol=0, atol=0)
    il, is_ = np.asarray(res_local.ids), np.asarray(res_sharded.ids)
    for a, b in zip(il, is_):
        assert set(a[a >= 0]) == set(b[b >= 0])
    for name in ("approx_dco", "refine_dco", "scanned_blocks",
                 "dropped_blocks"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_local, name)),
            np.asarray(getattr(res_sharded, name)), err_msg=name)


@pytest.mark.parametrize("exec_mode", ["paged", "grouped"])
def test_sharded_matches_searcher(rairs_index, unit_data, mesh, exec_mode):
    """Acceptance: 1-device ShardedIndex bitwise == plain Searcher (both
    exec modes); an N-device mesh matches within top-k tie reordering."""
    x, q, gt = unit_data
    params = SearchParams(k=10, nprobe=8, exec_mode=exec_mode)
    sharded = rairs_index.shard(mesh)
    assert isinstance(sharded, ShardedIndex)
    res_l = rairs_index.searcher(params)(q[:32])
    res_s = sharded.searcher(params)(q[:32])
    assert_results_match(res_l, res_s, sharded.ndev)
    assert recall_at_k(np.asarray(res_s.ids), gt[:32]) > 0.8


def test_sharded_dedup_layout(dup_index, unit_data, mesh):
    """Duplicated layouts dedup across the gathered shard streams too.
    (max_scan is pinned un-truncating: a binding per-query budget drops
    different blocks under a per-device window — see DESIGN.md §4.)"""
    x, q, _ = unit_data
    params = SearchParams(k=10, nprobe=8, max_scan=4096)
    res_l = dup_index.searcher(params)(q[:24])
    res_s = dup_index.shard(mesh).searcher(params)(q[:24])
    assert_results_match(res_l, res_s, len(jax.devices()))
    ids = np.asarray(res_s.ids)
    for row in ids:
        row = row[row >= 0]
        assert len(row) == len(set(row)), "duplicate id in sharded top-k"


def test_sharded_session_protocol(rairs_index, unit_data, mesh):
    """Same session surface as the single-host path: cached per params,
    pad-and-dispatch buckets, compile-cache stats."""
    _, q, _ = unit_data
    sharded = rairs_index.shard(mesh)
    params = SearchParams(k=5, nprobe=4, batch_buckets=(16, 64))
    s1 = sharded.searcher(params)
    assert sharded.searcher(params) is s1          # cached per params
    r = s1(q[:23])                                 # pads 23 -> 64... no: chunk
    assert r.ids.shape == (23, 5)
    assert s1.stats.padded_rows > 0
    s1(q[:23])
    assert s1.stats.cache_hits > 0
    st = sharded.searcher_stats()
    assert st["ndev"] == len(jax.devices())
    assert st["compiles"] >= 1
    # the kwarg convenience path mirrors RairsIndex.search
    r2 = sharded.search(q[:8], k=5, nprobe=4)
    assert r2.ids.shape == (8, 5)


def test_sharded_kernel_sessions_serve(rairs_index, unit_data, mesh):
    """The mesh ``use_kernel`` rejection is lifted: kernel sessions lower
    through ``build_serve_step`` and return the same ids as the jnp path
    (refine recomputes exact distances, absorbing scan-stage rounding)."""
    _, q, _ = unit_data
    sharded = rairs_index.shard(mesh)
    base = sharded.searcher(SearchParams(k=5, nprobe=4))(q[:16])
    rk = sharded.searcher(SearchParams(k=5, nprobe=4, use_kernel=True))(q[:16])
    assert np.array_equal(np.asarray(rk.ids), np.asarray(base.ids))


def test_sharded_shard_cache(rairs_index, mesh):
    assert rairs_index.shard(mesh) is rairs_index.shard(mesh)
    assert rairs_index.shard(mesh, max_scan_local=64) is not \
        rairs_index.shard(mesh)


# ---------------------------------------------------------------------------
# streaming on a mesh
# ---------------------------------------------------------------------------

def _fresh_stream(unit_data, n=6000):
    x, q, gt = unit_data
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                      kmeans_iters=8, pq_iters=6)
    base = build_index(jax.random.PRNGKey(0), x[:n - 400], cfg)
    return base.streaming(), x, q


def test_streaming_on_mesh_matches_single_host(unit_data, mesh):
    """Satellite regression: insert -> delete -> compact on a sharded
    StreamingIndex matches the single-host one (tombstone mask
    replicated, delta scanned on every device, compaction re-shards)."""
    stream, x, q = _fresh_stream(unit_data)
    sharded = stream.shard(mesh)
    params = SearchParams(k=10, nprobe=8)
    qs = q[:32]
    ndev = sharded.ndev

    # pristine epoch: mesh == single host (bitwise on 1 device)
    assert_results_match(stream.searcher(params)(qs),
                         sharded.searcher(params)(qs), ndev)

    # mutations flow through the sharded view and stay coherent; the
    # epoch's base placement (block store) is never re-transferred
    base_placed = sharded._placement.base
    ids = sharded.insert(x[-400:-100])
    assert np.array_equal(ids, np.arange(stream.n_base,
                                         stream.n_base + 300))
    sharded.delete(ids[:80])
    sharded.delete(np.arange(40))
    assert stream.n_dead == 120
    assert_results_match(stream.searcher(params)(qs),
                         sharded.searcher(params)(qs), ndev)
    assert sharded._placement.base is base_placed  # per-epoch, not per-version

    # deleted ids can never surface from any shard
    got = np.asarray(sharded.searcher(params)(qs).ids)
    dead = set(ids[:80].tolist()) | set(range(40))
    assert not (set(got[got >= 0].tolist()) & dead)

    # compaction re-shards the fresh base; parity holds in the new epoch
    info = sharded.compact()
    assert info["epoch"] == 1
    sharded.searcher(params)
    assert sharded._placement.base is not base_placed  # epoch re-shard
    assert_results_match(stream.searcher(params)(qs),
                         sharded.searcher(params)(qs), ndev)
    # and the id space was renumbered identically (shared base object)
    assert sharded.version == stream.version


def test_streaming_mesh_sessions_pin_version(unit_data, mesh):
    stream, x, q = _fresh_stream(unit_data)
    sharded = stream.shard(mesh)
    params = SearchParams(k=5, nprobe=4)
    sess = sharded.searcher(params)
    sess(q[:8])
    sharded.insert(x[-50:])
    with pytest.raises(StaleSessionError):
        sess(q[:8])
    fresh = sharded.searcher(params)
    assert fresh is not sess
    fresh(q[:8])
    stats = sharded.searcher_stats()
    assert stats["invalidations"] == 1

    # steady-state churn inside one capacity bucket reuses executables:
    # same (params, shape signature) -> zero new compiles
    before = sharded.searcher_stats()["compiles"]
    for _ in range(3):
        sharded.insert(x[-8:])
        sharded.searcher(params)(q[:8])
    assert sharded.searcher_stats()["compiles"] == before


def test_mutations_require_streaming_base(rairs_index, mesh):
    with pytest.raises(TypeError, match="streaming base"):
        rairs_index.shard(mesh).insert(np.zeros((1, 32), np.float32))


# ---------------------------------------------------------------------------
# compat shims
# ---------------------------------------------------------------------------

def test_distributed_search_compat(rairs_index, unit_data, mesh):
    """The deprecated wrapper now rides the unified sessions: identical
    results to the session path, unified SearchResult type."""
    _, q, _ = unit_data
    qs = q[:16]
    res_c = distributed_search(rairs_index, mesh, qs, nprobe=8, k=10,
                               max_scan_local=4096)
    res_s = rairs_index.shard(mesh, max_scan_local=4096).searcher(
        SearchParams(k=10, nprobe=8))(qs)
    for name in res_s._fields:
        np.testing.assert_array_equal(np.asarray(getattr(res_c, name)),
                                      np.asarray(getattr(res_s, name)),
                                      err_msg=name)
    # params-object path + kwarg overrides still compose
    res_p = distributed_search(
        rairs_index, mesh, qs, params=SearchParams(k=10, nprobe=4),
        nprobe=8, max_scan_local=4096)
    np.testing.assert_array_equal(np.asarray(res_p.ids),
                                  np.asarray(res_c.ids))
    # per-query max_scan would be silently overridden by the per-device
    # budget, so the wrapper refuses it (sessions accept it natively)
    with pytest.raises(ValueError, match="max_scan"):
        distributed_search(rairs_index, mesh, qs,
                           params=SearchParams(k=10, nprobe=8,
                                               max_scan=4096))
