"""Staged query engine: grouped (list-major batch-union) execution must
be exactly the paged execution — same ids, distances, and DCO counters —
and both search frontends (single-host, distributed) must compose the
same stages.  Plus stage-level unit tests for planning and the grouped
kernel path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IndexConfig, build_index
from repro.core.distributed import distributed_search
from repro.core.engine import (BlockStore, batch_union, plan_blocks,
                               scan_blocks, select_lists, store_from_arrays,
                               tables_from_arrays)
from repro.core.engine.types import BIG
from repro.kernels.ref import pq_scan_paged_ref


def _assert_results_identical(ra, rb):
    for field in ra._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ra, field)), np.asarray(getattr(rb, field)),
            err_msg=field)


@pytest.mark.parametrize("nprobe", [2, 8])
def test_grouped_equals_paged_bitwise(rairs_index, unit_data, nprobe):
    _, q, _ = unit_data
    qs = q[:48]
    rp = rairs_index.search(qs, k=10, nprobe=nprobe, max_scan=4096,
                            exec_mode="paged")
    rg = rairs_index.search(qs, k=10, nprobe=nprobe, max_scan=4096,
                            exec_mode="grouped")
    _assert_results_identical(rp, rg)


def test_grouped_equals_paged_duplicated_layout(unit_data, shared_trained):
    """The id-dedup tail for non-SEIL layouts must also be mode-invariant."""
    x, q, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="srair", seil=False)
    idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                      codebook=cb)
    rp = idx.search(q[:32], k=10, nprobe=8, exec_mode="paged")
    rg = idx.search(q[:32], k=10, nprobe=8, exec_mode="grouped")
    _assert_results_identical(rp, rg)


def test_grouped_equals_paged_under_budget_pressure(rairs_index, unit_data):
    """Equivalence must hold even when the plan drops blocks to the
    budget — both modes scan the same compacted plan."""
    _, q, _ = unit_data
    rp = rairs_index.search(q[:16], k=10, nprobe=8, max_scan=12,
                            exec_mode="paged")
    rg = rairs_index.search(q[:16], k=10, nprobe=8, max_scan=12,
                            exec_mode="grouped")
    assert np.asarray(rp.dropped_blocks).max() > 0  # budget actually binds
    _assert_results_identical(rp, rg)


def test_distributed_exec_modes_match(rairs_index, unit_data):
    _, q, gt = unit_data
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    qs = q[:16]
    rd_p = distributed_search(rairs_index, mesh, qs, nprobe=8, k=10,
                              max_scan_local=4096, exec_mode="paged")
    rd_g = distributed_search(rairs_index, mesh, qs, nprobe=8, k=10,
                              max_scan_local=4096, exec_mode="grouped")
    _assert_results_identical(rd_p, rd_g)
    # and the shard_map path still matches the single-host engine's DCO
    # (the unified SearchResult replaced DistSearchResult.local_dco)
    rl = rairs_index.search(qs, k=10, nprobe=8, max_scan=4096)
    np.testing.assert_array_equal(np.asarray(rd_g.approx_dco),
                                  np.asarray(rl.approx_dco))


def test_batch_union_covers_plan(rairs_index, unit_data):
    """The batch-union block list is sorted, duplicate-free, and contains
    every valid planned block (so grouped mode can never drop one)."""
    _, q, _ = unit_data
    arrays = rairs_index.arrays
    selection = select_lists(q[:32], rairs_index.centroids, nprobe=8,
                             metric="l2")
    plan = plan_blocks(tables_from_arrays(arrays), selection, max_scan=4096)
    union = np.asarray(batch_union(plan, arrays.block_codes.shape[0]))
    live = union[union < int(BIG)]
    assert (np.diff(live) > 0).all(), "sorted + unique"
    planned = np.unique(np.asarray(plan.blocks)[np.asarray(plan.valid)])
    assert np.isin(planned, live).all()
    assert len(live) == len(planned)


def test_plan_budget_and_dropped(rairs_index, unit_data):
    _, q, _ = unit_data
    selection = select_lists(q[:8], rairs_index.centroids, nprobe=8,
                             metric="l2")
    tables = tables_from_arrays(rairs_index.arrays)
    full = plan_blocks(tables, selection, max_scan=100000)
    tight = plan_blocks(tables, selection, max_scan=4)
    n_full = np.asarray(full.valid).sum(1)
    n_tight = np.asarray(tight.valid).sum(1)
    assert (n_tight <= 4).all()
    np.testing.assert_array_equal(
        np.asarray(tight.dropped), np.maximum(n_full - 4, 0))
    # compaction is stable: the tight plan is a prefix of the full plan
    fb, tb_ = np.asarray(full.blocks), np.asarray(tight.blocks)
    for i in range(len(tb_)):
        keep = int(n_tight[i])
        np.testing.assert_array_equal(tb_[i][:keep], fb[i][:keep])


def test_scan_grouped_kernel_matches_oracle():
    """pq_scan_grouped through the engine == jnp oracle on a synthetic
    store (the §5.3 kernel path, interpret mode on CPU)."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    b, m, kk, tbn, blk, s = 8, 8, 16, 24, 32, 6
    lut = jax.random.normal(k1, (b, m, kk), jnp.float32)
    store = BlockStore(
        block_codes=jax.random.randint(k2, (tbn, blk, m), 0, kk
                                       ).astype(jnp.uint8),
        block_ids=jax.random.randint(k3, (tbn, blk), 0, 5000, jnp.int32),
        block_other=jnp.full((tbn, blk), -1, jnp.int32))
    nlist = 16
    sel = jax.random.randint(k4, (b, 4), 0, nlist, jnp.int32)
    rank_of = jnp.full((b, nlist), BIG, jnp.int32)
    blocks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, tbn,
                                jnp.int32)
    from repro.core.engine.types import QueryPlan
    plan = QueryPlan(blocks=blocks, ranks=jnp.zeros((b, s), jnp.int32),
                     valid=jnp.ones((b, s), bool),
                     dropped=jnp.zeros((b,), jnp.int32))
    out_k = scan_blocks(store, plan, lut, rank_of, exec_mode="grouped",
                        use_kernel=True, query_tile=4)
    ref = np.asarray(pq_scan_paged_ref(lut, store.block_codes, blocks)
                     ).reshape(b, -1)
    np.testing.assert_allclose(np.asarray(out_k.flat_d), ref,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(out_k.flat_i),
        np.asarray(store.block_ids[blocks]).reshape(b, -1))


def test_kernel_exec_modes_agree_end_to_end(rairs_index, unit_data):
    """Pallas paged vs grouped (interpret mode) on the real index: tiny
    workload, ids must match (distances agree to kernel tolerance)."""
    _, q, _ = unit_data
    qs = q[:8]
    rk_p = rairs_index.search(qs, k=10, nprobe=2, max_scan=24,
                              use_kernel=True, exec_mode="paged")
    rk_g = rairs_index.search(qs, k=10, nprobe=2, max_scan=24,
                              use_kernel=True, exec_mode="grouped")
    np.testing.assert_array_equal(np.asarray(rk_p.ids), np.asarray(rk_g.ids))
    np.testing.assert_allclose(np.asarray(rk_p.dists),
                               np.asarray(rk_g.dists), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(rk_p.approx_dco),
                                  np.asarray(rk_g.approx_dco))


def test_insert_batch_uses_cached_codes(rairs_index, unit_data, monkeypatch):
    """insert_batch must not re-encode the old corpus (codes are cached)."""
    import repro.core.index as index_mod
    x, _, _ = unit_data
    assert rairs_index.codes is not None
    calls = []
    real = index_mod.pq_encode

    def counting(cb, xs):
        calls.append(xs.shape[0])
        return real(cb, xs)

    monkeypatch.setattr(index_mod, "pq_encode", counting)
    idx2 = index_mod.insert_batch(rairs_index, x[:500])
    assert calls == [500], calls  # only the new batch was encoded
    assert idx2.codes.shape[0] == rairs_index.codes.shape[0] + 500
