"""Distributed-runtime tests at host scale: train step integration,
checkpoint save/restore (+ elastic resharding), grad compression,
distributed k-means, sharding rules."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.dist import shard_map
from repro.dist.checkpoint import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.dist.elastic import replan_mesh, rescale_batch
from repro.dist.sharding import axis_rules, logical_spec
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init
from repro.train.step import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)


def _tiny_setup(arch="qwen3-8b", accum=2, **tover):
    r = ARCHS[arch].reduced()
    params = init_params(KEY, r)
    opt = adamw_init(params)
    tcfg = TrainConfig(accum=accum, **tover)
    step = make_train_step(r, tcfg)
    b, s = 4, 32
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, r.vocab),
             "labels": jax.random.randint(KEY, (b, s), 0, r.vocab)}
    return r, params, opt, step, batch


def test_train_step_decreases_loss():
    r, params, opt, step, batch = _tiny_setup()
    step = jax.jit(step)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()
    assert int(opt.step) == 8


def test_grad_accum_equivalence():
    """accum=1 vs accum=4 must give (nearly) the same update."""
    outs = {}
    for a in (1, 4):
        r, params, opt, step, batch = _tiny_setup(accum=a)
        p2, o2, m = jax.jit(step)(params, opt, batch)
        outs[a] = (m["loss"], p2)
    np.testing.assert_allclose(float(outs[1][0]), float(outs[4][0]),
                               rtol=2e-2)
    diffs = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()),
                         outs[1][1], outs[4][1])
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_grad_compression_modes():
    base = None
    for mode in ("none", "bf16", "int8"):
        r, params, opt, step, batch = _tiny_setup(grad_compress=mode)
        p2, _, m = jax.jit(step)(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        if mode == "none":
            base = p2
        else:
            err = max(jax.tree.leaves(jax.tree.map(
                lambda x, y: float(jnp.abs(x - y).max()), base, p2)))
            assert err < (1e-2 if mode == "bf16" else 5e-2), (mode, err)


def test_compressed_psum_shardmap():
    from repro.optim.compress import compressed_psum
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"a": jnp.arange(8, dtype=jnp.float32) / 7.0}

    @jax.jit
    def run(t):
        return shard_map(
            lambda x: compressed_psum(x, ("data",), "int8"),
            mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
            out_specs=jax.sharding.PartitionSpec())(t)

    out = run(tree)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(tree["a"]), atol=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    r, params, opt, step, batch = _tiny_setup()
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, {"params": params, "opt": opt})
    save_checkpoint(d, 7, {"params": params, "opt": opt})
    assert latest_step(d) == 7
    restored = restore_checkpoint(d, {"params": params, "opt": opt})
    flat_a = jax.tree.leaves(restored["params"])
    flat_b = jax.tree.leaves(params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path):
    r, params, opt, step, batch = _tiny_setup()
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, {"p": params["final_norm"]}, keep=2)
    steps = sorted(os.listdir(d))
    assert steps == ["step_00000004", "step_00000005"]


def test_elastic_replan_and_restore(tmp_path):
    """Simulated node failure: checkpoint on 'full fleet', drop devices,
    replan mesh, restore, keep training."""
    r, params, opt, step, batch = _tiny_setup()
    d = str(tmp_path / "ckpt")
    params2, opt2, _ = jax.jit(step)(params, opt, batch)
    save_checkpoint(d, 1, {"params": params2, "opt": opt2})
    plan = replan_mesh(jax.devices(), model=1, failed=[])
    assert plan.data_size >= 1
    gb, accum = rescale_batch(4, 2, plan)
    assert gb * 0 + accum >= 2
    restored = restore_checkpoint(d, {"params": params2, "opt": opt2})
    p3, o3, m3 = jax.jit(step)(restored["params"], restored["opt"], batch)
    assert np.isfinite(float(m3["loss"]))
    assert int(o3.step) == 2  # resumed from step 1


def test_distributed_kmeans_step_matches_single():
    from repro.core.kmeans import kmeans_step_sharded, assign_nearest
    from repro.core.kmeans import _update_centroids
    x = jax.random.normal(KEY, (256, 8))
    c = x[:8]
    mesh = jax.make_mesh((1,), ("data",))
    got = shard_map(
        lambda xl, cc: kmeans_step_sharded(xl, cc, axis_names=("data",)),
        mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec("data"),
                  jax.sharding.PartitionSpec()),
        out_specs=jax.sharding.PartitionSpec())(x, c)
    a = assign_nearest(x, c)
    want, _ = _update_centroids(x, a, 8, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_logical_spec_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with axis_rules(mesh):
        # vocab=504 not divisible by model=1 -> trivially ok; simulate
        # the guard logic directly
        sp = logical_spec("vocab", "d_model", shape=(504, 64))
        assert sp is not None


def test_hubert_vocab_stays_replicated():
    """vocab=504 % 16 != 0: param_shardings must drop the model axis."""
    from repro.dist.sharding import param_shardings
    from repro.models.transformer import ParamSpec, param_specs
    # fake a 16-wide model axis using a reshaped single-device mesh is not
    # possible; assert via the pure spec function with a mocked mesh shape
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    from repro.dist import sharding as sh
    with_rules = {"vocab": "model", "d_model": None}
    import contextlib
    sh._state.ctx = (FakeMesh(), with_rules)
    try:
        sp = sh.logical_spec("vocab", "d_model", shape=(504, 1280))
        assert sp[0] is None  # dropped: 504 % 16 != 0
        sp2 = sh.logical_spec("vocab", "d_model", shape=(512, 1280))
        assert sp2[0] == ("model",) or sp2[0] == "model"
    finally:
        sh._state.ctx = None
