"""Persistence back-compat: golden v1/v2 bundles + the v3 sharded format.

The golden fixtures (tests/data/, written by tests/data/make_golden.py
at the version that introduced them) pin the on-disk contract: every
later format bump — the v3 sharded manifest included — must keep
loading them unchanged, and a load->save->load round-trip must be
byte-for-byte stable on every array.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (CHECKSUM_FORMAT_VERSION, INDEX_FORMAT,
                        PLANE_FORMAT_VERSION, RefineParams, SearchParams,
                        RairsIndex, SHARDED_FORMAT_VERSION, StreamingIndex,
                        load_index, read_index_meta, save_index)

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN_V1 = os.path.join(DATA, "golden_v1.npz")
GOLDEN_V2 = os.path.join(DATA, "golden_v2.npz")
GOLDEN_V4 = os.path.join(DATA, "golden_v4.npz")

_ARRAY_FIELDS = ("centroids", "vectors", "assigns", "codes")
_SEIL_FIELDS = ("block_codes", "block_ids", "block_other", "owned",
                "refs", "refs_other", "misc")


def _base(index):
    return index.base if isinstance(index, StreamingIndex) else index


def assert_indexes_equal(a, b):
    """Every persisted array bitwise identical, config/stats equal."""
    ab, bb = _base(a), _base(b)
    assert ab.config == bb.config
    assert ab.stats == bb.stats
    for f in _ARRAY_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ab, f)),
                                      np.asarray(getattr(bb, f)), err_msg=f)
    np.testing.assert_array_equal(
        np.asarray(ab.codebook.codebooks), np.asarray(bb.codebook.codebooks))
    pa = getattr(ab, "_planes", None) or {}
    pb = getattr(bb, "_planes", None) or {}
    assert sorted(pa) == sorted(pb)
    for backend in pa:
        for f in ("codes", "block_codes"):
            np.testing.assert_array_equal(
                np.asarray(getattr(pa[backend], f)),
                np.asarray(getattr(pb[backend], f)),
                err_msg=f"plane_{backend}.{f}")
        np.testing.assert_array_equal(
            np.asarray(pa[backend].codec.codebooks),
            np.asarray(pb[backend].codec.codebooks),
            err_msg=f"plane_{backend}.codebooks")
    for f in _SEIL_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ab.arrays, f)),
                                      np.asarray(getattr(bb.arrays, f)),
                                      err_msg=f)
    assert isinstance(a, StreamingIndex) == isinstance(b, StreamingIndex)
    if isinstance(a, StreamingIndex):
        assert (a.epoch, a.version) == (b.epoch, b.version)
        assert a.stream_config == b.stream_config
        np.testing.assert_array_equal(a.live_mask(), b.live_mask())
        da, db = a._delta, b._delta
        assert da.count == db.count
        for f in ("vectors", "codes", "assigns", "live"):
            np.testing.assert_array_equal(
                getattr(da, f)[:da.count], getattr(db, f)[:db.count],
                err_msg=f"delta.{f}")


def test_golden_v1_loads_unchanged():
    meta = read_index_meta(GOLDEN_V1)
    assert meta["format"] == INDEX_FORMAT
    assert meta["format_version"] == 1
    assert "streaming" not in meta
    idx = load_index(GOLDEN_V1)
    assert isinstance(idx, RairsIndex) and not isinstance(idx, StreamingIndex)
    assert idx.vectors.shape == (96, 8)
    # the frozen bundle still serves through sessions
    res = idx.searcher(SearchParams(k=5, nprobe=2))(np.asarray(idx.vectors)[:4])
    assert np.array_equal(np.asarray(res.ids)[:, 0], np.arange(4))


def test_golden_v2_loads_unchanged():
    meta = read_index_meta(GOLDEN_V2)
    assert meta["format_version"] == 2
    assert meta["streaming"]["delta_count"] == 12
    stream = load_index(GOLDEN_V2)
    assert isinstance(stream, StreamingIndex)
    assert stream.n_base == 96 and stream.n_total == 108
    assert stream.n_dead == 6          # 3 delta + 3 base tombstones
    assert not stream.live_mask()[[2, 7, 11, 96, 97, 98]].any()
    # mutations resume from the restored state
    assert stream.delete([0]) == 1


def test_golden_v4_loads_unchanged():
    """The quant-ladder bundle: v2's streaming state + both compact
    planes, written by the build that introduced format v4."""
    meta = read_index_meta(GOLDEN_V4)
    assert meta["format_version"] == PLANE_FORMAT_VERSION == 4
    assert meta["planes"] == ["binary", "pq4"]
    assert meta["streaming"]["delta_count"] == 12
    stream = load_index(GOLDEN_V4)
    assert isinstance(stream, StreamingIndex)
    assert stream.n_base == 96 and stream.n_dead == 6
    assert sorted(stream.base._planes) == ["binary", "pq4"]
    # the restored codecs are the carried ones: searcher resolution and
    # future compactions reuse them instead of retraining
    assert sorted(stream._plane_codecs) == ["binary", "pq4"]
    for b in ("binary", "pq4"):
        assert stream._plane_codecs[b] is stream.base._planes[b].codec
        assert stream.base.plane(b) is stream.base._planes[b]
    # restored planes serve two-tier, and rf=1 still matches single-tier
    q = np.asarray(stream.base.vectors)[:8]
    r2 = stream.searcher(SearchParams(
        k=5, nprobe=2, refine=RefineParams(plane="pq4")))(q)
    assert np.asarray(r2.ids).shape == (8, 5)
    r0 = stream.searcher(SearchParams(k=5, nprobe=2))(q)
    r1 = stream.searcher(SearchParams(
        k=5, nprobe=2, refine=RefineParams(plane="pq4", refine_factor=1)))(q)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))


@pytest.mark.parametrize("golden", [GOLDEN_V1, GOLDEN_V2, GOLDEN_V4],
                         ids=["v1", "v2", "v4"])
def test_golden_round_trips_byte_for_byte(golden, tmp_path):
    first = load_index(golden)
    resaved = tmp_path / "resaved.npz"
    save_index(first, resaved)
    second = load_index(resaved)
    assert_indexes_equal(first, second)


@pytest.mark.parametrize("golden", [GOLDEN_V1, GOLDEN_V2],
                         ids=["v1", "v2"])
@pytest.mark.parametrize("shards", [1, 3])
def test_golden_through_v3_sharded(golden, shards, tmp_path):
    """Old bundles round-trip through the v3 sharded layout unchanged,
    for any shard count (file sharding is independent of the mesh)."""
    first = load_index(golden)
    out = tmp_path / "sharded"
    save_index(first, out, shards=shards)
    meta = read_index_meta(out)
    assert meta["format_version"] == CHECKSUM_FORMAT_VERSION
    assert meta["shards"] == shards
    second = load_index(out)
    assert_indexes_equal(first, second)


@pytest.mark.parametrize("shards", [1, 3])
def test_golden_v4_through_sharded(shards, tmp_path):
    """Plane-carrying bundles shard like any other — the plane arrays
    live in the common (unsharded) file."""
    first = load_index(GOLDEN_V4)
    out = tmp_path / "sharded"
    save_index(first, out, shards=shards)
    meta = read_index_meta(out)
    assert meta["format_version"] == CHECKSUM_FORMAT_VERSION
    assert meta["planes"] == ["binary", "pq4"]
    second = load_index(out)
    assert_indexes_equal(first, second)


def test_v3_rejects_unknown_version(tmp_path):
    import json
    first = load_index(GOLDEN_V1)
    out = tmp_path / "sharded"
    save_index(first, out, shards=2)
    mpath = out / "MANIFEST.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = 99
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="format_version"):
        load_index(out)


def test_fixtures_match_generator_shape():
    """Guard against silently-regenerated fixtures drifting in shape."""
    assert os.path.getsize(GOLDEN_V1) < 64 * 1024
    assert os.path.getsize(GOLDEN_V2) < 64 * 1024
    assert os.path.getsize(GOLDEN_V4) < 64 * 1024


# ---------------------------------------------------------------------------
# v5: per-array checksums + atomic commit (DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_v5_single_file_carries_checksums(tmp_path):
    first = load_index(GOLDEN_V1)
    out = tmp_path / "idx.npz"
    save_index(first, out)
    meta = read_index_meta(out)
    assert meta["format_version"] == CHECKSUM_FORMAT_VERSION
    assert meta["checksums"]                 # every member covered
    assert "centroids" in meta["checksums"]


def test_v5_sharded_manifest_checksums_cover_every_member(tmp_path):
    import json
    first = load_index(GOLDEN_V1)
    out = tmp_path / "sharded"
    save_index(first, out, shards=2)
    manifest = json.loads((out / "MANIFEST.json").read_text())
    table = manifest["checksums"]
    for fname in manifest["shard_files"] + [manifest["common"]]:
        assert table[fname]                  # non-empty per-member map
    # shard member names are content-addressed: no bare shard_NNNN.npz
    assert all("-" in f for f in manifest["shard_files"])


def test_v5_bitflipped_member_rejected_by_name(tmp_path):
    from repro.core import CorruptBundleError
    first = load_index(GOLDEN_V1)
    out = tmp_path / "sharded"
    save_index(first, out, shards=2)
    import json
    manifest = json.loads((out / "MANIFEST.json").read_text())
    victim = manifest["shard_files"][0]
    # rewrite one member with different bytes but a *valid* zip, so
    # only the manifest crc32 can catch it
    with np.load(out / victim) as z:
        members = {k: np.array(z[k]) for k in z.files}
    name = sorted(members)[0]
    arr = members[name].copy()
    flat = arr.reshape(-1).view(np.uint8)
    flat[0] ^= 0x01                          # one flipped bit
    members[name] = arr
    np.savez_compressed(out / victim, **members)
    with pytest.raises(CorruptBundleError) as ei:
        load_index(out)
    assert victim in str(ei.value) and name in str(ei.value)


def test_v5_raw_bitflip_in_zip_stream_rejected(tmp_path):
    # flip a byte in the *file itself* (not a re-zipped member): the zip
    # stream decodes bad, and numpy only notices at the lazy member
    # read — that too must surface as CorruptBundleError, not BadZipFile
    from repro.core import CorruptBundleError
    first = load_index(GOLDEN_V1)
    bundle = tmp_path / "single.npz"
    save_index(first, bundle)
    raw = bytearray(bundle.read_bytes())
    raw[len(raw) // 2] ^= 0x10
    bundle.write_bytes(bytes(raw))
    with pytest.raises(CorruptBundleError, match="unreadable|crc32"):
        load_index(bundle)


def test_v5_truncated_member_rejected(tmp_path):
    from repro.core import CorruptBundleError
    first = load_index(GOLDEN_V1)
    out = tmp_path / "sharded"
    save_index(first, out, shards=2)
    import json
    manifest = json.loads((out / "MANIFEST.json").read_text())
    victim = out / manifest["shard_files"][1]
    raw = victim.read_bytes()
    victim.write_bytes(raw[: len(raw) // 2])
    with pytest.raises(CorruptBundleError):
        load_index(out)


def test_v5_missing_member_rejected(tmp_path):
    from repro.core import CorruptBundleError
    first = load_index(GOLDEN_V1)
    out = tmp_path / "sharded"
    save_index(first, out, shards=2)
    import json
    manifest = json.loads((out / "MANIFEST.json").read_text())
    os.remove(out / manifest["common"])
    with pytest.raises(CorruptBundleError, match="missing"):
        load_index(out)


def test_v5_save_leaves_no_temp_files(tmp_path):
    first = load_index(GOLDEN_V1)
    out = tmp_path / "sharded"
    save_index(first, out, shards=2)
    save_index(first, out, shards=3)         # overwrite in place
    leftovers = [f for f in os.listdir(out) if ".tmp." in f]
    assert leftovers == []
    assert_indexes_equal(first, load_index(out))


def test_v4_manifest_without_checksums_still_loads(tmp_path):
    """A v4-era manifest (no checksum table) must load with
    verification skipped — back-compat over integrity."""
    import json
    first = load_index(GOLDEN_V1)
    out = tmp_path / "sharded"
    save_index(first, out, shards=2)
    mpath = out / "MANIFEST.json"
    manifest = json.loads(mpath.read_text())
    manifest.pop("checksums")
    manifest["format_version"] = 4
    mpath.write_text(json.dumps(manifest))
    assert_indexes_equal(first, load_index(out))
