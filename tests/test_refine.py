"""Two-tier quantization ladder (DESIGN.md §12, repro/quant/).

The load-bearing invariants:

  * ``refine_factor=1`` (or ``plane='full'`` at rf=1, or no refine) is
    *bitwise* the single-tier path — the compiled program is literally
    today's, so turning the feature off can never change an answer;
  * the three exec modes agree bitwise under any plane, like they do
    single-tier;
  * pure widening (``plane='full'``, rf>1) re-ranks a superset of the
    single-tier candidate set with exact distances, so recall@k is
    monotone in the refine factor — the hypothesis property;
  * the ladder composes with every session type (frozen / streaming /
    sharded), the kernel + fused path, plan reuse, and compaction
    (codec carried, re-encode bitwise).
"""
import functools

import jax
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.core import (IndexConfig, RefineParams, SearchParams,
                        StaleSessionError, build_index, recall_at_k)
from repro.quant import (PLANE_BACKENDS, compact_subdim, encode_plane,
                         pack_nibbles, packed_width, train_plane,
                         unpack_nibbles)

EXEC_MODES = ("paged", "grouped", "clustered")


def _ref(plane="binary", rf=4):
    return RefineParams(plane=plane, refine_factor=rf)


# ---------------------------------------------------------------------------
# params surface
# ---------------------------------------------------------------------------

def test_refine_params_validation():
    with pytest.raises(ValueError, match="plane"):
        RefineParams(plane="int8")
    with pytest.raises(ValueError, match="refine_factor"):
        RefineParams(plane="pq4", refine_factor=0)
    p = SearchParams(k=10, nprobe=8, refine=_ref("pq4", 4))
    assert p.bigk_eff == 4 * p.bigk
    assert p.active_plane == "pq4"
    # rf=1 and the 'full' plane run the exact single-tier program
    assert SearchParams(k=10, nprobe=8,
                        refine=_ref("pq4", 1)).active_plane is None
    assert SearchParams(k=10, nprobe=8,
                        refine=_ref("full", 4)).active_plane is None
    assert SearchParams(k=10, nprobe=8).bigk_eff == \
        SearchParams(k=10, nprobe=8).bigk


def test_plane_cache_and_validation(rairs_index):
    with pytest.raises(ValueError, match="backend"):
        rairs_index.plane("int8")
    p1 = rairs_index.plane("pq4")
    assert rairs_index.plane("pq4") is p1           # cached per backend
    # carried codec: identical codec object -> cache hit, not a rebuild
    assert rairs_index.plane("pq4", codec=p1.codec) is p1
    mc = compact_subdim(32)
    assert p1.m == 32 // mc and p1.ksub == 16
    assert p1.block_codes.shape[-1] == packed_width(p1.m)


# ---------------------------------------------------------------------------
# frozen sessions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exec_mode", EXEC_MODES)
@pytest.mark.parametrize("plane", PLANE_BACKENDS + ("full",))
def test_rf1_bitwise_identical(rairs_index, unit_data, exec_mode, plane):
    """Acceptance: refine_factor=1 is bitwise the single-tier path."""
    _, q, _ = unit_data
    base = rairs_index.searcher(
        SearchParams(k=10, nprobe=16, exec_mode=exec_mode))(q[:48])
    rf1 = rairs_index.searcher(
        SearchParams(k=10, nprobe=16, exec_mode=exec_mode,
                     refine=_ref(plane, 1)))(q[:48])
    for f in base._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(base, f)), np.asarray(getattr(rf1, f)),
            err_msg=f)


@pytest.mark.parametrize("plane", PLANE_BACKENDS)
def test_two_tier_exec_modes_agree(rairs_index, unit_data, plane):
    _, q, _ = unit_data
    res = [rairs_index.searcher(
        SearchParams(k=10, nprobe=16, exec_mode=em, refine=_ref(plane, 4))
        )(q[:48]) for em in EXEC_MODES]
    for r in res[1:]:
        np.testing.assert_array_equal(np.asarray(res[0].ids),
                                      np.asarray(r.ids))
        np.testing.assert_array_equal(np.asarray(res[0].dists),
                                      np.asarray(r.dists))


def test_two_tier_recall_and_widening(rairs_index, unit_data):
    """binary tier-1 at rf=4 stays close to single-tier recall; pure
    widening (plane='full') can only improve it (superset re-rank)."""
    _, q, gt = unit_data
    p0 = SearchParams(k=10, nprobe=16)
    r0 = recall_at_k(np.asarray(rairs_index.searcher(p0)(q).ids), gt)
    r_bin = recall_at_k(np.asarray(rairs_index.searcher(
        SearchParams(k=10, nprobe=16, refine=_ref("binary", 4)))(q).ids), gt)
    r_full = recall_at_k(np.asarray(rairs_index.searcher(
        SearchParams(k=10, nprobe=16, refine=_ref("full", 4)))(q).ids), gt)
    assert r_bin >= r0 - 0.02, (r_bin, r0)
    assert r_full >= r0, (r_full, r0)


def test_two_tier_kernel_fused_parity(rairs_index, unit_data):
    """The Pallas scan->top-k path scans the packed plane in VMEM and
    returns the same ids as the jnp reference (exact tier-2 absorbs
    tier-1 rounding differences)."""
    _, q, _ = unit_data
    ref = _ref("pq4", 4)
    rj = rairs_index.searcher(
        SearchParams(k=10, nprobe=16, exec_mode="clustered",
                     refine=ref))(q[:32])
    rk = rairs_index.searcher(
        SearchParams(k=10, nprobe=16, exec_mode="clustered", use_kernel=True,
                     fused_topk=True, refine=ref))(q[:32])
    np.testing.assert_array_equal(np.asarray(rj.ids), np.asarray(rk.ids))


def test_two_tier_plan_reuse_parity(rairs_index, unit_data):
    """Incremental plans compose with the ladder; the deep-signature
    split counter (satellite: smarter plan signatures) is reported."""
    _, q, _ = unit_data
    ref = _ref("binary", 4)
    pp = SearchParams(k=10, nprobe=16, exec_mode="clustered",
                      plan_reuse=True, refine=ref)
    sess = rairs_index.searcher(pp)
    rp = sess(q[:48])
    rm = rairs_index.searcher(
        SearchParams(k=10, nprobe=16, exec_mode="clustered", refine=ref)
        )(q[:48])
    np.testing.assert_array_equal(np.asarray(rp.ids), np.asarray(rm.ids))
    np.testing.assert_array_equal(np.asarray(rp.dists), np.asarray(rm.dists))
    plan = sess.compile_stats()["plan"]
    assert plan["sig_deep_split"] >= 0


def test_two_tier_dco_split(rairs_index, unit_data):
    """Tier-1 scans the same candidate count; tier-2 rescoring widens
    with the refine factor — the split the traffic model reports."""
    from repro.obs.stats import session_traffic_model
    _, q, _ = unit_data
    s0 = rairs_index.searcher(SearchParams(k=10, nprobe=16))
    s2 = rairs_index.searcher(
        SearchParams(k=10, nprobe=16, refine=_ref("pq4", 4)))
    r0, r2 = s0(q[:32]), s2(q[:32])
    assert np.asarray(r2.approx_dco).sum() == np.asarray(r0.approx_dco).sum()
    assert np.asarray(r2.refine_dco).sum() > np.asarray(r0.refine_dco).sum()
    model = session_traffic_model(s2)["refine"]
    assert model["plane"] == "pq4" and model["bigk_eff"] == 4 * model["bigk"]
    assert model["m_compact"] < model["m_full"]
    assert model["total_ops"] < model["single_tier_ops"]
    assert "refine" not in session_traffic_model(s0)


# ---------------------------------------------------------------------------
# streaming sessions
# ---------------------------------------------------------------------------

@pytest.fixture()
def fresh_stream(unit_data):
    x, q, _ = unit_data
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                      kmeans_iters=8, pq_iters=6)
    base = build_index(jax.random.PRNGKey(0), x[:5600], cfg)
    return base.streaming(), x, q


def test_streaming_two_tier(fresh_stream):
    stream, x, q = fresh_stream
    ref = _ref("binary", 4)
    p_two = SearchParams(k=10, nprobe=16, refine=ref)
    p_one = SearchParams(k=10, nprobe=16)
    # pristine epoch: rf=1 delegates to the base session bitwise
    r0 = stream.searcher(p_one)(q)
    r1 = stream.searcher(SearchParams(k=10, nprobe=16,
                                      refine=_ref("binary", 1)))(q)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    stream.searcher(p_two)(q)
    codec0 = stream._plane_codecs["binary"]

    # mutations: new items reachable through the plane path, dead masked
    ids = stream.insert(x[5600:5800])
    stream.delete(np.arange(60))
    got = np.asarray(stream.searcher(p_two)(q).ids)
    assert not (set(got[got >= 0].tolist()) & set(range(60)))
    assert set(got[got >= 0].tolist()) & set(ids.tolist()), \
        "inserted items never surfaced through the two-tier path"
    r0 = stream.searcher(p_one)(q)
    r1 = stream.searcher(SearchParams(k=10, nprobe=16,
                                      refine=_ref("binary", 1)))(q)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    np.testing.assert_array_equal(np.asarray(r0.dists), np.asarray(r1.dists))

    # compaction carries the codec: the rebuilt epoch re-encodes with
    # the pinned codec instead of retraining (bitwise plane continuity)
    stream.compact()
    stream.searcher(p_two)(q)
    assert stream._plane_codecs["binary"] is codec0
    assert stream.base.plane("binary").codec is codec0

    # sessions pin versions exactly like single-tier ones
    sess = stream.searcher(p_two)
    stream.insert(x[:4])
    with pytest.raises(StaleSessionError):
        sess(q[:8])
    stream.searcher(p_two)(q[:8])


# ---------------------------------------------------------------------------
# sharded sessions
# ---------------------------------------------------------------------------

def test_sharded_two_tier(rairs_index, unit_data):
    """1-device mesh: the serve-step ladder is bitwise the local one."""
    _, q, _ = unit_data
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    sharded = rairs_index.shard(mesh)
    for ref in (_ref("pq4", 1), _ref("binary", 4)):
        p = SearchParams(k=10, nprobe=16, refine=ref)
        r_l = rairs_index.searcher(p)(q[:32])
        r_s = sharded.searcher(p)(q[:32])
        if sharded.ndev == 1:
            np.testing.assert_array_equal(np.asarray(r_l.ids),
                                          np.asarray(r_s.ids))
            np.testing.assert_array_equal(np.asarray(r_l.dists),
                                          np.asarray(r_s.dists))
        else:
            np.testing.assert_array_equal(
                np.sort(np.asarray(r_l.dists), 1),
                np.sort(np.asarray(r_s.dists), 1))


# ---------------------------------------------------------------------------
# nibble layout + backends
# ---------------------------------------------------------------------------

def test_nibble_roundtrip_exhaustive():
    rng = np.random.default_rng(0)
    for m in (1, 2, 3, 4, 7, 8, 16):
        codes = rng.integers(0, 16, size=(5, 9, m), dtype=np.uint8)
        packed = pack_nibbles(codes)
        assert packed.shape[-1] == packed_width(m) == (m + 1) // 2
        np.testing.assert_array_equal(
            np.asarray(unpack_nibbles(packed, m)), codes)


def test_binary_backend_is_sign_code():
    """Nearest-corner encoding of the virtual codebook is exactly the
    per-dimension sign bit against the corpus mean."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(512, 16)).astype(np.float32) * \
        rng.uniform(0.5, 2.0, size=16).astype(np.float32)
    codec = train_plane("binary", jax.random.PRNGKey(0), x)
    codes = encode_plane(codec, x)
    bits = (x > x.mean(axis=0)).astype(np.uint8).reshape(512, 4, 4)
    expect = (bits << np.arange(4)[None, None, :]).sum(-1).astype(np.uint8)
    np.testing.assert_array_equal(codes, expect)


@functools.lru_cache(maxsize=None)
def _tiny_index(seed: int):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(8, 16)).astype(np.float32) * 3.0
    x = (centers[rng.integers(0, 8, 1200)]
         + rng.normal(size=(1200, 16)).astype(np.float32) * 0.5)
    q = (centers[rng.integers(0, 8, 24)]
         + rng.normal(size=(24, 16)).astype(np.float32) * 0.5)
    cfg = IndexConfig(nlist=16, block=16, strategy="rair", seil=True,
                      kmeans_iters=4, pq_iters=4)
    idx = build_index(jax.random.PRNGKey(seed), x, cfg)
    from repro.core import ground_truth
    gt = np.asarray(ground_truth(idx.vectors, q, 10))
    return idx, q, gt


# satellite: hypothesis property — two-tier recall@k with a pure
# widening plane is >= single-tier recall at equal k (the widened
# survivor set is a superset and tier-2 re-ranks it exactly).
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 3), nprobe=st.sampled_from([2, 4, 8]),
       rf=st.sampled_from([2, 4, 8]),
       exec_mode=st.sampled_from(list(EXEC_MODES)))
def test_property_widening_recall_monotone(seed, nprobe, rf, exec_mode):
    idx, q, gt = _tiny_index(seed)
    base = recall_at_k(np.asarray(idx.searcher(
        SearchParams(k=10, nprobe=nprobe, exec_mode=exec_mode))(q).ids), gt)
    wide = recall_at_k(np.asarray(idx.searcher(
        SearchParams(k=10, nprobe=nprobe, exec_mode=exec_mode,
                     refine=_ref("full", rf)))(q).ids), gt)
    assert wide >= base, (wide, base, seed, nprobe, rf, exec_mode)


def test_widening_recall_monotone_deterministic():
    """The property above at fixed points (runs without hypothesis)."""
    for seed in (0, 1):
        idx, q, gt = _tiny_index(seed)
        for nprobe in (2, 8):
            base = recall_at_k(np.asarray(idx.searcher(
                SearchParams(k=10, nprobe=nprobe))(q).ids), gt)
            for rf in (2, 4):
                wide = recall_at_k(np.asarray(idx.searcher(
                    SearchParams(k=10, nprobe=nprobe,
                                 refine=_ref("full", rf)))(q).ids), gt)
                assert wide >= base, (seed, nprobe, rf, wide, base)
