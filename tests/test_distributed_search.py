"""Legacy distributed entry points ride the unified ShardedIndex path."""
import jax
import numpy as np

from repro.core import SearchParams, recall_at_k
from repro.core.distributed import distributed_search


def test_distributed_matches_local(rairs_index, unit_data):
    x, q, gt = unit_data
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    qs = q[:32]
    res_d = distributed_search(rairs_index, mesh, qs, nprobe=8, k=10,
                               max_scan_local=4096)
    res_l = rairs_index.searcher(
        SearchParams(k=10, nprobe=8, max_scan=4096))(qs)
    gl, gd = np.asarray(res_l.ids), np.asarray(res_d.ids)
    same = 0
    for i in range(len(qs)):
        a = set(gl[i][gl[i] >= 0].tolist())
        b = set(gd[i][gd[i] >= 0].tolist())
        same += len(a & b) / max(len(a | b), 1)
    assert same / len(qs) > 0.95, same / len(qs)
    # DCO matches the local searcher exactly (same scan semantics; the
    # wrapper now returns the unified SearchResult, so the counter is
    # ``approx_dco`` — the legacy ``local_dco`` field is gone)
    np.testing.assert_array_equal(np.asarray(res_d.approx_dco),
                                  np.asarray(res_l.approx_dco))
    assert recall_at_k(gd, gt[:32]) > 0.8
