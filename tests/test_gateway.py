"""Async serving gateway (src/repro/gateway/, DESIGN.md §10).

Key invariants:
  * a request served through the gateway returns exactly what a direct
    session call returns — coalescing changes latency, never results;
  * a concurrent burst coalesces (batch_fill > 1) while an isolated
    request still flushes on its deadline with batch == 1;
  * the queue drains whole signature lanes oldest-first (locality
    grouping can reorder only within one flush window) and honors
    per-request deadlines;
  * telemetry counters are monotone, percentile estimates never
    understate, and periodic sink records arrive in order;
  * streaming gateways return *stable external ids*: mutations round
    trip (insert -> search -> delete -> resolve) and survive an epoch
    handover;
  * zero-downtime handover: concurrent client threads see zero errors
    and zero result gaps while ``compact_async`` folds and installs a
    new epoch under live traffic (satellite: no StaleSessionError
    escapes, every returned id still resolves afterwards).
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import (IndexConfig, SearchParams, StreamConfig,
                        StreamingIndex, build_index)
from repro.gateway import (Gateway, GatewayClosed, GatewayConfig,
                           LatencyHistogram, MemorySink, PendingRequest,
                           RequestQueue, run_open_loop)


@pytest.fixture()
def stream_index(unit_data, shared_trained):
    """Fresh mutable index per test (never wrap the session-scoped
    rairs_index for mutation tests)."""
    x, _, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True)
    base = build_index(jax.random.PRNGKey(0), x[:4000], cfg,
                       centroids=cents, codebook=cb)
    return StreamingIndex(base, StreamConfig(delta_pad=512))


# ---------------------------------------------------------------------------
# config validation + lifecycle
# ---------------------------------------------------------------------------

def test_config_validation(rairs_index):
    with pytest.raises(ValueError):
        GatewayConfig(max_delay_ms=-1.0)
    with pytest.raises(ValueError):
        GatewayConfig(max_batch=0)
    with pytest.raises(ValueError):
        GatewayConfig(admission="lifo")
    with pytest.raises(ValueError):
        GatewayConfig(compact_delta_frac=0.0)
    # compaction thresholds need something to compact
    with pytest.raises(ValueError):
        Gateway(rairs_index, k=10, nprobe=8,
                config=GatewayConfig(compact_delta_frac=0.5))


def test_submit_validates_and_close_rejects(rairs_index, unit_data):
    _, q, _ = unit_data
    with Gateway(rairs_index, k=10, nprobe=8,
                 config=GatewayConfig(max_batch=4)) as gw:
        with pytest.raises(ValueError):
            gw.submit(q[0][:8])                  # wrong dimensionality
        with pytest.raises(ValueError):
            gw.submit(q[:2])                     # a batch is not a query
        r = gw.search(q[0])
        assert r.ids.shape == (10,)
        # mutations need a streaming index
        with pytest.raises(TypeError):
            gw.insert(q[:1])
        with pytest.raises(TypeError):
            gw.compact_async()
    assert gw.stats()["closed"]
    # typed close error — and still a RuntimeError for legacy callers
    with pytest.raises(GatewayClosed):
        gw.submit(q[0])
    with pytest.raises(RuntimeError):
        gw.submit(q[0])


# ---------------------------------------------------------------------------
# results: gateway == direct session, coalescing happens
# ---------------------------------------------------------------------------

def test_gateway_matches_direct_session(rairs_index, unit_data):
    _, q, _ = unit_data
    params = SearchParams(k=10, nprobe=8)
    direct = rairs_index.searcher(params)
    with Gateway(rairs_index, params,
                 config=GatewayConfig(max_batch=8, max_delay_ms=5.0)) as gw:
        pending = [gw.submit(q[i]) for i in range(16)]
        results = [p.result(30.0) for p in pending]
    for i, r in enumerate(results):
        ref = direct(q[i:i + 1])
        np.testing.assert_array_equal(np.asarray(r.ids),
                                      np.asarray(ref.ids)[0].astype(np.int64))
        np.testing.assert_allclose(np.asarray(r.dists),
                                   np.asarray(ref.dists)[0], rtol=1e-5)


def test_burst_coalesces_and_deadline_flushes(rairs_index, unit_data):
    _, q, _ = unit_data
    with Gateway(rairs_index, k=10, nprobe=8,
                 config=GatewayConfig(max_batch=16, max_delay_ms=50.0)) as gw:
        pending = [gw.submit(q[i]) for i in range(32)]
        results = [p.result(30.0) for p in pending]
        assert max(r.batch for r in results) > 1
        snap = gw.telemetry.snapshot()
        assert snap["batch_fill"] > 1.0
        assert snap["counters"]["responses"] == 32
        # an isolated request flushes on its own deadline, alone
        t0 = time.perf_counter()
        lone = gw.search(q[0], timeout=30.0)
        assert lone.batch == 1
        assert time.perf_counter() - t0 < 5.0


def test_open_loop_generator(rairs_index, unit_data):
    _, q, _ = unit_data
    with Gateway(rairs_index, k=10, nprobe=8,
                 config=GatewayConfig(max_batch=8, max_delay_ms=2.0)) as gw:
        out = run_open_loop(gw, q[:32], offered_qps=2000.0, n_requests=64,
                            timeout_s=60.0)
    assert out["errors"] == 0 and out["n_ok"] == 64
    assert out["p50_ms"] > 0 and out["p99_ms"] >= out["p50_ms"]
    assert out["mean_batch"] >= 1.0


# ---------------------------------------------------------------------------
# queue semantics (no gateway, no compiles)
# ---------------------------------------------------------------------------

def _req(sig, deadline=None):
    return PendingRequest(np.zeros(4, np.float32), sig, deadline=deadline)


def test_queue_drains_whole_lanes_oldest_first():
    qu = RequestQueue(grouped=True)
    a0, b0, a1 = _req(7), _req(3), _req(7)
    for r in (a0, b0, a1):
        qu.put(r)
    batch = qu.take_batch(16)
    # lane 7 is oldest (a0) so drains whole before lane 3
    assert batch == [a0, a1, b0]
    assert qu.depth == 0 and qu.take_batch(4) == []


def test_queue_respects_max_batch_and_fifo_within_lane():
    qu = RequestQueue(grouped=False)
    reqs = [_req(i) for i in range(5)]
    for r in reqs:
        qu.put(r)
    assert qu.take_batch(3) == reqs[:3]
    assert qu.take_batch(3) == reqs[3:]


def test_queue_deadline_tightens_flush():
    qu = RequestQueue(grouped=True)
    now = time.perf_counter()
    qu.put(_req(1, deadline=now + 0.001))
    due = qu.oldest_flush_at(max_delay=10.0)
    assert due is not None and due - now < 0.1   # deadline, not max_delay
    qu.take_batch(8)
    assert qu.oldest_flush_at(10.0) is None      # empty -> no flush time


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_latency_histogram_percentiles_never_understate():
    h = LatencyHistogram()
    vals = [1e-4, 5e-4, 1e-3, 5e-3, 1e-2]
    for v in vals:
        h.record(v)
    assert h.percentile(50) >= 5e-4
    assert h.percentile(99) >= h.percentile(50) >= h.percentile(10)
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["max_ms"] == pytest.approx(10.0)
    # locked snapshot schema: sinks derive rates from count and
    # cross-interval means from sum_ms without re-binning
    assert set(snap) == {"count", "sum_ms", "mean_ms", "p50_ms", "p95_ms",
                         "p99_ms", "max_ms"}
    assert snap["sum_ms"] == pytest.approx(sum(vals) * 1e3)
    assert snap["mean_ms"] == pytest.approx(snap["sum_ms"] / snap["count"])
    empty = LatencyHistogram().snapshot()
    assert empty["count"] == 0 and empty["mean_ms"] == 0.0


def test_telemetry_add_rejects_negative_deltas():
    from repro.gateway.telemetry import Telemetry
    tm = Telemetry()
    tm.add("approx_dco", 16.0)
    with pytest.raises(ValueError, match="monotone"):
        tm.add("approx_dco", -1.0)
    assert tm.snapshot()["counters"] == {}      # counters untouched
    # signed sums (ip-metric top-1 scores are negated inner products)
    # go through the documented escape hatch
    tm.add_signed("top1_dist", -3.5)
    tm.add_signed("top1_dist", 1.0)
    tm.inc("responses")
    assert tm.snapshot()["mean_top1_dist"] == pytest.approx(-2.5)


def test_periodic_sink_and_monotone_counters(rairs_index, unit_data):
    _, q, _ = unit_data
    sink = MemorySink()
    with Gateway(rairs_index, k=10, nprobe=8, sinks=(sink,),
                 config=GatewayConfig(max_batch=4, max_delay_ms=1.0,
                                      telemetry_interval_s=0.02)) as gw:
        for i in range(12):
            gw.search(q[i])
        time.sleep(0.08)                      # let at least one period pass
        stats = gw.stats()
    assert stats["telemetry"]["counters"]["responses"] == 12
    assert stats["session"]["compiles"] >= 1
    kinds = [r["kind"] for r in sink.records]
    assert kinds[-1] == "gateway_final"       # close() emits a final record
    assert "gateway_stats" in kinds
    # counters only ever grow across successive records
    for name in ("requests", "responses", "batches"):
        seq = [r["counters"].get(name, 0) for r in sink.records]
        assert seq == sorted(seq)
    assert all(r["counters"].get("errors", 0) == 0 for r in sink.records)


def test_warmup_ladder_precompiles_every_bucket(rairs_index, unit_data):
    _, q, _ = unit_data
    # distinct params -> a session no other test has warmed
    with Gateway(rairs_index, k=10, nprobe=5,
                 config=GatewayConfig(max_batch=4, max_delay_ms=1.0)) as gw:
        compiles_after_warmup = gw.stats()["session"]["compiles"]
        assert compiles_after_warmup >= 3     # buckets 1, 2, 4
        for i in range(6):                    # lands in buckets 1 and 2
            gw.search(q[i])
        assert gw.stats()["session"]["compiles"] == compiles_after_warmup


# ---------------------------------------------------------------------------
# streaming: stable external ids + zero-downtime handover
# ---------------------------------------------------------------------------

def test_mutations_roundtrip_external_ids(stream_index, unit_data):
    x, q, _ = unit_data
    new = x[4000:4032]
    with Gateway(stream_index, k=10, nprobe=16,
                 config=GatewayConfig(max_batch=4, max_delay_ms=1.0)) as gw:
        ext = gw.insert(new)
        assert ext.shape == (32,)
        # an inserted vector is its own nearest neighbor, by external id
        r = gw.search(new[0])
        assert int(np.asarray(r.ids)[0]) == int(ext[0])
        assert gw.delete(ext[:8]) == 8
        h = gw.compact_async("test")
        info = h.wait(120.0)
        assert h.state == "installed" and info["n_live"] > 0
        # handles survive the epoch swap: deleted -> -1, live -> resolvable
        resolved = gw.resolve_ids(ext)
        assert (resolved[:8] == -1).all() and (resolved[8:] >= 0).all()
        r2 = gw.search(new[9])
        assert int(np.asarray(r2.ids)[0]) == int(ext[9])
        st = gw.stats()
        assert st["stream"]["epoch"] == 1
        assert st["telemetry"]["counters"]["handovers"] == 1
        assert st["handover"]["state"] == "idle"
        assert st["handover"]["last"]["reason"] == "test"


def test_handover_under_live_traffic(stream_index, unit_data):
    """Satellite: clients keep searching while compaction folds and the
    new epoch installs — zero errors, zero StaleSessionError escapes,
    and every id any client ever received still resolves afterwards."""
    x, q, _ = unit_data
    cfg = GatewayConfig(max_batch=8, max_delay_ms=1.0)
    with Gateway(stream_index, k=10, nprobe=16, config=cfg) as gw:
        gw.insert(x[4000:4128])               # give the fold real work
        failures, results = [], []
        res_lock = threading.Lock()

        def client(seed):
            rng = np.random.default_rng(seed)
            for _ in range(25):
                try:
                    r = gw.search(q[int(rng.integers(len(q)))], timeout=60.0)
                    with res_lock:
                        results.append(r)
                except Exception as e:        # noqa: BLE001 — recorded
                    failures.append(e)

        threads = [threading.Thread(target=client, args=(s,))
                   for s in range(3)]
        for t in threads:
            t.start()
        h = gw.compact_async("churn")
        h.wait(120.0)
        for t in threads:
            t.join()

        assert not failures
        st = gw.stats()
        assert st["telemetry"]["counters"].get("errors", 0) == 0
        assert st["telemetry"]["counters"].get("stale_retries", 0) == 0
        assert st["stream"]["epoch"] == 1
        epochs = {r.epoch for r in results}
        assert 0 in epochs                    # old epoch kept serving
        # every id any client received resolves against the live corpus
        all_ids = np.unique(np.concatenate(
            [np.asarray(r.ids) for r in results]))
        all_ids = all_ids[all_ids >= 0]
        assert (gw.resolve_ids(all_ids) >= 0).all()


def test_telemetry_observe_atomic_under_threads():
    """The batched ``observe`` path keeps cross-metric invariants exact
    in *every* snapshot: a dispatch records responses and its latency
    samples under one lock acquisition, so a concurrent reader can
    never see the counter move without the histogram (or half a
    multi-sum update)."""
    from repro.gateway.telemetry import Telemetry
    tm = Telemetry()
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            tm.observe(counters={"responses": 2, "batches": 1},
                       sums={"result_slots": 20.0, "result_filled": 18.0},
                       latencies=[(tm.latency, 1e-3), (tm.latency, 2e-3)])

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        bad = []
        for _ in range(300):
            snap = tm.snapshot()
            c, s = snap["counters"], snap["latency"]
            if c.get("responses", 0) != s["count"]:
                bad.append((c.get("responses", 0), s["count"]))
            if c.get("responses", 0) != 2 * c.get("batches", 0):
                bad.append(("responses/batches", c))
            # multi-sum atomicity: slots and filled move together
            slots = snap["counters"].get("responses", 0) * 10.0
            if abs(slots * 0.9 -
                   (snap["result_fill_rate"] * slots)) > 1e-6:
                bad.append(("fill_rate", snap["result_fill_rate"]))
        assert not bad, bad[:5]
    finally:
        stop.set()
        for t in threads:
            t.join(10.0)
    # a torn observe (negative sum) must reject before mutating anything
    before = tm.snapshot()
    with pytest.raises(ValueError):
        tm.observe(counters={"responses": 1}, sums={"approx_dco": -1.0})
    after = tm.snapshot()
    assert after["counters"] == before["counters"]
