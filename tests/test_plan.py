"""Locality-aware query planning (engine/cluster.py, DESIGN.md §5).

Key invariants:
  * ``exec_mode="clustered"`` (query-tile clustering, per-tile block
    unions) is bitwise identical to ``"paged"`` on every search path —
    frozen, streaming (mutated), sharded (1-device mesh);
  * incremental plans (``SearchParams(plan_reuse=True)``) — the probe ->
    plan-cache merge -> scan split — are bitwise identical to fresh
    batch-wide plans, for grouped and clustered modes, and the cache
    invalidates with the session across mutations and epoch bumps;
  * every valid planned block lands inside its tile's union, unions are
    sorted/unique, and the cluster order is a stable permutation;
  * routed delta scans return exactly the exhaustive path's results
    whenever every delta item is reachable through the probed lists
    (nprobe = nlist), reduce DCO at serving nprobe, and keep inserted
    items retrievable;
  * the derived per-device ``max_scan_local`` (per-shard list occupancy)
    never truncates a plan — recall-neutral vs an un-truncating budget.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.core import (IndexConfig, SearchParams, StaleSessionError,
                        StreamingIndex, build_index, cluster_order,
                        merge_unions_host, plan_blocks, select_lists,
                        tile_signatures, tile_unions, union_dims, union_live)
from repro.core.engine import tables_from_arrays
from repro.core.engine.types import BIG


def _assert_results_identical(ra, rb, msg=""):
    for field in ra._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ra, field)), np.asarray(getattr(rb, field)),
            err_msg=f"{msg}{field}")


# ---------------------------------------------------------------------------
# clustered exec mode == paged, everywhere
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nprobe", [2, 8])
def test_clustered_equals_paged_bitwise(rairs_index, unit_data, nprobe):
    _, q, _ = unit_data
    qs = q[:48]
    rp = rairs_index.search(qs, k=10, nprobe=nprobe, max_scan=4096,
                            exec_mode="paged")
    rc = rairs_index.search(qs, k=10, nprobe=nprobe, max_scan=4096,
                            exec_mode="clustered")
    _assert_results_identical(rp, rc)


def test_clustered_equals_paged_under_budget_pressure(rairs_index,
                                                      unit_data):
    _, q, _ = unit_data
    rp = rairs_index.search(q[:16], k=10, nprobe=8, max_scan=12,
                            exec_mode="paged")
    rc = rairs_index.search(q[:16], k=10, nprobe=8, max_scan=12,
                            exec_mode="clustered")
    assert np.asarray(rp.dropped_blocks).max() > 0
    _assert_results_identical(rp, rc)


def test_clustered_kernel_path(rairs_index, unit_data):
    """pq_scan_tiled through the engine (interpret mode): ids must match
    paged-kernel, distances to kernel tolerance."""
    _, q, _ = unit_data
    qs = q[:8]
    rk_p = rairs_index.search(qs, k=10, nprobe=2, max_scan=24,
                              use_kernel=True, exec_mode="paged")
    rk_c = rairs_index.search(qs, k=10, nprobe=2, max_scan=24,
                              use_kernel=True, exec_mode="clustered")
    np.testing.assert_array_equal(np.asarray(rk_p.ids), np.asarray(rk_c.ids))
    np.testing.assert_allclose(np.asarray(rk_p.dists),
                               np.asarray(rk_c.dists), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(rk_p.approx_dco),
                                  np.asarray(rk_c.approx_dco))


def test_clustered_streaming_mutated(small_stream, unit_data):
    stream, _ = small_stream
    _, q, _ = unit_data
    rp = stream.search(q[:32], k=10, nprobe=8, exec_mode="paged")
    rc = stream.search(q[:32], k=10, nprobe=8, exec_mode="clustered")
    _assert_results_identical(rp, rc)


def test_clustered_sharded_matches(rairs_index, unit_data):
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    _, q, _ = unit_data
    params = SearchParams(k=10, nprobe=8, exec_mode="clustered")
    res_l = rairs_index.searcher(params)(q[:32])
    res_s = rairs_index.shard(mesh).searcher(params)(q[:32])
    if len(jax.devices()) == 1:
        _assert_results_identical(res_l, res_s)
    else:
        np.testing.assert_allclose(
            np.sort(np.asarray(res_l.dists), 1),
            np.sort(np.asarray(res_s.dists), 1), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# planner unit invariants
# ---------------------------------------------------------------------------
def test_cluster_order_is_stable_permutation(rairs_index, unit_data):
    _, q, _ = unit_data
    sel = select_lists(q[:64], rairs_index.centroids, nprobe=8,
                       metric="l2").sel
    perm = np.asarray(cluster_order(sel))
    assert sorted(perm.tolist()) == list(range(64))
    # grouped by the full signature prefix, stable within equal prefixes
    sig = np.asarray(sel)[:, :4]
    ordered = sig[perm]
    keys = [tuple(r) for r in ordered]
    assert keys == sorted(keys), "not in signature order"
    for a, b in zip(perm[:-1], perm[1:]):
        if tuple(sig[a]) == tuple(sig[b]):
            assert a < b, "stability violated on equal signatures"


def test_tile_unions_cover_plans(rairs_index, unit_data):
    _, q, _ = unit_data
    selection = select_lists(q[:32], rairs_index.centroids, nprobe=8,
                             metric="l2")
    plan = plan_blocks(tables_from_arrays(rairs_index.arrays), selection,
                       max_scan=4096)
    perm = np.asarray(cluster_order(selection.sel))
    t, w = union_dims(32, plan.blocks.shape[1],
                      rairs_index.arrays.block_codes.shape[0],
                      "clustered", 8)
    unions = np.asarray(tile_unions(jnp.asarray(np.asarray(plan.blocks)[perm]),
                                    jnp.asarray(np.asarray(plan.valid)[perm]),
                                    t, w))
    blocks = np.asarray(plan.blocks)[perm].reshape(t, -1, plan.blocks.shape[1])
    valid = np.asarray(plan.valid)[perm].reshape(blocks.shape)
    for i in range(t):
        live = unions[i][unions[i] < int(BIG)]
        assert (np.diff(live) > 0).all(), "sorted + unique"
        planned = np.unique(blocks[i][valid[i]])
        assert np.isin(planned, live).all()
        assert len(live) == len(planned)     # nothing beyond the tile's plans


def test_merge_unions_host_semantics():
    big = int(BIG)
    a = np.array([[1, 5, 9, big]], np.int64)
    # hit: subset reuses the cache unchanged
    used, hit, ext = merge_unions_host(a, np.array([[5, 9, big, big]],
                                                   np.int64))
    assert hit.all() and not ext.any()
    np.testing.assert_array_equal(used, a)
    # extend: merged fits the width
    used, hit, ext = merge_unions_host(a, np.array([[2, 5, big, big]],
                                                   np.int64))
    assert ext.all() and not hit.any()
    np.testing.assert_array_equal(used[0], [1, 2, 5, 9])
    # miss: merged would overflow -> own union wins (correctness first)
    own = np.array([[2, 3, 4, 6]], np.int64)
    used, hit, ext = merge_unions_host(a, own)
    assert not hit.any() and not ext.any()
    np.testing.assert_array_equal(used, own)
    # cold cache: own, counted as miss by the caller
    used, hit, ext = merge_unions_host(None, own)
    np.testing.assert_array_equal(used, own)
    assert not hit.any() and not ext.any()
    # signature-keyed alignment: a BIG-filled row for a first-seen tile
    # must classify as a miss (not an extend), and still scan own
    pad = np.full((1, 4), big, np.int64)
    own2 = np.array([[5, 7, big, big]], np.int64)
    used, hit, ext = merge_unions_host(
        np.concatenate([a, pad]), np.concatenate([own2, own2]),
        present=np.array([True, False]))
    assert ext[0] and not hit[0]                 # real cache row extends
    assert not hit[1] and not ext[1]             # absent row is a miss
    np.testing.assert_array_equal(used[1], own2[0])
    np.testing.assert_array_equal(used[0], [1, 5, 7, 9])


def test_tile_signatures_follow_working_set():
    """Tiles are named by lead list + run index; a boundary shift keeps
    the keys of the surviving groups identical across batches."""
    assert tile_signatures(np.array([4, 4, 9, 17])) == [
        (4, 0), (4, 1), (9, 0), (17, 0)]
    # popularity drift: list 4 loses a tile, 9 gains one — 9's first
    # tile and 17's tile keep their keys, so their cached unions survive
    assert tile_signatures(np.array([4, 9, 9, 17])) == [
        (4, 0), (9, 0), (9, 1), (17, 0)]


def test_tile_signatures_deep_split_same_lead():
    """At large nprobe the deep key separates tiles that share a hot
    lead list but probe different working sets: the probe prefix beyond
    the lead joins the key, so drift cannot reshuffle their cached
    unions into each other — while tiles with identical prefixes still
    coalesce under run indexing exactly as lead-only keys do."""
    rows = np.array([[4, 7, 2], [4, 11, 5], [4, 7, 2]])
    lead_only = tile_signatures(rows[:, 0])
    assert lead_only == [(4, 0), (4, 1), (4, 2)]       # positional only
    deep = tile_signatures(rows[:, 0], deep=rows)
    # distinct prefixes -> distinct identities; the repeat of (4,(7,2))
    # restarts its own run count instead of inheriting position 2
    assert deep == [(4, (7, 2), 0), (4, (11, 5), 0), (4, (7, 2), 0)]
    # identical consecutive working sets still coalesce by run index
    same = np.array([[4, 7, 2], [4, 7, 2]])
    assert tile_signatures(same[:, 0], deep=same) == [
        (4, (7, 2), 0), (4, (7, 2), 1)]


# ---------------------------------------------------------------------------
# incremental plans (plan_reuse)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("exec_mode", ["grouped", "clustered"])
def test_plan_reuse_bitwise_and_stats(rairs_index, unit_data, exec_mode):
    """Split-pipeline results == fresh monolithic plans; repeated batches
    hit the plan cache and stats surface next to compile stats."""
    _, q, _ = unit_data
    # max_scan pinned: keeps these sessions distinct from the default-
    # params sessions other test files assert fresh stats on
    rp = rairs_index.search(q[:48], k=10, nprobe=8, max_scan=4096,
                            exec_mode="paged")
    s = rairs_index.searcher(SearchParams(
        k=10, nprobe=8, max_scan=4096, exec_mode=exec_mode,
        plan_reuse=True))
    for _ in range(3):
        _assert_results_identical(rp, s(q[:48]), msg=f"{exec_mode} ")
    stats = s.compile_stats()["plan"]
    assert stats["batches"] == 3
    assert stats["hits"] > 0                      # steady state reuses
    assert stats["misses"] >= 1                   # cold cache
    assert stats["mean_union_live"] > 0
    # the dispatched width always covers the live union entries
    assert stats["mean_width"] >= stats["mean_union_live"]


def test_plan_reuse_rejects_paged():
    with pytest.raises(ValueError, match="plan_reuse"):
        SearchParams(exec_mode="paged", plan_reuse=True)


def test_plan_reuse_streaming_and_epoch_bump(small_stream, unit_data):
    """Plan cache lives on the session: mutations stale it with the
    session, and a fresh post-epoch session serves correct plans."""
    stream, x = small_stream
    _, q, _ = unit_data
    params = SearchParams(k=10, nprobe=8, exec_mode="clustered",
                          plan_reuse=True)
    s0 = stream.searcher(params)
    r0 = s0(q[:32])
    _assert_results_identical(
        stream.search(q[:32], k=10, nprobe=8, exec_mode="paged"), r0)
    assert s0.plan_stats.batches == 1

    stream.insert(x[5600:5650])                   # version bump
    with pytest.raises(StaleSessionError):
        s0(q[:32])
    s1 = stream.searcher(params)
    assert s1 is not s0 and s1.plan_stats.batches == 0   # fresh cache
    _assert_results_identical(
        stream.search(q[:32], k=10, nprobe=8, exec_mode="paged"),
        s1(q[:32]), msg="post-insert ")

    stream.compact()                              # epoch bump
    with pytest.raises(StaleSessionError):
        s1(q[:32])
    s2 = stream.searcher(params)
    assert s2.epoch == stream.epoch and s2.plan_stats.batches == 0
    _assert_results_identical(
        stream.search(q[:32], k=10, nprobe=8, exec_mode="paged"),
        s2(q[:32]), msg="post-epoch ")


def test_plan_reuse_probe_survives_capacity_jump(small_stream, unit_data):
    """The probe half consumes only base arrays: a delta capacity-bucket
    jump (which re-lowers the scan half) must reuse the compiled probe
    executable instead of paying a redundant compile."""
    stream, x = small_stream
    _, q, _ = unit_data
    params = SearchParams(k=10, nprobe=8, exec_mode="clustered",
                          plan_reuse=True)
    s0 = stream.searcher(params)
    s0(q[:32])
    before = dict(stream._probe_cache[s0.params])
    assert before                                 # probe compiled
    cap0 = stream._delta.capacity
    stream.insert(x[5500:6000])                   # 500 -> 1000 slots
    assert stream._delta.capacity > cap0          # bucket jump
    s1 = stream.searcher(params)
    _assert_results_identical(
        stream.search(q[:32], k=10, nprobe=8, exec_mode="paged"),
        s1(q[:32]), msg="post-jump ")
    after = stream._probe_cache[s1.params]
    for key, exe in before.items():
        assert after[key] is exe                  # shared, not recompiled
    assert 32 in s1.buckets                       # probe store reported


# ---------------------------------------------------------------------------
# routed delta scans
# ---------------------------------------------------------------------------
@pytest.fixture()
def routed_pair(unit_data, shared_trained):
    """Two streams over the same base corpus + churn: one exhaustive
    (huge threshold), one routed from the first insert."""
    x, _, _ = unit_data
    cents, cb = shared_trained
    streams = []
    for route_min in (10 ** 9, 0):
        cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                          kmeans_iters=8, pq_iters=6,
                          delta_route_min=route_min)
        base = build_index(jax.random.PRNGKey(0), x[:5000], cfg,
                           centroids=cents, codebook=cb)
        st = StreamingIndex(base)
        ids = st.insert(x[5000:5600])
        st.delete(ids[:64])
        st.delete(np.arange(32))
        streams.append(st)
    exhaustive, routed = streams
    assert not exhaustive.delta_routed and routed.delta_routed
    return exhaustive, routed


def test_routed_delta_bitwise_at_full_probe(routed_pair, unit_data):
    """With every list probed, routing reaches every live delta item:
    results identical to the exhaustive path (ids and distances)."""
    _, q, _ = unit_data
    exhaustive, routed = routed_pair
    re_ = exhaustive.search(q[:48], k=10, nprobe=64)
    rr = routed.search(q[:48], k=10, nprobe=64)
    np.testing.assert_array_equal(np.asarray(re_.ids), np.asarray(rr.ids))
    np.testing.assert_array_equal(np.asarray(re_.dists),
                                  np.asarray(rr.dists))
    # routing computes each reachable live slot exactly once -> identical
    # logical DCO at full probe depth
    np.testing.assert_array_equal(np.asarray(re_.approx_dco),
                                  np.asarray(rr.approx_dco))


def test_routed_delta_reduces_dco(routed_pair, unit_data):
    _, q, _ = unit_data
    exhaustive, routed = routed_pair
    de = np.asarray(exhaustive.search(q[:48], k=10, nprobe=8).approx_dco)
    dr = np.asarray(routed.search(q[:48], k=10, nprobe=8).approx_dco)
    assert dr.mean() < de.mean()


def test_routed_delta_items_retrievable(routed_pair, unit_data):
    x, _, _ = unit_data
    _, routed = routed_pair
    probe = x[5100][None, :]
    r = routed.search(probe, k=1, nprobe=16)
    assert int(np.asarray(r.ids)[0, 0]) == 5100


def test_routing_threshold_activates_on_capacity(unit_data, shared_trained):
    """Auto threshold: the delta routes only once its capacity bucket
    outgrows delta_route_min (static per-bucket property)."""
    x, _, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                      kmeans_iters=8, pq_iters=6, delta_route_min=256)
    base = build_index(jax.random.PRNGKey(0), x[:5000], cfg,
                       centroids=cents, codebook=cb)
    st = StreamingIndex(base)
    assert st.delta_route_threshold == 256
    st.insert(x[5000:5100])          # capacity 256 == threshold -> exhaustive
    assert not st.delta_routed
    st.insert(x[5100:5400])          # capacity 512 > threshold -> routed
    assert st.delta_routed
    # default: nlist * block
    st2 = StreamingIndex(build_index(
        jax.random.PRNGKey(0), x[:5000],
        dataclasses.replace(cfg, delta_route_min=None),
        centroids=cents, codebook=cb))
    assert st2.delta_route_threshold == 64 * 32
    # explicit threshold is final: sessions route even at probe depths
    # where the padded gather would be dearer than the exhaustive scan
    assert st.routes_at(64)


def test_auto_routing_cost_guard(unit_data, shared_trained):
    """Auto threshold only: a hot-list-skewed delta grows the posting
    width until the routed gather (~nprobe x post_width rows/query)
    costs more than the exhaustive scan — the session then keeps the
    exhaustive fast path, and results stay correct."""
    x, _, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                      kmeans_iters=8, pq_iters=6)          # auto threshold
    base = build_index(jax.random.PRNGKey(0), x[:5000], cfg,
                       centroids=cents, codebook=cb)
    st = StreamingIndex(base)
    rng = np.random.default_rng(7)
    hot = np.asarray(x[5000])[None, :] + rng.normal(
        0, 1e-3, (2200, x.shape[1])).astype(np.float32)    # one hot list
    st.insert(hot)
    assert st.delta_routed                  # capacity gate fires...
    assert st._delta.post_width * 8 > st._delta.capacity
    assert not st.routes_at(8)              # ...but routing would cost more
    r = st.search(np.asarray(x[5000])[None, :], k=1, nprobe=8)
    assert int(np.asarray(r.ids)[0, 0]) >= 5000    # delta item found


def test_routed_postings_follow_restore(unit_data, shared_trained,
                                        tmp_path):
    """Posting maps rebuild on bundle load: a restored routed stream
    searches identically to the in-memory one."""
    import os

    from repro.core import load_index, save_index
    x, q, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                      kmeans_iters=8, pq_iters=6, delta_route_min=0)
    base = build_index(jax.random.PRNGKey(0), x[:5000], cfg,
                       centroids=cents, codebook=cb)
    st = StreamingIndex(base)
    st.insert(x[5000:5300])
    st.delete([5005, 17])
    path = os.path.join(tmp_path, "routed.npz")
    save_index(st, path)
    restored = load_index(path)
    assert restored.delta_routed
    _assert_results_identical(st.search(q[:24], k=10, nprobe=8),
                              restored.search(q[:24], k=10, nprobe=8))


# ---------------------------------------------------------------------------
# derived per-device budget (sharded)
# ---------------------------------------------------------------------------
def test_derived_max_scan_local_recall_neutral(rairs_index, unit_data):
    """The occupancy-derived per-device budget must never truncate: same
    results as an un-truncating explicit budget, with a tighter bound."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    _, q, _ = unit_data
    params = SearchParams(k=10, nprobe=8)
    derived = rairs_index.shard(mesh).searcher(params)
    wide = rairs_index.shard(mesh, max_scan_local=4096).searcher(params)
    _assert_results_identical(wide(q[:32]), derived(q[:32]))
    assert derived.max_scan_local <= derived.params.max_scan
    bound = rairs_index.shard(mesh).derived_max_scan_local(8)
    assert derived.max_scan_local == min(derived.params.max_scan, bound)


def test_sharded_rejects_plan_reuse(rairs_index):
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    with pytest.raises(ValueError, match="plan_reuse"):
        rairs_index.shard(mesh).searcher(
            SearchParams(exec_mode="clustered", plan_reuse=True))


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------
@pytest.fixture()
def small_stream(unit_data, shared_trained):
    x, _, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True,
                      kmeans_iters=8, pq_iters=6)
    base = build_index(jax.random.PRNGKey(0), x[:5000], cfg,
                       centroids=cents, codebook=cb)
    stream = StreamingIndex(base)
    ids = stream.insert(x[5000:5500])
    stream.delete(ids[:40])
    stream.delete(np.arange(20))
    return stream, x


# ---------------------------------------------------------------------------
# property test: plan equivalence across modes/paths (needs hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       nprobe=st.sampled_from([2, 4, 8, 16]),
       exec_mode=st.sampled_from(["grouped", "clustered"]),
       query_tile=st.sampled_from([1, 4, 8, 16]),
       mutate=st.booleans())
def test_plan_equivalence_property(seed, nprobe, exec_mode, query_tile,
                                   mutate):
    """Clustered + incremental plans == fresh batch-wide plans, bitwise,
    across exec modes and frozen/streaming/sharded paths — including
    across a mutation epoch bump (stale plan caches must die with their
    sessions)."""
    from repro.data import make_dataset
    x, q, _ = make_dataset("unit")
    rng = np.random.default_rng(seed)
    qs = jnp.asarray(np.asarray(q)[rng.choice(len(q), 32, replace=False)])
    cfg = IndexConfig(nlist=32, strategy="rair", seil=True,
                      kmeans_iters=4, pq_iters=4, delta_route_min=64)
    base = build_index(jax.random.PRNGKey(0), jnp.asarray(x[:2000]), cfg)
    params = SearchParams(k=10, nprobe=nprobe, exec_mode=exec_mode,
                          query_tile=query_tile, plan_reuse=True)
    paged = dataclasses.replace(params, exec_mode="paged",
                                plan_reuse=False)

    index = base.streaming() if mutate else base
    if mutate:
        ids = index.insert(x[2000:2000 + int(rng.integers(50, 300))])
        index.delete(ids[:10])
        index.delete(rng.choice(2000, 25, replace=False))

    ref = index.searcher(paged)(qs)
    sess = index.searcher(params)
    for _ in range(2):                       # second pass rides the cache
        _assert_results_identical(ref, sess(qs), msg="single-host ")

    if mutate:                               # epoch bump invalidates plans
        index.compact()
        with pytest.raises(StaleSessionError):
            sess(qs)
        ref2 = index.searcher(paged)(qs)
        _assert_results_identical(ref2, index.searcher(params)(qs),
                                  msg="post-compact ")
    else:                                    # frozen path rides a mesh too
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        sharded = index.shard(mesh)
        rs = sharded.searcher(dataclasses.replace(params, plan_reuse=False)
                              )(qs)
        if sharded.ndev == 1:
            _assert_results_identical(ref, rs, msg="sharded ")
