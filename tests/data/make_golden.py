"""Regenerate the golden persistence fixtures (run from the repo root):

    PYTHONPATH=src python tests/data/make_golden.py

Writes ``golden_v1.npz`` (a frozen pre-streaming bundle, exactly "v2
without the streaming section" with ``format_version: 1``) and
``golden_v2.npz`` (a StreamingIndex bundle with a live delta segment
and tombstones).  tests/test_io_compat.py asserts these keep loading
unchanged — the back-compat contract of every later format bump (the
v3 sharded manifest included).

The fixtures are intentionally tiny (a 96x8 corpus, nlist=4) so they
stay a few KB in git.  Do NOT regenerate them casually: the whole point
is that bundles written by *old* builds keep loading; regeneration is
only legitimate when a fixture itself was produced by a buggy writer.
"""
import json
import os

import jax
import numpy as np

from repro.core import IndexConfig, StreamConfig, build_index, save_index

HERE = os.path.dirname(os.path.abspath(__file__))


def build_tiny_index():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(4, 8)).astype(np.float32) * 3.0
    x = (centers[rng.integers(0, 4, 96)]
         + rng.normal(size=(96, 8)).astype(np.float32) * 0.4)
    cfg = IndexConfig(nlist=4, block=8, strategy="rair", seil=True,
                      kmeans_iters=4, pq_iters=4, n_cands=3)
    return build_index(jax.random.PRNGKey(0), x.astype(np.float32), cfg), x


def rewrite_version(path: str, version: int) -> None:
    """Rewrite the embedded meta's format_version (to forge a v1 bundle
    exactly as the pre-streaming writer produced it)."""
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(bytes(arrays["meta_json"].tobytes()).decode("utf-8"))
    meta["format_version"] = version
    arrays["meta_json"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), np.uint8)
    with open(path, "wb") as fh:
        np.savez_compressed(fh, **arrays)


def main():
    idx, x = build_tiny_index()
    v1 = os.path.join(HERE, "golden_v1.npz")
    save_index(idx, v1, extra={"fixture": "golden_v1"})
    rewrite_version(v1, 1)

    stream = idx.streaming(StreamConfig(delta_pad=16))
    rng = np.random.default_rng(1)
    ids = stream.insert(x[:12] + rng.normal(size=(12, 8)).astype(np.float32)
                        * 0.05)
    stream.delete(ids[:3])
    stream.delete([2, 7, 11])
    v2 = os.path.join(HERE, "golden_v2.npz")
    save_index(stream, v2, extra={"fixture": "golden_v2"})

    # v4: the same streaming bundle with both compact planes attached
    # (quant ladder, DESIGN.md §12).  Planes are attached only AFTER the
    # v2 save so the v1/v2 bytes stay exactly what the old writers
    # produced — a plane-free save must remain byte-identical v2.
    idx.plane("pq4")
    idx.plane("binary")
    v4 = os.path.join(HERE, "golden_v4.npz")
    save_index(stream, v4, extra={"fixture": "golden_v4"})
    for p in (v1, v2, v4):
        print(f"{p}: {os.path.getsize(p)} bytes")


if __name__ == "__main__":
    main()
