"""Dry-run / roofline methodology tests (host-scale, 1 device).

Validates on tiny configs exactly what the 512-device dry-run relies on:
  * unrolled lowering gives exact FLOP totals (scanned lowering counts
    while bodies once);
  * plan_cell produces lowerable plans for every shape kind;
  * collective-HLO parsing finds the expected op kinds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.runtime_flags import unrolled
from repro.models.transformer import abstract_params, train_loss
from repro.launch.mesh import make_host_mesh
from repro.launch.shapes import plan_cell, skip_reason


def test_unrolled_cost_analysis_exact():
    """Scan vs unroll: unrolled flops ~= trip_count x body flops."""
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w, unroll=False)
        return h.sum()

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    scanned = jax.jit(f).lower(x, w).cost_analysis()["flops"]

    def fu(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w, unroll=True)
        return h.sum()
    unrolled_f = jax.jit(fu).lower(x, w).cost_analysis()["flops"]
    true = 12 * 2 * 64 * 128 * 128
    assert abs(unrolled_f - true) / true < 0.01
    assert scanned < true / 5    # the undercount we correct for


def test_unroll_flag_changes_model_lowering():
    # many layers + tiny vocab so the layer scan dominates total FLOPs
    cfg = dataclasses.replace(ARCHS["qwen3-1.7b"].reduced(),
                              n_layers=8, vocab=64)
    params = abstract_params(cfg)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}

    # distinct callables: jax caches traces per function object, and the
    # unroll flag is consulted at trace time (the cost pass runs in a
    # fresh process so this only matters for in-process A/B like here)
    def loss_a(p, b):
        return train_loss(p, cfg, b, remat=False)

    def loss_b(p, b):
        return train_loss(p, cfg, b, remat=False)

    base = jax.jit(loss_a).lower(params, batch).cost_analysis()["flops"]
    with unrolled():
        full = jax.jit(loss_b).lower(params, batch).cost_analysis()["flops"]
    # 8 layers -> unrolled total is several x the once-counted scan body
    assert full > base * 1.5


@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k",
                                   "long_500k"])
def test_plan_cell_lowers_reduced(shape):
    """Every shape kind's plan must trace/lower on a tiny arch + host mesh
    (full sizes are exercised by the real dry-run)."""
    import repro.launch.shapes as shp
    import repro.configs as cfgs
    arch = "qwen3-1.7b"
    tiny = dataclasses.replace(
        ARCHS[arch].reduced(), name=arch)  # keep registry key semantics
    # shrink the shape table for the host
    old_shapes = dict(shp.SHAPES)
    old_arch = cfgs.ARCHS[arch]
    shp.SHAPES = {shape: {**old_shapes[shape],
                          "seq_len": 64, "global_batch": 4}}
    shp.ARCHS = dict(shp.ARCHS)
    shp.ARCHS[arch] = tiny
    try:
        kcfg = dataclasses.replace(shp.LONG_KNN_CFG, nlist=8, nprobe=2,
                                   block=8, max_blocks_per_list=4, window=8)
        mesh = make_host_mesh()
        plan = shp.plan_cell(arch, shape, mesh, accum=2, knn_cfg=kcfg)
        lowered = jax.jit(plan.step_fn).lower(*plan.args)
        assert lowered is not None
        assert plan.mode in ("train", "prefill", "decode", "rairs_knn",
                             "ssm_long")
    finally:
        shp.SHAPES = old_shapes
        shp.ARCHS[arch] = old_arch


def test_skip_policy():
    assert skip_reason("hubert-xlarge", "decode_32k")
    assert skip_reason("hubert-xlarge", "long_500k")
    assert skip_reason("hubert-xlarge", "train_4k") is None
    assert skip_reason("mamba2-2.7b", "long_500k") is None


def test_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
      %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={}
      %ar = bf16[1024]{0} all-reduce(%y), to_apply=%sum
      %rs = f32[8,8]{1,0} reduce-scatter(%z)
    """
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 1024 * 2
    assert out["reduce-scatter"] == 64 * 4


def test_dryrun_results_complete():
    """If the real dry-run artifacts exist, assert the required matrix:
    every (arch x shape x mesh) is ok or explicitly skipped."""
    import json
    import os
    d = os.path.join(os.path.dirname(__file__), "..", "launch_results",
                     "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("full dry-run artifacts not present")
    from repro.configs import ARCHS as A
    from repro.configs.base import SHAPES as S
    for arch in A:
        for shape in S:
            for pod in ("pod1", "pod2"):
                p = os.path.join(d, f"{arch}__{shape}__{pod}.json")
                assert os.path.exists(p), p
                rec = json.load(open(p))
                assert rec["status"] in ("ok", "skipped"), \
                    (arch, shape, pod, rec.get("error", "")[-300:])
                if rec["status"] == "skipped":
                    assert skip_reason(arch, shape)
