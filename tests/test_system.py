"""End-to-end system behaviour tests for RAIRS (paper-level claims at
unit scale) + insert/delete lifecycle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (IndexConfig, build_index, dco_summary, ground_truth,
                        insert_batch, recall_at_k)
from repro.core.seil import build_id_map, delete_ids


def test_end_to_end_recall_dco_tradeoff(rairs_index, unit_data):
    _, q, gt = unit_data
    prev_dco = 0
    for p in (2, 8, 32):
        r = rairs_index.search(q, k=10, nprobe=p, k_factor=20)
        s = dco_summary(r)
        assert s["approx_dco"] > prev_dco
        prev_dco = s["approx_dco"]
    assert recall_at_k(np.asarray(r.ids), gt) > 0.95


def test_strategies_all_build_and_search(unit_data, shared_trained):
    x, q, gt = unit_data
    cents, cb = shared_trained
    for strat in ("single", "naive", "soar", "rair", "srair"):
        for seil in ((False,) if strat == "single" else (False, True)):
            cfg = IndexConfig(nlist=64, strategy=strat, seil=seil)
            idx = build_index(jax.random.PRNGKey(0), x, cfg,
                              centroids=cents, codebook=cb)
            r = idx.search(q[:128], k=10, nprobe=8)
            rec = recall_at_k(np.asarray(r.ids), gt[:128])
            assert rec > 0.5, (strat, seil, rec)
            assert not np.isnan(np.asarray(r.dists)).any()


def test_insert_batch_preserves_and_extends(unit_data, shared_trained):
    x, q, gt = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True)
    n0 = 5000
    idx = build_index(jax.random.PRNGKey(0), x[:n0], cfg, centroids=cents,
                      codebook=cb)
    idx2 = insert_batch(idx, x[n0:])
    assert idx2.vectors.shape[0] == x.shape[0]
    r = idx2.search(q, k=10, nprobe=16)
    assert recall_at_k(np.asarray(r.ids), gt) > 0.85
    # inserted ids must be retrievable: query at an inserted point
    probe = x[n0 + 7][None, :]
    r2 = idx2.search(probe, k=1, nprobe=16)
    assert int(np.asarray(r2.ids)[0, 0]) == n0 + 7


def test_delete_then_search_excludes(unit_data, rairs_index):
    x, q, _ = unit_data
    probe = x[42][None, :]
    r = rairs_index.search(probe, k=1, nprobe=16)
    assert int(np.asarray(r.ids)[0, 0]) == 42
    id_map = build_id_map(rairs_index.arrays)
    with pytest.warns(DeprecationWarning, match="StreamingIndex.delete"):
        arrays2 = delete_ids(rairs_index.arrays, id_map, [42])
    idx2 = dataclasses.replace(rairs_index, arrays=arrays2)
    r2 = idx2.search(probe, k=1, nprobe=16)
    assert int(np.asarray(r2.ids)[0, 0]) != 42


def test_multi_assignment_builds(unit_data, shared_trained):
    x, q, gt = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="srair", seil=False, multi_m=3,
                      aggr="max")
    idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                      codebook=cb)
    assert idx.assigns.shape[1] == 3
    r = idx.search(q[:128], k=10, nprobe=4)
    assert recall_at_k(np.asarray(r.ids), gt[:128]) > 0.5


def test_multi_assignment_recall_beats_rair_baseline(unit_data,
                                                     shared_trained):
    """End-to-end m-assignment (paper Fig. 14): at low nprobe a 3-assigned
    index must reach at least the recall of the 2-assignment RAIR
    baseline (extra redundancy -> better probe coverage)."""
    x, q, gt = unit_data
    cents, cb = shared_trained
    rec = {}
    for name, cfg in (
        ("rair", IndexConfig(nlist=64, strategy="rair", seil=True)),
        ("m3", IndexConfig(nlist=64, strategy="srair", seil=False,
                           multi_m=3, aggr="max")),
    ):
        idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                          codebook=cb)
        rec[name] = {p: recall_at_k(
            np.asarray(idx.search(q, k=10, nprobe=p).ids), gt)
            for p in (2, 4)}
    # measured margins on this corpus: +0.05 at nprobe=2, +0.01 at nprobe=4
    assert rec["m3"][2] >= rec["rair"][2], rec
    assert rec["m3"][4] >= rec["rair"][4] - 0.005, rec


def test_inner_product_metric():
    from repro.data import make_dataset
    x, q, spec = make_dataset("unit_ip")
    cfg = IndexConfig(nlist=64, strategy="soar", seil=True, metric="ip")
    idx = build_index(jax.random.PRNGKey(0), x, cfg)
    gt = ground_truth(x, q, 10, metric="ip")
    r = idx.search(q, k=10, nprobe=16)
    assert recall_at_k(np.asarray(r.ids), gt) > 0.7
