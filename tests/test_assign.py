"""Unit + property tests for AIR / SOAR / NaiveRA assignment (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.core.assign import (candidate_lists, rair_assign,
                               rair_assign_multi, single_assign)


def _geometry_case():
    """x at origin; c1 nearest at (1,0); c_orth at (0,1.05); c_inv at
    (-1.1,0); c_near at (0.0, 1.5) filler.  AIR must pick the inverse
    centroid, SOAR the orthogonal one, Naive the 2nd-nearest (Fig. 2)."""
    d = 8
    x = np.zeros((1, d), np.float32)
    c1 = np.zeros(d, np.float32); c1[0] = 1.0
    c_orth = np.zeros(d, np.float32); c_orth[1] = 1.05
    c_inv = np.zeros(d, np.float32); c_inv[0] = -1.1
    c_far = np.full(d, 2.0, np.float32)
    cents = np.stack([c1, c_orth, c_inv, c_far])
    return jnp.asarray(x), jnp.asarray(cents)


def test_air_prefers_inverse_residual():
    x, c = _geometry_case()
    a = rair_assign(x, c, metric="air", lam=0.5, n_cands=4, strict=True)
    assert set(np.asarray(a[0]).tolist()) == {0, 2}  # primary + inverse


def test_soar_prefers_orthogonal_residual():
    x, c = _geometry_case()
    a = rair_assign(x, c, metric="soar", lam=1.0, n_cands=4, strict=True)
    assert set(np.asarray(a[0]).tolist()) == {0, 1}  # primary + orthogonal


def test_naive_picks_second_nearest():
    x, c = _geometry_case()
    a = rair_assign(x, c, metric="naive", n_cands=4, strict=True)
    assert set(np.asarray(a[0]).tolist()) == {0, 1}  # 1.05 < 1.1


def test_air_lambda_zero_degenerates_to_naive(unit_data):
    x, _, _ = unit_data
    x = x[:512]
    c = x[::8][:32]
    a_air = rair_assign(x, c, metric="air", lam=0.0, n_cands=8, strict=True)
    a_nai = rair_assign(x, c, metric="naive", n_cands=8, strict=True)
    assert np.array_equal(np.asarray(a_air), np.asarray(a_nai))


def test_rair_skip_condition():
    """RAIR keeps single assignment iff the primary list minimizes the AIR
    loss, i.e. for all c': ||r'||^2 + lam r^T r' >= (1+lam)||r||^2."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (256, 16))
    c = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    lam = 0.5
    a = rair_assign(x, c, metric="air", lam=lam, n_cands=8, strict=False)
    a = np.asarray(a)
    cid, cd2 = candidate_lists(x, c, 8)
    cid, cd2 = np.asarray(cid), np.asarray(cd2)
    r = np.asarray(c)[cid] - np.asarray(x)[:, None, :]
    loss = cd2 + lam * np.einsum("nd,ncd->nc", r[:, 0], r)
    single = a[:, 0] == a[:, 1]
    best_is_primary = loss.argmin(axis=1) == 0
    assert np.array_equal(single, best_is_primary)
    # and the skip threshold identity: loss[0] == (1+lam)*||r||^2
    np.testing.assert_allclose(loss[:, 0], (1 + lam) * cd2[:, 0], rtol=1e-4)


def test_single_assign_is_nearest(unit_data):
    x, _, _ = unit_data
    x = x[:256]
    c = x[::16][:16]
    a = np.asarray(single_assign(x, c))
    d = np.linalg.norm(np.asarray(x)[:, None] - np.asarray(c)[None], axis=-1)
    assert np.array_equal(a[:, 0], d.argmin(axis=1))
    assert np.array_equal(a[:, 0], a[:, 1])


def test_multi_assign_distinct_sorted():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (128, 16))
    c = jax.random.normal(jax.random.PRNGKey(4), (32, 16))
    for aggr in ("max", "min", "avg"):
        a = np.asarray(rair_assign_multi(x, c, m=3, aggr=aggr, n_cands=10))
        assert a.shape == (128, 3)
        assert (np.diff(a, axis=1) > 0).all(), "strict m-assignment: distinct"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), lam=st.floats(0.05, 2.0),
       strict=st.booleans())
def test_property_air_argmin_optimal(seed, lam, strict):
    """The chosen secondary list minimizes the AIR loss over candidates."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (32, 8))
    c = jax.random.normal(k2, (24, 8))
    nc = 6
    a = np.asarray(rair_assign(x, c, metric="air", lam=lam, n_cands=nc,
                               strict=strict))
    cid, cd2 = map(np.asarray, candidate_lists(x, c, nc))
    r = np.asarray(c)[cid] - np.asarray(x)[:, None, :]
    loss = cd2 + lam * np.einsum("nd,ncd->nc", r[:, 0], r)
    if strict:
        loss[:, 0] = np.inf
    chosen_other = np.where(a[:, 0] == cid[:, 0], a[:, 1], a[:, 0])
    # both outputs sorted; recover the secondary as "the one != primary",
    # falling back to primary when single-assigned (non-strict)
    primary = cid[:, 0]
    sec = np.where(a[:, 1] != primary, a[:, 1],
                   np.where(a[:, 0] != primary, a[:, 0], primary))
    best = cid[np.arange(len(x)), loss.argmin(axis=1)]
    if not strict:
        best = np.where(loss.min(axis=1) >= (1 + lam) * cd2[:, 0] - 1e-5,
                        np.where(loss.argmin(axis=1) == 0, primary, best),
                        best)
    # compare losses, not ids (ties can differ)
    best_loss = loss.min(axis=1)
    sec_pos = (cid == sec[:, None]).argmax(axis=1)
    sec_loss = np.where(sec == primary, (1 + lam) * cd2[:, 0],
                        loss[np.arange(len(x)), sec_pos])
    np.testing.assert_allclose(sec_loss, np.minimum(best_loss, sec_loss),
                               rtol=1e-4, atol=1e-4)
