"""Engine-deep tracing & unified stats (src/repro/obs/, DESIGN.md §11).

Key invariants:
  * tracing disabled is the production path: the module-global work
    counter does not move across a full search dispatch (a counter
    assertion, deliberately not a timing one), ``span()`` hands back a
    shared no-op singleton, and ``fence()`` returns its argument
    untouched;
  * tracing on changes *when* the host observes device values, never
    the values — every dispatch path (monolithic, fused top-k,
    plan-reuse, sharded, streaming delta) returns bitwise-identical
    ids/dists traced vs untraced;
  * spans are well-nested per thread even under concurrent gateway
    submits (request exemplars live on separate virtual tracks);
  * the exported document is schema-valid Chrome/Perfetto trace-event
    JSON, and ``snapshot_all``/``to_prometheus`` carry the documented
    layout.
"""
import itertools
import json
import threading

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import obs
from repro.core import (IndexConfig, SearchParams, StreamConfig,
                        StreamingIndex, build_index)
from repro.gateway import Gateway, GatewayConfig
from repro.obs.tracer import _REQ_TID_BASE


@pytest.fixture(autouse=True)
def clean_tracer():
    """No tracer leaks into or out of any test, even on failure."""
    if obs.enabled():
        obs.stop()
    yield
    if obs.enabled():
        obs.stop()


def _run(searcher, q, n=32):
    res = searcher(q[:n])
    return jax.tree.map(np.asarray, res)


# ---------------------------------------------------------------------------
# zero overhead while disabled
# ---------------------------------------------------------------------------

def test_disabled_tracing_does_no_work(rairs_index, unit_data):
    _, q, _ = unit_data
    searcher = rairs_index.searcher(SearchParams(k=10, nprobe=8))
    _run(searcher, q)                       # compile outside the window
    assert not obs.enabled() and obs.tracer() is None
    w0 = obs.work_count()
    _run(searcher, q)
    assert obs.work_count() == w0           # no span, event, or fence
    # span() is a shared no-op singleton; fence() is identity
    assert obs.span("a", cat="device") is obs.span("b")
    x = np.arange(3)
    assert obs.fence(x) is x
    assert obs.work_count() == w0


# ---------------------------------------------------------------------------
# traced == untraced, bitwise, on every dispatch path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label,params,expect_spans", [
    ("paged", SearchParams(k=10, nprobe=8),
     {"stage.select_lists", "stage.plan_blocks", "stage.scan_blocks",
      "stage.finalize"}),
    ("fused", SearchParams(k=10, nprobe=8, fused_topk=True),
     {"stage.scan_blocks_topk"}),
    ("plan_reuse", SearchParams(k=10, nprobe=8, exec_mode="clustered",
                                plan_reuse=True),
     {"stage.probe_plan", "stage.merge_unions_host",
      "stage.scan_finalize"}),
])
def test_traced_results_bitwise_identical(rairs_index, unit_data, label,
                                          params, expect_spans):
    _, q, _ = unit_data
    searcher = rairs_index.searcher(params)
    ref = _run(searcher, q)
    with obs.trace():
        _run(searcher, q)                   # compile the traced stages
    with obs.trace() as tr:
        res = _run(searcher, q)
    np.testing.assert_array_equal(ref.ids, res.ids)
    np.testing.assert_array_equal(ref.dists, res.dists)
    np.testing.assert_array_equal(ref.approx_dco, res.approx_dco)
    summary = tr.stage_summary()
    assert expect_spans <= set(summary), summary.keys()
    assert "searcher.dispatch" in summary
    assert tr.fences > 0                    # device work was fenced


def test_traced_sharded_dispatch_bitwise_identical(rairs_index, unit_data):
    _, q, _ = unit_data
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    searcher = rairs_index.shard(mesh).searcher(SearchParams(k=10, nprobe=8))
    ref = _run(searcher, q)
    with obs.trace():
        _run(searcher, q)
    with obs.trace() as tr:
        res = _run(searcher, q)
    np.testing.assert_array_equal(ref.ids, res.ids)
    np.testing.assert_array_equal(ref.dists, res.dists)
    summary = tr.stage_summary()
    assert {"stage.shard_scan", "stage.gather_finalize"} <= set(summary)
    # the per-stage DCO split lands on the right stages
    assert summary["stage.shard_scan"]["counters"]["approx_dco"] > 0
    assert summary["stage.gather_finalize"]["counters"]["refine_dco"] > 0


def test_traced_streaming_delta_scan(unit_data, shared_trained):
    x, q, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True)
    base = build_index(jax.random.PRNGKey(0), x[:4000], cfg,
                       centroids=cents, codebook=cb)
    stream = StreamingIndex(base, StreamConfig(delta_pad=512))
    stream.insert(x[4000:4256])
    searcher = stream.searcher(SearchParams(k=10, nprobe=8))
    ref = _run(searcher, q)
    with obs.trace():
        _run(searcher, q)
    with obs.trace() as tr:
        res = _run(searcher, q)
    np.testing.assert_array_equal(ref.ids, res.ids)
    np.testing.assert_array_equal(ref.dists, res.dists)
    summary = tr.stage_summary()
    assert "stage.delta_scan" in summary
    assert summary["stage.delta_scan"]["counters"]["delta_dco"] > 0


# ---------------------------------------------------------------------------
# well-nesting under concurrent gateway traffic
# ---------------------------------------------------------------------------

def _assert_well_nested(records):
    by_tid = {}
    for r in records:
        if r["kind"] == "span":
            by_tid.setdefault(r["tid"], []).append(
                (r["ts"], r["ts"] + r["dur"]))
    assert by_tid
    for tid, iv in by_tid.items():
        for (s1, e1), (s2, e2) in itertools.combinations(sorted(iv), 2):
            disjoint = e1 <= s2 or e2 <= s1
            nested = (s1 <= s2 and e2 <= e1) or (s2 <= s1 and e1 <= e2)
            assert disjoint or nested, \
                f"tid {tid}: spans ({s1},{e1}) and ({s2},{e2}) interleave"


def test_spans_well_nested_under_concurrent_submits(rairs_index, unit_data):
    _, q, _ = unit_data
    errors = []

    def client(seed, gw):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(8):
                gw.search(q[int(rng.integers(0, q.shape[0]))], timeout=60.0)
        except Exception as e:                         # pragma: no cover
            errors.append(e)

    with obs.trace() as tr:
        with Gateway(rairs_index, k=10, nprobe=8,
                     config=GatewayConfig(max_batch=8,
                                          max_delay_ms=2.0)) as gw:
            threads = [threading.Thread(target=client, args=(i, gw))
                       for i in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
    assert not errors
    names = {r["name"] for r in tr.records}
    assert {"gateway.submit", "gateway.flush", "searcher.dispatch"} <= names
    _assert_well_nested(tr.records)
    # request exemplars are events on virtual tracks, outside the
    # nesting contract
    reqs = [r for r in tr.records if r["name"] == "gateway.request"]
    assert reqs and all(r["kind"] == "event" and r["tid"] >= _REQ_TID_BASE
                        for r in reqs)


# ---------------------------------------------------------------------------
# tracer contracts
# ---------------------------------------------------------------------------

def test_start_stop_contracts():
    with pytest.raises(RuntimeError):
        obs.stop()                          # nothing active
    t = obs.start()
    try:
        with pytest.raises(RuntimeError):
            obs.start()                     # no nested tracers
    finally:
        assert obs.stop() is t
    with pytest.raises(ValueError):
        obs.Tracer(sample=0)


def test_max_events_bounds_memory_and_counts_drops():
    with obs.trace(max_events=2) as tr:
        for i in range(5):
            with obs.span(f"s{i}"):
                pass
    assert len(tr.records) == 2 and tr.dropped == 3


def test_event_sampling_and_virtual_tracks():
    with obs.trace(sample=3) as tr:
        hits = [tr.sampled() for _ in range(9)]
        tr.event("gateway.request", tr.t0, 1e-3, queued_ms=0.5)
    assert hits == [True, False, False] * 3
    (ev,) = tr.records
    assert ev["kind"] == "event" and ev["tid"] >= _REQ_TID_BASE


# ---------------------------------------------------------------------------
# export: trace-event JSON + Prometheus text
# ---------------------------------------------------------------------------

def test_trace_event_export_roundtrip(tmp_path):
    with obs.trace() as tr:
        with obs.span("stage.demo", cat="device", approx_dco=3):
            with obs.span("inner"):
                pass
        tr.event("gateway.request", tr.t0, 1e-3, queued_ms=0.1)
    path = tmp_path / "trace.json"
    doc = obs.write_trace(tr, str(path))
    assert json.loads(path.read_text()) == doc
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"stage.demo", "inner",
                                       "gateway.request"}
    demo = next(e for e in xs if e["name"] == "stage.demo")
    inner = next(e for e in xs if e["name"] == "inner")
    assert demo["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= demo["ts"] + demo["dur"] + 1e-6
    assert demo["args"]["approx_dco"] == 3
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(m["name"] == "process_name" for m in metas)
    tracks = {m["args"]["name"] for m in metas if m["name"] == "thread_name"}
    assert any(n.startswith("thread-") for n in tracks)
    assert any(n.startswith("requests-") for n in tracks)
    assert doc["otherData"]["fences"] == tr.fences


def test_validate_trace_rejects_malformed():
    ok = {"traceEvents": [{"name": "a", "ph": "X", "pid": 1, "tid": 0,
                           "ts": 0.0, "dur": 1.0}]}
    assert obs.validate_trace(ok) is ok
    for bad in (
        [],                                             # not an object
        {"traceEvents": []},                            # empty
        {"traceEvents": [{"name": "a", "ph": "B",       # unsupported ph
                          "pid": 1, "tid": 0}]},
        {"traceEvents": [{"ph": "X", "pid": 1, "tid": 0,
                          "ts": 0.0, "dur": 1.0}]},     # nameless
        {"traceEvents": [{"name": "a", "ph": "X", "pid": 1, "tid": 0,
                          "ts": -1.0, "dur": 1.0}]},    # negative ts
        {"traceEvents": [{"name": "a", "ph": "X", "pid": 1, "tid": 0,
                          "ts": 0.0, "dur": 1.0, "args": 7}]},
    ):
        with pytest.raises(ValueError):
            obs.validate_trace(bad)


def test_prometheus_exposition():
    text = obs.to_prometheus({"a": {"b": 1.5, "on": True}, "c": 2,
                              "drop": ["x"], "strs": "no",
                              "name.with-dots": 7})
    lines = text.splitlines()
    assert text.endswith("\n") and lines == sorted(lines)
    assert "rairs_a_b 1.5" in lines
    assert "rairs_a_on 1" in lines
    assert "rairs_c 2" in lines
    assert "rairs_name_with_dots 7" in lines
    assert not any("drop" in ln or "strs" in ln for ln in lines)


# ---------------------------------------------------------------------------
# snapshot_all: the unified stats schema
# ---------------------------------------------------------------------------

def test_snapshot_all_schema(rairs_index, unit_data):
    _, q, _ = unit_data
    searcher = rairs_index.searcher(SearchParams(k=10, nprobe=8))
    with obs.trace():
        _run(searcher, q)                   # compile traced stages
    with obs.trace() as tr:
        _run(searcher, q)
    snap = obs.snapshot_all(searcher=searcher, tracer=tr)
    assert snap["schema_version"] == 1
    assert set(snap) == {"schema_version", "session", "hbm_model", "trace"}
    assert snap["session"]["compiles"] >= 1
    model = snap["hbm_model"]
    assert model["scan_width"] >= model["fetch"] > 0
    assert set(model["bytes_per_query"]) == {
        "unfused_scan_write", "fused_scan_write", "write_reduction_x",
        "unfused_roundtrip", "fused_roundtrip", "roundtrip_reduction_x"}
    trace = snap["trace"]
    assert 0.0 < trace["stage_attribution"] <= 1.0
    assert trace["fences"] > 0 and trace["dropped"] == 0
    assert trace["dco"]["stage.scan_blocks.approx_dco"] > 0
    assert trace["dco"]["stage.finalize.refine_dco"] > 0
    # the trace section renders to prometheus lines end-to-end
    assert "rairs_trace_stage_attribution" in obs.to_prometheus(snap)


def test_snapshot_all_with_gateway(rairs_index, unit_data):
    _, q, _ = unit_data
    with Gateway(rairs_index, k=10, nprobe=8,
                 config=GatewayConfig(max_batch=8, max_delay_ms=2.0)) as gw:
        for i in range(8):
            gw.search(q[i])
        snap = obs.snapshot_all(gateway=gw)
    assert {"schema_version", "gateway", "session", "hbm_model"} <= set(snap)
    assert snap["gateway"]["telemetry"]["counters"]["responses"] == 8
    assert "trace" not in snap              # no tracer supplied
