"""SEIL layout invariants (paper §5) — unit + hypothesis property tests."""
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.core.seil import (build_seil, build_id_map, cell_stats, delete_ids,
                             vectors_in_large_cells)


def _random_case(rng, n, nlist, m_pq=8, frac_single=0.3):
    l1 = rng.integers(0, nlist, n)
    l2 = rng.integers(0, nlist, n)
    single = rng.random(n) < frac_single
    l2 = np.where(single, l1, l2)
    assigns = np.sort(np.stack([l1, l2], 1), axis=1).astype(np.int32)
    codes = rng.integers(0, 16, (n, m_pq)).astype(np.uint8)
    ids = np.arange(n, dtype=np.int32)
    return assigns, codes, ids


def _occurrences(arrays):
    ids = np.asarray(arrays.block_ids)
    valid = ids >= 0
    return np.bincount(ids[valid], minlength=0)


def test_every_vector_stored_correct_multiplicity():
    rng = np.random.default_rng(0)
    assigns, codes, ids = _random_case(rng, 2000, 16)
    arrays, stats = build_seil(assigns, codes, ids, 16, block=32, shared=True)
    occ = _occurrences(arrays)
    # multiplicity: 1 for full-shared-block items and single-assigned items
    # in full blocks; misc items of shared cells appear twice.
    assert occ.min() >= 1 and occ.max() <= 2
    assert len(occ) == 2000
    # duplicated (non-SEIL) layout: once per distinct assigned list
    arrays2, _ = build_seil(assigns, codes, ids, 16, block=32, shared=False)
    occ2 = _occurrences(arrays2)
    expect = 1 + (assigns[:, 0] != assigns[:, 1])
    assert np.array_equal(occ2, expect)


def test_refs_point_to_other_lists_blocks():
    rng = np.random.default_rng(1)
    assigns, codes, ids = _random_case(rng, 3000, 12)
    arrays, _ = build_seil(assigns, codes, ids, 12, block=32, shared=True)
    owned = np.asarray(arrays.owned)
    refs = np.asarray(arrays.refs)
    refs_other = np.asarray(arrays.refs_other)
    block_other = np.asarray(arrays.block_other)
    owner_of = {}
    for l in range(owned.shape[0]):
        for b in owned[l]:
            if b >= 0:
                owner_of[int(b)] = l
    for l in range(refs.shape[0]):
        for b, o in zip(refs[l], refs_other[l]):
            if b < 0:
                continue
            assert owner_of[int(b)] == int(o), "ref home mismatch"
            # a referenced shared block's items carry other == this list
            assert (block_other[int(b)] == l).all()


def test_shared_blocks_are_full_and_stored_once():
    rng = np.random.default_rng(2)
    assigns, codes, ids = _random_case(rng, 4000, 8)
    arrays, stats = build_seil(assigns, codes, ids, 8, block=32, shared=True)
    owned = np.asarray(arrays.owned)
    flat = owned[owned >= 0]
    assert len(flat) == len(np.unique(flat)), "each block owned by one list"
    bids = np.asarray(arrays.block_ids)
    misc = np.asarray(arrays.misc)
    misc_set = set(misc[misc >= 0].tolist())
    for b in flat:
        if int(b) in misc_set:
            continue
        assert (bids[int(b)] >= 0).all(), "shared-cell blocks are full"


def test_memory_savings_match_cell_math():
    """SEIL item count == n + (duplicated misc items of shared cells)."""
    rng = np.random.default_rng(3)
    assigns, codes, ids = _random_case(rng, 5000, 10, frac_single=0.2)
    arrays, stats = build_seil(assigns, codes, ids, 10, block=32, shared=True)
    a = assigns
    keys = a[:, 0].astype(np.int64) * 10 + a[:, 1]
    uniq, counts = np.unique(keys, return_counts=True)
    shared_cell = (uniq // 10) != (uniq % 10)
    dup_misc = (counts % 32)[shared_cell].sum()
    assert stats.n_items_stored == 5000 + dup_misc
    _, stats2 = build_seil(assigns, codes, ids, 10, block=32, shared=False)
    n_dup = (a[:, 0] != a[:, 1]).sum()
    assert stats2.n_items_stored == 5000 + n_dup
    assert stats.logical_bytes < stats2.logical_bytes


def test_cell_stats_and_large_cell_fraction(rairs_index):
    frac = vectors_in_large_cells(rairs_index.assigns, block=32)
    sizes = cell_stats(rairs_index.assigns)["cell_sizes"]
    assert sizes.sum() == rairs_index.assigns.shape[0]
    # clustered data ⇒ strong skew: a material fraction in large cells (Fig 5)
    assert frac > 0.25


def test_delete_ids(rairs_index):
    import jax.numpy as jnp
    arrays = rairs_index.arrays
    id_map = build_id_map(arrays)
    victims = [0, 1, 2, 3, 4]
    # layout-only helper: deprecated in favour of StreamingIndex.delete
    with pytest.warns(DeprecationWarning, match="StreamingIndex.delete"):
        arrays2 = delete_ids(arrays, id_map, victims)
    ids2 = np.asarray(arrays2.block_ids)
    for v in victims:
        assert not (ids2 == v).any()
    # all other ids retained with unchanged multiplicity
    ids1 = np.asarray(arrays.block_ids)
    occ1 = np.bincount(ids1[ids1 >= 5], minlength=0)
    occ2 = np.bincount(ids2[ids2 >= 5], minlength=0)
    assert np.array_equal(occ1, occ2)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n=st.integers(50, 800),
       nlist=st.integers(2, 24), block=st.sampled_from([8, 32, 64]),
       frac=st.floats(0.0, 1.0))
def test_property_layout_invariants(seed, n, nlist, block, frac):
    rng = np.random.default_rng(seed)
    assigns, codes, ids = _random_case(rng, n, nlist, frac_single=frac)
    arrays, stats = build_seil(assigns, codes, ids, nlist, block=block,
                               shared=True)
    occ = _occurrences(arrays)
    assert len(occ) == n and occ.min() >= 1 and occ.max() <= 2
    # codes survive the layout round trip
    bids = np.asarray(arrays.block_ids)
    bcodes = np.asarray(arrays.block_codes)
    bs, ss = np.nonzero(bids >= 0)
    for b, s in zip(bs[:200], ss[:200]):
        assert np.array_equal(bcodes[b, s], codes[bids[b, s]])
    # stats bookkeeping
    assert stats.n_items_stored == int((bids >= 0).sum())
    assert stats.n_blocks == bids.shape[0]
