"""Import-or-stub layer for ``hypothesis``.

The runtime image does not ship hypothesis (it is a dev-only dep, see
requirements-dev.txt).  Importing through this module keeps every unit
test runnable while the property-based tests skip gracefully: the stub
``@given`` replaces the test body with a ``pytest.skip`` (taking no
parameters, so pytest does not go looking for fixtures named after the
strategy arguments).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def settings(*_args, **_kwargs):
        return lambda f: f

    def given(*_args, **_kwargs):
        def deco(f):
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = f.__name__
            skipper.__doc__ = f.__doc__
            return skipper
        return deco

    class _StrategiesStub:
        """Accepts any strategy constructor call; values are never used."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategiesStub()
