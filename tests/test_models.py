"""Per-architecture smoke tests (reduced configs, one forward/train step
on CPU, shape + finiteness assertions) + model-level correctness:
decode == teacher-forced prefill, SSD chunked == sequential recurrence,
RAIRS-kNN attention == exact attention at full probe coverage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.mamba2 import MambaState, mamba2_step, ssd_chunked
from repro.models.transformer import (abstract_params, decode_step,
                                      init_params, prefill, train_loss)

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(r, with_labels=True):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, r.vocab)}
    if with_labels:
        b["labels"] = jax.random.randint(KEY, (B, S), 0, r.vocab)
    if r.frontend == "frame":
        b["frames"] = jax.random.normal(KEY, (B, S, r.d_model))
    if r.frontend == "patch":
        b["patch_embeds"] = jax.random.normal(KEY, (B, S // 4, r.patch_dim))
    if r.m_rope:
        b["positions3"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    r = ARCHS[arch].reduced()
    params = init_params(KEY, r)
    loss = jax.jit(lambda p, b: train_loss(p, r, b))(params, _batch(r))
    assert jnp.isfinite(loss), (arch, loss)
    assert 3.0 < float(loss) < 12.0  # ~ln(vocab) at init
    # one forward (prefill) with shape checks
    logits, cache = prefill(params, r, _batch(r, with_labels=False),
                            cache_slack=2)
    assert logits.shape == (B, 1, r.vocab)
    assert jnp.isfinite(logits).all()
    if r.has_decode:
        l2, c2 = decode_step(params, r, cache,
                             jnp.zeros((B, 1), jnp.int32))
        assert l2.shape == (B, 1, r.vocab)
        assert jnp.isfinite(l2).all()
        assert int(c2["len"][0]) == S + 1


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma-2b", "qwen2-vl-7b",
                                  "jamba-1.5-large-398b", "mamba2-2.7b"])
def test_decode_matches_teacher_forcing(arch):
    r = dataclasses.replace(ARCHS[arch].reduced(), capacity_factor=8.0)
    params = init_params(KEY, r)
    batch = _batch(r, with_labels=False)
    logits_full, _ = prefill(params, r, batch)
    short = {k: (v[:, :, :S - 1] if v.ndim == 3 and v.shape[0] == 3
                 else (v[:, :S - 1] if v.shape[1] == S else v))
             for k, v in batch.items()}
    _, cache = prefill(params, r, short, cache_slack=2)
    logits_dec, _ = decode_step(params, r, cache,
                                batch["tokens"][:, S - 1:S])
    a, b = np.asarray(logits_full[:, 0]), np.asarray(logits_dec[:, 0])
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert err < 0.05, (arch, err)
    # and the same next-token argmax
    assert (a.argmax(-1) == b.argmax(-1)).mean() > 0.9


def test_ssd_chunked_equals_sequential():
    b, s, h, p, n = 2, 37, 4, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.random.normal(ks[1], (b, s, h))
    A_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    Bm = jax.random.normal(ks[2], (b, s, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, n)) * 0.5
    D = jnp.ones((h,))
    y_c, h_c = ssd_chunked(x, dt, A_log, Bm, C, D, chunk=8)
    hs = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y_t, hs = mamba2_step(x[:, t], MambaState(h=hs, conv=None),
                              dt[:, t], A_log, Bm[:, t], C[:, t], D)
        ys.append(y_t)
    y_s = jnp.stack(ys, axis=1)
    assert float(jnp.abs(y_c - y_s).max() / jnp.abs(y_s).max()) < 2e-2
    assert float(jnp.abs(h_c - hs).max() / jnp.abs(hs).max()) < 2e-2


def test_moe_routing_exact_topk():
    from repro.models.moe import route_topk
    t, e, k, cap = 64, 8, 2, 64  # no overflow
    logits = jax.random.normal(KEY, (t, e))
    slot_token, slot_gate, load = route_topk(logits, k, cap)
    probs = jax.nn.softmax(logits, -1)
    topg, topi = jax.lax.top_k(probs, k)
    topg = topg / topg.sum(-1, keepdims=True)
    # every (token, expert) routed pair appears exactly once w/ right gate
    got = {}
    st, sg = np.asarray(slot_token), np.asarray(slot_gate)
    for ei in range(e):
        for c in range(cap):
            if st[ei, c] >= 0:
                got[(int(st[ei, c]), ei)] = sg[ei, c]
    for ti in range(t):
        for j in range(k):
            key = (ti, int(topi[ti, j]))
            assert key in got
            np.testing.assert_allclose(got[key], float(topg[ti, j]),
                                       rtol=1e-5)
    assert len(got) == t * k


def test_rairs_knn_attention_full_probe_equals_exact():
    """With nprobe == nlist (+ window covering the tail) the RAIRS-kNN
    paged attention must reproduce exact softmax attention: redundant
    assignment + SEIL dedup never double-counts a key."""
    from repro.models.retrieval import (KnnAttnConfig, build_knn_cache,
                                        rairs_attention_decode)
    b, s, kvh, hd, h = 1, 256, 2, 16, 4
    ks = jax.random.split(KEY, 4)
    keys = np.asarray(jax.random.normal(ks[0], (b, s, kvh, hd)))
    vals = np.asarray(jax.random.normal(ks[1], (b, s, kvh, hd)))
    kcfg = KnnAttnConfig(nlist=8, nprobe=8, block=16,
                         max_blocks_per_list=32, window=16)
    cache = build_knn_cache(keys, vals, kcfg)
    q = jax.random.normal(ks[2], (b, 1, h, hd))
    kv_len = jnp.array([s], jnp.int32)
    out = rairs_attention_decode(q, cache, kv_len, kcfg)
    # exact reference over all keys (window is empty: kv_len counts only
    # clustered keys here, window buffer zeros are masked by wmask=0 len)
    qg = np.asarray(q)[:, 0].reshape(b, kvh, h // kvh, hd)
    scale = 1.0 / np.sqrt(hd)
    sc = np.einsum("bgrd,bsgd->bgrs", qg * scale, keys)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bgrs,bsgd->bgrd", p, vals).reshape(b, 1, h, hd)
    err = np.abs(np.asarray(out, np.float32) - ref).max() / np.abs(ref).max()
    assert err < 0.05, err


def test_knn_attention_subsets_with_lower_nprobe():
    """Lower nprobe = fewer keys attended; output stays finite and close
    to exact when probes cover the hot lists."""
    from repro.models.retrieval import (KnnAttnConfig, build_knn_cache,
                                        rairs_attention_decode)
    b, s, kvh, hd, h = 1, 256, 2, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    keys = np.asarray(jax.random.normal(ks[0], (b, s, kvh, hd)))
    vals = np.asarray(jax.random.normal(ks[1], (b, s, kvh, hd)))
    kcfg = KnnAttnConfig(nlist=8, nprobe=3, block=16,
                         max_blocks_per_list=32, window=16)
    cache = build_knn_cache(keys, vals, kcfg)
    q = jax.random.normal(ks[2], (b, 1, h, hd))
    out = rairs_attention_decode(q, cache, jnp.array([s], jnp.int32), kcfg)
    assert jnp.isfinite(out).all()
