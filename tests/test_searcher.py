"""Searcher sessions (compiled-plan search API), index persistence, and
the strategy registry — the PR-2 public-API surface.

Key invariants: a session is bitwise-identical to the legacy kwarg path
in both exec modes (even when the batch pads up to a bucket), repeated
batches hit cached executables with zero new compilations, and a
save/load round-trip returns an index whose results match in-memory."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (IndexConfig, SearchParams, Searcher, build_index,
                        insert_batch, load_index, register_strategy,
                        save_index, single_assign)
from repro.core.assign import STRATEGY_REGISTRY, available_strategies
from repro.core.io import INDEX_FORMAT_VERSION
from repro.core.search import seil_search


def _legacy_search(index, queries, *, k, nprobe, k_factor=10, max_scan=None,
                   exec_mode="paged", use_kernel=False, query_tile=8):
    """The pre-session kwarg path: a direct jit call at the exact batch
    shape (what RairsIndex.search compiled before searcher sessions)."""
    if max_scan is None:
        max_scan = index.default_max_scan(nprobe)
    return seil_search(
        index.arrays, index.centroids, index.codebook, index.vectors,
        queries, nprobe=nprobe, bigk=k * k_factor, k=k, max_scan=max_scan,
        metric=index.config.metric, dedup_results=index.needs_result_dedup,
        use_kernel=use_kernel, oversample=index.result_oversample,
        exec_mode=exec_mode, query_tile=query_tile)


def _assert_results_identical(ra, rb):
    for field in ra._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ra, field)), np.asarray(getattr(rb, field)),
            err_msg=field)


# ---------------------------------------------------------------------------
# Searcher sessions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("exec_mode", ["paged", "grouped"])
def test_searcher_bitwise_matches_legacy_path(rairs_index, unit_data,
                                              exec_mode):
    """B=48 pads to the 64 bucket — results must still be bitwise equal
    to the exact-shape legacy jit path (acceptance criterion)."""
    _, q, _ = unit_data
    qs = q[:48]
    searcher = rairs_index.searcher(
        SearchParams(k=10, nprobe=8, exec_mode=exec_mode))
    res = searcher(qs)
    legacy = _legacy_search(rairs_index, qs, k=10, nprobe=8,
                            exec_mode=exec_mode)
    _assert_results_identical(res, legacy)
    assert searcher.stats.padded_rows == 16


def test_searcher_zero_recompiles_after_warmup(rairs_index, unit_data):
    """Repeated batches of one shape never compile again (acceptance)."""
    _, q, _ = unit_data
    searcher = Searcher(rairs_index, SearchParams(k=10, nprobe=4))
    searcher(q[:32])
    compiles_after_warmup = searcher.stats.compiles
    assert compiles_after_warmup == 1
    for _ in range(3):
        searcher(q[:32])
    assert searcher.stats.compiles == compiles_after_warmup  # zero new
    assert searcher.stats.cache_hits == 3
    assert searcher.stats.calls == 4


def test_searcher_bucket_dispatch_shares_executables(rairs_index, unit_data):
    """Different batch sizes under one power-of-two bucket share one
    executable; a bigger batch adds exactly one more."""
    _, q, _ = unit_data
    searcher = Searcher(rairs_index, SearchParams(k=10, nprobe=4))
    for bs in (3, 5, 8, 7):                      # all fit the 8 bucket
        searcher(q[:bs])
    assert searcher.buckets == (4, 8)            # 3 -> 4, rest -> 8
    assert searcher.stats.compiles == 2
    searcher(q[:9])                              # new 16 bucket
    assert searcher.buckets == (4, 8, 16)
    assert searcher.stats.compiles == 3


def test_searcher_chunks_oversize_batches(rairs_index, unit_data):
    """Batches above the largest bucket are chunked and re-merged."""
    _, q, _ = unit_data
    searcher = rairs_index.searcher(
        SearchParams(k=10, nprobe=4, batch_buckets=(64,)))
    res = searcher(q[:150])                      # 64 + 64 + pad(22 -> 64)
    assert np.asarray(res.ids).shape == (150, 10)
    assert searcher.stats.compiles == 1
    assert searcher.stats.dispatches == 3
    legacy = _legacy_search(rairs_index, q[:150], k=10, nprobe=4)
    _assert_results_identical(res, legacy)


def test_index_search_wrapper_reuses_sessions(rairs_index, unit_data):
    """The kwarg path is a thin wrapper: identical kwargs -> one cached
    session, so repeat calls are compile-free."""
    _, q, _ = unit_data
    r1 = rairs_index.search(q[:16], k=10, nprobe=4)
    cache = rairs_index._searcher_cache
    key = SearchParams(k=10, nprobe=4)
    assert key in cache
    compiles = cache[key].stats.compiles
    r2 = rairs_index.search(q[:16], k=10, nprobe=4)
    assert cache[key].stats.compiles == compiles
    _assert_results_identical(r1, r2)


def test_searcher_rejects_bad_query_shapes(rairs_index, unit_data):
    _, q, _ = unit_data
    searcher = rairs_index.searcher(SearchParams(k=10, nprobe=4))
    with pytest.raises(ValueError, match="empty query batch"):
        searcher(q[:0])
    with pytest.raises(ValueError, match=r"\(B, D\)"):
        searcher(q[0])


def test_search_params_validation():
    with pytest.raises(ValueError):
        SearchParams(k=0)
    with pytest.raises(ValueError):
        SearchParams(nprobe=0)
    with pytest.raises(ValueError):
        SearchParams(exec_mode="vectorized")
    with pytest.raises(ValueError):
        SearchParams(max_scan=0)
    with pytest.raises(ValueError):
        SearchParams(batch_buckets=(8, 4))       # not ascending
    with pytest.raises(ValueError):
        SearchParams(query_tile=0)


def test_search_params_resolve_pins_max_scan(rairs_index):
    p = SearchParams(k=10, nprobe=8)
    r = p.resolve(rairs_index)
    assert r.max_scan == rairs_index.default_max_scan(8)
    assert SearchParams(k=10, nprobe=8, max_scan=7).resolve(rairs_index).max_scan == 7
    with pytest.raises(ValueError):
        SearchParams(nprobe=10_000).resolve(rairs_index)


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------
def test_save_load_roundtrip_identical_results(rairs_index, unit_data,
                                               tmp_path):
    """load_index(save_index(x)) searches bitwise like the in-memory
    index (acceptance criterion)."""
    _, q, _ = unit_data
    path = os.path.join(tmp_path, "idx.npz")
    save_index(rairs_index, path)
    restored = load_index(path)
    assert restored.config == rairs_index.config
    assert restored.stats == rairs_index.stats
    np.testing.assert_array_equal(restored.assigns, rairs_index.assigns)
    for mode in ("paged", "grouped"):
        ra = rairs_index.search(q[:40], k=10, nprobe=8, exec_mode=mode)
        rb = restored.search(q[:40], k=10, nprobe=8, exec_mode=mode)
        _assert_results_identical(ra, rb)


def test_loaded_index_supports_insert(rairs_index, unit_data, tmp_path):
    """The bundle keeps assigns + cached codes, so append works post-load."""
    x, q, _ = unit_data
    path = os.path.join(tmp_path, "idx.npz")
    save_index(rairs_index, path)
    restored = load_index(path)
    grown = insert_batch(restored, x[:100])
    assert grown.vectors.shape[0] == rairs_index.vectors.shape[0] + 100
    r = grown.search(q[:8], k=10, nprobe=8)
    assert not np.isnan(np.asarray(r.dists)).any()


def test_load_rejects_wrong_version_and_garbage(rairs_index, tmp_path):
    import json
    path = os.path.join(tmp_path, "idx.npz")
    save_index(rairs_index, path)
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(bytes(arrays["meta_json"].tobytes()).decode())
    meta["format_version"] = INDEX_FORMAT_VERSION + 1
    arrays["meta_json"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    bad = os.path.join(tmp_path, "bad.npz")
    with open(bad, "wb") as f:
        np.savez(f, **arrays)
    with pytest.raises(ValueError, match="format_version"):
        load_index(bad)

    not_index = os.path.join(tmp_path, "not_index.npz")
    with open(not_index, "wb") as f:
        np.savez(f, a=np.zeros(3))
    with pytest.raises(ValueError):
        load_index(not_index)


# ---------------------------------------------------------------------------
# Strategy registry + IndexConfig validation
# ---------------------------------------------------------------------------
def test_registry_has_paper_presets():
    assert available_strategies() == ("naive", "rair", "single", "soar",
                                      "srair")


def test_register_custom_strategy_builds_and_searches(unit_data,
                                                      shared_trained):
    """A user-registered strategy is a first-class IndexConfig citizen."""
    x, q, _ = unit_data
    cents, cb = shared_trained
    name = "test_reverse_single"

    @register_strategy(name)
    def _reverse(x_, centroids, cfg):
        a = np.asarray(single_assign(x_, centroids))
        return a[:, ::-1].copy() if a.shape[1] > 1 else a

    try:
        cfg = IndexConfig(nlist=64, strategy=name, seil=False)
        idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                          codebook=cb)
        r = idx.search(q[:16], k=5, nprobe=8)
        assert np.asarray(r.ids).shape == (16, 5)
        with pytest.raises(ValueError, match="already registered"):
            register_strategy(name)(_reverse)
    finally:
        del STRATEGY_REGISTRY[name]


def test_index_config_validates_at_construction():
    with pytest.raises(ValueError, match="strategy"):
        IndexConfig(strategy="does_not_exist")
    with pytest.raises(ValueError, match="metric"):
        IndexConfig(metric="cosine")
    with pytest.raises(ValueError, match="nbits"):
        IndexConfig(nbits=9)
    with pytest.raises(ValueError, match="block"):
        IndexConfig(block=0)
    with pytest.raises(ValueError, match="multi_m"):
        IndexConfig(multi_m=1)
    with pytest.raises(ValueError, match="aggr"):
        IndexConfig(aggr="median")
    with pytest.raises(ValueError, match="nlist"):
        IndexConfig(nlist=0)
    # the old path only asserted inside build_index; now construction fails
    IndexConfig(strategy="rair", metric="ip", nbits=8)  # valid combos pass


def test_save_index_extra_meta_roundtrips(rairs_index, tmp_path):
    from repro.core import read_index_meta
    path = os.path.join(tmp_path, "idx.npz")
    save_index(rairs_index, path, extra={"dataset": "unit"})
    meta = read_index_meta(path)
    assert meta["extra"] == {"dataset": "unit"}
    assert meta["config"]["strategy"] == rairs_index.config.strategy


def test_distributed_rejects_unsupported_params(rairs_index, unit_data):
    """The shard_map path must refuse SearchParams fields it would
    otherwise silently drop, and still require nprobe/k without params.
    use_kernel is no longer one of them: the serve step routes the scan
    through the (interpret-mode on CPU) Pallas kernels since the fused
    top-k work, so it must serve rather than raise."""
    from repro.core.distributed import distributed_search
    _, q, _ = unit_data
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    base = distributed_search(rairs_index, mesh, q[:4],
                              params=SearchParams(k=10, nprobe=4))
    rk = distributed_search(rairs_index, mesh, q[:4],
                            params=SearchParams(k=10, nprobe=4,
                                                use_kernel=True))
    assert np.array_equal(np.asarray(rk.ids), np.asarray(base.ids))
    with pytest.raises(ValueError, match="max_scan"):
        distributed_search(rairs_index, mesh, q[:4],
                           params=SearchParams(k=10, nprobe=4, max_scan=64))
    with pytest.raises(TypeError, match="nprobe"):
        distributed_search(rairs_index, mesh, q[:4], k=10)


def test_insert_batch_does_not_reuse_stale_sessions(rairs_index, unit_data):
    """Sessions cache compiled executables over one index's arrays; a
    grown index must get fresh sessions, not stale ones."""
    x, q, _ = unit_data
    rairs_index.search(q[:8], k=10, nprobe=4)          # populate cache
    grown = insert_batch(rairs_index, x[:64])
    assert getattr(grown, "_searcher_cache", None) in (None, {})
    r = grown.search(q[:8], k=10, nprobe=4)
    assert np.asarray(r.ids).shape == (8, 10)
