"""Chaos suite: deterministic fault injection across the serving stack
(DESIGN.md §13).

Every test here drives *production* code paths under an installed
``FaultPlan`` and asserts the overload/failure contract:

  * no deadlock and no silently dropped request — every ``submit``
    resolves with a result or a typed ``RairsError``;
  * a compaction-worker crash retries with backoff, then rolls back to
    the pinned old epoch and surfaces ``HandoverFailed`` — serving
    continues, and the external-id remap chain is NOT consumed by the
    failed attempt (a retried compaction resolves ids exactly once);
  * requests past their deadline fail typed at dequeue, never dispatch;
  * close() honors the drain grace window, then fails leftovers typed;
  * corrupted / truncated bundles are rejected naming the bad member.

CI (chaos-smoke) runs this file under two values of ``RAIRS_CHAOS_SEED``
— determinism means a failure reproduces from the seed alone.
"""
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import (IndexConfig, SearchParams, StreamConfig,
                        StreamingIndex, build_index, load_index, save_index)
from repro.errors import (CorruptBundleError, DeadlineExceeded,
                          FaultInjected, GatewayClosed, HandoverFailed,
                          Overloaded, RairsError)
from repro.faults import FaultPlan, FaultSpec
from repro.gateway import Gateway, GatewayConfig, degrade_ladder

CHAOS_SEED = int(os.environ.get("RAIRS_CHAOS_SEED", "0"))


@pytest.fixture()
def stream_index(unit_data, shared_trained):
    x, _, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True)
    base = build_index(jax.random.PRNGKey(0), x[:2000], cfg,
                       centroids=cents, codebook=cb)
    return StreamingIndex(base, StreamConfig(delta_pad=512))


# ---------------------------------------------------------------------------
# the plan itself: deterministic schedules
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic():
    specs = (FaultSpec("a", prob=0.3), FaultSpec("b", prob=0.7),)

    def schedule(seed):
        plan = FaultPlan(seed, specs)
        return [(plan.visit("a") is not None, plan.visit("b") is not None)
                for _ in range(64)]

    s1, s2 = schedule(CHAOS_SEED), schedule(CHAOS_SEED)
    assert s1 == s2                      # same seed -> same schedule
    assert schedule(CHAOS_SEED + 1) != s1   # seeds actually matter
    fires_a = sum(a for a, _ in s1)
    assert 0 < fires_a < 64              # prob is neither 0 nor 1


def test_fault_spec_validates_and_explicit_schedule():
    with pytest.raises(ValueError):
        FaultSpec("x", kind="explode")
    plan = FaultPlan(CHAOS_SEED, (FaultSpec("s", at=(1, 3)),))
    fired = [plan.visit("s") is not None for _ in range(5)]
    assert fired == [False, True, False, True, False]
    assert plan.visits("s") == 5 and plan.fired() == 2


def test_max_hits_caps_a_probabilistic_spec():
    plan = FaultPlan(CHAOS_SEED, (FaultSpec("s", prob=1.0, max_hits=2),))
    fired = [plan.visit("s") is not None for _ in range(6)]
    assert sum(fired) == 2 and fired[:2] == [True, True]


# ---------------------------------------------------------------------------
# dispatch faults: typed failure, no dropped request, service recovers
# ---------------------------------------------------------------------------

def test_dispatch_fault_fails_typed_and_recovers(rairs_index, unit_data):
    _, q, _ = unit_data
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("gateway.dispatch", kind="raise", at=(0,)),))
    with plan.installed():
        with Gateway(rairs_index, k=10, nprobe=8,
                     config=GatewayConfig(max_batch=4, max_delay_ms=1.0,
                                          warmup=False)) as gw:
            bad = gw.submit(q[0])
            with pytest.raises(FaultInjected):
                bad.result(30.0)
            # the fault consumed visit 0; the service keeps serving
            good = gw.search(q[1], timeout=30.0)
            assert good.ids.shape == (10,)
            snap = gw.telemetry.snapshot()
            assert snap["counters"]["errors"] >= 1
            assert snap["counters"]["responses"] >= 1


def test_overload_chaos_every_request_resolves(rairs_index, unit_data):
    """2x-saturating offered load + injected dispatch latency + a
    bounded queue: every submitted request must resolve — result,
    ``Overloaded``, or ``DeadlineExceeded`` — none may hang."""
    _, q, _ = unit_data
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("gateway.dispatch", kind="delay", prob=0.5,
                  delay_s=0.02),))
    n, results = 120, []
    with plan.installed():
        with Gateway(rairs_index, k=10, nprobe=8,
                     config=GatewayConfig(max_batch=8, max_delay_ms=1.0,
                                          max_queue=16, overload="reject",
                                          warmup=False)) as gw:
            pending = []
            for i in range(n):
                pending.append(gw.submit(q[i % len(q)]))
                time.sleep(0.0005)       # ~2000 qps offered, far past sat.
            for p in pending:
                try:
                    results.append(p.result(60.0))
                except RairsError as e:
                    results.append(e)
            snap = gw.telemetry.snapshot()
    assert len(results) == n             # nothing hung, nothing dropped
    ok = sum(1 for r in results if not isinstance(r, Exception))
    shed = sum(1 for r in results if isinstance(r, Overloaded))
    assert ok + shed == n
    assert ok > 0 and shed > 0           # overload actually bit
    c = snap["counters"]
    assert c["requests"] == n
    assert c["responses"] == ok and c["shed"] == shed


def test_block_policy_applies_backpressure(rairs_index, unit_data):
    """overload="block" parks producers instead of shedding: every
    request completes, and the queue never exceeds its bound."""
    _, q, _ = unit_data
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("gateway.dispatch", kind="delay", prob=1.0,
                  delay_s=0.005),))
    n = 48
    with plan.installed():
        with Gateway(rairs_index, k=10, nprobe=8,
                     config=GatewayConfig(max_batch=4, max_delay_ms=0.5,
                                          max_queue=8, overload="block",
                                          warmup=False)) as gw:
            depths, pending = [], []

            def producer():
                for i in range(n):
                    pending.append(gw.submit(q[i % len(q)]))
                    depths.append(gw.queue.depth)

            t = threading.Thread(target=producer)
            t.start()
            t.join(60.0)
            assert not t.is_alive()      # backpressure, not deadlock
            results = [p.result(60.0) for p in pending]
    assert len(results) == n
    assert max(depths) <= 8


# ---------------------------------------------------------------------------
# deadlines and drain
# ---------------------------------------------------------------------------

def test_expired_request_fails_at_dequeue_never_dispatched(rairs_index,
                                                           unit_data):
    _, q, _ = unit_data
    with Gateway(rairs_index, k=10, nprobe=8,
                 config=GatewayConfig(max_batch=4, warmup=False)) as gw:
        before = gw.telemetry.counter("responses")
        r = gw.submit(q[0], deadline_s=-0.001)   # already expired
        with pytest.raises(DeadlineExceeded):
            r.result(30.0)
        assert gw.telemetry.counter("deadline_failures") == 1
        # it was never dispatched: responses did not move for it
        assert gw.telemetry.counter("responses") == before
        # a healthy request with a generous deadline still completes
        assert gw.submit(q[1], deadline_s=30.0).result(30.0).ids.shape \
            == (10,)


def test_close_drain_window_fails_leftovers_typed(rairs_index, unit_data):
    """drain_s=0: close() fails queued work immediately — with the
    typed ``GatewayClosed``, not a bare RuntimeError."""
    _, q, _ = unit_data
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("gateway.dispatch", kind="delay", prob=1.0,
                  delay_s=0.05),))
    with plan.installed():
        gw = Gateway(rairs_index, k=10, nprobe=8,
                     config=GatewayConfig(max_batch=2, max_delay_ms=0.5,
                                          drain_s=0.0, warmup=False))
        pending = [gw.submit(q[i % len(q)]) for i in range(32)]
        gw.close()
    outcomes = []
    for p in pending:
        try:
            outcomes.append(p.result(10.0))
        except GatewayClosed as e:
            outcomes.append(e)
    assert len(outcomes) == 32
    dropped = [o for o in outcomes if isinstance(o, GatewayClosed)]
    assert dropped                       # the zero-grace window cut some
    assert all(isinstance(o, GatewayClosed) or o.ids.shape == (10,)
               for o in outcomes)


def test_close_default_drains_everything(rairs_index, unit_data):
    _, q, _ = unit_data
    gw = Gateway(rairs_index, k=10, nprobe=8,
                 config=GatewayConfig(max_batch=8, warmup=False))
    pending = [gw.submit(q[i % len(q)]) for i in range(24)]
    gw.close()                            # drain_s=None: drain until empty
    assert all(p.result(10.0).ids.shape == (10,) for p in pending)


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def test_degradation_ladder_steps_down_and_recovers(rairs_index, unit_data):
    _, q, _ = unit_data
    params = SearchParams(k=10, nprobe=8)
    ladder = degrade_ladder(params, levels=2)
    assert [p.nprobe for p in ladder] == [8, 4, 2]
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("gateway.dispatch", kind="delay", prob=1.0,
                  delay_s=0.01, max_hits=30),))
    with plan.installed():
        with Gateway(rairs_index, params,
                     config=GatewayConfig(
                         max_batch=4, max_delay_ms=0.5, max_queue=8,
                         overload="block", degrade=ladder[1:],
                         degrade_hold=1, warmup=False)) as gw:
            pending = [gw.submit(q[i % len(q)]) for i in range(64)]
            results = [p.result(60.0) for p in pending]
            levels = {r.level for r in results}
            assert levels - {0}          # pressure pushed the ladder down
            snap = gw.telemetry.snapshot()
            assert snap["counters"]["degrade_steps_down"] >= 1
            # pressure gone (faults exhausted): the ladder steps back up
            deadline = time.time() + 30.0
            while time.time() < deadline:
                if gw.search(q[0], timeout=30.0).level == 0:
                    break
                time.sleep(0.01)
            assert gw.stats()["quality"]["level"] == 0
            assert gw.search(q[0], timeout=30.0).level == 0
            assert gw.telemetry.counter("degrade_steps_up") >= 1


# ---------------------------------------------------------------------------
# compaction crash: retry -> rollback -> typed surface, old epoch serves
# ---------------------------------------------------------------------------

def test_fold_crash_retries_then_succeeds(stream_index, unit_data):
    _, q, _ = unit_data
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("gateway.fold", kind="raise", at=(0,)),))
    with plan.installed():
        with Gateway(stream_index, k=10, nprobe=8,
                     config=GatewayConfig(max_batch=8, warmup=False,
                                          handover_retries=2,
                                          handover_backoff_s=0.01)) as gw:
            gw.insert(np.asarray(unit_data[0][2000:2032]))
            epoch0 = stream_index.epoch
            h = gw.compact_async("chaos")
            info = h.wait(60.0)
            assert h.state == "installed" and info["epoch"] == epoch0 + 1
            assert gw.telemetry.counter("handover_retries") == 1
            assert gw.search(q[0], timeout=30.0).epoch == epoch0 + 1


def test_fold_crash_exhausts_retries_rolls_back(stream_index, unit_data):
    x, q, _ = unit_data
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("gateway.fold", kind="raise", prob=1.0),))
    with Gateway(stream_index, k=10, nprobe=8,
                 config=GatewayConfig(max_batch=8, warmup=False,
                                      handover_retries=1,
                                      handover_backoff_s=0.01)) as gw:
        ext = gw.insert(np.asarray(x[2000:2064]))
        gw.delete(ext[:8])
        epoch0, version0 = stream_index.epoch, stream_index.version
        resolved0 = gw.resolve_ids(ext)
        with plan.installed():
            h = gw.compact_async("chaos")
            with pytest.raises(HandoverFailed) as ei:
                h.wait(60.0)
            assert isinstance(ei.value.__cause__, FaultInjected)
        # rolled back: same epoch, pinned session still serves
        assert stream_index.epoch == epoch0
        assert gw.telemetry.counter("handover_failures") == 1
        r = gw.search(q[0], timeout=30.0)
        assert r.epoch == epoch0 and r.ids.shape == (10,)
        # the failed attempt consumed NO remap link: handles unchanged
        np.testing.assert_array_equal(gw.resolve_ids(ext), resolved0)
        assert stream_index.version == version0
        # a clean retry compacts and the same handles still resolve
        h2 = gw.compact_async("retry")
        assert h2.wait(60.0)["epoch"] == epoch0 + 1
        resolved1 = gw.resolve_ids(ext)
        assert (resolved1[:8] == -1).all()       # deletes stayed deleted
        assert (resolved1[8:] >= 0).all()        # survivors still resolve
        # exactly one remap was consumed, by the successful install
        res = gw.search(np.asarray(x[2010]), timeout=30.0)
        assert (np.asarray(res.ids) >= 0).any()


def test_failed_then_retried_compaction_remap_chain(stream_index, unit_data):
    """Satellite: the streaming-level contract behind the gateway test
    above — ``abort()`` must not consume a remap link, so resolve_ids
    chains exactly one remap per *successful* install."""
    x, _, _ = unit_data
    stream = stream_index
    ids = stream.insert(np.asarray(x[2000:2040]))
    ext = stream.external_ids(ids)
    stream.delete(ids[:5])
    before = stream.resolve_ids(ext)
    # attempt 1: folds fine, then rolls back (simulating install crash)
    p1 = stream.begin_compact("will-abort")
    p1.fold()
    p1.abort()
    np.testing.assert_array_equal(stream.resolve_ids(ext), before)
    assert stream._pending_compact is None   # rollback released the slot
    # attempt 2: retried compaction lands; the chain advances once
    p2 = stream.begin_compact("retry")
    p2.fold()
    info = p2.install()
    assert info["epoch"] == stream.epoch
    after = stream.resolve_ids(ext)
    assert (after[:5] == -1).all() and (after[5:] >= 0).all()
    # remapped internal ids still point at the same vectors
    live_ext = ext[5:]
    ints = stream.resolve_ids(live_ext)
    got = np.asarray(stream.base.vectors)[ints[ints < stream.n_base]]
    want = np.asarray(x[2000:2040])[5:][ints < stream.n_base]
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# storage faults: truncation / bit-flips reject typed, old bundle survives
# ---------------------------------------------------------------------------

def test_bitflip_fault_rejected_naming_member(rairs_index, tmp_path):
    path = os.path.join(tmp_path, "idx.npz")
    save_index(rairs_index, path)
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("io.read_array", kind="bitflip", at=(0,)),))
    with plan.installed():
        with pytest.raises(CorruptBundleError, match="crc32 mismatch"):
            load_index(path)
    # uninstalled plan: the same bundle loads clean
    assert load_index(path) is not None


def test_truncation_fault_rejected(rairs_index, tmp_path):
    path = os.path.join(tmp_path, "sharded")
    save_index(rairs_index, path, shards=2)
    plan = FaultPlan(CHAOS_SEED, (
        FaultSpec("io.read_array", kind="truncate", at=(1,)),))
    with plan.installed():
        with pytest.raises(CorruptBundleError):
            load_index(path)
    assert load_index(path) is not None


def test_interrupted_save_previous_bundle_loadable(stream_index, unit_data,
                                                   tmp_path):
    """Crash-safe commit protocol: kill the sharded save before the
    manifest lands — the previous bundle must still load byte-clean."""
    x, _, _ = unit_data
    path = os.path.join(tmp_path, "bundle")
    save_index(stream_index, path, shards=2)
    first = load_index(path)
    stream_index.insert(np.asarray(x[2000:2016]))
    # a torn second save: member files appear, the manifest commit never
    # happens (simulated by the writer dying mid-way)
    with open(os.path.join(path, "shard_0000-00000000.npz"), "wb") as fh:
        fh.write(b"\x00" * 100)           # torn write from the dead saver
    again = load_index(path)
    assert again.n_total == first.n_total     # still the committed state
    # the next successful save commits atomically and sweeps the orphan
    save_index(stream_index, path, shards=2)
    assert "shard_0000-00000000.npz" not in os.listdir(path)
    assert load_index(path).n_total == stream_index.n_total
