"""Fused scan -> top-k parity (engine/fused.py + kernels/topk.py).

The contract under test: ``scan_blocks_topk`` — oracle or Pallas kernel
— returns bitwise the stable ``preselect_candidates`` selection over
``scan_blocks``' unfused candidate stream (ties broken by flat plan
position, masked entries normalized to ``(+inf, -1)``), with logical
DCO accounting unchanged.  Covered across exec modes, tombstones,
synthetic adversarial plans (duplicate distances, duplicate ids, dead
items), and end-to-end through the frozen / streaming / sharded
pipelines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.core import IndexConfig, build_index
from repro.core.engine import (BlockStore, QueryPlan, preselect_candidates,
                               scan_blocks, scan_blocks_topk)
from repro.core.params import SearchParams
from repro.core.search import seil_search
from repro.kernels.topk import PAD_POS, bitonic_sort, merge_topf, pow2_ceil

EXEC_MODES = ("paged", "grouped", "clustered")


# ---------------------------------------------------------------------------
# kernels/topk.py primitives vs numpy lexsort ground truth
# ---------------------------------------------------------------------------

def _lexsorted(d, p, i):
    """Ascending by (d, p) — np ground truth for the bitonic networks."""
    order = np.lexsort((p, d), axis=-1)
    return (np.take_along_axis(d, order, -1),
            np.take_along_axis(p, order, -1),
            np.take_along_axis(i, order, -1))


@pytest.mark.parametrize("n", [2, 8, 32, 128])
def test_bitonic_sort_matches_lexsort(n):
    rng = np.random.default_rng(n)
    # few distinct distances -> plenty of exact ties for the pos key
    d = rng.integers(0, 5, (3, n)).astype(np.float32)
    d[0, : n // 2] = np.inf                       # masked entries sort last
    p = rng.permutation(n)[None, :].repeat(3, 0).astype(np.int32)
    i = rng.integers(-1, 50, (3, n)).astype(np.int32)
    out = bitonic_sort([jnp.asarray(d), jnp.asarray(p), jnp.asarray(i)])
    ref = _lexsorted(d, p, i)
    for o, r in zip(out, ref):
        np.testing.assert_array_equal(np.asarray(o), r)


@pytest.mark.parametrize("f,blocks", [(4, 7), (16, 5), (64, 3)])
def test_merge_topf_accumulates_global_topf(f, blocks):
    """Feeding sorted width-f chunks through merge_topf must equal the
    top-f of the concatenated stream under the same (d, pos) order."""
    rng = np.random.default_rng(f * 31 + blocks)
    all_d, all_p, all_i = [], [], []
    acc = [jnp.full((2, f), np.inf, jnp.float32),
           jnp.full((2, f), PAD_POS, jnp.int32),
           jnp.full((2, f), -1, jnp.int32)]
    for step in range(blocks):
        d = rng.integers(0, 4, (2, f)).astype(np.float32)
        p = (np.arange(f)[None, :] + step * f).astype(np.int32)
        p = np.broadcast_to(p, (2, f)).copy()
        i = rng.integers(0, 30, (2, f)).astype(np.int32)
        all_d.append(d), all_p.append(p), all_i.append(i)
        new = bitonic_sort([jnp.asarray(d), jnp.asarray(p), jnp.asarray(i)])
        acc = merge_topf(acc, new)
    ref = _lexsorted(np.concatenate(all_d, -1), np.concatenate(all_p, -1),
                     np.concatenate(all_i, -1))
    for o, r in zip(acc, ref):
        np.testing.assert_array_equal(np.asarray(o), r[:, :f])


def test_pow2_ceil():
    assert [pow2_ceil(n) for n in (1, 2, 3, 5, 8, 9, 64)] == \
        [1, 2, 4, 8, 8, 16, 64]


# ---------------------------------------------------------------------------
# satellite: pq_scan_paged_kernel tile-row invariant fails loudly
# ---------------------------------------------------------------------------

def test_paged_kernel_tile_row_invariant():
    from jax.experimental import checkify

    from repro.kernels.pq_scan import pq_scan_paged_kernel
    rng = np.random.default_rng(3)
    lut = jnp.asarray(rng.standard_normal((4, 4, 16)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 16, (6, 8, 4)).astype(np.uint8))
    per_query = jnp.asarray(rng.integers(0, 6, (4, 3)).astype(np.int32))
    shared = jnp.repeat(per_query[::2], 2, axis=0)     # rows agree per tile

    # tile-shared rows: allowed, and row 0's list is really what's scored
    out = pq_scan_paged_kernel(lut, codes, shared, query_tile=2,
                               interpret=True)
    ref = pq_scan_paged_kernel(lut, codes, shared, query_tile=1,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    # eager misuse raises instead of silently scoring the wrong blocks
    with pytest.raises(ValueError, match="tile rows"):
        pq_scan_paged_kernel(lut, codes, per_query, query_tile=2,
                             interpret=True)

    # traced misuse is checkable via debug=True + checkify
    def run(bi):
        return pq_scan_paged_kernel(lut, codes, bi, query_tile=2,
                                    interpret=True, debug=True)

    err, _ = jax.jit(checkify.checkify(run))(per_query)
    with pytest.raises(Exception, match="tile rows"):
        err.throw()
    err, _ = jax.jit(checkify.checkify(run))(shared)
    err.throw()                                        # no error when shared


# ---------------------------------------------------------------------------
# engine-level parity on adversarial synthetic plans
# ---------------------------------------------------------------------------

def _synth(seed, *, b=8, s=5, tb=12, blk=32, m=4, k=16, nlist=10, nid=200,
           tie_heavy=False):
    """A consistent (store, plan, lut, rank_of, sel, live) with duplicate
    ids, invalid items, misc co-assignments, and (optionally) integer
    luts so exact distance ties are everywhere."""
    rng = np.random.default_rng(seed)
    if tie_heavy:
        lut = rng.integers(0, 3, (b, m, k)).astype(np.float32)
    else:
        lut = rng.standard_normal((b, m, k)).astype(np.float32)
    codes = rng.integers(0, k, (tb, blk, m)).astype(np.uint8)
    ids = rng.integers(-1, nid, (tb, blk)).astype(np.int32)
    other = rng.integers(-1, nlist, (tb, blk)).astype(np.int32)
    # SEIL plans are per-query duplicate-free among valid slots
    blocks = np.stack([rng.choice(tb, s, replace=False)
                       for _ in range(b)]).astype(np.int32)
    ranks = np.sort(rng.integers(0, nlist, (b, s)), axis=1).astype(np.int32)
    valid = rng.random((b, s)) < 0.85
    rank_of = np.where(rng.random((b, nlist)) < 0.5,
                       rng.integers(0, nlist, (b, nlist)),
                       2 ** 30).astype(np.int32)
    sel = np.sort(rng.choice(nlist, (b, 3), replace=True), 1).astype(np.int32)
    live = jnp.asarray(rng.random(nid) < 0.8)
    store = BlockStore(jnp.asarray(codes), jnp.asarray(ids),
                       jnp.asarray(other))
    plan = QueryPlan(jnp.asarray(blocks), jnp.asarray(ranks),
                     jnp.asarray(valid), jnp.zeros(b, jnp.int32))
    return store, plan, jnp.asarray(lut), jnp.asarray(rank_of), \
        jnp.asarray(sel), live


def _unfused_reference(store, plan, lut, rank_of, sel, live, fetch,
                       exec_mode, use_kernel=False):
    """scan_blocks + live mask + stable preselect — the ground truth the
    fused stage must reproduce bitwise.  ``use_kernel`` must match the
    fused side so both streams carry the same ADC rounding (one-hot
    dot_general vs gather-sum differ in the last ulp)."""
    out = scan_blocks(store, plan, lut, rank_of, exec_mode=exec_mode,
                      sel=sel, use_kernel=use_kernel, query_tile=4)
    d = out.flat_d
    if live is not None:
        dead = (out.flat_i >= 0) & ~live[jnp.maximum(out.flat_i, 0)]
        d = jnp.where(dead, jnp.inf, d)
    ids = jnp.where(jnp.isfinite(d), out.flat_i, -1)
    cd, ci = preselect_candidates(d, ids, fetch=fetch)
    return cd, ci, out.approx_dco


@pytest.mark.parametrize("exec_mode", EXEC_MODES)
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("with_live", [False, True])
def test_scan_blocks_topk_matches_preselect(exec_mode, use_kernel,
                                            with_live):
    store, plan, lut, rank_of, sel, live = _synth(
        17 + hash(exec_mode) % 100, tie_heavy=True)
    live = live if with_live else None
    fetch = 16
    ref_d, ref_i, ref_dco = _unfused_reference(
        store, plan, lut, rank_of, sel, live, fetch, exec_mode,
        use_kernel=use_kernel)
    out = scan_blocks_topk(store, plan, lut, rank_of, fetch=fetch,
                           exec_mode=exec_mode, use_kernel=use_kernel,
                           query_tile=4, sel=sel, live=live)
    np.testing.assert_array_equal(np.asarray(out.flat_d), np.asarray(ref_d))
    np.testing.assert_array_equal(np.asarray(out.flat_i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(out.approx_dco),
                                  np.asarray(ref_dco))


def test_scan_blocks_topk_fetch_clamped_to_stream():
    """fetch beyond the unfused stream width degrades to a full stable
    sort of the stream — never an error, never a dropped candidate."""
    store, plan, lut, rank_of, sel, live = _synth(5, s=2, blk=8)
    wide = 999
    out = scan_blocks_topk(store, plan, lut, rank_of, fetch=wide,
                           exec_mode="paged", use_kernel=True, query_tile=1)
    s, blk = plan.blocks.shape[1], store.block_codes.shape[1]
    assert out.flat_d.shape == (plan.blocks.shape[0], s * blk)
    ref_d, ref_i, _ = _unfused_reference(store, plan, lut, rank_of, None,
                                         None, s * blk, "paged",
                                         use_kernel=True)
    np.testing.assert_array_equal(np.asarray(out.flat_d), np.asarray(ref_d))
    np.testing.assert_array_equal(np.asarray(out.flat_i), np.asarray(ref_i))


# satellite: hypothesis property — fused candidate order equals the
# stable preselect over the unfused stream for random plans, duplicate
# distances/ids, and tombstones, in both fused implementations.
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10 ** 6), exec_mode=st.sampled_from(EXEC_MODES),
       blk=st.sampled_from([8, 32]), s=st.integers(1, 6),
       fetch=st.sampled_from([1, 8, 24]), use_kernel=st.booleans(),
       with_live=st.booleans())
def test_property_fused_topk_order(seed, exec_mode, blk, s, fetch,
                                   use_kernel, with_live):
    store, plan, lut, rank_of, sel, live = _synth(
        seed, s=s, blk=blk, tie_heavy=True)
    live = live if with_live else None
    ref_d, ref_i, ref_dco = _unfused_reference(
        store, plan, lut, rank_of, sel, live,
        min(fetch, s * blk), exec_mode, use_kernel=use_kernel)
    out = scan_blocks_topk(store, plan, lut, rank_of, fetch=fetch,
                           exec_mode=exec_mode, use_kernel=use_kernel,
                           query_tile=4, sel=sel, live=live)
    np.testing.assert_array_equal(np.asarray(out.flat_d), np.asarray(ref_d))
    np.testing.assert_array_equal(np.asarray(out.flat_i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(out.approx_dco),
                                  np.asarray(ref_dco))


# ---------------------------------------------------------------------------
# end-to-end: frozen / streaming / sharded pipelines, fused == unfused
# ---------------------------------------------------------------------------

def _assert_results_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.approx_dco),
                                  np.asarray(b.approx_dco))
    np.testing.assert_array_equal(np.asarray(a.refine_dco),
                                  np.asarray(b.refine_dco))


@pytest.mark.parametrize("exec_mode", EXEC_MODES)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_seil_search_fused_parity(rairs_index, unit_data, exec_mode,
                                  use_kernel):
    _, q, _ = unit_data
    idx = rairs_index
    kw = dict(nprobe=8, bigk=32, k=10, max_scan=idx.default_max_scan(8),
              dedup_results=idx.needs_result_dedup,
              oversample=idx.result_oversample, exec_mode=exec_mode,
              query_tile=4)
    base = seil_search(idx.arrays, idx.centroids, idx.codebook, idx.vectors,
                       q[:16], use_kernel=use_kernel, **kw)
    fused = seil_search(idx.arrays, idx.centroids, idx.codebook, idx.vectors,
                        q[:16], use_kernel=use_kernel, fused_topk=True, **kw)
    _assert_results_equal(fused, base)


@pytest.mark.parametrize("exec_mode", EXEC_MODES)
def test_streaming_fused_parity(rairs_index, unit_data, exec_mode):
    from repro.core.stream import StreamingIndex
    x, q, _ = unit_data
    rng = np.random.default_rng(11)
    st_idx = StreamingIndex(rairs_index)
    st_idx.insert(jnp.asarray(
        rng.standard_normal((37, x.shape[1])).astype(np.float32)))
    st_idx.delete(jnp.arange(0, 60, 5, dtype=jnp.int32))
    for uk in (False, True):
        base = st_idx.searcher(SearchParams(
            k=10, nprobe=8, exec_mode=exec_mode, query_tile=4,
            use_kernel=uk))(q[:16])
        fused = st_idx.searcher(SearchParams(
            k=10, nprobe=8, exec_mode=exec_mode, query_tile=4,
            use_kernel=uk, fused_topk=True))(q[:16])
        _assert_results_equal(fused, base)


def test_streaming_fused_parity_plan_reuse(rairs_index, unit_data):
    from repro.core.stream import StreamingIndex
    x, q, _ = unit_data
    rng = np.random.default_rng(13)
    st_idx = StreamingIndex(rairs_index)
    st_idx.insert(jnp.asarray(
        rng.standard_normal((21, x.shape[1])).astype(np.float32)))
    st_idx.delete(jnp.arange(0, 40, 7, dtype=jnp.int32))
    base = st_idx.searcher(SearchParams(
        k=10, nprobe=8, exec_mode="clustered", query_tile=4,
        plan_reuse=True, use_kernel=True))
    fused = st_idx.searcher(SearchParams(
        k=10, nprobe=8, exec_mode="clustered", query_tile=4,
        plan_reuse=True, use_kernel=True, fused_topk=True))
    for lo in (0, 8):                     # second batch hits the plan cache
        _assert_results_equal(fused(q[lo:lo + 8]), base(q[lo:lo + 8]))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_sharded_fused_parity(rairs_index, unit_data, use_kernel):
    """Mesh sessions now run the (interpret-mode) kernel path too: the
    fused per-device top-fetch replaces the preselect before the gather."""
    _, q, _ = unit_data
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    sh = rairs_index.shard(mesh)
    base = sh.searcher(SearchParams(k=10, nprobe=8, exec_mode="grouped",
                                    query_tile=4,
                                    use_kernel=use_kernel))(q[:16])
    fused = sh.searcher(SearchParams(k=10, nprobe=8, exec_mode="grouped",
                                     query_tile=4, use_kernel=use_kernel,
                                     fused_topk=True))(q[:16])
    _assert_results_equal(fused, base)
