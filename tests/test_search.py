"""Searcher correctness: vectorized rank-compare dedup vs a sequential
``listVisited`` reference implementation of paper Alg. 5, plus recall and
DCO-ordering system tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (IndexConfig, build_index, dco_summary, ground_truth,
                        recall_at_k)
from repro.core.pq import pq_lut
from repro.core.kmeans import pairwise_sq_l2


def sequential_reference(index, q_np, nprobe, bigk, k):
    """Faithful sequential Alg. 2 + Alg. 5 in numpy (hash-set listVisited)."""
    arrays = index.arrays
    cents = np.asarray(index.centroids)
    owned = np.asarray(arrays.owned)
    refs = np.asarray(arrays.refs)
    refs_other = np.asarray(arrays.refs_other)
    misc = np.asarray(arrays.misc)
    bids = np.asarray(arrays.block_ids)
    bother = np.asarray(arrays.block_other)
    lut_all = np.asarray(pq_lut(index.codebook, jnp.asarray(q_np)))
    vectors = np.asarray(index.vectors)

    out_ids, out_dco = [], []
    for qi in range(q_np.shape[0]):
        q = q_np[qi]
        d2 = ((cents - q) ** 2).sum(1)
        sel = np.argsort(d2, kind="stable")[:nprobe]
        visited = set()
        cand = {}
        dco = 0
        lut = lut_all[qi]
        for l in sel:
            def score_block(b, dedup_items):
                nonlocal dco
                for s in range(bids.shape[1]):
                    vid = bids[b, s]
                    if vid < 0:
                        continue
                    dco += 1
                    if dedup_items and bother[b, s] >= 0 \
                            and bother[b, s] in visited:
                        continue  # computed then discarded (Alg.5 L16)
                    dist = lut[np.arange(lut.shape[0]),
                               np.asarray(index.arrays.block_codes)[b, s].astype(int)].sum()
                    if vid not in cand or dist < cand[vid]:
                        cand[vid] = dist
            for b, o in zip(refs[l], refs_other[l]):
                if b >= 0 and o not in visited:
                    score_block(b, dedup_items=False)
            for b in owned[l]:
                if b < 0:
                    continue
                # cell-level compute-once in both directions (see search.py):
                # skip a home shared block if its co-list was scanned earlier
                co = bother[b, 0]
                if co >= 0 and co in visited:
                    continue
                score_block(b, dedup_items=False)
            for b in misc[l]:
                if b >= 0:
                    score_block(b, dedup_items=True)
            visited.add(int(l))
        top = sorted(cand.items(), key=lambda kv: kv[1])[:bigk]
        ids = np.array([t[0] for t in top])
        exact = ((vectors[ids] - q) ** 2).sum(1)
        out_ids.append(ids[np.argsort(exact, kind="stable")[:k]])
        out_dco.append(dco)
    return out_ids, np.array(out_dco)


@pytest.mark.parametrize("nprobe", [2, 4, 8])
def test_vectorized_matches_sequential_alg5(rairs_index, unit_data, nprobe):
    x, q, _ = unit_data
    qs = np.asarray(q[:12])
    k, bigk = 10, 100
    res = rairs_index.search(jnp.asarray(qs), k=k, nprobe=nprobe,
                             k_factor=10, max_scan=4096)
    ref_ids, ref_dco = sequential_reference(rairs_index, qs, nprobe, bigk, k)
    assert np.asarray(res.dropped_blocks).max() == 0
    np.testing.assert_array_equal(np.asarray(res.approx_dco), ref_dco)
    got = np.asarray(res.ids)
    for i in range(len(qs)):
        a, b = set(got[i][got[i] >= 0].tolist()), set(ref_ids[i].tolist())
        # identical modulo distance ties at the boundary
        assert len(a ^ b) <= 2, (i, a ^ b)


def test_no_duplicate_result_ids(rairs_index, unit_data):
    _, q, _ = unit_data
    res = rairs_index.search(q[:64], k=10, nprobe=8)
    ids = np.asarray(res.ids)
    for row in ids:
        row = row[row >= 0]
        assert len(row) == len(np.unique(row))


def test_no_duplicates_even_without_seil(unit_data, shared_trained):
    x, q, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="srair", seil=False)
    idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                      codebook=cb)
    res = idx.search(q[:64], k=10, nprobe=8)
    ids = np.asarray(res.ids)
    for row in ids:
        row = row[row >= 0]
        assert len(row) == len(np.unique(row))


def test_seil_reduces_dco_same_recall(unit_data, shared_trained):
    x, q, gt = unit_data
    cents, cb = shared_trained
    res = {}
    for seil in (False, True):
        cfg = IndexConfig(nlist=64, strategy="srair", seil=seil)
        idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                          codebook=cb)
        r = idx.search(q, k=10, nprobe=8, max_scan=4096)
        res[seil] = (recall_at_k(np.asarray(r.ids), gt),
                     dco_summary(r)["approx_dco"])
    assert res[True][1] < res[False][1], "SEIL must cut approx DCO"
    assert res[True][0] >= res[False][0] - 0.02


def test_recall_increases_with_nprobe(rairs_index, unit_data):
    _, q, gt = unit_data
    recalls = []
    for p in (1, 4, 16):
        r = rairs_index.search(q, k=10, nprobe=p)
        recalls.append(recall_at_k(np.asarray(r.ids), gt))
    assert recalls[0] < recalls[-1]
    assert recalls[-1] > 0.9


def test_exhaustive_probe_high_recall(unit_data, shared_trained):
    x, q, gt = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True)
    idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                      codebook=cb)
    r = idx.search(q[:128], k=10, nprobe=64, k_factor=20, max_scan=8192)
    assert recall_at_k(np.asarray(r.ids), gt[:128]) > 0.97


def test_rair_beats_single_at_fixed_nprobe(unit_data, shared_trained):
    x, q, gt = unit_data
    cents, cb = shared_trained
    rec = {}
    for strat in ("single", "rair"):
        cfg = IndexConfig(nlist=64, strategy=strat, seil=(strat == "rair"))
        idx = build_index(jax.random.PRNGKey(0), x, cfg, centroids=cents,
                          codebook=cb)
        r = idx.search(q, k=10, nprobe=4)
        rec[strat] = recall_at_k(np.asarray(r.ids), gt)
    assert rec["rair"] > rec["single"], rec
