"""Streaming mutable index (core/stream/, DESIGN.md §8).

Key invariants:
  * an unmutated StreamingIndex searches bitwise-identically to its
    wrapped RairsIndex (acceptance criterion);
  * appends go through the delta segment — never a full layout rebuild
    (build_seil call counting) — and inserted ids are retrievable;
  * deletes tombstone coherently across every view (the old layout-level
    seil.delete_ids path left assigns/vectors/stats/sessions stale);
  * mutations invalidate pinned sessions deterministically
    (StaleSessionError), and compaction bumps the epoch;
  * compact() reproduces a from-scratch build over the surviving corpus
    bitwise (same frozen centroids/codebook);
  * churn (interleaved insert/delete/compact) keeps recall vs a
    brute-force oracle within tolerance of a from-scratch rebuild;
  * format-v2 bundles round-trip streaming state; v1 bundles still load.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.core import (IndexConfig, SearchParams, StaleSessionError,
                        StreamConfig, StreamingIndex, build_index,
                        build_seil_call_count, ground_truth, insert_batch,
                        load_index, recall_at_k, save_index)
from repro.core.seil import build_seil


def _assert_results_identical(ra, rb):
    for field in ra._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ra, field)), np.asarray(getattr(rb, field)),
            err_msg=field)


@pytest.fixture()
def small_index(unit_data, shared_trained):
    """A fresh mutable-safe index over the first 5000 unit vectors (the
    session-scoped rairs_index must never be wrapped for mutation tests
    that could pollute its searcher cache semantics)."""
    x, _, _ = unit_data
    cents, cb = shared_trained
    cfg = IndexConfig(nlist=64, strategy="rair", seil=True)
    return build_index(jax.random.PRNGKey(0), x[:5000], cfg,
                       centroids=cents, codebook=cb)


# ---------------------------------------------------------------------------
# unmutated identity + insert path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("exec_mode", ["paged", "grouped"])
def test_unmutated_stream_is_bitwise_identical(small_index, unit_data,
                                               exec_mode):
    """Wrapping alone changes nothing: same ids, distances, and DCO
    counters as the plain index (acceptance criterion).  (Uses the
    function-scoped index: delegation shares the base's searcher cache,
    which must not leak stats into the session-scoped fixture.)"""
    _, q, _ = unit_data
    stream = StreamingIndex(small_index)
    ra = small_index.search(q[:40], k=10, nprobe=8, exec_mode=exec_mode)
    rb = stream.search(q[:40], k=10, nprobe=8, exec_mode=exec_mode)
    _assert_results_identical(ra, rb)


def test_insert_goes_through_delta_not_layout_rebuild(small_index, unit_data):
    """Appends must not call build_seil (the O(n) rebuild the subsystem
    exists to avoid), and inserted vectors are immediately retrievable
    under their new ids."""
    x, _, _ = unit_data
    stream = StreamingIndex(small_index)
    before = build_seil_call_count()
    ids = stream.insert(x[5000:5400])
    assert build_seil_call_count() == before
    assert stream.base is small_index            # base epoch untouched
    np.testing.assert_array_equal(ids, np.arange(5000, 5400))
    assert stream.n_delta == 400 and stream.n_live == 5400
    probe = x[5007][None, :]
    r = stream.search(probe, k=1, nprobe=16)
    assert int(np.asarray(r.ids)[0, 0]) == 5007


def test_steady_state_churn_does_not_recompile(small_index, unit_data):
    """Within one capacity bucket, mutation-driven session turnover must
    reuse the stream-level executables: one compile total."""
    x, q, _ = unit_data
    stream = StreamingIndex(small_index, StreamConfig(delta_pad=512))
    params = SearchParams(k=10, nprobe=8)
    for step in range(4):
        stream.insert(x[5000 + step * 64:5000 + (step + 1) * 64])
        stream.delete([int(stream.live_ids()[step])])
        stream.searcher(params)(q[:16])
    stats = stream.searcher_stats()
    assert stats["compiles"] == 1, stats
    assert stats["invalidations"] == 3, stats


def test_delta_capacity_buckets_are_geometric(small_index, unit_data):
    x, _, _ = unit_data
    stream = StreamingIndex(small_index, StreamConfig(delta_pad=64))
    stream.insert(x[5000:5010])
    assert stream._delta.capacity == 64
    stream.insert(x[5010:5100])
    assert stream._delta.capacity == 128
    stream.insert(x[5100:5400])
    assert stream._delta.capacity == 512


# ---------------------------------------------------------------------------
# delete consistency (regression for the orphaned seil.delete_ids hole)
# ---------------------------------------------------------------------------
def test_delete_keeps_all_views_coherent(small_index, unit_data):
    """The old path (seil.delete_ids on the arrays) rewrote the layout
    only: assigns/vectors/stats stayed stale and cached sessions kept
    serving the deleted id.  StreamingIndex.delete must keep every view
    coherent and fail the stale session deterministically."""
    x, q, _ = unit_data
    stream = StreamingIndex(small_index)
    probe = x[42][None, :]
    assert int(np.asarray(stream.search(probe, k=1, nprobe=16).ids)[0, 0]) == 42

    stale = stream.searcher(SearchParams(k=1, nprobe=16))
    n = stream.delete([42, 42, 43])              # dupes are one tombstone
    assert n == 2
    # the session created pre-delete would have silently returned 42 on
    # the old path; now it is deterministically unusable
    with pytest.raises(StaleSessionError, match="version"):
        stale(probe)
    # fresh session: deleted id can never be returned
    r = stream.search(probe, k=10, nprobe=16)
    assert 42 not in np.asarray(r.ids)
    assert 43 not in np.asarray(r.ids)
    # id-aligned views stay coherent (n_total unchanged, liveness masked)
    assert stream.n_live == 4998
    assert stream.vectors.shape[0] == 5000
    assert stream.assigns.shape[0] == 5000
    assert not stream.live_mask()[42]
    # deleting again is a no-op; out-of-range raises
    assert stream.delete([42]) == 0
    with pytest.raises(ValueError, match="out of range"):
        stream.delete([stream.n_total])


def test_delete_of_delta_items(small_index, unit_data):
    x, _, _ = unit_data
    stream = StreamingIndex(small_index)
    ids = stream.insert(x[5000:5100])
    victim = int(ids[7])
    assert stream.delete([victim]) == 1
    r = stream.search(x[5007][None, :], k=5, nprobe=16)
    assert victim not in np.asarray(r.ids)
    assert stream.n_delta == 99


# ---------------------------------------------------------------------------
# session versioning / epochs
# ---------------------------------------------------------------------------
def test_mutations_invalidate_sessions_and_epochs_bump(small_index,
                                                       unit_data):
    x, q, _ = unit_data
    stream = StreamingIndex(small_index)
    params = SearchParams(k=10, nprobe=8)
    s0 = stream.searcher(params)
    assert s0.epoch == 0 and stream.version == 0
    s0(q[:8])                                    # usable while current

    stream.insert(x[5000:5064])
    with pytest.raises(StaleSessionError):
        s0(q[:8])
    s1 = stream.searcher(params)
    assert s1 is not s0 and s1.version == stream.version
    s1(q[:8])

    info = stream.compact()
    assert info["epoch"] == stream.epoch == 1
    with pytest.raises(StaleSessionError):
        s1(q[:8])
    s2 = stream.searcher(params)
    assert s2.epoch == 1
    assert np.asarray(s2(q[:8]).ids).shape == (8, 10)
    assert stream.stats.invalidations >= 1
    assert stream.searcher_stats()["epoch"] == 1


def test_searcher_cache_returns_same_session_while_current(small_index,
                                                           unit_data):
    _, q, _ = unit_data
    stream = StreamingIndex(small_index)
    a = stream.searcher(k=10, nprobe=8)
    b = stream.searcher(SearchParams(k=10, nprobe=8))
    assert a is b


# ---------------------------------------------------------------------------
# compaction equivalence
# ---------------------------------------------------------------------------
def test_compact_matches_from_scratch_rebuild(small_index, unit_data,
                                              shared_trained):
    """Churn equivalence (acceptance criterion): after inserts+deletes,
    compact() must equal build_index over the surviving corpus with the
    same frozen centroids/codebook — same layout arrays, same search
    ids, same distances."""
    x, q, _ = unit_data
    cents, cb = shared_trained
    stream = StreamingIndex(small_index)
    stream.insert(x[5000:5500])
    victims = np.array([1, 42, 4999, 5003, 5499])
    stream.delete(victims)
    info = stream.compact()
    assert info["n_live"] == 5495 and info["dropped"] == 5

    keep = np.ones(5500, bool)
    keep[victims] = False
    surv = np.asarray(x[:5500])[keep]
    ref = build_index(jax.random.PRNGKey(0), jnp.asarray(surv),
                      small_index.config, centroids=cents, codebook=cb)
    np.testing.assert_array_equal(np.asarray(stream.base.arrays.block_ids),
                                  np.asarray(ref.arrays.block_ids))
    np.testing.assert_array_equal(np.asarray(stream.base.arrays.block_codes),
                                  np.asarray(ref.arrays.block_codes))
    assert stream.base.stats == ref.stats
    for mode in ("paged", "grouped"):
        ra = stream.search(q[:48], k=10, nprobe=8, exec_mode=mode)
        rb = ref.search(q[:48], k=10, nprobe=8, exec_mode=mode)
        _assert_results_identical(ra, rb)
    # id remap: old id -> position in the surviving corpus
    remap = info["id_remap"]
    assert remap.shape == (5500,)
    assert (remap[victims] == -1).all()
    np.testing.assert_array_equal(remap[keep], np.arange(5495))


def test_auto_compaction_thresholds(small_index, unit_data):
    x, _, _ = unit_data
    stream = StreamingIndex(
        small_index, StreamConfig(delta_pad=64, compact_delta_frac=0.05))
    stream.insert(x[5000:5200])                  # 200 < 250 -> no compact
    assert stream.epoch == 0
    stream.insert(x[5200:5300])                  # 300 > 250 -> compact
    assert stream.epoch == 1 and stream.stats.auto_compactions == 1
    assert stream.n_delta == 0 and stream.n_live == 5300


def test_auto_compaction_returns_renumbered_ids(small_index, unit_data):
    """When an insert itself triggers compaction, the returned ids must
    be post-renumbering — stale pre-compaction ids would point a caller
    at the wrong vectors once tombstones shift the id space."""
    x, _, _ = unit_data
    stream = StreamingIndex(
        small_index, StreamConfig(delta_pad=64, compact_delta_frac=0.05))
    stream.delete(np.arange(10))                 # shift every later id down
    ids = stream.insert(x[5000:5300])            # crosses 250 -> auto-compact
    assert stream.epoch == 1
    np.testing.assert_array_equal(ids, np.arange(4990, 5290))
    probe = x[5007][None, :]
    r = stream.search(probe, k=1, nprobe=16)
    assert int(np.asarray(r.ids)[0, 0]) == int(ids[7])


def test_noop_delete_does_not_invalidate_sessions(small_index, unit_data):
    """Replaying a deletion log (idempotent retry) must not stale live
    sessions: a delete that changes nothing leaves the version alone."""
    _, q, _ = unit_data
    stream = StreamingIndex(small_index)
    stream.delete([42])
    sess = stream.searcher(SearchParams(k=10, nprobe=8))
    sess(q[:8])
    v = stream.version
    assert stream.delete([42]) == 0              # retry: already dead
    assert stream.version == v
    sess(q[:8])                                  # still current, no raise
    assert stream.searcher(SearchParams(k=10, nprobe=8)) is sess


# ---------------------------------------------------------------------------
# insert_batch compat wrapper
# ---------------------------------------------------------------------------
def test_insert_batch_is_a_streaming_wrapper(small_index, unit_data):
    """insert_batch returns a read-compatible StreamingIndex, appends in
    O(batch) (no layout rebuild), and compact() reproduces the legacy
    pooled rebuild bitwise (acceptance criterion)."""
    x, q, _ = unit_data
    before = build_seil_call_count()
    grown = insert_batch(small_index, x[5000:5300])
    assert isinstance(grown, StreamingIndex)
    assert build_seil_call_count() == before
    assert grown.vectors.shape[0] == 5300
    # repeat appends reuse the same stream
    grown2 = insert_batch(grown, x[5300:5400])
    assert grown2 is grown and grown.vectors.shape[0] == 5400

    # the legacy behaviour: pooled re-add rebuilding the full layout
    cfg = small_index.config
    legacy_arrays, legacy_stats = build_seil(
        grown.assigns, np.concatenate([small_index.codes,
                                       grown._delta.codes[:400]], axis=0),
        np.arange(5400, dtype=np.int32), cfg.nlist, block=cfg.block,
        shared=cfg.seil and cfg.multi_m == 2, code_bits=cfg.nbits)
    legacy = dataclasses.replace(
        small_index, arrays=legacy_arrays, stats=legacy_stats,
        assigns=grown.assigns, codes=None, vectors=grown.vectors)
    grown.compact()
    ra = grown.search(q[:32], k=10, nprobe=8)
    rb = legacy.search(q[:32], k=10, nprobe=8)
    _assert_results_identical(ra, rb)


# ---------------------------------------------------------------------------
# persistence (bundle v2)
# ---------------------------------------------------------------------------
def test_streaming_bundle_roundtrip(small_index, unit_data, tmp_path):
    x, q, _ = unit_data
    stream = StreamingIndex(small_index, StreamConfig(delta_pad=128))
    stream.insert(x[5000:5200])
    stream.delete([7, 5003])
    path = os.path.join(tmp_path, "stream.npz")
    save_index(stream, path, extra={"dataset": "unit"})
    restored = load_index(path)
    assert isinstance(restored, StreamingIndex)
    assert restored.epoch == stream.epoch
    assert restored.version == stream.version
    assert restored.n_live == stream.n_live
    assert restored.n_delta == stream.n_delta
    assert restored.stream_config == stream.stream_config
    ra = stream.search(q[:32], k=10, nprobe=8)
    rb = restored.search(q[:32], k=10, nprobe=8)
    _assert_results_identical(ra, rb)
    # a restored stream keeps mutating correctly
    restored.insert(x[5200:5250])
    assert restored.n_live == stream.n_live + 50


def test_v1_bundle_still_loads(small_index, unit_data, tmp_path):
    """Migration story: pre-streaming (v1) bundles are exactly v2 minus
    the streaming section — they must load as a plain RairsIndex."""
    _, q, _ = unit_data
    path = os.path.join(tmp_path, "v2.npz")
    save_index(small_index, path)
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(bytes(arrays["meta_json"].tobytes()).decode())
    assert meta["format_version"] == 5   # current writer (checksummed)
    meta["format_version"] = 1
    arrays["meta_json"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    v1 = os.path.join(tmp_path, "v1.npz")
    with open(v1, "wb") as f:
        np.savez(f, **arrays)
    restored = load_index(v1)
    assert not isinstance(restored, StreamingIndex)
    ra = small_index.search(q[:16], k=10, nprobe=8)
    rb = restored.search(q[:16], k=10, nprobe=8)
    _assert_results_identical(ra, rb)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_stream_config_and_inputs_validate(small_index):
    with pytest.raises(ValueError, match="delta_pad"):
        StreamConfig(delta_pad=0)
    with pytest.raises(ValueError, match="compact_delta_frac"):
        StreamConfig(compact_delta_frac=0.0)
    stream = StreamingIndex(small_index)
    with pytest.raises(TypeError, match="StreamingIndex"):
        StreamingIndex(stream)
    with pytest.raises(ValueError, match="insert batch"):
        stream.insert(np.zeros((4, 3), np.float32))
    assert stream.insert(np.zeros((0, 32), np.float32)).size == 0
    assert stream.delete([]) == 0
    assert stream.version == 0                   # no-ops don't bump


# ---------------------------------------------------------------------------
# property-style churn test (auto-skips without hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       n_ops=st.integers(2, 6),
       mid_compact=st.booleans())
def test_churn_recall_matches_scratch_rebuild(seed, n_ops, mid_compact):
    """Interleaved insert/delete(/compact) sequences: streaming recall vs
    a brute-force oracle over survivors must match a from-scratch
    rebuild's recall within tolerance, and the final compacted index
    must return exactly the rebuild's ids."""
    from repro.data import make_dataset
    x, q, _ = make_dataset("unit")
    x = np.asarray(x)
    q = jnp.asarray(q[:64])
    rng = np.random.default_rng(seed)
    cfg = IndexConfig(nlist=32, strategy="rair", seil=True,
                      kmeans_iters=4, pq_iters=4)
    n0 = 2000
    base = build_index(jax.random.PRNGKey(0), jnp.asarray(x[:n0]), cfg)
    stream = StreamingIndex(base, StreamConfig(delta_pad=64))

    pool = n0                                    # next unused corpus row
    rows = {i: i for i in range(n0)}             # live id -> corpus row
    for _ in range(n_ops):
        op = rng.integers(0, 3 if mid_compact else 2)
        if op == 0 and pool + 200 <= x.shape[0]:
            ids = stream.insert(x[pool:pool + 200])
            for j, i in enumerate(ids):
                rows[int(i)] = pool + j
            pool += 200
        elif op == 1 and len(rows) > 300:
            victims = rng.choice(stream.live_ids(), size=100, replace=False)
            stream.delete(victims)
            for v in victims:
                rows.pop(int(v), None)
        elif op == 2:
            remap = stream.compact()["id_remap"]
            rows = {int(remap[i]): r for i, r in rows.items()}

    surv_rows = np.array([rows[i] for i in sorted(rows)])
    oracle_corpus = jnp.asarray(x[surv_rows])
    gt = ground_truth(oracle_corpus, q, 10)

    rebuilt = build_index(jax.random.PRNGKey(0), oracle_corpus, cfg,
                          centroids=base.centroids, codebook=base.codebook)
    rec_rebuild = recall_at_k(np.asarray(rebuilt.search(q, k=10, nprobe=8).ids),
                              gt)
    live = stream.live_ids()
    pos_of = {int(i): p for p, i in enumerate(live)}
    r_stream = stream.search(q, k=10, nprobe=8)
    ids_as_pos = np.array([[pos_of.get(int(i), -1) for i in row]
                           for row in np.asarray(r_stream.ids)])
    rec_stream = recall_at_k(ids_as_pos, gt)
    assert rec_stream >= rec_rebuild - 0.05, (rec_stream, rec_rebuild)

    stream.compact()
    _assert_results_identical(stream.search(q, k=10, nprobe=8),
                              rebuilt.search(q, k=10, nprobe=8))
