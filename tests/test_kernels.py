"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp oracle
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_stub import given, settings, st

from repro.kernels.ops import pq_scan_grouped, pq_scan_paged, pq_scan_tiled
from repro.kernels.ref import onehot_lut_ref, pq_scan_paged_ref


@pytest.mark.parametrize("b,m,k,tb,blk,s", [
    (1, 4, 16, 3, 32, 2),
    (4, 8, 16, 10, 32, 6),
    (8, 64, 16, 32, 32, 5),
    (2, 16, 16, 7, 128, 3),
    (2, 32, 8, 5, 64, 4),     # 3-bit-table variant
    (16, 2, 16, 4, 32, 1),
])
def test_pq_scan_paged_matches_ref(b, m, k, tb, blk, s):
    key = jax.random.PRNGKey(b * 131 + m)
    k1, k2, k3 = jax.random.split(key, 3)
    lut = jax.random.normal(k1, (b, m, k), jnp.float32)
    codes = jax.random.randint(k2, (tb, blk, m), 0, k).astype(jnp.uint8)
    idx = jax.random.randint(k3, (b, s), 0, tb, jnp.int32)
    out = pq_scan_paged(lut, codes, idx)
    ref = pq_scan_paged_ref(lut, codes, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pq_scan_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    lut = jax.random.normal(k1, (4, 8, 16), jnp.float32).astype(dtype)
    codes = jax.random.randint(k2, (6, 32, 8), 0, 16).astype(jnp.uint8)
    idx = jax.random.randint(k3, (4, 3), 0, 6, jnp.int32)
    out = pq_scan_paged(lut.astype(jnp.float32), codes, idx)
    ref = pq_scan_paged_ref(lut.astype(jnp.float32), codes, idx)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_grouped_mode_query_tiles():
    key = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(key, 3)
    b, m, kk, tb, blk, s = 8, 16, 16, 12, 32, 7
    lut = jax.random.normal(k1, (b, m, kk), jnp.float32)
    codes = jax.random.randint(k2, (tb, blk, m), 0, kk).astype(jnp.uint8)
    sidx = jax.random.randint(k3, (s,), 0, tb, jnp.int32)
    for qt in (1, 2, 4, 8):
        out = pq_scan_grouped(lut, codes, sidx, query_tile=qt)
        ref = pq_scan_paged_ref(lut, codes,
                                jnp.broadcast_to(sidx[None], (b, s)))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_tiled_mode_per_tile_lists():
    """pq_scan_tiled: each query tile pages its own (tile-padded) scan
    list through the scalar-prefetched index_map — the clustered exec
    mode's kernel path, validated in interpret mode on CPU against the
    per-query oracle fed the tile-broadcast lists."""
    key = jax.random.PRNGKey(13)
    k1, k2, k3 = jax.random.split(key, 3)
    b, m, kk, tb, blk, w = 16, 8, 16, 20, 32, 5
    lut = jax.random.normal(k1, (b, m, kk), jnp.float32)
    codes = jax.random.randint(k2, (tb, blk, m), 0, kk).astype(jnp.uint8)
    for qt in (1, 2, 4, 8, 16):
        tiles = b // qt
        tile_idx = jax.random.randint(k3, (tiles, w), 0, tb, jnp.int32)
        out = pq_scan_tiled(lut, codes, tile_idx, query_tile=qt)
        full = jnp.repeat(tile_idx, qt, axis=0)          # (B, W) broadcast
        ref = pq_scan_paged_ref(lut, codes, full)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_onehot_identity_vs_gather():
    """The MXU one-hot contraction is exactly the LUT gather."""
    key = jax.random.PRNGKey(11)
    k1, k2 = jax.random.split(key)
    lut = jax.random.normal(k1, (16, 16), jnp.float32)
    codes = jax.random.randint(k2, (64, 16), 0, 16, jnp.int32)
    oh = onehot_lut_ref(lut, codes)
    gather = lut[jnp.arange(16)[None, :], codes].sum(-1)
    np.testing.assert_allclose(np.asarray(oh), np.asarray(gather),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), m=st.sampled_from([2, 4, 8, 16]),
       blk=st.sampled_from([8, 32]), s=st.integers(1, 6),
       b=st.sampled_from([1, 2, 4]))
def test_property_pq_scan(seed, m, blk, s, b):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    tb = 8
    lut = jax.random.normal(k1, (b, m, 16), jnp.float32)
    codes = jax.random.randint(k2, (tb, blk, m), 0, 16).astype(jnp.uint8)
    idx = jax.random.randint(k3, (b, s), 0, tb, jnp.int32)
    np.testing.assert_allclose(
        np.asarray(pq_scan_paged(lut, codes, idx)),
        np.asarray(pq_scan_paged_ref(lut, codes, idx)), rtol=1e-5, atol=1e-5)
