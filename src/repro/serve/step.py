"""Serving steps (prefill / decode / long-context decode) + cache specs.

Cache sharding (production defaults):
  * KV caches (NP, B, S, kvH, hd): batch over ("pod","data"), head_dim
    over "model" (kvH is often < |model|, hd=128 always divides);
    long-context B=1 caches shard S over "data" instead of batch.
  * Mamba states (NP, B, H, P, N): batch over data, heads over model.
  * RAIRS-kNN caches: block pool over ("pod","data") (like IVF lists),
    head_dim over "model".
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..dist.sharding import axis_rules, logical_spec, param_shardings
from ..models.mamba2 import MambaState
from ..models.retrieval import KnnAttnConfig, knn_cache_specs
from ..models.transformer import ParamSpec, decode_step, param_specs, prefill

SDS = jax.ShapeDtypeStruct


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int,
                kv_dtype=jnp.bfloat16) -> Dict:
    """Abstract decode cache matching transformer.decode_step's pytree."""
    np_ = cfg.n_periods
    kvh, hd = cfg.n_kv_heads, cfg.hd
    blocks = {}
    for j, (mixer, _) in enumerate(cfg.slot_kinds()):
        if mixer == "attn":
            kv = SDS((np_, batch, seq_len, kvh, hd), kv_dtype)
            blocks[f"s{j}"] = (kv, kv)
        else:
            d_inner = cfg.ssm_heads * cfg.ssm_head_dim
            c = d_inner + 2 * cfg.ssm_state
            blocks[f"s{j}"] = MambaState(
                h=SDS((np_, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32),
                conv=SDS((np_, batch, 3, c), jnp.float32))
    return {"blocks": blocks, "len": SDS((batch,), jnp.int32)}


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree,
                    long_context: bool = False):
    """NamedShardings for a (possibly knn) cache pytree, by leaf shape."""
    kvh, hd = cfg.n_kv_heads, cfg.hd

    def shard_leaf(leaf):
        shp = leaf.shape
        names = [None] * len(shp)
        if len(shp) >= 2:
            if long_context and len(shp) >= 3 and shp[1] == 1:
                # B=1 long context: shard the big pool/seq dim over data
                big = max(range(1, len(shp)), key=lambda i: shp[i])
                names[big] = "lists"
            else:
                names[1] = "batch"
            if shp[-1] == hd:
                names[-1] = "kv_head_dim"
            elif len(shp) == 5 and shp[2] == cfg.ssm_heads:
                names[2] = "ssm_head"
        with axis_rules(mesh, rules=_cache_rules()):
            return NamedSharding(mesh, logical_spec(*names, shape=shp))

    return jax.tree.map(shard_leaf, cache_tree)


def _cache_rules():
    from ..dist.sharding import DEFAULT_RULES
    r = dict(DEFAULT_RULES)
    r["kv_head_dim"] = "model"
    return r


def make_prefill_step(cfg: ModelConfig, cache_slack: int = 0):
    def step(params, batch):
        return prefill(params, cfg, batch, cache_slack=cache_slack)
    return step


def make_decode_step(cfg: ModelConfig):
    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)
    return step


def make_long_decode_step(cfg: ModelConfig, kcfg: KnnAttnConfig):
    from ..models.retrieval import decode_step_long

    def step(params, cache, tokens):
        return decode_step_long(params, cfg, cache, tokens, kcfg)
    return step


def knn_decode_cache_specs(cfg: ModelConfig, kcfg: KnnAttnConfig,
                           batch: int) -> Dict:
    """Abstract long-context cache: knn slots for attention, MambaState
    for ssm slots (matches retrieval.decode_step_long)."""
    np_ = cfg.n_periods
    slot_specs = knn_cache_specs(cfg, kcfg, batch, np_)
    blocks = {}
    for j, (mixer, _) in enumerate(cfg.slot_kinds()):
        if mixer == "attn":
            blocks[f"s{j}"] = dict(slot_specs)
        else:
            d_inner = cfg.ssm_heads * cfg.ssm_head_dim
            c = d_inner + 2 * cfg.ssm_state
            blocks[f"s{j}"] = MambaState(
                h=SDS((np_, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32),
                conv=SDS((np_, batch, 3, c), jnp.float32))
    return {"blocks": blocks, "len": SDS((batch,), jnp.int32)}
