from .step import (make_prefill_step, make_decode_step,  # noqa
                   make_long_decode_step, cache_specs, cache_shardings)
