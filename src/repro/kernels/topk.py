"""Bitonic partial-sort primitives for the fused scan->top-k kernel.

The fused kernel (pq_scan.py::pq_scan_topk_kernel) keeps a per-query
top-``F`` candidate accumulator resident in VMEM across grid steps, so
it needs a selection network built from vector ops only — no gathers,
no data-dependent control flow, nothing Mosaic cannot lower.  Every
routine here is a reshape-based compare-exchange network over the
*trailing* axis of a (distance, position, id) triple:

  * keys are lexicographic ``(d, pos)`` ascending — ``pos`` is the flat
    plan-layout position ``slot * BLK + lane`` of a candidate, which is
    exactly the tie-break order of ``jax.lax.top_k`` over the unfused
    candidate stream (``preselect_candidates``' stability contract), so
    a merge network over these keys reproduces the unfused selection
    *bitwise*, ties included;
  * masked/padding entries carry ``(+inf, BIG, -1)``; with pos unique
    among real candidates the key is a total order, so the network
    needs no stability of its own;
  * a compare-exchange at distance ``g`` is one reshape to
    ``(..., n // 2g, 2, g)`` plus ``jnp.where`` selects — the standard
    TPU idiom for sorting networks (lane-aligned, MXU-free).

The same functions run inside the Pallas kernel body (interpret mode on
CPU, Mosaic on TPU) and in the pure-jnp oracle, so kernel and reference
can never diverge on the network itself.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

# padding position for masked candidates — matches engine BIG
# (core/engine/types.py) without importing across the package boundary.
PAD_POS = 2 ** 30


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (network widths must be powers of 2)."""
    p = 1
    while p < n:
        p *= 2
    return p


def _lex_le(ad, ap, bd, bp):
    """a precedes-or-equals b under the ascending (d, pos) lex key."""
    return (ad < bd) | ((ad == bd) & (ap <= bp))


def _compare_exchange(arrs: Sequence[jnp.ndarray], kk: int, j: int
                      ) -> List[jnp.ndarray]:
    """One bitonic substage: exchange at distance 2^j inside 2^kk blocks.

    arrs: [d, pos, ...] arrays of shape (..., n); the first two are the
    sort key, the rest ride along.  Block direction alternates with the
    block index (the standard bitonic schedule): ascending iff bit
    (kk-1-j) of the outer block index is 0.
    """
    n = arrs[0].shape[-1]
    g = 1 << j
    lead = arrs[0].shape[:-1]
    r = [x.reshape(lead + (n // (2 * g), 2, g)) for x in arrs]
    a = [x[..., 0, :] for x in r]
    b = [x[..., 1, :] for x in r]
    o = jax.lax.broadcasted_iota(jnp.int32, a[0].shape, len(lead))
    asc = ((o >> (kk - 1 - j)) & 1) == 0
    a_first = _lex_le(a[0], a[1], b[0], b[1])
    take_a_lo = jnp.where(asc, a_first, ~a_first)
    out = []
    for xa, xb in zip(a, b):
        lo = jnp.where(take_a_lo, xa, xb)
        hi = jnp.where(take_a_lo, xb, xa)
        out.append(jnp.stack([lo, hi], axis=-2).reshape(lead + (n,)))
    return out


def bitonic_sort(arrs: Sequence[jnp.ndarray]) -> List[jnp.ndarray]:
    """Full ascending sort of (..., n) triples by the (d, pos) lex key.
    n must be a power of two; log2(n)*(log2(n)+1)/2 substages."""
    n = arrs[0].shape[-1]
    logn = n.bit_length() - 1
    assert 1 << logn == n, f"bitonic_sort needs a power-of-two width, got {n}"
    arrs = list(arrs)
    for kk in range(1, logn + 1):
        for j in range(kk - 1, -1, -1):
            arrs = _compare_exchange(arrs, kk, j)
    return arrs


def bitonic_merge(arrs: Sequence[jnp.ndarray]) -> List[jnp.ndarray]:
    """Sort a *bitonic* (..., n) sequence ascending — log2(n) substages."""
    n = arrs[0].shape[-1]
    logn = n.bit_length() - 1
    assert 1 << logn == n, f"bitonic_merge needs a power-of-two width, got {n}"
    arrs = list(arrs)
    for j in range(logn - 1, -1, -1):
        arrs = _compare_exchange(arrs, logn, j)
    return arrs


def merge_topf(acc: Sequence[jnp.ndarray], new: Sequence[jnp.ndarray]
               ) -> List[jnp.ndarray]:
    """Merge two ascending-sorted (..., F) triples into the top-F of
    their union (ascending).  ``concat(acc, reverse(new))`` is bitonic,
    so one log2(2F)-stage merge sorts the 2F candidates; the first F
    are the survivors.  This is the per-grid-step accumulator update of
    the fused kernel: O(F log F) compares, no HBM round-trip."""
    f = acc[0].shape[-1]
    cat = [jnp.concatenate([a, x[..., ::-1]], axis=-1)
           for a, x in zip(acc, new)]
    merged = bitonic_merge(cat)
    return [x[..., :f] for x in merged]
