"""Paged PQ fast-scan Pallas TPU kernel — the DCO hot spot of the paper.

CPU PQ Fast Scan keeps 16-entry LUTs in SIMD registers and uses the
AVX2 ``pshufb`` 16-way shuffle to score 32 packed items at once.  TPUs
have no shuffle unit, so we adapt the insight (block-wise LUT scoring
with no per-item scalar work) to the MXU:

  * the 4-bit code of item i, subspace m selects ``lut[m, code]``; we
    materialize the selection as a one-hot tile and contract
    ``(BLK, M*K) @ (M*K, 1)`` on the MXU — one systolic pass scores a
    whole block (the TPU idiom for small-table gathers);
  * SEIL's reference-entry indirection becomes *paging*: the per-query
    deduplicated block-id list is scalar-prefetched
    (``PrefetchScalarGridSpec``) and drives the BlockSpec ``index_map``,
    so the HBM->VMEM DMA fetches each shared cell block exactly once —
    skipping a reference entry never issues its loads, the DMA-level
    analogue of Alg. 5's ``listVisited`` probe;
  * grid order is (query-block, scan-position): consecutive grid steps
    for the *same* scan position across the query tile reuse the code
    tile already resident in VMEM — the TPU analogue of the paper's
    "group tasks by list" cache optimization (§5.3).

Production tiling notes (TPU v5e): native block size 128 (lane width)
instead of the paper's 32 — ``block`` stays a config knob and the
paper's Fig. 16 block-size study covers the sweep.  uint8 code tiles
want (32, 128) alignment, so M is zero-padded to a multiple of 128 by
``ops.pq_scan_paged`` (padded codes select lut[m_pad, 0] == 0).
Validated against ``ref.py`` in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, lut_ref, codes_ref, out_ref):
    """One grid step: score one code block for QT queries.

    lut_ref:   (QT, M, K) f32 in VMEM
    codes_ref: (BLK, M) uint8 in VMEM (the paged block)
    out_ref:   (QT, 1, BLK) f32
    """
    qt, m, k = lut_ref.shape
    blk = codes_ref.shape[1]
    codes = codes_ref[0].astype(jnp.int32)                     # (BLK, M)
    # one-hot over the K table entries; flatten (M, K) -> MK for the MXU
    sel = codes[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
    oh = sel.astype(jnp.float32).reshape(blk, m * k)           # (BLK, MK)
    lut = lut_ref[...].reshape(qt, m * k)                      # (QT, MK)
    # (QT, MK) @ (MK, BLK) on the MXU: every query scores the block at once
    d = jax.lax.dot_general(lut, oh, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    out_ref[...] = d[:, None, :]


@functools.partial(jax.jit, static_argnames=("query_tile", "interpret"))
def pq_scan_tiled_kernel(lut: jnp.ndarray, block_codes: jnp.ndarray,
                         tile_idx: jnp.ndarray, *, query_tile: int = 8,
                         interpret: bool = False) -> jnp.ndarray:
    """Per-tile paged scan: every query tile pages its *own* scan list.

    lut (B, M, K) f32, block_codes (TB, BLK, M) uint8, tile_idx
    (B // query_tile, S) -> (B, S, BLK) f32.  The scalar-prefetched
    ``tile_idx`` drives the BlockSpec index_map directly at tile
    granularity — the clustered exec mode hands each tile its own
    (tile-padded) block union with no re-broadcast to a batch-wide
    list.  B % query_tile == 0; entries must be valid (callers clamp
    padding to 0 and mask downstream)."""
    b, m, k = lut.shape
    qb, s = tile_idx.shape
    tb, blk, m2 = block_codes.shape
    assert m2 == m, (m2, m)
    assert b == qb * query_tile, (b, qb, query_tile)

    grid = (qb, s)
    kernel = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((query_tile, m, k), lambda qi, si, idx: (qi, 0, 0)),
                pl.BlockSpec((1, blk, m),
                             lambda qi, si, idx: (idx[qi, si], 0, 0)),
            ],
            out_specs=pl.BlockSpec((query_tile, 1, blk),
                                   lambda qi, si, idx: (qi, si, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, s, blk), jnp.float32),
        interpret=interpret,
    )
    return kernel(tile_idx, lut, block_codes)


@functools.partial(jax.jit, static_argnames=("query_tile", "interpret"))
def pq_scan_paged_kernel(lut: jnp.ndarray, block_codes: jnp.ndarray,
                         block_idx: jnp.ndarray, *, query_tile: int = 8,
                         interpret: bool = False) -> jnp.ndarray:
    """lut (B, M, K) f32, block_codes (TB, BLK, M) uint8, block_idx (B, S)
    -> (B, S, BLK) f32.  B % query_tile == 0; block_idx entries must be
    valid (callers clamp padding to 0 and mask downstream).

    Paging is per (query-tile, position): with query_tile == 1 every query
    pages its own scan list; with query_tile > 1 the caller guarantees the
    tile shares one list (the paper's §5.3 list-major batch mode — see
    ops.pq_scan_grouped / ops.pq_scan_tiled)."""
    b = lut.shape[0]
    assert b % query_tile == 0, (b, query_tile)
    qb = b // query_tile
    s = block_idx.shape[1]
    idx_tiled = block_idx.reshape(qb, query_tile, s)[:, 0, :]
    return pq_scan_tiled_kernel(lut, block_codes, idx_tiled,
                                query_tile=query_tile, interpret=interpret)
