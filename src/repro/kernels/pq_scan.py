"""Paged PQ fast-scan Pallas TPU kernel — the DCO hot spot of the paper.

CPU PQ Fast Scan keeps 16-entry LUTs in SIMD registers and uses the
AVX2 ``pshufb`` 16-way shuffle to score 32 packed items at once.  TPUs
have no shuffle unit, so we adapt the insight (block-wise LUT scoring
with no per-item scalar work) to the MXU:

  * the 4-bit code of item i, subspace m selects ``lut[m, code]``; we
    materialize the selection as a one-hot tile and contract
    ``(BLK, M*K) @ (M*K, 1)`` on the MXU — one systolic pass scores a
    whole block (the TPU idiom for small-table gathers);
  * SEIL's reference-entry indirection becomes *paging*: the per-query
    deduplicated block-id list is scalar-prefetched
    (``PrefetchScalarGridSpec``) and drives the BlockSpec ``index_map``,
    so the HBM->VMEM DMA fetches each shared cell block exactly once —
    skipping a reference entry never issues its loads, the DMA-level
    analogue of Alg. 5's ``listVisited`` probe;
  * grid order is (query-block, scan-position): consecutive grid steps
    for the *same* scan position across the query tile reuse the code
    tile already resident in VMEM — the TPU analogue of the paper's
    "group tasks by list" cache optimization (§5.3).

Production tiling notes (TPU v5e): native block size 128 (lane width)
instead of the paper's 32 — ``block`` stays a config knob and the
paper's Fig. 16 block-size study covers the sweep.  uint8 code tiles
want (32, 128) alignment, so M is zero-padded to a multiple of 128 by
``ops.pq_scan_paged`` (padded codes select lut[m_pad, 0] == 0).
Validated against ``ref.py`` in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import checkify
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .topk import PAD_POS, bitonic_sort, merge_topf, pow2_ceil


def _tile_codes(codes_ref, packed: bool) -> jnp.ndarray:
    """Code tile -> (BLK, M) int32 codes, unpacking nibble pairs in-VMEM.

    A packed tile (quant plane, two 4-bit codes per byte) carries MB =
    M/2 bytes; the lo nibble is the even subquantizer, hi the odd —
    the single layout defined by ``quant/nibbles.py``.  Callers
    guarantee 2*MB == lut M (ops wrappers zero-pad the LUT so a padded
    byte's two zero codes select zero rows and contribute nothing).
    """
    raw = codes_ref[0].astype(jnp.int32)                       # (BLK, MB)
    if not packed:
        return raw
    blk, mb = raw.shape
    lo = raw & 15
    hi = raw >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(blk, 2 * mb)


def _make_kernel(packed: bool):
    """Body factory for the unfused scan (packed-ness is static)."""

    def _kernel(idx_ref, lut_ref, codes_ref, out_ref):
        """One grid step: score one code block for QT queries.

        lut_ref:   (QT, M, K) f32 in VMEM
        codes_ref: (BLK, MB) uint8 in VMEM (the paged block; MB = M, or
                   M/2 when nibble-packed)
        out_ref:   (QT, 1, BLK) f32
        """
        qt, m, k = lut_ref.shape
        codes = _tile_codes(codes_ref, packed)                 # (BLK, M)
        blk = codes.shape[0]
        # one-hot over the K table entries; flatten (M, K) -> MK for the MXU
        sel = (codes[:, :, None]
               == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2))
        oh = sel.astype(jnp.float32).reshape(blk, m * k)       # (BLK, MK)
        lut = lut_ref[...].reshape(qt, m * k)                  # (QT, MK)
        # (QT, MK) @ (MK, BLK) on the MXU: every query scores the block at once
        d = jax.lax.dot_general(lut, oh, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        out_ref[...] = d[:, None, :]

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("query_tile", "interpret", "packed"))
def pq_scan_tiled_kernel(lut: jnp.ndarray, block_codes: jnp.ndarray,
                         tile_idx: jnp.ndarray, *, query_tile: int = 8,
                         interpret: bool = False,
                         packed: bool = False) -> jnp.ndarray:
    """Per-tile paged scan: every query tile pages its *own* scan list.

    lut (B, M, K) f32, block_codes (TB, BLK, M) uint8, tile_idx
    (B // query_tile, S) -> (B, S, BLK) f32.  The scalar-prefetched
    ``tile_idx`` drives the BlockSpec index_map directly at tile
    granularity — the clustered exec mode hands each tile its own
    (tile-padded) block union with no re-broadcast to a batch-wide
    list.  B % query_tile == 0; entries must be valid (callers clamp
    padding to 0 and mask downstream).  With ``packed=True`` the code
    tile carries two 4-bit codes per byte (quant plane) and M must be
    2x the byte width — half the DMA bytes per block."""
    b, m, k = lut.shape
    qb, s = tile_idx.shape
    tb, blk, mb = block_codes.shape
    assert (2 * mb if packed else mb) == m, (mb, m, packed)
    assert b == qb * query_tile, (b, qb, query_tile)

    grid = (qb, s)
    kernel = pl.pallas_call(
        _make_kernel(packed),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((query_tile, m, k), lambda qi, si, idx: (qi, 0, 0)),
                pl.BlockSpec((1, blk, mb),
                             lambda qi, si, idx: (idx[qi, si], 0, 0)),
            ],
            out_specs=pl.BlockSpec((query_tile, 1, blk),
                                   lambda qi, si, idx: (qi, si, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, s, blk), jnp.float32),
        interpret=interpret,
    )
    return kernel(tile_idx, lut, block_codes)


def pq_scan_paged_kernel(lut: jnp.ndarray, block_codes: jnp.ndarray,
                         block_idx: jnp.ndarray, *, query_tile: int = 8,
                         interpret: bool = False, packed: bool = False,
                         debug: bool = False) -> jnp.ndarray:
    """lut (B, M, K) f32, block_codes (TB, BLK, M) uint8, block_idx (B, S)
    -> (B, S, BLK) f32.  B % query_tile == 0; block_idx entries must be
    valid (callers clamp padding to 0 and mask downstream).

    Paging is per (query-tile, position): with query_tile == 1 every query
    pages its own scan list; with query_tile > 1 every query of a tile
    MUST carry the same scan list (the paper's §5.3 list-major batch mode
    — see ops.pq_scan_grouped / ops.pq_scan_tiled), because only row 0 of
    each tile drives the paging index_map.  The invariant is enforced:
    eager calls raise ``ValueError`` on mismatched tile rows, and traced
    calls with ``debug=True`` emit a ``checkify.check`` (run the caller
    under ``checkify.checkify`` and ``err.throw()``) — misuse fails
    loudly instead of silently scoring the wrong blocks."""
    b = lut.shape[0]
    assert b % query_tile == 0, (b, query_tile)
    qb = b // query_tile
    s = block_idx.shape[1]
    rows = block_idx.reshape(qb, query_tile, s)
    if query_tile > 1:
        shared = jnp.all(rows == rows[:, :1, :])
        if not isinstance(block_idx, jax.core.Tracer):
            if not bool(shared):
                raise ValueError(
                    f"pq_scan_paged_kernel: query_tile={query_tile} but the "
                    "tile rows of block_idx disagree — per-tile paging "
                    "scores row 0's list for the whole tile.  Use "
                    "query_tile=1 (per-query paging) or a tile-shared scan "
                    "list (ops.pq_scan_grouped / ops.pq_scan_tiled).")
        elif debug:
            checkify.check(
                shared, "pq_scan_paged_kernel: tile rows of block_idx "
                "disagree under query_tile > 1 (tile-shared-list invariant)")
    return pq_scan_tiled_kernel(lut, block_codes, rows[:, 0, :],
                                query_tile=query_tile, interpret=interpret,
                                packed=packed)


def _make_topk_kernel(query_tile: int, blk: int, f: int, with_dead: bool,
                      packed: bool = False):
    """Kernel body factory for the fused scan->top-k (shapes are static)."""

    def kernel(idx_ref, lut_ref, codes_ref, bids_ref, bother_ref, rank_ref,
               slot_ref, ranku_ref, *rest):
        if with_dead:
            (dead_ref, acc_d_ref, acc_pos_ref, acc_id_ref, dco_ref) = rest
        else:
            (acc_d_ref, acc_pos_ref, acc_id_ref, dco_ref) = rest
        qt, m, k = lut_ref.shape
        si = pl.program_id(1)

        # the accumulator blocks map to (qi, 0) for every scan position,
        # so they stay resident in VMEM across the inner grid dimension;
        # first visit initializes them to the empty top-F
        @pl.when(si == 0)
        def _init():
            acc_d_ref[...] = jnp.full((qt, f), jnp.inf, jnp.float32)
            acc_pos_ref[...] = jnp.full((qt, f), PAD_POS, jnp.int32)
            acc_id_ref[...] = jnp.full((qt, f), -1, jnp.int32)
            dco_ref[...] = jnp.zeros((qt, 1), jnp.int32)

        # -- score the paged block: same one-hot MXU contraction as the
        # unfused kernel (_make_kernel), so distances are bitwise identical
        codes = _tile_codes(codes_ref, packed)                 # (BLK, M)
        onehot = (codes[:, :, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2))
        oh = onehot.astype(jnp.float32).reshape(blk, m * k)
        lut = lut_ref[...].reshape(qt, m * k)
        d = jax.lax.dot_general(lut, oh, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        # -- in-kernel keep mask (Alg. 5 L15-16, scan_blocks' post-hoc
        # logic moved here): invalid slots/absent union positions
        # (slot < 0), invalid items (id < 0), and misc duplicates whose
        # co-assigned list was scanned at an earlier probe rank
        ids = bids_ref[0]                                      # (BLK,)
        other = bother_ref[0]                                  # (BLK,)
        slot = slot_ref[...][:, 0]                             # (QT,)
        ranku = ranku_ref[...][:, 0]                           # (QT,)
        o = jnp.maximum(other, 0)
        orank = jnp.take_along_axis(
            rank_ref[...], jnp.broadcast_to(o[None, :], (qt, blk)), axis=1)
        dup = (other[None, :] >= 0) & (orank < ranku[:, None])
        item_ok = (ids[None, :] >= 0) & (slot[:, None] >= 0)
        keep = item_ok & ~dup
        if with_dead:
            # tombstoned candidates must not consume accumulator slots
            # (they are ADC-computed — DCO counts them — then discarded)
            keep &= dead_ref[0][None, :] == 0
        dco_ref[...] += jnp.sum(item_ok.astype(jnp.int32), axis=1,
                                keepdims=True)

        # -- candidate triple in plan layout: pos = slot*BLK + lane is the
        # flat position of the unfused stream, the lax.top_k tie-break
        lane = jax.lax.broadcasted_iota(jnp.int32, (qt, blk), 1)
        pos = slot[:, None] * blk + lane
        new = bitonic_sort([jnp.where(keep, d, jnp.inf),
                            jnp.where(keep, pos, PAD_POS),
                            jnp.where(keep, ids[None, :], -1)])
        if blk >= f:
            # candidates beyond a block's own top-F can never survive
            new = [x[:, :f] for x in new]
        else:
            pad = ((0, 0), (0, f - blk))
            new = [jnp.pad(new[0], pad, constant_values=jnp.inf),
                   jnp.pad(new[1], pad, constant_values=PAD_POS),
                   jnp.pad(new[2], pad, constant_values=-1)]
        acc = merge_topf([acc_d_ref[...], acc_pos_ref[...], acc_id_ref[...]],
                         new)
        acc_d_ref[...], acc_pos_ref[...], acc_id_ref[...] = acc

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("query_tile", "fetch", "interpret",
                                    "packed"))
def pq_scan_topk_kernel(lut: jnp.ndarray, block_codes: jnp.ndarray,
                        block_ids: jnp.ndarray, block_other: jnp.ndarray,
                        tile_idx: jnp.ndarray, rank_of: jnp.ndarray,
                        slot_of: jnp.ndarray, rank_u: jnp.ndarray,
                        dead=None, *, query_tile: int = 8, fetch: int = 64,
                        interpret: bool = False, packed: bool = False):
    """Fused paged scan -> partial top-``fetch``: only ``fetch`` candidates
    per query ever leave the kernel, instead of (S, BLK) scores.

    lut        (B, M, K) f32     per-query ADC tables
    block_codes(TB, BLK, M) u8   physical code blocks
    block_ids  (TB, BLK) i32     item ids (-1 invalid)
    block_other(TB, BLK) i32     co-assigned list of shared items (-1 none)
    tile_idx   (B//QT, S) i32    scalar-prefetched per-tile scan lists
    rank_of    (B, nlist) i32    probe rank table (BIG if unprobed)
    slot_of    (B, S) i32        plan slot of scan position s for query b
                                 (-1: not in this query's plan -> masked)
    rank_u     (B, S) i32        probe rank of that slot's scan
    dead       (TB, BLK) u8?     optional tombstone tile (1 = dead)

    Returns ``(acc_d, acc_pos, acc_id, dco)``: (B, fetch) ascending
    distances / plan-layout flat positions / ids, plus the (B,) logical
    DCO counter (one per valid item of a planned block, duplicates
    included — exactly ``scan_blocks``' accounting).  The accumulator
    triple lives in VMEM for the whole inner grid pass (out BlockSpecs
    constant in the scan dimension); each step is one bitonic sort of
    the block + one bitonic merge against the accumulator (kernels/
    topk.py), keyed lexicographically by (d, pos) so the result is
    bitwise the stable ``preselect_candidates`` selection over the
    unfused stream with masked entries at ``(+inf, PAD_POS, -1)``.
    """
    b, m, k = lut.shape
    qb, s = tile_idx.shape
    tb, blk, mb = block_codes.shape
    assert (2 * mb if packed else mb) == m, (mb, m, packed)
    assert b == qb * query_tile, (b, qb, query_tile)
    assert blk == pow2_ceil(blk), f"block size must be a power of 2: {blk}"
    assert slot_of.shape == (b, s), (slot_of.shape, (b, s))
    assert rank_u.shape == (b, s), (rank_u.shape, (b, s))
    f = pow2_ceil(max(fetch, 1))
    nlist = rank_of.shape[1]
    with_dead = dead is not None

    in_specs = [
        pl.BlockSpec((query_tile, m, k), lambda qi, si, idx: (qi, 0, 0)),
        pl.BlockSpec((1, blk, mb), lambda qi, si, idx: (idx[qi, si], 0, 0)),
        pl.BlockSpec((1, blk), lambda qi, si, idx: (idx[qi, si], 0)),
        pl.BlockSpec((1, blk), lambda qi, si, idx: (idx[qi, si], 0)),
        pl.BlockSpec((query_tile, nlist), lambda qi, si, idx: (qi, 0)),
        pl.BlockSpec((query_tile, 1), lambda qi, si, idx: (qi, si)),
        pl.BlockSpec((query_tile, 1), lambda qi, si, idx: (qi, si)),
    ]
    operands = [lut, block_codes, block_ids.astype(jnp.int32),
                block_other.astype(jnp.int32), rank_of.astype(jnp.int32),
                slot_of.astype(jnp.int32), rank_u.astype(jnp.int32)]
    if with_dead:
        in_specs.append(
            pl.BlockSpec((1, blk), lambda qi, si, idx: (idx[qi, si], 0)))
        operands.append(dead.astype(jnp.uint8))

    kernel = pl.pallas_call(
        _make_topk_kernel(query_tile, blk, f, with_dead, packed),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(qb, s),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((query_tile, f), lambda qi, si, idx: (qi, 0)),
                pl.BlockSpec((query_tile, f), lambda qi, si, idx: (qi, 0)),
                pl.BlockSpec((query_tile, f), lambda qi, si, idx: (qi, 0)),
                pl.BlockSpec((query_tile, 1), lambda qi, si, idx: (qi, 0)),
            ]),
        out_shape=[
            jax.ShapeDtypeStruct((b, f), jnp.float32),
            jax.ShapeDtypeStruct((b, f), jnp.int32),
            jax.ShapeDtypeStruct((b, f), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )
    acc_d, acc_pos, acc_id, dco = kernel(tile_idx.astype(jnp.int32),
                                         *operands)
    return (acc_d[:, :fetch], acc_pos[:, :fetch], acc_id[:, :fetch],
            dco[:, 0])
