"""Pure-jnp oracles for the Pallas kernels (ground truth for tests)."""
from __future__ import annotations

import jax.numpy as jnp


def adc_gather(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """The reference ADC gather shared by oracle and engine fallback.

    lut (B, M, K) f32, codes (B, S, BLK, M) -> (B, S, BLK) distances:
    out[b,s,i] = sum_m lut[b, m, codes[b,s,i,m]].  The single source of
    truth — ``core/engine/scan.py`` imports this same function for its
    jnp scan path, so oracle and engine can never diverge."""
    g = jnp.take_along_axis(
        lut[:, None, None, :, :],                        # (B,1,1,M,K)
        codes.astype(jnp.int32)[..., None], axis=-1)     # (B,S,BLK,M,1)
    return jnp.sum(g[..., 0], axis=-1)


def pq_scan_paged_ref(lut: jnp.ndarray, block_codes: jnp.ndarray,
                      block_idx: jnp.ndarray) -> jnp.ndarray:
    """ADC distances over paged code blocks.

    lut:         (B, M, K) f32 per-query subspace tables
    block_codes: (TB, BLK, M) uint8 codes, values < K
    block_idx:   (B, S) int32 physical block ids (callers pre-clamp to >=0)
    returns      (B, S, BLK) f32:  out[b,s,i] = sum_m lut[b, m, codes[i,m]]
    """
    return adc_gather(lut, block_codes[block_idx])


def onehot_lut_ref(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Single-tile oracle: lut (M, K), codes (N, M) -> (N,) distances,
    written the way the TPU kernel computes it (one-hot contraction)."""
    m, k = lut.shape
    oh = (codes[:, :, None] == jnp.arange(k)[None, None, :]).astype(lut.dtype)
    return (oh.reshape(codes.shape[0], m * k) @ lut.reshape(m * k))
