"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel
body executes in Python for correctness validation; on TPU backends the
same code lowers to Mosaic.  ``M`` (PQ subspaces) is zero-padded to the
uint8 lane tile so production shapes are alignment-clean; padded codes
are 0 and padded LUT rows are 0, so they contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .pq_scan import (pq_scan_paged_kernel, pq_scan_tiled_kernel,
                      pq_scan_topk_kernel)

_LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_m(lut: jnp.ndarray, block_codes: jnp.ndarray, align: int):
    m = lut.shape[1]
    pad = (-m) % align
    if pad:
        lut = jnp.pad(lut, ((0, 0), (0, pad), (0, 0)))
        block_codes = jnp.pad(block_codes, ((0, 0), (0, 0), (0, pad)))
    return lut, block_codes


def _pad_m_packed(lut: jnp.ndarray, block_codes: jnp.ndarray, on_tpu: bool):
    """Align a nibble-packed code plane with its LUT.

    The kernel unpacks each byte into two codes, so its effective M is
    always 2x the byte width: the LUT is zero-padded to that width on
    every backend (this also absorbs an odd Mc's phantom hi nibble),
    and on TPU the byte width is first padded to half the uint8 lane
    tile so the unpacked M lands on the lane boundary.  Padded bytes
    are 0 -> both nibbles select zero LUT rows -> contribute nothing.
    """
    mb = block_codes.shape[-1]
    if on_tpu:
        pad_b = (-mb) % (_LANE // 2)
        if pad_b:
            block_codes = jnp.pad(block_codes,
                                  ((0, 0), (0, 0), (0, pad_b)))
            mb += pad_b
    pad = 2 * mb - lut.shape[1]
    if pad:
        lut = jnp.pad(lut, ((0, 0), (0, pad), (0, 0)))
    return lut, block_codes


def _align(lut, block_codes, packed: bool, on_tpu: bool):
    if packed:
        return _pad_m_packed(lut, block_codes, on_tpu)
    if on_tpu:
        return _pad_m(lut, block_codes, _LANE)
    return lut, block_codes


@functools.partial(jax.jit, static_argnames=("packed",))
def pq_scan_paged(lut: jnp.ndarray, block_codes: jnp.ndarray,
                  block_idx: jnp.ndarray, *,
                  packed: bool = False) -> jnp.ndarray:
    """Per-query paged ADC scan.  lut (B, M, K) f32, block_codes
    (TB, BLK, M) uint8, block_idx (B, S) int32 (>= 0) -> (B, S, BLK) f32."""
    on_tpu = _on_tpu()
    lut, block_codes = _align(lut, block_codes, packed, on_tpu)
    return pq_scan_paged_kernel(lut, block_codes, block_idx.astype(jnp.int32),
                                query_tile=1, interpret=not on_tpu,
                                packed=packed)


def pq_scan_grouped(lut: jnp.ndarray, block_codes: jnp.ndarray,
                    shared_idx: jnp.ndarray, query_tile: int = 8,
                    *, packed: bool = False) -> jnp.ndarray:
    """List-major batch mode (paper §5.3 cache optimization): all B queries
    score the SAME scan list.  lut (B, M, K), shared_idx (S,) -> (B, S, BLK).
    The code tile for each position stays resident in VMEM across the
    query-tile grid steps."""
    b = lut.shape[0]
    on_tpu = _on_tpu()
    lut, block_codes = _align(lut, block_codes, packed, on_tpu)
    idx = jnp.broadcast_to(shared_idx[None, :],
                           (b // query_tile, shared_idx.shape[0]))
    return pq_scan_tiled_kernel(lut, block_codes, idx.astype(jnp.int32),
                                query_tile=query_tile, interpret=not on_tpu,
                                packed=packed)


def pq_scan_tiled(lut: jnp.ndarray, block_codes: jnp.ndarray,
                  tile_idx: jnp.ndarray, query_tile: int = 8,
                  *, packed: bool = False) -> jnp.ndarray:
    """Clustered mode (locality-aware §5.3): each query *tile* scores its
    own scan list — the tile's block union, padded per tile rather than
    to the batch-wide maximum.  lut (B, M, K) in cluster order, tile_idx
    (B // query_tile, W) -> (B, W, BLK).  The scalar-prefetched tile
    lists feed the kernel index_map directly (no (B, W) re-broadcast);
    the code tile for each union position stays resident in VMEM across
    its tile's grid steps."""
    on_tpu = _on_tpu()
    lut, block_codes = _align(lut, block_codes, packed, on_tpu)
    return pq_scan_tiled_kernel(lut, block_codes, tile_idx.astype(jnp.int32),
                                query_tile=query_tile, interpret=not on_tpu,
                                packed=packed)


def pq_scan_topk(lut: jnp.ndarray, block_codes: jnp.ndarray,
                 block_ids: jnp.ndarray, block_other: jnp.ndarray,
                 tile_idx: jnp.ndarray, rank_of: jnp.ndarray,
                 slot_of: jnp.ndarray, rank_u: jnp.ndarray, dead=None,
                 *, fetch: int, query_tile: int = 8, packed: bool = False):
    """Fused scan -> top-``fetch``: the paged ADC scan with the keep mask
    and the stable partial top-k folded into the kernel, so only
    ``fetch`` candidates per query cross the HBM boundary instead of
    (S, BLK) scores.  tile_idx (B // query_tile, S) pages per-tile scan
    lists exactly like ``pq_scan_tiled``; ``slot_of``/``rank_u`` (B, S)
    map each scan position back to the query's plan slot (see
    ``core/engine/fused.py`` for the per-exec-mode construction).
    Returns (acc_d, acc_pos, acc_id, dco) — (B, fetch) sorted candidate
    triple + (B,) logical DCO."""
    on_tpu = _on_tpu()
    lut, block_codes = _align(lut, block_codes, packed, on_tpu)
    return pq_scan_topk_kernel(
        lut, block_codes, block_ids, block_other,
        tile_idx.astype(jnp.int32), rank_of, slot_of, rank_u, dead,
        query_tile=query_tile, fetch=fetch, interpret=not on_tpu,
        packed=packed)
