"""Typed error taxonomy for the serving stack (DESIGN.md §13).

Every failure a caller can observe from the gateway, the streaming
handover machinery, or the persistence layer is a subclass of
``RairsError`` — so ``except RairsError`` catches "the system told me
no" while letting genuine bugs (TypeError, KeyError, ...) propagate.

Several leaves *also* subclass the stdlib exception callers
historically saw at that site (``GatewayClosed`` is a RuntimeError,
``DeadlineExceeded`` a TimeoutError, ``CorruptBundleError`` a
ValueError), so pre-taxonomy ``except`` clauses keep working — the
taxonomy tightens what is raised, never what is caught.

This module is dependency-free on purpose: anything (core/io.py, the
gateway, the fault injector, the stdlib-only regression gate's test
fixtures) may import it without pulling in jax.
"""
from __future__ import annotations

__all__ = [
    "RairsError",
    "Overloaded",
    "DeadlineExceeded",
    "GatewayClosed",
    "HandoverFailed",
    "CorruptBundleError",
    "FaultInjected",
]


class RairsError(Exception):
    """Root of every deliberate, typed failure this system raises."""


class Overloaded(RairsError):
    """Admission control shed the request: the gateway queue was at
    ``max_queue`` under the ``reject`` overload policy.  The request
    was never enqueued; retrying after backoff is safe."""


class DeadlineExceeded(RairsError, TimeoutError):
    """The request's deadline passed before dispatch.  Raised at
    dequeue time — a request that has already blown its budget is
    failed, never scanned.  Subclasses TimeoutError so generic
    timeout handling still applies."""


class GatewayClosed(RairsError, RuntimeError):
    """The gateway is shut down (or closed while this request was
    queued past the drain window).  Subclasses RuntimeError: callers
    that caught the old ``RuntimeError("gateway is closed")`` still
    do."""


class HandoverFailed(RairsError, RuntimeError):
    """Async compaction failed after exhausting its retry budget; the
    gateway rolled back to the pinned old epoch and keeps serving.
    ``__cause__`` carries the final underlying exception."""


class CorruptBundleError(RairsError, ValueError):
    """A persisted index bundle failed integrity verification
    (truncated file, bad magic, or a per-array crc32 mismatch).  The
    message names the offending member, e.g.
    ``shard_0003-1a2b3c4d.npz:block_codes``."""


class FaultInjected(RairsError):
    """Raised by an installed ``FaultPlan`` at a ``raise``-kind fault
    site.  Only ever seen in chaos tests — production code paths treat
    it like any other dispatch/worker failure."""
