"""Async serving gateway (DESIGN.md §10).

Deadline-batched request queue + probe-signature admission over the
compiled ``Searcher`` session layer, zero-downtime epoch handover for
streaming indexes, and first-class pluggable telemetry::

    from repro.gateway import Gateway, GatewayConfig, LogSink

    with Gateway(index, k=10, nprobe=8,
                 config=GatewayConfig(max_delay_ms=2.0, max_batch=64),
                 sinks=(LogSink(),)) as gw:
        ids = gw.search(q).ids          # blocking, or gw.submit(q) async
        print(gw.stats()["telemetry"]["batch_fill"])

Overload resilience (DESIGN.md §13): ``GatewayConfig(max_queue=...,
overload="reject"|"block")`` bounds admission (shed requests fail with
``repro.errors.Overloaded``), ``degrade=degrade_ladder(params)`` steps
quality down under sustained queue pressure and back up when load
recedes, and requests past their deadline fail typed at dequeue.
"""
from ..errors import (DeadlineExceeded, GatewayClosed,  # noqa: F401
                      HandoverFailed, Overloaded, RairsError)
from .gateway import (Gateway, GatewayConfig, Handover,  # noqa: F401
                      degrade_ladder)
from .loadgen import run_open_loop  # noqa: F401
from .queue import PendingRequest, RequestQueue, RequestResult  # noqa: F401
from .telemetry import (LatencyHistogram, LogSink, MemorySink,  # noqa: F401
                        Telemetry, TelemetrySink)
