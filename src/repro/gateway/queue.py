"""Deadline-batched request queue with probe-signature admission.

The queue is the gateway's coalescing buffer: single-query arrivals
wait here until either the oldest request's flush deadline expires or a
full dispatch bucket has accumulated — whichever comes first — and are
then taken as one batch (``Gateway`` dispatches it through a compiled
``Searcher`` bucket).

Admission is *probe-signature-aware*: each request carries the id of
its nearest centroid (its rank-0 probed list, computed host-side at
submit time), and the queue keeps one FIFO lane per signature.
``take_batch`` drains whole lanes oldest-first, so requests probing the
same lists land in the same dispatch — exactly the traffic shape the
clustered exec mode and the session ``plan_reuse`` cache are built for
(queries sharing probed lists co-tile, and adjacent batches re-probe
the same hot lists).  FIFO order is preserved *within* a lane, and
lanes are served by the age of their oldest request, so signature
grouping can reorder requests only within one flush window — bounded
by the deadline, never starvation.

Admission is *bounded* (DESIGN.md §13): with ``max_queue`` set, a full
queue either sheds the arrival (``policy="reject"`` raises
``Overloaded`` — the producer was never enqueued, retry after backoff
is safe) or applies backpressure (``policy="block"`` parks the
producer thread until the dispatcher frees a slot).  Unbounded is the
default only because the gateway owns choosing a bound.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import List, NamedTuple, Optional

from ..errors import GatewayClosed, Overloaded

_OVERLOAD_POLICIES = ("reject", "block")


class RequestResult(NamedTuple):
    """What a completed request resolves to."""
    ids: "object"          # (k,) int64 result ids (external ids under churn)
    dists: "object"        # (k,) float32 exact distances
    latency_s: float       # enqueue -> fulfilled
    queued_s: float        # enqueue -> taken into a batch
    batch: int             # coalesced batch size this request rode in
    epoch: int             # index epoch that served it
    level: int = 0         # degradation-ladder quality level (0 = full)


class PendingRequest:
    """A submitted query: future-like handle the client blocks on."""

    __slots__ = ("query", "t_enqueue", "deadline", "signature",
                 "_event", "_result", "_error")

    def __init__(self, query, signature: int,
                 deadline: Optional[float] = None):
        self.query = query
        self.t_enqueue = time.perf_counter()
        self.deadline = deadline      # absolute perf_counter time or None
        self.signature = signature
        self._event = threading.Event()
        self._result: Optional[RequestResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RequestResult:
        """Block until fulfilled; raises the dispatch error if it failed."""
        if not self._event.wait(timeout):
            raise TimeoutError("gateway request not fulfilled in time")
        if self._error is not None:
            raise self._error
        return self._result

    # -- fulfilled by the dispatcher ------------------------------------
    def _fulfill(self, result: RequestResult) -> None:
        self._result = result
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()


class RequestQueue:
    """Signature-laned FIFO with a condition variable the dispatcher
    sleeps on.  All methods are thread-safe."""

    def __init__(self, grouped: bool = True,
                 max_queue: Optional[int] = None, policy: str = "reject"):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 or None, "
                             f"got {max_queue}")
        if policy not in _OVERLOAD_POLICIES:
            raise ValueError(f"policy must be one of {_OVERLOAD_POLICIES}, "
                             f"got {policy!r}")
        self.grouped = grouped
        self.max_queue = max_queue
        self.policy = policy
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # one FIFO lane per probe signature (signature 0 lane only when
        # grouping is off); OrderedDict keeps lane creation order cheap
        self._lanes: "collections.OrderedDict[int, collections.deque]" = \
            collections.OrderedDict()
        self._depth = 0
        self._peak = 0
        self._closed = False

    @property
    def depth(self) -> int:
        return self._depth

    def take_peak(self) -> int:
        """High-watermark depth since the last call (and reset to the
        current depth).  The degradation ladder keys on this, not on an
        instantaneous sample: the dispatcher wakes the moment a full
        batch accumulates, so sampling depth right after the flush wait
        systematically reads ~max_batch even while the queue saturates
        and sheds *between* wakeups."""
        with self._lock:
            peak = self._peak
            self._peak = self._depth
            return peak

    def put(self, req: PendingRequest) -> None:
        """Enqueue one request, applying the overload policy when the
        queue is bounded and full: "reject" raises ``Overloaded``
        without enqueuing; "block" parks this producer until the
        dispatcher frees a slot (raising ``GatewayClosed`` if the
        gateway shuts down while it waits)."""
        key = req.signature if self.grouped else 0
        with self._cond:
            if self.max_queue is not None and self._depth >= self.max_queue:
                if self.policy == "reject":
                    raise Overloaded(
                        f"queue at max_queue={self.max_queue}; shed")
                while self._depth >= self.max_queue and not self._closed:
                    self._cond.wait()
            if self._closed:
                raise GatewayClosed("gateway is closed")
            lane = self._lanes.get(key)
            if lane is None:
                lane = self._lanes[key] = collections.deque()
            lane.append(req)
            self._depth += 1
            if self._depth > self._peak:
                self._peak = self._depth
            self._cond.notify()

    def kick(self) -> None:
        """Wake the dispatcher without enqueuing (close, handover-ready)."""
        with self._cond:
            self._cond.notify_all()

    def close(self) -> None:
        """Mark closed and wake everyone: blocked producers raise
        ``GatewayClosed``, the dispatcher sees the flag and drains."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def oldest_flush_at(self, max_delay: float) -> Optional[float]:
        """Earliest moment any queued request must flush (perf_counter
        time), honoring per-request deadlines; None when empty."""
        with self._lock:
            t = None
            for lane in self._lanes.values():
                if not lane:
                    continue
                r = lane[0]
                due = r.t_enqueue + max_delay
                if r.deadline is not None:
                    due = min(due, r.deadline)
                t = due if t is None else min(t, due)
            return t

    def wait_for_work(self, timeout: Optional[float]) -> None:
        """Sleep until a request arrives, a kick, or the timeout."""
        with self._cond:
            if self._depth == 0:
                self._cond.wait(timeout)

    def wait_for_flush(self, max_batch: int, due: float) -> None:
        """Sleep out the coalescing window: returns once ``max_batch``
        requests have accumulated or the flush deadline ``due``
        (perf_counter time) passes."""
        with self._cond:
            while self._depth < max_batch:
                remaining = due - time.perf_counter()
                if remaining <= 0:
                    return
                self._cond.wait(remaining)

    def take_expired(self, now: float) -> List[PendingRequest]:
        """Remove (and return) every queued request whose deadline is
        already past at ``now`` — the dispatcher fails these with
        ``DeadlineExceeded`` instead of dispatching them (a scan whose
        client has given up is pure wasted capacity)."""
        with self._cond:
            if self._depth == 0:
                return []
            out: List[PendingRequest] = []
            for key in list(self._lanes):
                lane = self._lanes[key]
                kept = collections.deque(
                    r for r in lane
                    if r.deadline is None or r.deadline >= now)
                if len(kept) != len(lane):
                    out.extend(r for r in lane
                               if r.deadline is not None and r.deadline < now)
                    if kept:
                        self._lanes[key] = kept
                    else:
                        del self._lanes[key]
            self._depth -= len(out)
            if out:
                self._cond.notify_all()   # free slots for blocked producers
            return out

    def take_batch(self, max_batch: int) -> List[PendingRequest]:
        """Drain up to ``max_batch`` requests, whole signature lanes at a
        time, lanes ordered by their oldest member (never starves)."""
        with self._cond:
            if self._depth == 0:
                return []
            order = sorted(
                (k for k, lane in self._lanes.items() if lane),
                key=lambda k: self._lanes[k][0].t_enqueue)
            out: List[PendingRequest] = []
            for key in order:
                lane = self._lanes[key]
                while lane and len(out) < max_batch:
                    out.append(lane.popleft())
                if not lane:
                    del self._lanes[key]
                if len(out) >= max_batch:
                    break
            self._depth -= len(out)
            if out:
                self._cond.notify_all()   # free slots for blocked producers
            return out
