"""The async serving gateway (DESIGN.md §10).

``Gateway`` turns the session layer into a service: single-query
requests arrive continuously (``submit`` / ``search`` from any thread),
wait in a deadline-batched queue (queue.py), and a dispatcher thread
coalesces them into the pad-and-dispatch batch buckets the ``Searcher``
sessions already AOT-compile — flushing on the oldest request's
deadline or on a full bucket, whichever comes first.  Admission groups
requests by probe signature so clustered tiles and the ``plan_reuse``
cache stay hot across consecutive dispatches.

Zero-downtime epoch handover (streaming indexes): ``compact_async``
snapshots the epoch (``StreamingIndex.begin_compact``), folds it on a
worker thread while the dispatcher keeps serving the pinned old-epoch
session, and the dispatcher installs the new epoch atomically *between*
batches — no in-flight request is dropped or stale-errored, and
because responses carry stable external ids, results clients are
holding remain valid across the swap (``resolve_ids``).

Handover state machine::

    IDLE --compact_async--> FOLDING --fold done--> READY
    READY --dispatcher, between batches--> INSTALLING --> IDLE
                (install + session refresh + width-ladder warmup)

Telemetry is first-class and pluggable (telemetry.py): QPS, DCO,
queue depth, batch-fill ratio, recall proxies, and p50/p95/p99 latency
histograms via ``stats()`` plus a periodic structured JSON log.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Tuple

import numpy as np

from .. import obs
from ..core.params import SearchParams
from ..core.stream.streaming import StaleSessionError, StreamingIndex
from .queue import PendingRequest, RequestQueue, RequestResult
from .telemetry import Telemetry, TelemetrySink

_ADMISSION_MODES = ("signature", "fifo")


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Gateway-side knobs (query knobs stay in ``SearchParams``).

    max_delay_ms        micro-batch deadline: the longest a request may
                        wait for co-batching before it flushes anyway
    max_batch           coalescing target (clamped to the session's
                        ``max_chunk``); a full bucket flushes early
    admission           "signature" groups requests by their rank-0
                        probed list (plan/tile locality), "fifo" is
                        arrival order only
    warmup              pre-compile the dispatch bucket (and, with
                        plan_reuse, the whole union-width ladder) at
                        startup and after each epoch swap
    telemetry_interval_s  period of the structured telemetry log through
                        the configured sinks (0 = no periodic log)
    compact_delta_frac  background-handover trigger: delta slots exceed
                        this fraction of the base (None = explicit only)
    compact_dead_frac   background-handover trigger: tombstones exceed
                        this fraction of the id space (None = explicit)
    """
    max_delay_ms: float = 2.0
    max_batch: int = 256
    admission: str = "signature"
    warmup: bool = True
    telemetry_interval_s: float = 0.0
    compact_delta_frac: Optional[float] = None
    compact_dead_frac: Optional[float] = None

    def __post_init__(self):
        if self.max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0, got {self.max_delay_ms}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.admission not in _ADMISSION_MODES:
            raise ValueError(f"admission must be one of {_ADMISSION_MODES}, "
                             f"got {self.admission!r}")
        for name in ("compact_delta_frac", "compact_dead_frac"):
            v = getattr(self, name)
            if v is not None and not v > 0:
                raise ValueError(f"{name} must be > 0 or None, got {v!r}")


class Handover:
    """Handle for one zero-downtime epoch swap (``compact_async``)."""

    def __init__(self, pending):
        self.pending = pending
        self.state = "folding"     # folding -> ready -> installed | failed
        self.info: Optional[dict] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def wait(self, timeout: Optional[float] = None) -> dict:
        """Block until installed; returns the install info dict."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"handover still {self.state}")
        if self.error is not None:
            raise self.error
        return self.info


class Gateway:
    """Deadline-batched serving front-end over any index exposing the
    session protocol (``RairsIndex`` / ``StreamingIndex`` /
    ``ShardedIndex``).  Create, submit from any thread, ``close()`` (or
    use as a context manager) to drain and stop."""

    def __init__(self, index, params: Optional[SearchParams] = None,
                 config: Optional[GatewayConfig] = None,
                 sinks: Tuple[TelemetrySink, ...] = (), **param_kwargs):
        if params is None:
            params = SearchParams(**param_kwargs)
        elif param_kwargs:
            params = dataclasses.replace(params, **param_kwargs)
        self.index = index
        self.params = params.resolve(index)
        cfg = config or GatewayConfig()
        if cfg.max_batch > self.params.max_chunk:
            cfg = dataclasses.replace(cfg, max_batch=self.params.max_chunk)
        self.config = cfg
        self.telemetry = Telemetry()
        self._sinks = tuple(sinks)
        self._is_stream = isinstance(index, StreamingIndex)
        if not self._is_stream and (cfg.compact_delta_frac is not None
                                    or cfg.compact_dead_frac is not None):
            raise ValueError("compact_*_frac thresholds need a "
                             "StreamingIndex (nothing to compact otherwise)")
        self.queue = RequestQueue(grouped=cfg.admission == "signature")
        # host-side probe-signature scorer: centroids are frozen across
        # compaction, so one copy serves every epoch
        self._centroids = np.asarray(index.centroids, np.float32)
        self._c2 = (self._centroids ** 2).sum(axis=1)
        self._metric = index.config.metric
        self._dim = int(self._centroids.shape[1])
        self._lock = threading.RLock()   # session use + mutations + install
        self._last_session = None
        self._handover: Optional[Handover] = None
        self._last_handover: Optional[dict] = None
        self._last_emit = time.perf_counter()
        self._closed = threading.Event()
        with self._lock:
            self._session_locked()       # build + warm the serving session
        self._thread = threading.Thread(
            target=self._serve_loop, name="gateway-dispatch", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    # client API (any thread)
    # ------------------------------------------------------------------
    def submit(self, query, deadline_s: Optional[float] = None
               ) -> PendingRequest:
        """Enqueue one query vector; returns a future-like handle.
        ``deadline_s`` tightens this request's flush deadline below the
        gateway-wide ``max_delay_ms`` (it never loosens it)."""
        if self._closed.is_set():
            raise RuntimeError("gateway is closed")
        with obs.span("gateway.submit", cat="gateway"):
            q = np.asarray(query, np.float32)
            if q.ndim == 2 and q.shape[0] == 1:
                q = q[0]
            if q.ndim != 1 or q.shape[0] != self._dim:
                raise ValueError(
                    f"query must be ({self._dim},), got shape {q.shape}")
            sig = self._signature(q) if self.queue.grouped else 0
            deadline = (time.perf_counter() + deadline_s
                        if deadline_s is not None else None)
            req = PendingRequest(q, sig, deadline=deadline)
            self.telemetry.inc("requests")
            self.queue.put(req)
        return req

    def search(self, query, timeout: Optional[float] = None) -> RequestResult:
        """Blocking single-query convenience over ``submit``."""
        return self.submit(query).result(timeout)

    # -- mutations (streaming indexes; serialized with dispatch) --------
    def insert(self, x) -> np.ndarray:
        """Insert vectors; returns their *stable external* ids (valid
        across any number of epoch handovers)."""
        self._require_stream("insert")
        with self._lock:
            ids = self.index.insert(x)
            ext = self.index.external_ids(ids)
        self.telemetry.inc("inserts", int(ext.size))
        self._maybe_auto_handover()
        return ext

    def delete(self, external_ids) -> int:
        """Tombstone items by their external ids; returns how many were
        live.  Unknown / already-dead handles are a no-op."""
        self._require_stream("delete")
        with self._lock:
            internal = self.index.resolve_ids(external_ids)
            n = self.index.delete(internal[internal >= 0])
        self.telemetry.inc("deletes", n)
        self._maybe_auto_handover()
        return n

    def resolve_ids(self, external_ids) -> np.ndarray:
        """Current internal ids for previously returned external ids."""
        self._require_stream("resolve_ids")
        with self._lock:
            return self.index.resolve_ids(external_ids)

    # -- zero-downtime handover -----------------------------------------
    def compact_async(self, reason: str = "gateway") -> Handover:
        """Start a background epoch handover; serving continues on the
        old epoch until the dispatcher installs the folded one between
        batches.  Returns a ``Handover`` to ``wait()`` on; idempotent
        while one is in flight."""
        self._require_stream("compact_async")
        with self._lock:
            if self._handover is not None:
                return self._handover
            pending = self.index.begin_compact(reason)
            h = Handover(pending)
            self._handover = h
        threading.Thread(target=self._fold_worker, args=(h,),
                         name="gateway-fold", daemon=True).start()
        return h

    def _fold_worker(self, h: Handover) -> None:
        try:
            h.pending.fold()
            h.state = "ready"
        except BaseException as e:   # surface through the handle
            h.error = e
            h.state = "failed"
            h.pending.abort()
            with self._lock:
                self._handover = None
            h._done.set()
        self.queue.kick()            # wake the dispatcher to install

    def _maybe_auto_handover(self) -> None:
        c = self.config
        st = self.index
        if self._handover is not None:
            return
        n_delta_slots = st.n_total - st.n_base
        if (c.compact_delta_frac is not None
                and n_delta_slots > c.compact_delta_frac
                * max(1, st.n_base)):
            self.compact_async("delta_threshold")
        elif (c.compact_dead_frac is not None
                and st.n_dead > c.compact_dead_frac * max(1, st.n_total)):
            self.compact_async("dead_threshold")

    # ------------------------------------------------------------------
    # observability / lifecycle
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """One coherent dict: telemetry snapshot, queue depth, handover
        state, session compile stats, and (streaming) epoch state."""
        h = self._handover
        out = {
            "telemetry": self.telemetry.snapshot(),
            "queue_depth": self.queue.depth,
            "closed": self._closed.is_set(),
            "handover": {"state": h.state if h is not None else "idle",
                         "last": self._last_handover},
        }
        sess = self._last_session
        if sess is not None:
            out["session"] = sess.compile_stats()
        if self._is_stream:
            st = self.index
            out["stream"] = {"epoch": st.epoch, "version": st.version,
                             "n_live": st.n_live, "n_delta": st.n_delta,
                             "n_dead": st.n_dead}
        return out

    def close(self, timeout: float = 30.0) -> None:
        """Drain the queue, stop the dispatcher, emit a final record."""
        if self._closed.is_set():
            return
        self._closed.set()
        self.queue.kick()
        self._thread.join(timeout)
        if self._sinks:
            self.telemetry.emit(self._sinks, kind="gateway_final",
                                extra={"queue_depth": self.queue.depth})

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # dispatcher internals
    # ------------------------------------------------------------------
    def _require_stream(self, what: str) -> None:
        if not self._is_stream:
            raise TypeError(f"{what} needs a StreamingIndex-backed gateway "
                            f"(got {type(self.index).__name__})")

    def _bucket_ladder(self) -> list:
        """Every dispatch bucket a flush can land in: deadline flushes
        carry anywhere from 1 to ``max_batch`` requests."""
        p = self.params
        top = p.bucket_for(min(self.config.max_batch, p.max_chunk))
        if p.batch_buckets is not None:
            return [b for b in p.batch_buckets if b <= top]
        sizes, b = [], 1
        while b <= top:
            sizes.append(b)
            b *= 2
        return sizes

    def _signature(self, q: np.ndarray) -> int:
        """Rank-0 probed list, host-side (admission locality hint)."""
        if self._metric == "ip":
            return int(np.argmax(self._centroids @ q))
        return int(np.argmin(self._c2 - 2.0 * (self._centroids @ q)))

    def _session_locked(self):
        """The current serving session; refreshed (and, on an epoch
        change, width-warmed) when the index has moved past it."""
        if self._is_stream:
            sess = self.index.searcher(self.params)
        elif self._last_session is None:
            sess = self.index.searcher(self.params)
        else:
            sess = self._last_session
        if sess is not self._last_session:
            prev_epoch = getattr(self._last_session, "epoch", None)
            if self.config.warmup and sess.epoch != prev_epoch:
                # a new epoch starts with cold executable caches: pre-pay
                # the compiles now, not on the first request — every
                # batch bucket a partial flush can dispatch at (and with
                # plan_reuse, each bucket's union-width ladder).  A
                # pristine streaming session delegates to its base
                # session — warm the delegate.
                target = getattr(sess, "_delegate", None) or sess
                before = target.stats.warmup_compiles
                target.warmup_widths(*self._bucket_ladder())
                self.telemetry.inc(
                    "warmup_compiles",
                    target.stats.warmup_compiles - before)
            self._last_session = sess
        return sess

    def _serve_loop(self) -> None:
        try:
            while True:
                self._install_if_ready()
                self._maybe_emit()
                if self._closed.is_set() and self.queue.depth == 0:
                    break
                due = self.queue.oldest_flush_at(
                    self.config.max_delay_ms / 1e3)
                if due is None:
                    self.queue.wait_for_work(0.05)   # idle tick
                    continue
                if not self._closed.is_set():        # draining flushes now
                    self.queue.wait_for_flush(self.config.max_batch, due)
                batch = self.queue.take_batch(self.config.max_batch)
                if batch:
                    self._dispatch(batch)
        finally:
            for req in self.queue.take_batch(1 << 30):   # never strand
                req._fail(RuntimeError("gateway closed"))

    def _install_if_ready(self) -> None:
        h = self._handover
        if h is None or h.state != "ready":
            return
        try:
            with self._lock:
                info = h.pending.install()
                self._session_locked()   # refresh + warm the new epoch
        except BaseException as e:
            h.error = e
            h.state = "failed"
        else:
            h.info = info
            h.state = "installed"
            self._last_handover = {k: v for k, v in info.items()
                                   if k != "id_remap"}
            self.telemetry.inc("handovers")
        with self._lock:
            self._handover = None
        h._done.set()

    def _dispatch(self, batch) -> None:
        tm = self.telemetry
        t_take = time.perf_counter()
        for r in batch:
            tm.record_latency(tm.queue_wait, t_take - r.t_enqueue)
        tm.gauge("queue_depth", self.queue.depth)
        with obs.span("gateway.flush", cat="gateway",
                      batch=len(batch)) as fsp:
            q = np.stack([r.query for r in batch])
            try:
                with self._lock:
                    res, epoch = self._search_locked(q)
                    ids = np.asarray(res.ids)
                    if self._is_stream:
                        # responses carry stable external ids so clients
                        # survive epoch handovers (resolve_ids maps back)
                        ids = self.index.external_ids(ids)
                    else:
                        ids = ids.astype(np.int64)
                    dists = np.asarray(res.dists)
                    approx = float(np.sum(np.asarray(res.approx_dco)))
                    refine = float(np.sum(np.asarray(res.refine_dco)))
            except BaseException as e:
                tm.inc("errors", len(batch))
                for r in batch:
                    r._fail(e)
                return
            fsp.add(approx_dco=approx, refine_dco=refine)
        t_done = time.perf_counter()
        tm.record_latency(tm.dispatch, t_done - t_take)
        tm.inc("batches")
        tm.inc("responses", len(batch))
        tm.inc("bucket_rows", self.params.bucket_for(
            min(len(batch), self.params.max_chunk)))
        tm.add("approx_dco", approx)
        tm.add("refine_dco", refine)
        tm.add("result_slots", float(ids.size))
        tm.add("result_filled", float((ids >= 0).sum()))
        # exact top-1 distances are signed under the ip metric (finalize
        # scores are negated inner products) — not a monotone counter
        tm.add_signed("top1_dist", float(dists[:, 0].sum()))
        tr = obs.tracer()
        for i, r in enumerate(batch):
            tm.record_latency(tm.latency, t_done - r.t_enqueue)
            if tr is not None and tr.sampled():
                # one exemplar complete-event per sampled request,
                # spanning enqueue -> fulfill on a virtual request track
                tr.event("gateway.request", r.t_enqueue,
                         t_done - r.t_enqueue,
                         queued_ms=(t_take - r.t_enqueue) * 1e3,
                         batch=len(batch), epoch=epoch)
            r._fulfill(RequestResult(
                ids=ids[i], dists=dists[i], latency_s=t_done - r.t_enqueue,
                queued_s=t_take - r.t_enqueue, batch=len(batch),
                epoch=epoch))

    def _search_locked(self, q: np.ndarray):
        """Dispatch through the current session; a session staled by an
        out-of-band mutation (the caller bypassing the gateway) is
        refreshed and retried rather than surfacing to clients."""
        last_err = None
        for _ in range(3):
            sess = self._session_locked()
            try:
                return sess(q), getattr(sess, "epoch", 0)
            except StaleSessionError as e:
                self.telemetry.inc("stale_retries")
                last_err = e
        raise last_err

    def _maybe_emit(self) -> None:
        iv = self.config.telemetry_interval_s
        if not self._sinks or iv <= 0:
            return
        now = time.perf_counter()
        if now - self._last_emit >= iv:
            self._last_emit = now
            self.telemetry.emit(self._sinks,
                                extra={"queue_depth": self.queue.depth})
