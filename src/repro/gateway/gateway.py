"""The async serving gateway (DESIGN.md §10).

``Gateway`` turns the session layer into a service: single-query
requests arrive continuously (``submit`` / ``search`` from any thread),
wait in a deadline-batched queue (queue.py), and a dispatcher thread
coalesces them into the pad-and-dispatch batch buckets the ``Searcher``
sessions already AOT-compile — flushing on the oldest request's
deadline or on a full bucket, whichever comes first.  Admission groups
requests by probe signature so clustered tiles and the ``plan_reuse``
cache stay hot across consecutive dispatches.

Zero-downtime epoch handover (streaming indexes): ``compact_async``
snapshots the epoch (``StreamingIndex.begin_compact``), folds it on a
worker thread while the dispatcher keeps serving the pinned old-epoch
session, and the dispatcher installs the new epoch atomically *between*
batches — no in-flight request is dropped or stale-errored, and
because responses carry stable external ids, results clients are
holding remain valid across the swap (``resolve_ids``).

Handover state machine::

    IDLE --compact_async--> FOLDING --fold done--> READY
    READY --dispatcher, between batches--> INSTALLING --> IDLE
                (install + session refresh + width-ladder warmup)

Telemetry is first-class and pluggable (telemetry.py): QPS, DCO,
queue depth, batch-fill ratio, recall proxies, and p50/p95/p99 latency
histograms via ``stats()`` plus a periodic structured JSON log.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Tuple

import numpy as np

from .. import faults, obs
from ..core.params import SearchParams
from ..core.stream.streaming import StaleSessionError, StreamingIndex
from ..errors import (DeadlineExceeded, GatewayClosed, HandoverFailed,
                      Overloaded)
from .queue import PendingRequest, RequestQueue, RequestResult
from .telemetry import Telemetry, TelemetrySink

_ADMISSION_MODES = ("signature", "fifo")
_OVERLOAD_POLICIES = ("reject", "block")


def degrade_ladder(params: SearchParams, levels: int = 2,
                   factor: float = 0.5) -> Tuple[SearchParams, ...]:
    """Derive a quality/cost ladder below ``params``: each level scales
    ``nprobe`` (and any explicit ``max_scan``) by ``factor`` over the
    previous one, floored at 1 probe.  Level 0 is ``params`` itself —
    full quality; RAIRS's redundant assignment means the early probes
    carry most of the recall, so halving nprobe sheds scan cost much
    faster than it sheds recall (the knob the ladder exists to turn)."""
    if levels < 1:
        raise ValueError(f"levels must be >= 1, got {levels}")
    out = [params]
    for _ in range(levels):
        p = out[-1]
        nprobe = max(1, int(p.nprobe * factor))
        if nprobe == p.nprobe and p.nprobe > 1:
            nprobe = p.nprobe - 1
        kw = {"nprobe": nprobe}
        if p.max_scan is not None:
            kw["max_scan"] = max(p.k, int(p.max_scan * factor))
        out.append(dataclasses.replace(p, **kw))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Gateway-side knobs (query knobs stay in ``SearchParams``).

    max_delay_ms        micro-batch deadline: the longest a request may
                        wait for co-batching before it flushes anyway
    max_batch           coalescing target (clamped to the session's
                        ``max_chunk``); a full bucket flushes early
    admission           "signature" groups requests by their rank-0
                        probed list (plan/tile locality), "fifo" is
                        arrival order only
    warmup              pre-compile the dispatch bucket (and, with
                        plan_reuse, the whole union-width ladder) at
                        startup and after each epoch swap
    telemetry_interval_s  period of the structured telemetry log through
                        the configured sinks (0 = no periodic log)
    compact_delta_frac  background-handover trigger: delta slots exceed
                        this fraction of the base (None = explicit only)
    compact_dead_frac   background-handover trigger: tombstones exceed
                        this fraction of the id space (None = explicit)
    max_queue           bounded admission (DESIGN.md §13): queue depth
                        cap; None = unbounded (no shedding, no degrade)
    overload            policy when the bounded queue is full:
                        "reject" sheds the arrival with ``Overloaded``,
                        "block" applies producer backpressure
    drain_s             close() grace window: how long the dispatcher
                        keeps flushing queued work before failing
                        leftovers with ``GatewayClosed``; None drains
                        until empty, 0 fails queued work immediately
    degrade             quality/cost ladder: SearchParams tuple *below*
                        level 0 (= the gateway params), stepped down
                        under sustained queue pressure and back up when
                        load recedes; see ``degrade_ladder``.  Requires
                        max_queue (watermarks are depth fractions)
    degrade_high        step-down watermark, fraction of max_queue
    degrade_low         step-up watermark, fraction of max_queue
    degrade_hold        hysteresis: consecutive dispatch cycles the
                        depth must sit past a watermark before stepping
    handover_retries    extra fold attempts before a failed async
                        compaction rolls back and surfaces
                        ``HandoverFailed``
    handover_backoff_s  sleep before fold retry i, scaled by 2**i
    """
    max_delay_ms: float = 2.0
    max_batch: int = 256
    admission: str = "signature"
    warmup: bool = True
    telemetry_interval_s: float = 0.0
    compact_delta_frac: Optional[float] = None
    compact_dead_frac: Optional[float] = None
    max_queue: Optional[int] = None
    overload: str = "reject"
    drain_s: Optional[float] = None
    degrade: Optional[Tuple[SearchParams, ...]] = None
    degrade_high: float = 0.75
    degrade_low: float = 0.25
    degrade_hold: int = 3
    handover_retries: int = 2
    handover_backoff_s: float = 0.05

    def __post_init__(self):
        if self.max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0, got {self.max_delay_ms}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.admission not in _ADMISSION_MODES:
            raise ValueError(f"admission must be one of {_ADMISSION_MODES}, "
                             f"got {self.admission!r}")
        for name in ("compact_delta_frac", "compact_dead_frac"):
            v = getattr(self, name)
            if v is not None and not v > 0:
                raise ValueError(f"{name} must be > 0 or None, got {v!r}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be >= 1 or None, got {self.max_queue}")
        if self.overload not in _OVERLOAD_POLICIES:
            raise ValueError(f"overload must be one of {_OVERLOAD_POLICIES}, "
                             f"got {self.overload!r}")
        if self.drain_s is not None and self.drain_s < 0:
            raise ValueError(
                f"drain_s must be >= 0 or None, got {self.drain_s}")
        if self.degrade is not None:
            if self.max_queue is None:
                raise ValueError("degrade ladder needs max_queue: the "
                                 "watermarks are fractions of the bound")
            if not self.degrade:
                raise ValueError("degrade must be a non-empty tuple of "
                                 "SearchParams (or None)")
            if not 0.0 < self.degrade_low < self.degrade_high <= 1.0:
                raise ValueError(
                    f"need 0 < degrade_low < degrade_high <= 1, got "
                    f"low={self.degrade_low} high={self.degrade_high}")
            if self.degrade_hold < 1:
                raise ValueError(
                    f"degrade_hold must be >= 1, got {self.degrade_hold}")
        if self.handover_retries < 0:
            raise ValueError(f"handover_retries must be >= 0, "
                             f"got {self.handover_retries}")
        if self.handover_backoff_s < 0:
            raise ValueError(f"handover_backoff_s must be >= 0, "
                             f"got {self.handover_backoff_s}")


class Handover:
    """Handle for one zero-downtime epoch swap (``compact_async``)."""

    def __init__(self, pending):
        self.pending = pending
        self.state = "folding"     # folding -> ready -> installed | failed
        self.info: Optional[dict] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def wait(self, timeout: Optional[float] = None) -> dict:
        """Block until installed; returns the install info dict."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"handover still {self.state}")
        if self.error is not None:
            raise self.error
        return self.info


class Gateway:
    """Deadline-batched serving front-end over any index exposing the
    session protocol (``RairsIndex`` / ``StreamingIndex`` /
    ``ShardedIndex``).  Create, submit from any thread, ``close()`` (or
    use as a context manager) to drain and stop."""

    def __init__(self, index, params: Optional[SearchParams] = None,
                 config: Optional[GatewayConfig] = None,
                 sinks: Tuple[TelemetrySink, ...] = (), **param_kwargs):
        if params is None:
            params = SearchParams(**param_kwargs)
        elif param_kwargs:
            params = dataclasses.replace(params, **param_kwargs)
        self.index = index
        self.params = params.resolve(index)
        cfg = config or GatewayConfig()
        if cfg.max_batch > self.params.max_chunk:
            cfg = dataclasses.replace(cfg, max_batch=self.params.max_chunk)
        self.config = cfg
        self.telemetry = Telemetry()
        self._sinks = tuple(sinks)
        self._is_stream = isinstance(index, StreamingIndex)
        if not self._is_stream and (cfg.compact_delta_frac is not None
                                    or cfg.compact_dead_frac is not None):
            raise ValueError("compact_*_frac thresholds need a "
                             "StreamingIndex (nothing to compact otherwise)")
        # quality/cost ladder: level 0 is the configured params, lower
        # levels are cheaper SearchParams served under queue pressure
        ladder = [self.params]
        for p in (cfg.degrade or ()):
            p = p.resolve(index)
            if p.k != self.params.k:
                raise ValueError(
                    f"every degrade level must keep k={self.params.k} "
                    f"(result shape is part of the response contract), "
                    f"got k={p.k}")
            ladder.append(p)
        self._ladder: Tuple[SearchParams, ...] = tuple(ladder)
        self._level = 0
        self._hold_down = 0          # cycles spent above the high mark
        self._hold_up = 0            # cycles spent below the low mark
        self.queue = RequestQueue(grouped=cfg.admission == "signature",
                                  max_queue=cfg.max_queue,
                                  policy=cfg.overload)
        # host-side probe-signature scorer: centroids are frozen across
        # compaction, so one copy serves every epoch
        self._centroids = np.asarray(index.centroids, np.float32)
        self._c2 = (self._centroids ** 2).sum(axis=1)
        self._metric = index.config.metric
        self._dim = int(self._centroids.shape[1])
        self._lock = threading.RLock()   # session use + mutations + install
        self._last_session = None
        self._warm_epoch: object = None  # last epoch the ladder was warmed on
        self._handover: Optional[Handover] = None
        self._last_handover: Optional[dict] = None
        self._last_emit = time.perf_counter()
        self._closed = threading.Event()
        self._drain_deadline: Optional[float] = None
        with self._lock:
            self._session_locked()       # build + warm the serving session
        self._thread = threading.Thread(
            target=self._serve_loop, name="gateway-dispatch", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    # client API (any thread)
    # ------------------------------------------------------------------
    def submit(self, query, deadline_s: Optional[float] = None
               ) -> PendingRequest:
        """Enqueue one query vector; returns a future-like handle.
        ``deadline_s`` tightens this request's flush deadline below the
        gateway-wide ``max_delay_ms`` (it never loosens it) — and a
        request still queued past its deadline is failed with
        ``DeadlineExceeded`` at dequeue, never dispatched.

        Bounded admission (``max_queue``) never raises from here: a
        shed arrival comes back as an already-failed handle whose
        ``result()`` raises ``Overloaded``, so open-loop producers keep
        a uniform submit -> result error path under overload."""
        if self._closed.is_set():
            raise GatewayClosed("gateway is closed")
        with obs.span("gateway.submit", cat="gateway"):
            q = np.asarray(query, np.float32)
            if q.ndim == 2 and q.shape[0] == 1:
                q = q[0]
            if q.ndim != 1 or q.shape[0] != self._dim:
                raise ValueError(
                    f"query must be ({self._dim},), got shape {q.shape}")
            sig = self._signature(q) if self.queue.grouped else 0
            deadline = (time.perf_counter() + deadline_s
                        if deadline_s is not None else None)
            req = PendingRequest(q, sig, deadline=deadline)
            self.telemetry.inc("requests")
            try:
                self.queue.put(req)
            except Overloaded as e:
                self.telemetry.inc("shed")
                req._fail(e)
        return req

    def search(self, query, timeout: Optional[float] = None) -> RequestResult:
        """Blocking single-query convenience over ``submit``."""
        return self.submit(query).result(timeout)

    # -- mutations (streaming indexes; serialized with dispatch) --------
    def insert(self, x) -> np.ndarray:
        """Insert vectors; returns their *stable external* ids (valid
        across any number of epoch handovers)."""
        self._require_stream("insert")
        with self._lock:
            ids = self.index.insert(x)
            ext = self.index.external_ids(ids)
        self.telemetry.inc("inserts", int(ext.size))
        self._maybe_auto_handover()
        return ext

    def delete(self, external_ids) -> int:
        """Tombstone items by their external ids; returns how many were
        live.  Unknown / already-dead handles are a no-op."""
        self._require_stream("delete")
        with self._lock:
            internal = self.index.resolve_ids(external_ids)
            n = self.index.delete(internal[internal >= 0])
        self.telemetry.inc("deletes", n)
        self._maybe_auto_handover()
        return n

    def resolve_ids(self, external_ids) -> np.ndarray:
        """Current internal ids for previously returned external ids."""
        self._require_stream("resolve_ids")
        with self._lock:
            return self.index.resolve_ids(external_ids)

    # -- zero-downtime handover -----------------------------------------
    def compact_async(self, reason: str = "gateway") -> Handover:
        """Start a background epoch handover; serving continues on the
        old epoch until the dispatcher installs the folded one between
        batches.  Returns a ``Handover`` to ``wait()`` on; idempotent
        while one is in flight."""
        self._require_stream("compact_async")
        with self._lock:
            if self._handover is not None:
                return self._handover
            pending = self.index.begin_compact(reason)
            h = Handover(pending)
            self._handover = h
        threading.Thread(target=self._fold_worker, args=(h,),
                         name="gateway-fold", daemon=True).start()
        return h

    def _fold_worker(self, h: Handover) -> None:
        cfg = self.config
        last = None
        for attempt in range(cfg.handover_retries + 1):
            if attempt:
                self.telemetry.inc("handover_retries")
                time.sleep(cfg.handover_backoff_s * 2 ** (attempt - 1))
            try:
                faults.injected("gateway.fold")
                h.pending.fold()
                h.state = "ready"
                break
            except BaseException as e:
                # a failed fold leaves the snapshot intact (state stays
                # "folding"), so retrying is safe; serving meanwhile
                # continues on the pinned old epoch
                last = e
        else:
            self._handover_failed(h, last, "fold")
        self.queue.kick()            # wake the dispatcher to install

    def _handover_failed(self, h: Handover, cause: BaseException,
                         stage: str) -> None:
        """Roll back: abort the pending compaction (the old epoch stays
        installed and keeps serving; the id-remap chain is untouched)
        and surface ``HandoverFailed`` through the handle."""
        err = HandoverFailed(
            f"epoch handover failed at {stage} after "
            f"{self.config.handover_retries + 1} attempt(s): {cause!r}")
        err.__cause__ = cause
        h.error = err
        h.state = "failed"
        h.pending.abort()
        with self._lock:
            self._handover = None
        self.telemetry.inc("handover_failures")
        tr = obs.tracer()
        if tr is not None:
            tr.event("gateway.handover_failed", time.perf_counter(), 0.0,
                     cat="gateway", stage=stage, error=repr(cause))
        h._done.set()

    def _maybe_auto_handover(self) -> None:
        c = self.config
        st = self.index
        if self._handover is not None:
            return
        n_delta_slots = st.n_total - st.n_base
        if (c.compact_delta_frac is not None
                and n_delta_slots > c.compact_delta_frac
                * max(1, st.n_base)):
            self.compact_async("delta_threshold")
        elif (c.compact_dead_frac is not None
                and st.n_dead > c.compact_dead_frac * max(1, st.n_total)):
            self.compact_async("dead_threshold")

    # ------------------------------------------------------------------
    # observability / lifecycle
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """One coherent dict: telemetry snapshot, queue depth, handover
        state, session compile stats, and (streaming) epoch state."""
        h = self._handover
        out = {
            "telemetry": self.telemetry.snapshot(),
            "queue_depth": self.queue.depth,
            "closed": self._closed.is_set(),
            "handover": {"state": h.state if h is not None else "idle",
                         "last": self._last_handover},
            "quality": {"level": self._level,
                        "ladder_levels": len(self._ladder)},
        }
        sess = self._last_session
        if sess is not None:
            out["session"] = sess.compile_stats()
        if self._is_stream:
            st = self.index
            out["stream"] = {"epoch": st.epoch, "version": st.version,
                             "n_live": st.n_live, "n_delta": st.n_delta,
                             "n_dead": st.n_dead}
        return out

    def close(self, timeout: float = 30.0) -> None:
        """Stop accepting work, drain queued requests for up to
        ``config.drain_s``, stop the dispatcher, emit a final record.
        Requests still queued when the drain window closes fail with
        ``GatewayClosed`` — typed, never a bare RuntimeError."""
        if self._closed.is_set():
            return
        if self.config.drain_s is not None:
            self._drain_deadline = time.perf_counter() + self.config.drain_s
        self._closed.set()
        self.queue.close()           # wake dispatcher + blocked producers
        self._thread.join(timeout)
        if self._sinks:
            self.telemetry.emit(self._sinks, kind="gateway_final",
                                extra={"queue_depth": self.queue.depth})

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # dispatcher internals
    # ------------------------------------------------------------------
    def _require_stream(self, what: str) -> None:
        if not self._is_stream:
            raise TypeError(f"{what} needs a StreamingIndex-backed gateway "
                            f"(got {type(self.index).__name__})")

    def _bucket_ladder(self, p: Optional[SearchParams] = None) -> list:
        """Every dispatch bucket a flush can land in: deadline flushes
        carry anywhere from 1 to ``max_batch`` requests."""
        p = p or self.params
        top = p.bucket_for(min(self.config.max_batch, p.max_chunk))
        if p.batch_buckets is not None:
            return [b for b in p.batch_buckets if b <= top]
        sizes, b = [], 1
        while b <= top:
            sizes.append(b)
            b *= 2
        return sizes

    def _signature(self, q: np.ndarray) -> int:
        """Rank-0 probed list, host-side (admission locality hint)."""
        if self._metric == "ip":
            return int(np.argmax(self._centroids @ q))
        return int(np.argmin(self._c2 - 2.0 * (self._centroids @ q)))

    def _session_locked(self):
        """The serving session for the *current quality level*;
        refreshed (and, on an epoch change, width-warmed across every
        ladder level) when the index has moved past it."""
        params = self._ladder[self._level]
        sess = self.index.searcher(params)
        epoch = getattr(sess, "epoch", 0)
        if self.config.warmup and epoch != self._warm_epoch:
            # a new epoch starts with cold executable caches: pre-pay
            # the compiles now, not on the first request — every batch
            # bucket a partial flush can dispatch at (and with
            # plan_reuse, each bucket's union-width ladder), for every
            # degradation level a pressure step can switch to (a step-
            # down must never stall on a compile).  A pristine streaming
            # session delegates to its base session — warm the delegate.
            self._warm_epoch = epoch
            for p in self._ladder:
                s = self.index.searcher(p)
                target = getattr(s, "_delegate", None) or s
                before = target.stats.warmup_compiles
                target.warmup_widths(*self._bucket_ladder(p))
                self.telemetry.inc(
                    "warmup_compiles",
                    target.stats.warmup_compiles - before)
        self._last_session = sess
        return sess

    def _serve_loop(self) -> None:
        try:
            while True:
                self._install_if_ready()
                self._maybe_emit()
                # true deadline enforcement: a request the dispatcher
                # could not reach by its deadline is failed here, at
                # dequeue, never dispatched — the check runs *before*
                # this cycle's flush wait, so a healthy request taken
                # exactly at its deadline still rides its flush
                self._fail_expired(time.perf_counter())
                if self._closed.is_set():
                    dd = self._drain_deadline
                    if self.queue.depth == 0 or (
                            dd is not None
                            and time.perf_counter() >= dd):
                        break
                due = self.queue.oldest_flush_at(
                    self.config.max_delay_ms / 1e3)
                if due is None:
                    self.queue.wait_for_work(0.05)   # idle tick
                    continue
                if not self._closed.is_set():        # draining flushes now
                    self.queue.wait_for_flush(self.config.max_batch, due)
                self._adjust_level()
                batch = self.queue.take_batch(self.config.max_batch)
                if batch:
                    self._dispatch(batch)
        finally:
            for req in self.queue.take_batch(1 << 30):   # never strand
                req._fail(GatewayClosed("gateway closed before this "
                                        "request could be dispatched"))

    def _fail_expired(self, now: float) -> None:
        expired = self.queue.take_expired(now)
        if not expired:
            return
        self.telemetry.inc("deadline_failures", len(expired))
        for r in expired:
            late_ms = (now - r.deadline) * 1e3
            r._fail(DeadlineExceeded(
                f"request deadline passed {late_ms:.1f}ms before dispatch"))

    def _adjust_level(self) -> None:
        """Degradation-ladder hysteresis, one decision per dispatch
        cycle: sustained depth above the high watermark steps quality
        down a level; sustained depth below the low watermark steps
        back up.  Transitions are telemetry counters + trace events."""
        cfg = self.config
        if len(self._ladder) == 1 or cfg.max_queue is None:
            return
        depth = self.queue.take_peak()   # high-watermark since last cycle
        if depth >= cfg.degrade_high * cfg.max_queue:
            self._hold_up = 0
            if self._level < len(self._ladder) - 1:
                self._hold_down += 1
                if self._hold_down >= cfg.degrade_hold:
                    self._step_to(self._level + 1, depth)
        elif depth <= cfg.degrade_low * cfg.max_queue:
            self._hold_down = 0
            if self._level > 0:
                self._hold_up += 1
                if self._hold_up >= cfg.degrade_hold:
                    self._step_to(self._level - 1, depth)
        else:
            self._hold_down = self._hold_up = 0

    def _step_to(self, level: int, depth: int) -> None:
        down = level > self._level
        self._level = level
        self._hold_down = self._hold_up = 0
        tm = self.telemetry
        tm.inc("degrade_steps_down" if down else "degrade_steps_up")
        tm.gauge("quality_level", level)
        tr = obs.tracer()
        if tr is not None:
            tr.event("gateway.degrade", time.perf_counter(), 0.0,
                     cat="gateway", level=level, queue_depth=depth,
                     direction="down" if down else "up")

    def _install_if_ready(self) -> None:
        h = self._handover
        if h is None or h.state != "ready":
            return
        try:
            with self._lock:
                info = h.pending.install()
                self._session_locked()   # refresh + warm the new epoch
        except BaseException as e:
            # a failed install rolls back like a failed fold: abort the
            # pending compaction so the old epoch (still installed)
            # resumes auto-compaction eligibility, and surface typed
            self._handover_failed(h, e, "install")
            return
        h.info = info
        h.state = "installed"
        self._last_handover = {k: v for k, v in info.items()
                               if k != "id_remap"}
        self.telemetry.inc("handovers")
        with self._lock:
            self._handover = None
        h._done.set()

    def _dispatch(self, batch) -> None:
        tm = self.telemetry
        t_take = time.perf_counter()
        tm.observe(
            gauges={"queue_depth": self.queue.depth},
            latencies=[(tm.queue_wait, t_take - r.t_enqueue)
                       for r in batch])
        level = self._level
        with obs.span("gateway.flush", cat="gateway",
                      batch=len(batch)) as fsp:
            q = np.stack([r.query for r in batch])
            try:
                faults.injected("gateway.dispatch")
                with self._lock:
                    res, epoch = self._search_locked(q)
                    ids = np.asarray(res.ids)
                    if self._is_stream:
                        # responses carry stable external ids so clients
                        # survive epoch handovers (resolve_ids maps back)
                        ids = self.index.external_ids(ids)
                    else:
                        ids = ids.astype(np.int64)
                    dists = np.asarray(res.dists)
                    approx = float(np.sum(np.asarray(res.approx_dco)))
                    refine = float(np.sum(np.asarray(res.refine_dco)))
            except BaseException as e:
                tm.inc("errors", len(batch))
                for r in batch:
                    r._fail(e)
                return
            fsp.add(approx_dco=approx, refine_dco=refine)
        t_done = time.perf_counter()
        counters = {
            "batches": 1,
            "responses": len(batch),
            "bucket_rows": self.params.bucket_for(
                min(len(batch), self.params.max_chunk)),
        }
        if len(self._ladder) > 1:
            counters[f"responses_level_{level}"] = len(batch)
        # one atomic multi-metric update per dispatch: a snapshot racing
        # this sees the batch fully counted or not at all, so derived
        # cross-metric invariants (latency.count == responses) are exact
        tm.observe(
            counters=counters,
            sums={"approx_dco": approx, "refine_dco": refine,
                  "result_slots": float(ids.size),
                  "result_filled": float((ids >= 0).sum())},
            # exact top-1 distances are signed under the ip metric
            # (finalize scores are negated inner products) — not monotone
            signed={"top1_dist": float(dists[:, 0].sum())},
            latencies=[(tm.dispatch, t_done - t_take)]
                      + [(tm.latency, t_done - r.t_enqueue)
                         for r in batch])
        tr = obs.tracer()
        for i, r in enumerate(batch):
            if tr is not None and tr.sampled():
                # one exemplar complete-event per sampled request,
                # spanning enqueue -> fulfill on a virtual request track
                tr.event("gateway.request", r.t_enqueue,
                         t_done - r.t_enqueue,
                         queued_ms=(t_take - r.t_enqueue) * 1e3,
                         batch=len(batch), epoch=epoch)
            r._fulfill(RequestResult(
                ids=ids[i], dists=dists[i], latency_s=t_done - r.t_enqueue,
                queued_s=t_take - r.t_enqueue, batch=len(batch),
                epoch=epoch, level=level))

    def _search_locked(self, q: np.ndarray):
        """Dispatch through the current session; a session staled by an
        out-of-band mutation (the caller bypassing the gateway) is
        refreshed and retried rather than surfacing to clients."""
        last_err = None
        for _ in range(3):
            sess = self._session_locked()
            try:
                return sess(q), getattr(sess, "epoch", 0)
            except StaleSessionError as e:
                self.telemetry.inc("stale_retries")
                last_err = e
        raise last_err

    def _maybe_emit(self) -> None:
        iv = self.config.telemetry_interval_s
        if not self._sinks or iv <= 0:
            return
        now = time.perf_counter()
        if now - self._last_emit >= iv:
            self._last_emit = now
            self.telemetry.emit(self._sinks,
                                extra={"queue_depth": self.queue.depth})
