"""Open-loop synthetic load generation for the gateway.

Open-loop means arrivals follow their own clock (a Poisson process at
``offered_qps``), not the server's: a slow server does not slow the
generator down, so queueing delay shows up in the measured latency
instead of being hidden by closed-loop back-pressure.  This is the
load model the bench (``bench_serve``) and the CI gateway-smoke job
drive.

The per-request baseline the bench compares against is the same
generator pointed at a gateway configured with ``max_batch=1`` /
``max_delay_ms=0`` — identical queue, identical sessions, but every
dispatch carries exactly one query — so the measured gap is purely the
value of deadline coalescing.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Optional

import numpy as np

from ..errors import DeadlineExceeded, GatewayClosed, Overloaded


def _pct(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = int(math.ceil(q / 100.0 * len(sorted_vals))) - 1
    return sorted_vals[min(max(i, 0), len(sorted_vals) - 1)]


def run_open_loop(gateway, queries: np.ndarray, offered_qps: float,
                  n_requests: int, seed: int = 0,
                  timeout_s: float = 60.0,
                  exponential: bool = True,
                  tick_ms: float = 2.0,
                  on_request: Optional[Callable[[int], None]] = None,
                  collect: bool = False) -> dict:
    """Drive ``n_requests`` single-query submissions at ``offered_qps``
    and block for every response.

    queries       (N, D) pool cycled through round-robin
    exponential   Poisson arrivals (True) or a fixed inter-arrival gap
    tick_ms       generator clock quantum: the generator wakes once per
                  tick and submits every arrival whose scheduled time
                  has passed, instead of one sleep per request — at high
                  offered rates per-request sleeps turn the generator
                  into a scheduler-churn benchmark (thousands of wakeups
                  a second competing with the dispatch compute),
                  drowning the system under test.  0 restores
                  per-request pacing.
    on_request    optional hook called after every submit with the
                  request index — the churn/handover tests use it to
                  interleave mutations with live traffic
    collect       also return the raw per-answer arrays (query index,
                  result ids) so a caller can score recall offline —
                  the overload bench needs this to price degradation

    Returns one load-point summary: achieved qps, latency percentiles
    (ms), the mean coalesced batch size, and a full typed accounting of
    every submission — ``n_ok + shed + deadline_failed + closed +
    errors == n_requests`` is the no-silent-drops invariant the
    regression gate asserts.  ``shed``/``deadline_failed``/``closed``
    count requests the gateway failed *typed* (``Overloaded`` /
    ``DeadlineExceeded`` / ``GatewayClosed``); ``errors`` is anything
    untyped — a healthy run, overloaded or not, keeps it at zero.
    """
    if offered_qps <= 0:
        raise ValueError(f"offered_qps must be > 0, got {offered_qps}")
    rng = np.random.default_rng(seed)
    if exponential:
        gaps = rng.exponential(1.0 / offered_qps, size=n_requests)
    else:
        gaps = np.full(n_requests, 1.0 / offered_qps)
    arrivals = np.cumsum(gaps)

    pending = []
    t0 = time.perf_counter()
    i = 0
    while i < n_requests:
        now = time.perf_counter() - t0
        while i < n_requests and arrivals[i] <= now:
            pending.append(gateway.submit(queries[i % len(queries)]))
            if on_request is not None:
                on_request(i)
            i += 1
        if i < n_requests:
            wait = arrivals[i] - (time.perf_counter() - t0)
            time.sleep(max(wait, tick_ms / 1e3) if tick_ms > 0
                       else max(wait, 0.0))

    results = []
    shed = deadline_failed = closed = errors = 0
    ok_idx, ok_ids = [], []
    levels: dict = {}
    for i, req in enumerate(pending):
        try:
            r = req.result(timeout_s)
        except Overloaded:
            shed += 1
            continue
        except DeadlineExceeded:
            deadline_failed += 1
            continue
        except GatewayClosed:
            closed += 1
            continue
        except Exception:
            errors += 1
            continue
        results.append(r)
        levels[r.level] = levels.get(r.level, 0) + 1
        if collect:
            ok_idx.append(i % len(queries))
            ok_ids.append(np.asarray(r.ids))
    t1 = time.perf_counter()

    lat = sorted(r.latency_s for r in results)
    wall = max(t1 - t0, 1e-9)
    return {
        "offered_qps": float(offered_qps),
        "achieved_qps": len(results) / wall,
        "n_requests": n_requests,
        "n_ok": len(results),
        "shed": shed,
        "deadline_failed": deadline_failed,
        "closed": closed,
        "errors": errors,
        "levels": {str(k): v for k, v in sorted(levels.items())},
        "wall_s": wall,
        **({"ok_query_idx": np.asarray(ok_idx, np.int64),
            "ok_ids": (np.stack(ok_ids) if ok_ids
                       else np.zeros((0, 0), np.int64))} if collect else {}),
        "p50_ms": _pct(lat, 50) * 1e3,
        "p95_ms": _pct(lat, 95) * 1e3,
        "p99_ms": _pct(lat, 99) * 1e3,
        "mean_latency_ms": (sum(lat) / len(lat) * 1e3) if lat else 0.0,
        "mean_batch": (float(np.mean([r.batch for r in results]))
                       if results else 0.0),
    }
