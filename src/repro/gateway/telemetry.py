"""First-class serving telemetry (DESIGN.md §10).

One ``Telemetry`` object per gateway: thread-safe counters, gauges, and
log-spaced latency histograms, snapshotted on demand (``Gateway.stats``)
and periodically emitted as one structured JSON line through pluggable
sinks.  Everything is host-side and O(1) per event — recording a
latency is an index into a fixed bin array, never an allocation — so
telemetry cost stays invisible next to a dispatch.

Counters are monotone by construction (asserted in CI gateway-smoke):
only ``inc`` exists, gauges are the separate escape hatch for values
that legitimately move both ways (queue depth).

The recall *proxy* is deliberately not recall: online traffic has no
ground truth.  We track the result fill rate (fraction of the k result
slots holding a live id — a search that comes back short is the first
observable symptom of a mis-sized nprobe/max_scan or a churn-starved
list) plus the mean exact top-1 distance, whose drift under a stable
query mix indicates index quality movement.
"""
from __future__ import annotations

import json
import math
import sys
import threading
import time
from typing import Dict, Optional

# histogram range: 10us .. 100s, log-spaced.  ~7.4% bin width — tighter
# than any latency SLO anyone will write against this gateway.
_H_LO = 1e-5
_H_HI = 100.0
_H_BINS = 192


class LatencyHistogram:
    """Fixed log-spaced latency histogram with percentile estimates.

    ``record`` is O(1); ``percentile`` interpolates within the covering
    bin (upper-edge biased, so reported percentiles never understate).
    Not thread-safe by itself — ``Telemetry`` holds the lock.
    """

    __slots__ = ("counts", "total", "sum_s", "max_s")

    def __init__(self):
        self.counts = [0] * _H_BINS
        self.total = 0
        self.sum_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        x = max(float(seconds), _H_LO)
        b = int(math.log(x / _H_LO) / math.log(_H_HI / _H_LO) * _H_BINS)
        self.counts[min(max(b, 0), _H_BINS - 1)] += 1
        self.total += 1
        self.sum_s += seconds
        if seconds > self.max_s:
            self.max_s = seconds

    def percentile(self, q: float) -> float:
        """q in [0, 100] -> estimated latency in seconds (0 if empty)."""
        if self.total == 0:
            return 0.0
        target = q / 100.0 * self.total
        seen = 0
        for b, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                # upper edge of bin b
                return _H_LO * (_H_HI / _H_LO) ** ((b + 1) / _H_BINS)
        return self.max_s

    def snapshot(self) -> Dict[str, float]:
        """Schema (locked by tests/test_gateway.py): count, sum_ms,
        mean_ms, p50/p95/p99_ms, max_ms — count + sum let sinks derive
        rates and cross-interval means without re-binning."""
        ms = 1e3
        return {
            "count": self.total,
            "sum_ms": self.sum_s * ms,
            "mean_ms": (self.sum_s / self.total * ms) if self.total else 0.0,
            "p50_ms": self.percentile(50) * ms,
            "p95_ms": self.percentile(95) * ms,
            "p99_ms": self.percentile(99) * ms,
            "max_ms": self.max_s * ms,
        }


class TelemetrySink:
    """Pluggable destination for periodic structured telemetry records.
    Subclass and override ``emit`` (a dict, JSON-serializable)."""

    def emit(self, record: dict) -> None:
        raise NotImplementedError


class LogSink(TelemetrySink):
    """Default sink: one structured JSON line per record to a stream."""

    def __init__(self, stream=None):
        self.stream = stream if stream is not None else sys.stderr

    def emit(self, record: dict) -> None:
        self.stream.write(json.dumps(record, default=float) + "\n")
        self.stream.flush()


class MemorySink(TelemetrySink):
    """Test/inspection sink: keeps every record in a list."""

    def __init__(self):
        self.records = []

    def emit(self, record: dict) -> None:
        self.records.append(record)


class Telemetry:
    """Thread-safe serving metrics for one gateway.

    Counters (monotone): requests, responses, errors, batches,
    bucket_rows (padded dispatch rows), stale_retries, handovers,
    warmup_compiles observed at session swaps.  Gauges: queue_depth.
    Histograms: end-to-end latency, queue wait, dispatch time.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._sums: Dict[str, float] = {}
        self.latency = LatencyHistogram()
        self.queue_wait = LatencyHistogram()
        self.dispatch = LatencyHistogram()

    def inc(self, name: str, v: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + v

    def add(self, name: str, v: float) -> None:
        """Accumulate a monotone float counter.  Negative deltas violate
        the counters-are-monotone contract (module docstring) and raise;
        values that legitimately move both ways go through ``gauge`` or
        ``add_signed``."""
        if v < 0:
            raise ValueError(
                f"accumulator {name!r}: negative delta {v!r} breaks the "
                f"monotone-counters contract; use add_signed() for sums "
                f"that are legitimately signed")
        with self._lock:
            self._sums[name] = self._sums.get(name, 0.0) + v

    def add_signed(self, name: str, v: float) -> None:
        """Accumulate a *signed* sum (e.g. top-1 inner-product scores,
        which are negated distances).  The escape hatch from ``add``'s
        monotonicity check — use sparingly and document the call site."""
        with self._lock:
            self._sums[name] = self._sums.get(name, 0.0) + v

    def gauge(self, name: str, v: float) -> None:
        with self._lock:
            self._gauges[name] = v

    def record_latency(self, hist: LatencyHistogram, seconds: float) -> None:
        with self._lock:
            hist.record(seconds)

    def observe(self, counters: Optional[dict] = None,
                sums: Optional[dict] = None,
                signed: Optional[dict] = None,
                gauges: Optional[dict] = None,
                latencies=()) -> None:
        """Apply one multi-metric update *atomically* — a single lock
        acquisition covers every counter, sum, gauge, and histogram
        record, so a concurrent ``snapshot()`` sees either none or all
        of it.  This is what keeps cross-metric invariants exact under
        load (e.g. ``latency.count == counters["responses"]`` after
        every dispatch, asserted by the threaded consistency test).

        ``latencies`` is an iterable of ``(histogram, seconds)`` pairs.
        Monotonicity is validated up front so a bad delta rejects the
        whole update instead of applying half of it.
        """
        for name, v in (sums or {}).items():
            if v < 0:
                raise ValueError(
                    f"accumulator {name!r}: negative delta {v!r} breaks "
                    f"the monotone-counters contract; use the signed= "
                    f"mapping for sums that are legitimately signed")
        with self._lock:
            for name, v in (counters or {}).items():
                self._counters[name] = self._counters.get(name, 0) + v
            for name, v in (sums or {}).items():
                self._sums[name] = self._sums.get(name, 0.0) + v
            for name, v in (signed or {}).items():
                self._sums[name] = self._sums.get(name, 0.0) + v
            for name, v in (gauges or {}).items():
                self._gauges[name] = v
            for hist, seconds in latencies:
                hist.record(seconds)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def snapshot(self) -> dict:
        """One coherent metrics dict: counters, gauges, derived rates
        (qps, batch-fill, recall proxies), and latency percentiles."""
        with self._lock:
            c = dict(self._counters)
            g = dict(self._gauges)
            s = dict(self._sums)
            lat = self.latency.snapshot()
            qw = self.queue_wait.snapshot()
            disp = self.dispatch.snapshot()
        elapsed = max(time.perf_counter() - self._t0, 1e-9)
        responses = c.get("responses", 0)
        batches = c.get("batches", 0)
        slots = s.get("result_slots", 0.0)
        out = {
            "uptime_s": elapsed,
            "counters": c,
            "gauges": g,
            "qps": responses / elapsed,
            # requests coalesced per compiled dispatch: > 1 means the
            # micro-batcher is actually amortizing dispatch overhead
            "batch_fill": responses / batches if batches else 0.0,
            # fraction of each dispatched bucket holding real queries
            # (the rest is pad-row waste)
            "bucket_fill": (responses / c["bucket_rows"]
                            if c.get("bucket_rows") else 0.0),
            "approx_dco_per_query": (s.get("approx_dco", 0.0) / responses
                                     if responses else 0.0),
            "refine_dco_per_query": (s.get("refine_dco", 0.0) / responses
                                     if responses else 0.0),
            # recall proxies (see module docstring)
            "result_fill_rate": (s.get("result_filled", 0.0) / slots
                                 if slots else 0.0),
            "mean_top1_dist": (s.get("top1_dist", 0.0) / responses
                               if responses else 0.0),
            "latency": lat,
            "queue_wait": qw,
            "dispatch": disp,
        }
        return out

    def emit(self, sinks, kind: str = "gateway_stats",
             extra: Optional[dict] = None) -> dict:
        """Snapshot once and push the record through every sink."""
        record = {"t": time.time(), "kind": kind, **self.snapshot()}
        if extra:
            record.update(extra)
        for sink in sinks:
            sink.emit(record)
        return record
