"""Recall / DCO metrics and ground-truth computation (paper §6.1)."""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans import pairwise_sq_l2


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _gt_chunk(x, q, k, metric):
    d = (pairwise_sq_l2(q, x) if metric == "l2" else -(q @ x.T))
    _, idx = jax.lax.top_k(-d, k)
    return idx.astype(jnp.int32)


def ground_truth(x: jnp.ndarray, q: jnp.ndarray, k: int,
                 metric: str = "l2", chunk: int = 256) -> np.ndarray:
    """Exact top-k ids by brute force, chunked over queries."""
    outs = []
    for s in range(0, q.shape[0], chunk):
        outs.append(np.asarray(_gt_chunk(x, q[s:s + chunk], k, metric)))
    return np.concatenate(outs, axis=0)


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Average |result ∩ gt| / K (paper's recall k@K)."""
    r = np.asarray(result_ids)
    g = np.asarray(gt_ids)
    k = g.shape[1]
    hits = (r[:, :, None] == g[:, None, :]).any(axis=1).sum(axis=1)
    return float(hits.mean() / k)


def per_query_recall(result_ids: np.ndarray, gt_ids: np.ndarray) -> np.ndarray:
    r, g = np.asarray(result_ids), np.asarray(gt_ids)
    return (r[:, :, None] == g[:, None, :]).any(axis=1).sum(axis=1) / g.shape[1]


def dco_summary(res) -> Dict[str, float]:
    a = np.asarray(res.approx_dco, np.float64)
    r = np.asarray(res.refine_dco, np.float64)
    return {
        "approx_dco": float(a.mean()),
        "refine_dco": float(r.mean()),
        "total_dco": float((a + r).mean()),
        "p99_dco": float(np.percentile(a + r, 99)),
        "dropped_blocks": float(np.asarray(res.dropped_blocks).mean()),
    }
