"""Compiled searcher sessions — the query-side public API (DESIGN.md §7).

A ``Searcher`` binds one ``RairsIndex`` to one ``SearchParams`` and
AOT-compiles the four-stage pipeline (``seil_search``) per batch-size
bucket.  Arbitrary batch sizes are padded up to the nearest bucket and
dispatched to a cached executable, so steady-state serving traffic with
varying batch shapes hits a small fixed set of XLA programs instead of
retracing the jit per shape.

Padding is row-safe: every pipeline stage is per-query (row-wise top-k,
gathers, reductions), so the first B rows of a padded batch are bitwise
identical to an unpadded run — asserted in tests/test_searcher.py.

Sessions are long-lived by design: they hold the lowered executables,
the resolved params, and compile/cache statistics, and they are the
natural home for the follow-on serving state (incremental batch-union
plans, query-tile clustering — ROADMAP.md).

Mutable indexes extend this machinery (DESIGN.md §8): a session records
the ``epoch`` of the index it compiled against, and the ``_lower`` /
``_call_inputs`` / ``_check_current`` hooks let ``StreamingSearcher``
(core/stream/) swap in the streaming pipeline and fail deterministically
once the owning ``StreamingIndex`` has mutated past the session.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .params import SearchParams
from .search import SearchResult, seil_search


@dataclasses.dataclass
class SearcherStats:
    """Compile/dispatch accounting for one session."""
    compiles: int = 0        # executables built (one per bucket)
    calls: int = 0           # searcher invocations
    dispatches: int = 0      # chunk dispatches (>= calls)
    cache_hits: int = 0      # dispatches served by an existing executable
    padded_rows: int = 0     # total pad rows added across dispatches

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class Searcher:
    """A compiled search session over one index (create via
    ``RairsIndex.searcher(params)``).

    Calling the session with a ``(B, D)`` query batch returns a
    ``SearchResult`` identical to the legacy ``index.search`` kwarg path
    for the same parameters.  ``stats`` exposes compile-cache counters;
    ``buckets`` lists the batch sizes with a live executable.
    """

    def __init__(self, index, params: SearchParams):
        if not isinstance(params, SearchParams):
            raise TypeError(f"params must be SearchParams, got {type(params)}")
        self.index = index
        self.params = params.resolve(index)
        self.epoch = getattr(index, "epoch", 0)
        self.stats = SearcherStats()
        self._compiled: Dict[int, Any] = {}

    @property
    def buckets(self):
        """Batch-size buckets with a compiled executable, ascending."""
        return tuple(sorted(self._compiled))

    def compile_stats(self) -> Dict[str, Any]:
        d = self.stats.as_dict()
        d["buckets"] = list(self.buckets)
        return d

    # -- overridable hooks (core/stream/ swaps in the streaming pipeline) --
    def _check_current(self) -> None:
        """Raise if the underlying index has mutated past this session.
        A plain ``RairsIndex`` is immutable, so the base hook is a no-op;
        ``StreamingSearcher`` raises ``StaleSessionError`` here."""

    def _lower(self, bucket: int):
        """Lower the search pipeline for one batch-size bucket."""
        p = self.params
        idx = self.index
        q_spec = jax.ShapeDtypeStruct(
            (bucket, idx.vectors.shape[1]), jnp.float32)
        return seil_search.lower(
            idx.arrays, idx.centroids, idx.codebook, idx.vectors, q_spec,
            nprobe=p.nprobe, bigk=p.bigk, k=p.k, max_scan=p.max_scan,
            metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            use_kernel=p.use_kernel, oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile)

    def _call_inputs(self) -> tuple:
        """Runtime arguments preceding the query batch at dispatch."""
        idx = self.index
        return (idx.arrays, idx.centroids, idx.codebook, idx.vectors)

    def _executable(self, bucket: int):
        hit = bucket in self._compiled
        if not hit:
            self._compiled[bucket] = self._lower(bucket).compile()
            self.stats.compiles += 1
        else:
            self.stats.cache_hits += 1
        self.stats.dispatches += 1
        return self._compiled[bucket]

    def warmup(self, *batch_sizes: int) -> "Searcher":
        """Pre-compile the buckets covering `batch_sizes` (chainable)."""
        for b in batch_sizes:
            self._executable(self.params.bucket_for(min(b, self.params.max_chunk)))
        return self

    def __call__(self, queries: jnp.ndarray) -> SearchResult:
        self._check_current()
        q = jnp.asarray(queries)
        if q.ndim != 2:
            raise ValueError(f"queries must be (B, D), got shape {q.shape}")
        if q.shape[0] == 0:
            raise ValueError("empty query batch (B=0)")
        if q.dtype != jnp.float32:
            q = q.astype(jnp.float32)
        n = q.shape[0]
        outs = []
        s = 0
        while s < n:
            b = min(n - s, self.params.max_chunk)
            bucket = self.params.bucket_for(b)
            qc = q[s:s + b]
            if b < bucket:
                qc = jnp.concatenate(
                    [qc, jnp.zeros((bucket - b, q.shape[1]), q.dtype)], axis=0)
                self.stats.padded_rows += bucket - b
            fn = self._executable(bucket)
            r = fn(*self._call_inputs(), qc)
            if b < bucket:
                r = jax.tree.map(lambda a: a[:b], r)
            outs.append(r)
            s += b
        self.stats.calls += 1
        if len(outs) == 1:
            return outs[0]
        return jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *outs)

    # explicit alias for callers that prefer a method name
    search = __call__
