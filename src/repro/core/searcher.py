"""Compiled searcher sessions — the query-side public API (DESIGN.md §7).

A ``Searcher`` binds one ``RairsIndex`` to one ``SearchParams`` and
AOT-compiles the four-stage pipeline (``seil_search``) per batch-size
bucket.  Arbitrary batch sizes are padded up to the nearest bucket and
dispatched to a cached executable, so steady-state serving traffic with
varying batch shapes hits a small fixed set of XLA programs instead of
retracing the jit per shape.

Padding is row-safe: every pipeline stage is per-query (row-wise top-k,
gathers, reductions), so the first B rows of a padded batch are bitwise
identical to an unpadded run — asserted in tests/test_searcher.py.

Sessions are long-lived by design: they hold the lowered executables,
the resolved params, and compile/cache statistics, and they are the
natural home for the follow-on serving state (incremental batch-union
plans, query-tile clustering — ROADMAP.md).

Mutable indexes extend this machinery (DESIGN.md §8): a session records
the ``epoch`` of the index it compiled against, and the ``_lower`` /
``_call_inputs`` / ``_check_current`` hooks let ``StreamingSearcher``
(core/stream/) swap in the streaming pipeline and fail deterministically
once the owning ``StreamingIndex`` has mutated past the session.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from .engine import (BIG, merge_unions_host, plan_width, tile_signatures,
                     union_live, width_buckets)
from .params import SearchParams
from .search import (SearchResult, probe_plan, scan_finalize, seil_search,
                     seil_search_traced)


@dataclasses.dataclass
class SearcherStats:
    """Compile/dispatch accounting for one session."""
    compiles: int = 0        # executables built (one per bucket)
    warmup_compiles: int = 0  # subset of compiles paid up-front by
                              # warmup/warmup_widths, not by live traffic
    calls: int = 0           # searcher invocations
    dispatches: int = 0      # chunk dispatches (>= calls)
    cache_hits: int = 0      # executable fetches served from the cache
                             # (plan_reuse chunks fetch two: probe + scan)
    padded_rows: int = 0     # total pad rows added across dispatches

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PlanStats:
    """Incremental-plan accounting (``SearchParams.plan_reuse``) — the
    plan-cache counterpart of the compile-cache stats above.

    A *tile* is one block union (the whole batch for ``grouped``, one
    query tile for ``clustered``); every dispatched batch classifies
    each of its tiles as hit (own union covered by the cache), extend
    (cache grew, still fits the width) or miss (first sight / overflow,
    cache replaced)."""
    batches: int = 0          # probe->scan dispatches
    tiles: int = 0            # unions processed (batches x tiles/batch)
    hits: int = 0             # reused unchanged
    extends: int = 0          # merged into the cache
    misses: int = 0           # replaced (cold cache or width overflow)
    union_live_sum: int = 0   # live entries actually scanned (per tile)
    own_live_sum: int = 0     # live entries this batch needed (per tile)
    width_sum: int = 0        # dispatched union-width buckets (per tile)
    sig_deep_split: int = 0   # tiles the deep (beyond-lead) signature
                              # separated from a lead-sharing neighbor —
                              # the collisions a lead-only key would eat

    def summary(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        t = max(self.tiles, 1)
        d["hit_rate"] = self.hits / t
        d["mean_union_live"] = self.union_live_sum / t
        d["mean_own_live"] = self.own_live_sum / t
        d["mean_width"] = self.width_sum / t
        return d


class Searcher:
    """A compiled search session over one index (create via
    ``RairsIndex.searcher(params)``).

    Calling the session with a ``(B, D)`` query batch returns a
    ``SearchResult`` identical to the legacy ``index.search`` kwarg path
    for the same parameters.  ``stats`` exposes compile-cache counters;
    ``buckets`` lists the batch sizes with a live executable.
    """

    def __init__(self, index, params: SearchParams):
        if not isinstance(params, SearchParams):
            raise TypeError(f"params must be SearchParams, got {type(params)}")
        self.index = index
        self.params = params.resolve(index)
        self.epoch = getattr(index, "epoch", 0)
        # two-tier scan (params.refine, DESIGN.md §12): resolve the
        # compact plane once — sessions pin it like everything else
        ap = self.params.active_plane
        self._plane = index.plane(ap) if ap is not None else None
        self.stats = SearcherStats()
        self.plan_stats = PlanStats()
        self._compiled: Dict[Any, Any] = {}
        # incremental plans (params.plan_reuse): per dispatch bucket, a
        # signature-keyed map of cached tile unions ((list, run) ->
        # (W,) row; engine/cluster.py tile_signatures) — keyed by what a
        # tile probes, not where it sits, so popularity drift shifting a
        # tile boundary does not orphan the cache.  It lives on the
        # session, so it invalidates with it — a mutation that stales
        # the session drops the plans too.
        self._plan_cache: Dict[int, "collections.OrderedDict"] = {}

    @property
    def buckets(self):
        """Batch-size buckets with a compiled executable, ascending.
        (With plan_reuse a bucket holds probe/scan executable pairs; the
        probe store may live outside ``_compiled`` — core/stream/.)"""
        keys = set(self._compiled) | set(self._probe_exe_store())
        return tuple(sorted({k if isinstance(k, int) else k[1]
                             for k in keys}))

    def compile_stats(self) -> Dict[str, Any]:
        d = self.stats.as_dict()
        d["buckets"] = list(self.buckets)
        if self.params.plan_reuse:
            d["plan"] = self.plan_stats.summary()
        return d

    # -- overridable hooks (core/stream/ swaps in the streaming pipeline) --
    def _check_current(self) -> None:
        """Raise if the underlying index has mutated past this session.
        A plain ``RairsIndex`` is immutable, so the base hook is a no-op;
        ``StreamingSearcher`` raises ``StaleSessionError`` here."""

    def _scan_state(self) -> tuple:
        """(arrays, codebook, packed) the scan stages run over: the
        compact-plane substitution when a refine tier is active —
        plane-packed block codes, the plane codec's LUT — else the
        index's own full-width pair.  Everything downstream (vectors,
        finalize) is untouched: tier-2 IS the existing exact re-rank,
        just over the ``bigk_eff`` widened survivor set."""
        idx = self.index
        if self._plane is None:
            return idx.arrays, idx.codebook, False
        return (dataclasses.replace(idx.arrays,
                                    block_codes=self._plane.block_codes),
                self._plane.codec, True)

    def _lower(self, bucket: int):
        """Lower the search pipeline for one batch-size bucket."""
        p = self.params
        idx = self.index
        arrays, codebook, packed = self._scan_state()
        q_spec = jax.ShapeDtypeStruct(
            (bucket, idx.vectors.shape[1]), jnp.float32)
        return seil_search.lower(
            arrays, idx.centroids, codebook, idx.vectors, q_spec,
            nprobe=p.nprobe, bigk=p.bigk_eff, k=p.k, max_scan=p.max_scan,
            metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            use_kernel=p.use_kernel, oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile,
            fused_topk=p.fused_topk, packed_codes=packed)

    def _call_inputs(self) -> tuple:
        """Runtime arguments preceding the query batch at dispatch."""
        idx = self.index
        arrays, codebook, _ = self._scan_state()
        return (arrays, idx.centroids, codebook, idx.vectors)

    # -- incremental-plan hooks (probe -> plan-cache merge -> scan) --------
    def _lower_probe(self, bucket: int):
        """Lower the probe half (stages 1-2 + own unions) for one bucket."""
        p = self.params
        idx = self.index
        arrays, codebook, _ = self._scan_state()
        q_spec = jax.ShapeDtypeStruct(
            (bucket, idx.vectors.shape[1]), jnp.float32)
        return probe_plan.lower(
            arrays, idx.centroids, codebook, q_spec,
            nprobe=p.nprobe, max_scan=p.max_scan, metric=idx.config.metric,
            exec_mode=p.exec_mode, query_tile=p.query_tile)

    def _probe_inputs(self) -> tuple:
        idx = self.index
        arrays, codebook, _ = self._scan_state()
        return (arrays, idx.centroids, codebook)

    def _lower_scan(self, bucket: int, probe_spec, unions_spec):
        """Lower the scan half (stages 3-4) at one union width."""
        p = self.params
        idx = self.index
        arrays, _, packed = self._scan_state()
        q_spec = jax.ShapeDtypeStruct(
            (bucket, idx.vectors.shape[1]), jnp.float32)
        return scan_finalize.lower(
            arrays, idx.vectors, q_spec, probe_spec, unions_spec,
            bigk=p.bigk_eff, k=p.k, metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            use_kernel=p.use_kernel, oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile,
            fused_topk=p.fused_topk, packed_codes=packed)

    def _scan_inputs(self) -> tuple:
        idx = self.index
        arrays, _, _ = self._scan_state()
        return (arrays, idx.vectors)

    def _get_exe(self, key, lower_fn, cache=None):
        cache = self._compiled if cache is None else cache
        hit = key in cache
        if not hit:
            with obs.span("searcher.compile", cat="compile", key=str(key)):
                cache[key] = lower_fn().compile()
            self.stats.compiles += 1
        else:
            self.stats.cache_hits += 1
        return cache[key]

    def _probe_exe_store(self) -> dict:
        """Where plan_reuse probe executables live.  The probe half never
        consumes mutable-segment buffers, so subclasses whose _compiled
        dict is keyed by delta shapes (core/stream/) point this at a
        longer-lived store to survive capacity-bucket jumps."""
        return self._compiled

    def _executable(self, bucket: int):
        return self._get_exe(bucket, lambda: self._lower(bucket))

    def _dispatch_traced(self, bucket: int, qc: jnp.ndarray):
        """Stage-fenced dispatch used while a tracer is active
        (repro/obs/): the same engine stages as the monolithic
        executable, one jitted program each, span + device fence per
        stage — bitwise identical results.  Subclasses without a staged
        pipeline return ``NotImplemented`` and ``_dispatch`` falls back
        to fencing the monolithic executable as one span."""
        p = self.params
        idx = self.index
        arrays, codebook, packed = self._scan_state()
        return seil_search_traced(
            arrays, idx.centroids, codebook, idx.vectors, qc,
            nprobe=p.nprobe, bigk=p.bigk_eff, k=p.k, max_scan=p.max_scan,
            metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            use_kernel=p.use_kernel, oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile,
            fused_topk=p.fused_topk, packed_codes=packed)

    def _dispatch(self, bucket: int, qc: jnp.ndarray) -> SearchResult:
        """One padded chunk through either the monolithic executable or
        the incremental probe -> merge -> scan pipeline.  With a tracer
        active (repro/obs/) the monolithic path reroutes through the
        stage-fenced ``_dispatch_traced`` and the plan_reuse path fences
        its (already natural) probe / host-merge / scan boundaries."""
        self.stats.dispatches += 1
        if not self.params.plan_reuse:
            if obs.enabled():
                r = self._dispatch_traced(bucket, qc)
                if r is not NotImplemented:
                    return r
                with obs.span("stage.execute", cat="device", bucket=bucket):
                    return obs.fence(
                        self._executable(bucket)(*self._call_inputs(), qc))
            return self._executable(bucket)(*self._call_inputs(), qc)
        probe = self._get_exe(("probe", bucket),
                              lambda: self._lower_probe(bucket),
                              cache=self._probe_exe_store())
        with obs.span("stage.probe_plan", cat="device", bucket=bucket):
            pr = obs.fence(probe(*self._probe_inputs(), qc))
        with obs.span("stage.merge_unions_host", cat="host") as msp:
            own = np.asarray(pr.unions)
            t, w = own.shape
            deep_split = 0
            if t == 1:                 # grouped: one batch-wide union
                sigs = [(0, 0)]
            else:                      # clustered: name tiles by working set
                rows = np.asarray(pr.sel)[np.asarray(pr.perm)][::bucket // t]
                sigs = tile_signatures(rows[:, 0], deep=rows)
                # how many tiles the beyond-lead prefix disambiguated —
                # distinct deep keys minus distinct leads this dispatch
                deep_split = (len({(s[0], s[1]) for s in sigs})
                              - len({s[0] for s in sigs}))
            cache = self._plan_cache.setdefault(bucket,
                                                collections.OrderedDict())
            rows = [cache.get(s) for s in sigs]
            present = np.array([r is not None for r in rows])
            if present.any():
                pad = np.full(w, int(BIG), own.dtype)
                cached = np.stack([pad if r is None else r for r in rows])
                used, hit, ext = merge_unions_host(cached, own, present)
            else:
                used, hit, ext = merge_unions_host(None, own)
            for s, row in zip(sigs, used):
                cache[s] = row
                cache.move_to_end(s)
            while len(cache) > max(64, 4 * t):  # bound drifting signatures
                cache.popitem(last=False)
            live = union_live(used)
            wp = plan_width(int(live.max(initial=1)), w)
            ps = self.plan_stats
            ps.batches += 1
            ps.tiles += t
            ps.hits += int(hit.sum())
            ps.extends += int(ext.sum())
            ps.misses += t - int(hit.sum()) - int(ext.sum())
            ps.union_live_sum += int(live.sum())
            ps.own_live_sum += int(union_live(own).sum())
            ps.width_sum += wp * t
            ps.sig_deep_split += deep_split
            msp.add(tiles=t, hits=int(hit.sum()), extends=int(ext.sum()),
                    misses=t - int(hit.sum()) - int(ext.sum()),
                    union_live=int(live.sum()), width=wp,
                    sig_deep_split=deep_split)
            unions_w = jnp.asarray(used[:, :wp])
        probe_spec = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), pr)
        unions_spec = jax.ShapeDtypeStruct(unions_w.shape, unions_w.dtype)
        scan = self._get_exe(
            ("scan", bucket, wp),
            lambda: self._lower_scan(bucket, probe_spec, unions_spec))
        with obs.span("stage.scan_finalize", cat="device", bucket=bucket,
                      width=wp):
            return obs.fence(scan(*self._scan_inputs(), qc, pr, unions_w))

    def warmup(self, *batch_sizes: int) -> "Searcher":
        """Pre-compile the buckets covering `batch_sizes` (chainable).
        With plan_reuse only the probe half pre-compiles — the scan
        half's union width is a property of the traffic (use
        ``warmup_widths`` to pre-pay the whole width ladder).  Compiles
        triggered here count as ``warmup_compiles``."""
        before = self.stats.compiles
        for b in batch_sizes:
            bucket = self.params.bucket_for(min(b, self.params.max_chunk))
            if self.params.plan_reuse:
                self._get_exe(("probe", bucket),
                              lambda: self._lower_probe(bucket),
                              cache=self._probe_exe_store())
            else:
                self._executable(bucket)
        self.stats.warmup_compiles += self.stats.compiles - before
        return self

    def warmup_widths(self, *batch_sizes: int) -> "Searcher":
        """Pre-compile the plan_reuse scan executables at every
        geometric union-width bucket for `batch_sizes` (chainable).

        A plan_reuse session dispatches its scan half at the smallest
        ``plan_width`` bucket covering the live union, so the set of
        executables traffic can demand is the ``width_buckets`` ladder —
        finite and known up-front.  Compiling it at gateway startup (or
        right after an epoch swap) means the first requests never eat
        compile latency.  Without plan_reuse this is plain ``warmup``.
        Compiles triggered here count as ``warmup_compiles`` in
        ``compile_stats()``, separate from traffic-driven compiles."""
        if not self.params.plan_reuse:
            return self.warmup(*batch_sizes)
        before = self.stats.compiles
        dim = int(self.index.vectors.shape[1])
        for b in batch_sizes:
            bucket = self.params.bucket_for(min(b, self.params.max_chunk))
            probe = self._get_exe(("probe", bucket),
                                  lambda: self._lower_probe(bucket),
                                  cache=self._probe_exe_store())
            # one throwaway probe dispatch yields the exact output spec
            # (tile count and full union width) for this bucket
            pr = probe(*self._probe_inputs(),
                       jnp.zeros((bucket, dim), jnp.float32))
            probe_spec = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), pr)
            t, w = pr.unions.shape
            udt = pr.unions.dtype
            for wp in width_buckets(w):
                spec = jax.ShapeDtypeStruct((t, wp), udt)
                self._get_exe(
                    ("scan", bucket, wp),
                    lambda s=spec: self._lower_scan(bucket, probe_spec, s))
        self.stats.warmup_compiles += self.stats.compiles - before
        return self

    def __call__(self, queries: jnp.ndarray) -> SearchResult:
        self._check_current()
        q = jnp.asarray(queries)
        if q.ndim != 2:
            raise ValueError(f"queries must be (B, D), got shape {q.shape}")
        if q.shape[0] == 0:
            raise ValueError("empty query batch (B=0)")
        if q.dtype != jnp.float32:
            q = q.astype(jnp.float32)
        n = q.shape[0]
        outs = []
        s = 0
        while s < n:
            b = min(n - s, self.params.max_chunk)
            bucket = self.params.bucket_for(b)
            with obs.span("searcher.dispatch", cat="searcher",
                          bucket=bucket, rows=b, pad=bucket - b):
                qc = q[s:s + b]
                if b < bucket:
                    qc = jnp.concatenate(
                        [qc, jnp.zeros((bucket - b, q.shape[1]), q.dtype)],
                        axis=0)
                    self.stats.padded_rows += bucket - b
                r = self._dispatch(bucket, qc)
                if b < bucket:
                    r = jax.tree.map(lambda a: a[:b], r)
            outs.append(r)
            s += b
        self.stats.calls += 1
        if len(outs) == 1:
            return outs[0]
        return jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *outs)

    # explicit alias for callers that prefer a method name
    search = __call__
