"""SEIL — Shared-cell Enhanced IVF Lists (paper §5).

``cell_{i,j}`` (i<=j) holds all vectors assigned to both list_i and
list_j (i==j: single assignment).  SEIL stores the *full* 32-item blocks
of a cell once (physically in list_i); list_j keeps a reference entry.
The ``nitems % block`` leftovers are stored in BOTH lists' miscellaneous
areas, with the other list id recorded per item (the paper embeds it in
high vector-id bits; we keep a parallel int32 array because JAX is x32).

Static-shape representation (TPU-friendly — see DESIGN.md §3):
  * flat block storage: ``block_codes (TB, BLK, M)``, ``block_ids (TB, BLK)``,
    ``block_other (TB, BLK)`` (-1 = no co-assigned list),
  * per-list padded tables of block indices:
      - ``owned``      : full shared-cell blocks stored here (always scanned)
      - ``refs``/``refs_other``: referenced blocks + their physical home list
      - ``misc``       : miscellaneous blocks (scanned with item-level dedup)
  * the ``listVisited`` hash of Alg. 5 becomes a vectorized rank-compare at
    query time (see search.py) — no hash table on TPU.

``shared=False`` builds the baseline duplicated layout (IVFPQfs /
NaiveRA / SOAR / RAIR *without* SEIL): every item is stored once per
assigned list, all blocks owned, no dedup metadata.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SeilArrays:
    """Finalized static-layout lists (a JAX pytree; shapes are static)."""
    block_codes: jnp.ndarray   # (TB, BLK, M) uint8
    block_ids: jnp.ndarray     # (TB, BLK) int32, -1 invalid
    block_other: jnp.ndarray   # (TB, BLK) int32, -1 none (misc-item dedup tag)
    owned: jnp.ndarray         # (nlist, MO) int32 block ids, -1 pad
    refs: jnp.ndarray          # (nlist, MR) int32 block ids, -1 pad
    refs_other: jnp.ndarray    # (nlist, MR) int32 physical-home list, -1 pad
    misc: jnp.ndarray          # (nlist, MM) int32 block ids, -1 pad

    @property
    def nlist(self) -> int:
        return self.owned.shape[0]

    @property
    def block_size(self) -> int:
        return self.block_ids.shape[1]


@dataclasses.dataclass
class SeilStats:
    """Logical storage accounting (paper Table 4 / Fig 13b)."""
    n_vectors: int
    n_items_stored: int        # vector items physically stored (code+id)
    n_ref_entries: int         # (other, nblocks, ptr) entries
    n_blocks: int
    n_misc_items: int          # items living in misc areas (incl. duplicates)
    code_bytes_per_item: float
    id_bytes_per_item: int = 4
    ref_entry_bytes: int = 8

    @property
    def logical_bytes(self) -> int:
        per_item = self.code_bytes_per_item + self.id_bytes_per_item
        return int(self.n_items_stored * per_item
                   + self.n_ref_entries * self.ref_entry_bytes)


def cell_stats(assigns: np.ndarray) -> Dict[str, np.ndarray]:
    """Cell-size distribution (paper Fig 5). assigns: (n, 2) with l1<=l2."""
    a = np.asarray(assigns)
    keys = a[:, 0].astype(np.int64) * (a.max() + 1) + a[:, 1]
    _, counts = np.unique(keys, return_counts=True)
    return {"cell_sizes": counts}


def vectors_in_large_cells(assigns: np.ndarray, block: int = 32) -> float:
    """Fraction of vectors residing in cells >= one block (paper: ~50%)."""
    sizes = cell_stats(assigns)["cell_sizes"]
    return float(sizes[sizes >= block].sum() / sizes.sum())


def _pad_table(groups: np.ndarray, values: np.ndarray, nlist: int,
               pad_to: Optional[int] = None) -> np.ndarray:
    """Scatter `values` grouped by `groups` into (nlist, MAX) with -1 pad."""
    order = np.argsort(groups, kind="stable")
    groups, values = groups[order], values[order]
    counts = np.bincount(groups, minlength=nlist)
    width = int(counts.max()) if counts.size and counts.max() > 0 else 1
    if pad_to is not None:
        width = max(width, pad_to)
    table = np.full((nlist, width), -1, np.int32)
    starts = np.zeros(nlist + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    pos = np.arange(len(groups)) - starts[groups]
    table[groups, pos] = values
    return table


# Monotonic count of full layout builds.  The streaming subsystem
# (core/stream/) asserts its delta append path never triggers one, and
# benchmarks/run.py records it in BENCH_stream.json — a rebuild is the
# O(n) cost the delta segment exists to avoid.
_BUILD_SEIL_CALLS = 0


def build_seil_call_count() -> int:
    """Number of full layout builds since process start."""
    return _BUILD_SEIL_CALLS


def build_seil(
    assigns: np.ndarray,        # (n, m) sorted list ids per vector
    codes: np.ndarray,          # (n, M) uint8
    ids: np.ndarray,            # (n,) int32 vector ids
    nlist: int,
    block: int = 32,
    shared: bool = True,
    code_bits: int = 4,
) -> Tuple[SeilArrays, SeilStats]:
    """Build the SEIL (or baseline duplicated) list layout. Paper Alg. 4."""
    global _BUILD_SEIL_CALLS
    _BUILD_SEIL_CALLS += 1
    assigns = np.asarray(assigns, np.int32)
    codes = np.asarray(codes, np.uint8)
    ids = np.asarray(ids, np.int32)
    n, m_assign = assigns.shape
    m_pq = codes.shape[1]

    blk_codes, blk_ids, blk_other = [], [], []     # streams of full blocks
    owned_l, owned_b = [], []                      # (list, block) pairs
    ref_l, ref_b, ref_o = [], [], []
    misc_list, misc_item, misc_other = [], [], []  # item-level misc pools
    n_ref_entries = 0

    if shared:
        assert m_assign == 2, "SEIL sharing is designed for 2-assignment (paper §6.3)"
        l1, l2 = assigns[:, 0], assigns[:, 1]
        order = np.lexsort((ids, l2, l1))
        sl1, sl2, sids = l1[order], l2[order], ids[order]
        change = np.empty(n, bool)
        change[0] = True
        change[1:] = (sl1[1:] != sl1[:-1]) | (sl2[1:] != sl2[:-1])
        starts = np.nonzero(change)[0]
        counts = np.diff(np.append(starts, n))
        cell_of_item = np.cumsum(change) - 1
        pos_in_cell = np.arange(n) - starts[cell_of_item]
        nfull_of_cell = (counts // block) * block
        full_mask = pos_in_cell < nfull_of_cell[cell_of_item]

        # ---- full shared blocks (stored once, in cell's first list) ----
        fidx = order[full_mask]                         # item rows, cell-contig
        nb_total = len(fidx) // block
        if nb_total:
            fb = fidx.reshape(nb_total, block)
            blk_codes.append(codes[fb])
            blk_ids.append(ids[fb])
            cell_l1 = l1[fb[:, 0]]
            cell_l2 = l2[fb[:, 0]]
            other = np.where(cell_l2 != cell_l1, cell_l2, -1)
            blk_other.append(np.broadcast_to(other[:, None], (nb_total, block)).copy())
            bid = np.arange(nb_total, dtype=np.int64)
            owned_l.append(cell_l1)
            owned_b.append(bid)
            sh = cell_l2 != cell_l1
            ref_l.append(cell_l2[sh])
            ref_b.append(bid[sh])
            ref_o.append(cell_l1[sh])
            # one (other, nblocks, ptr) entry per contiguous shared-cell run:
            cells_with_blocks = np.unique(
                cell_l1[sh].astype(np.int64) * nlist + cell_l2[sh])
            n_ref_entries = len(cells_with_blocks)

        # ---- miscellaneous leftovers: stored in BOTH lists ----
        midx = order[~full_mask]
        if len(midx):
            ml1, ml2 = l1[midx], l2[midx]
            dup = ml2 != ml1
            misc_list = np.concatenate([ml1, ml2[dup]])
            misc_item = np.concatenate([midx, midx[dup]])
            misc_other = np.concatenate([np.where(dup, ml2, -1), ml1[dup]])
        n_misc_items = len(misc_item) if len(misc_item) else 0
    else:
        # baseline duplicated layout: one copy per assigned list; dedup off
        pairs_l, pairs_i = [], []
        for j in range(m_assign):
            lj = assigns[:, j]
            if j == 0:
                keep = np.ones(n, bool)
            else:
                keep = (assigns[:, j:j + 1] != assigns[:, :j]).all(axis=1)
            pairs_l.append(lj[keep])
            pairs_i.append(np.nonzero(keep)[0])
        misc_list = np.concatenate(pairs_l)
        misc_item = np.concatenate(pairs_i)
        misc_other = np.full(len(misc_list), -1, np.int32)
        n_misc_items = 0  # not a SEIL misc area; counted as plain items

    # ---- pack per-list misc/item pools into blocks ----
    if len(misc_list):
        misc_list = np.asarray(misc_list)
        misc_item = np.asarray(misc_item)
        misc_other = np.asarray(misc_other, np.int32)
        o2 = np.lexsort((ids[misc_item], misc_item, misc_list))
        gl, gi, go = misc_list[o2], misc_item[o2], misc_other[o2]
        lcounts = np.bincount(gl, minlength=nlist)
        lstarts = np.zeros(nlist + 1, np.int64)
        np.cumsum(lcounts, out=lstarts[1:])
        pos = np.arange(len(gl)) - lstarts[gl]
        nmb = (lcounts + block - 1) // block          # misc blocks per list
        mb_off = np.zeros(nlist + 1, np.int64)
        np.cumsum(nmb, out=mb_off[1:])
        nb_full = sum(b.shape[0] for b in blk_ids)
        item_block = nb_full + mb_off[gl] + pos // block
        item_slot = pos % block
        n_misc_blocks = int(mb_off[-1])
        mcodes = np.zeros((n_misc_blocks, block, m_pq), np.uint8)
        mids = np.full((n_misc_blocks, block), -1, np.int32)
        mother = np.full((n_misc_blocks, block), -1, np.int32)
        rel = item_block - nb_full
        mcodes[rel, item_slot] = codes[gi]
        mids[rel, item_slot] = ids[gi]
        mother[rel, item_slot] = go
        blk_codes.append(mcodes)
        blk_ids.append(mids)
        blk_other.append(mother)
        mb_list = np.repeat(np.arange(nlist), nmb)
        mb_bid = nb_full + np.arange(n_misc_blocks)
        if shared:
            misc_l_tab, misc_b_tab = mb_list, mb_bid
        else:
            owned_l.append(mb_list)
            owned_b.append(mb_bid)
            misc_l_tab = np.zeros(0, np.int64)
            misc_b_tab = np.zeros(0, np.int64)
    else:
        misc_l_tab = np.zeros(0, np.int64)
        misc_b_tab = np.zeros(0, np.int64)

    tb = sum(b.shape[0] for b in blk_ids)
    if tb == 0:  # degenerate empty index
        blk_codes = [np.zeros((1, block, m_pq), np.uint8)]
        blk_ids = [np.full((1, block), -1, np.int32)]
        blk_other = [np.full((1, block), -1, np.int32)]
        tb = 1

    block_codes = np.concatenate(blk_codes, axis=0)
    block_ids = np.concatenate(blk_ids, axis=0).astype(np.int32)
    block_other = np.concatenate(blk_other, axis=0).astype(np.int32)

    def cat(xs):
        return (np.concatenate(xs).astype(np.int64)
                if xs else np.zeros(0, np.int64))

    owned_tab = _pad_table(cat(owned_l), cat(owned_b).astype(np.int32), nlist)
    refs_groups = cat(ref_l)
    refs_tab = _pad_table(refs_groups, cat(ref_b).astype(np.int32), nlist)
    refso_tab = _pad_table(refs_groups, cat(ref_o).astype(np.int32), nlist)
    misc_tab = _pad_table(misc_l_tab, misc_b_tab.astype(np.int32), nlist)

    arrays = SeilArrays(
        block_codes=jnp.asarray(block_codes),
        block_ids=jnp.asarray(block_ids),
        block_other=jnp.asarray(block_other),
        owned=jnp.asarray(owned_tab),
        refs=jnp.asarray(refs_tab),
        refs_other=jnp.asarray(refso_tab),
        misc=jnp.asarray(misc_tab),
    )
    n_items_stored = int((block_ids >= 0).sum())
    stats = SeilStats(
        n_vectors=n,
        n_items_stored=n_items_stored,
        n_ref_entries=n_ref_entries,
        n_blocks=tb,
        n_misc_items=int(n_misc_items),
        code_bytes_per_item=m_pq * code_bits / 8.0,
    )
    return arrays, stats


def build_id_map(arrays: SeilArrays) -> Dict[int, list]:
    """id -> [(block, slot), ...] (≤2 per id + misc dups), for deletions."""
    ids = np.asarray(arrays.block_ids)
    out: Dict[int, list] = {}
    bs, ss = np.nonzero(ids >= 0)
    for b, s in zip(bs.tolist(), ss.tolist()):
        out.setdefault(int(ids[b, s]), []).append((b, s))
    return out


def delete_ids(arrays: SeilArrays, id_map: Dict[int, list], del_ids) -> SeilArrays:
    """Deprecated: invalidate layout entries for `del_ids` (paper §6.1).

    LAYOUT-LEVEL ONLY: this rewrites ``SeilArrays`` in isolation and
    leaves an index's ``assigns``/``codes``/``vectors``/``SeilStats`` —
    and any cached searcher session — stale.  Index-level deletion must
    go through ``StreamingIndex.delete`` (core/stream/), which masks
    tombstones at query time and keeps every view plus session
    versioning coherent (tests/test_stream.py guards the regression).
    Emits a ``DeprecationWarning`` so the footgun is loud: it remains
    callable only for layout-isolation measurements."""
    import warnings
    warnings.warn(
        "seil.delete_ids is layout-only and leaves assigns/codes/vectors/"
        "stats and cached sessions stale; use StreamingIndex.delete "
        "(index.streaming().delete(ids)) for index-level deletion",
        DeprecationWarning, stacklevel=2)
    ids = np.asarray(arrays.block_ids).copy()
    for i in del_ids:
        for (b, s) in id_map.get(int(i), ()):
            ids[b, s] = -1
    return dataclasses.replace(arrays, block_ids=jnp.asarray(ids))
