"""K-means clustering (Lloyd's) for IVF list training — jittable, chunkable.

Used for: IVF coarse centroids (nlist lists), PQ sub-codebooks, and the
KV-cache clustering of the RAIRS-kNN attention path.  The distributed
variant exposes one Lloyd step as a shard_map-compatible function with
psum'd sufficient statistics (classic data-parallel k-means).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def pairwise_sq_l2(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distances ||x - c||^2, shapes (n, D) x (k, D) -> (n, k)."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (n, 1)
    c2 = jnp.sum(c * c, axis=-1)                          # (k,)
    xc = x @ c.T                                          # (n, k)
    return jnp.maximum(x2 - 2.0 * xc + c2[None, :], 0.0)


def assign_nearest(x: jnp.ndarray, c: jnp.ndarray, chunk: int = 16384) -> jnp.ndarray:
    """argmin_k ||x - c_k||^2, chunked over n to bound the (n,k) buffer."""
    n = x.shape[0]
    if n <= chunk:
        return jnp.argmin(pairwise_sq_l2(x, c), axis=-1).astype(jnp.int32)
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xs = xp.reshape(-1, chunk, x.shape[1])

    def body(_, xb):
        return None, jnp.argmin(pairwise_sq_l2(xb, c), axis=-1).astype(jnp.int32)

    _, out = jax.lax.scan(body, None, xs)
    return out.reshape(-1)[:n]


def _update_centroids(x, assign, k, old_c):
    sums = jax.ops.segment_sum(x, assign, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), assign, num_segments=k)
    new_c = sums / jnp.maximum(counts, 1.0)[:, None]
    # keep empty clusters where they were (Faiss splits them; we freeze them)
    return jnp.where((counts > 0)[:, None], new_c, old_c), counts


@functools.partial(jax.jit, static_argnames=("k", "iters", "chunk"))
def _kmeans_loop(x, init_c, k, iters, chunk):
    def step(c, _):
        a = assign_nearest(x, c, chunk)
        c2, counts = _update_centroids(x, a, k, c)
        return c2, counts

    c, _ = jax.lax.scan(step, init_c, None, length=iters)
    return c


def kmeans_fit(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    iters: int = 20,
    chunk: int = 16384,
    sample: Optional[int] = None,
) -> jnp.ndarray:
    """Fit k centroids.  Random-point init (Faiss default for IVF training)."""
    n = x.shape[0]
    if sample is not None and sample < n:
        idx = jax.random.choice(key, n, shape=(sample,), replace=False)
        xt = x[idx]
    else:
        xt = x
    perm = jax.random.permutation(key, xt.shape[0])[:k]
    init_c = xt[perm]
    return _kmeans_loop(xt, init_c, k, iters, chunk)


# ----------------------------------------------------------------------------
# Distributed Lloyd step (per-shard body; wrap in shard_map over the data axis)
# ----------------------------------------------------------------------------
def kmeans_step_sharded(x_local: jnp.ndarray, c: jnp.ndarray, *, axis_names) -> jnp.ndarray:
    """One Lloyd step where each device holds a shard of x.

    Must run inside shard_map with `axis_names` spanning the data axes;
    centroids replicated.  psum of (sums, counts) is the only collective.
    """
    a = assign_nearest(x_local, c)
    k = c.shape[0]
    sums = jax.ops.segment_sum(x_local, a, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((x_local.shape[0],), x_local.dtype), a, num_segments=k)
    sums = jax.lax.psum(sums, axis_names)
    counts = jax.lax.psum(counts, axis_names)
    new_c = sums / jnp.maximum(counts, 1.0)[:, None]
    return jnp.where((counts > 0)[:, None], new_c, c)
