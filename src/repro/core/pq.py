"""Product quantization (PQ) — training, encoding, and ADC lookup tables.

Paper baseline: IVF-PQ Fast Scan uses 4-bit codes (ksub=16) with
M = D/2 subquantizers (dsub = 2 dims per group).  LUTs are built per
query (by_residual=False, matching the paper's per-query LUT description
and IndexIVFPQFastScan's default), so estimated distance of item i is
    d(q, x_i) ~= sum_m LUT[m, code[i, m]].
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kmeans import kmeans_fit, pairwise_sq_l2


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PQCodebook:
    """codebooks: (M, ksub, dsub) float32."""
    codebooks: jnp.ndarray

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def ksub(self) -> int:
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]


def pq_train(key: jax.Array, x: jnp.ndarray, m: int, nbits: int = 4,
             iters: int = 15, sample: int = 65536) -> PQCodebook:
    """Train per-subspace k-means codebooks. x: (n, D), D % m == 0."""
    n, d = x.shape
    assert d % m == 0, f"D={d} not divisible by M={m}"
    dsub, ksub = d // m, 2 ** nbits
    xs = x.reshape(n, m, dsub)
    keys = jax.random.split(key, m)
    books = []
    for j in range(m):
        books.append(kmeans_fit(keys[j], xs[:, j, :], ksub, iters=iters, sample=sample))
    return PQCodebook(jnp.stack(books))


@jax.jit
def pq_encode(cb: PQCodebook, x: jnp.ndarray) -> jnp.ndarray:
    """Encode (n, D) -> (n, M) uint8 codes (values < ksub)."""
    n, d = x.shape
    m, ksub, dsub = cb.codebooks.shape
    xs = x.reshape(n, m, dsub)

    def enc_sub(xsub, book):  # (n, dsub), (ksub, dsub)
        return jnp.argmin(pairwise_sq_l2(xsub, book), axis=-1)

    codes = jax.vmap(enc_sub, in_axes=(1, 0), out_axes=1)(xs, cb.codebooks)
    return codes.astype(jnp.uint8)


@jax.jit
def pq_lut(cb: PQCodebook, q: jnp.ndarray) -> jnp.ndarray:
    """Per-query ADC tables.  q: (B, D) -> (B, M, ksub) squared-L2 partials."""
    b, d = q.shape
    m, ksub, dsub = cb.codebooks.shape
    qs = q.reshape(b, m, dsub)
    # (B, M, ksub): ||q_sub - c||^2
    diff = qs[:, :, None, :] - cb.codebooks[None, :, :, :]
    return jnp.sum(diff * diff, axis=-1)


def pq_lut_ip(cb: PQCodebook, q: jnp.ndarray) -> jnp.ndarray:
    """Inner-product ADC tables (for the SOAR/T2I experiments): -<q_sub, c>."""
    b, d = q.shape
    m, ksub, dsub = cb.codebooks.shape
    qs = q.reshape(b, m, dsub)
    return -jnp.einsum("bmd,mkd->bmk", qs, cb.codebooks)


@jax.jit
def pq_adc(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Estimate distances. lut: (M, ksub) single query; codes: (..., M)."""
    m = lut.shape[0]
    gathered = jnp.take_along_axis(
        lut[None, :, :].repeat(codes.shape[0], axis=0) if codes.ndim == 2 else lut,
        codes.astype(jnp.int32)[..., None], axis=-1)
    return jnp.sum(gathered[..., 0], axis=-1)


def pq_decode(cb: PQCodebook, codes: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct vectors from codes: (n, M) -> (n, D)."""
    m, ksub, dsub = cb.codebooks.shape
    rec = jnp.take_along_axis(
        cb.codebooks[None], codes.astype(jnp.int32)[:, :, None, None], axis=2)
    return rec[:, :, 0, :].reshape(codes.shape[0], m * dsub)
