"""Distributed RAIRS index: shard_map serving step for billion-vector
corpora (the paper's SIFT1B regime on the production mesh).

Sharding scheme (DESIGN.md §4):
  * flat block arrays shard over the ("pod","data") axes by block-id
    range — balanced by construction (straggler mitigation is
    structural: every device owns TB/ndev blocks and scans at most the
    same static budget per query);
  * centroids + per-list block tables replicate (nlist x maxb int32 —
    MBs, not GBs);
  * refine vectors shard by vector-id range over the same axes.

Per query batch each device composes the SAME engine stages as the
single-host searcher (core/engine/, DESIGN.md §5): ``select_lists``
runs replicated, ``plan_blocks`` windows the deduplicated candidate
set to the device's block range (``local_lo``/``local_count``), and
``scan_blocks`` scans the local ``BlockStore`` in either exec mode
("paged" per-query paging or "grouped" list-major batching).  A local
top-bigK plus one `all_gather` of (bigK ids, dists) merges candidates;
refinement scores each candidate on its owner device and a `pmin`
reduces exact distances — two small collectives per batch instead of
moving vector data.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..dist import shard_map
from .engine import (BlockStore, ListTables, plan_blocks, scan_blocks,
                     select_lists)
from .params import SearchParams


class DistSearchResult(NamedTuple):
    ids: jnp.ndarray
    dists: jnp.ndarray
    local_dco: jnp.ndarray     # (B,) per-device approx DCO (psum'd)


def make_distributed_serve_step(nlist: int, nprobe: int, bigk: int, k: int,
                                max_scan_local: int, axes=("data",),
                                exec_mode: str = "paged",
                                query_tile: int = 8):
    """Returns serve(arrays, tables, centroids, codebook_dec, vectors,
    queries) for use inside shard_map (see distributed_search)."""

    def serve(block_codes, block_ids, block_other, owned, owned_other,
              refs, refs_other, misc, centroids, lut_codebooks, vectors,
              vec_lo, block_lo, queries):
        # -- replicated control path: list selection + dedup + local plan
        # (identical on every device; no collective needed)
        selection = select_lists(queries, centroids, nprobe=nprobe,
                                 metric="l2")
        tables = ListTables(owned=owned, owned_other=owned_other, refs=refs,
                            refs_other=refs_other, misc=misc)
        plan = plan_blocks(tables, selection, max_scan=max_scan_local,
                           local_lo=block_lo[0],
                           local_count=block_ids.shape[0])

        # -- local scan over the device's block shard
        lut = pq_lut_from_tables(lut_codebooks, queries)
        store = BlockStore(block_codes=block_codes, block_ids=block_ids,
                           block_other=block_other)
        scan = scan_blocks(store, plan, lut, selection.rank_of,
                           exec_mode=exec_mode, query_tile=query_tile)

        # -- local top-bigK, then one all_gather to merge
        neg, pos = jax.lax.top_k(-scan.flat_d,
                                 min(bigk, scan.flat_d.shape[1]))
        l_ids = jnp.take_along_axis(scan.flat_i, pos, axis=1)
        l_d = -neg
        g_ids = jax.lax.all_gather(l_ids, axes, axis=1, tiled=True)
        g_d = jax.lax.all_gather(l_d, axes, axis=1, tiled=True)
        negg, posg = jax.lax.top_k(-g_d, bigk)
        cand_ids = jnp.take_along_axis(g_ids, posg, axis=1)
        cand_ok = jnp.isfinite(-negg)
        cand_ids = jnp.where(cand_ok, cand_ids, -1)

        # -- distributed refine: owner device scores, pmin reduces
        nloc = vectors.shape[0]
        rel = cand_ids - vec_lo[0]
        mine = cand_ok & (rel >= 0) & (rel < nloc)
        cv = vectors[jnp.clip(rel, 0, nloc - 1)]
        diff = cv - queries[:, None, :]
        exact = jnp.where(mine, jnp.sum(diff * diff, -1), jnp.inf)
        exact = jax.lax.pmin(exact, axes)
        negk, posk = jax.lax.top_k(-exact, k)
        out_ids = jnp.take_along_axis(cand_ids, posk, axis=1)
        out_d = -negk
        out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
        return DistSearchResult(ids=out_ids, dists=out_d,
                                local_dco=jax.lax.psum(scan.approx_dco, axes))

    return serve


def pq_lut_from_tables(codebooks, queries):
    """(M, ksub, dsub) f32 codebooks -> per-query LUTs (B, M, ksub)."""
    b, d = queries.shape
    m, ksub, dsub = codebooks.shape
    qs = queries.reshape(b, m, dsub)
    diff = qs[:, :, None, :] - codebooks[None]
    return jnp.sum(diff * diff, axis=-1)


def distributed_search(index, mesh: Mesh, queries, *,
                       params: SearchParams = None,
                       nprobe: int = None, k: int = None,
                       k_factor: int = None, max_scan_local: int = 512,
                       axes=("data",), exec_mode: str = None,
                       query_tile: int = None):
    """Host-callable wrapper: pads + shards a RairsIndex over `axes` and
    runs the shard_map serve step (used by tests and launch/serve).

    Query-side knobs come from `params` (the session API's SearchParams);
    individual kwargs override its fields.  Without `params`, `nprobe`
    and `k` are required (as before the session API).  `max_scan_local`
    stays separate — it is the per-device plan budget, a property of the
    shard layout rather than of the query.  Fields the shard_map path
    does not implement (`use_kernel`, `max_scan`, `batch_buckets`) are
    rejected rather than silently dropped."""
    import dataclasses as _dc
    import numpy as np
    if params is None:
        if nprobe is None or k is None:
            raise TypeError(
                "distributed_search requires nprobe= and k= when no "
                "params=SearchParams(...) is given")
        params = SearchParams()
    over = {name: v for name, v in (("nprobe", nprobe), ("k", k),
                                    ("k_factor", k_factor),
                                    ("exec_mode", exec_mode),
                                    ("query_tile", query_tile))
            if v is not None}
    if over:
        params = _dc.replace(params, **over)
    unsupported = [name for name, v in (("use_kernel", params.use_kernel),
                                        ("max_scan", params.max_scan),
                                        ("batch_buckets", params.batch_buckets))
                   if v not in (None, False)]
    if unsupported:
        raise ValueError(
            f"distributed_search does not support SearchParams fields "
            f"{unsupported} (use max_scan_local for the per-device budget; "
            f"the shard_map step runs the jnp scan path)")
    nprobe, k, k_factor = params.nprobe, params.k, params.k_factor
    exec_mode, query_tile = params.exec_mode, params.query_tile
    nd = 1
    for a in axes:
        nd *= mesh.shape[a]
    arrays = index.arrays
    owned_np = np.asarray(arrays.owned)
    bo_np = np.asarray(arrays.block_other)
    owned_other = np.where(owned_np >= 0,
                           bo_np[np.maximum(owned_np, 0), 0], -1
                           ).astype(np.int32)
    tb = arrays.block_codes.shape[0]
    pad = (-tb) % nd

    def padb(x, fill):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill)

    codes = padb(arrays.block_codes, 0)
    bids = padb(arrays.block_ids, -1)
    both = padb(arrays.block_other, -1)
    n = index.vectors.shape[0]
    vpad = (-n) % nd
    vecs = jnp.pad(index.vectors, ((0, vpad), (0, 0)))
    tb_l = (tb + pad) // nd
    n_l = (n + vpad) // nd
    block_lo = jnp.arange(nd, dtype=jnp.int32) * tb_l
    vec_lo = jnp.arange(nd, dtype=jnp.int32) * n_l

    serve = make_distributed_serve_step(
        nlist=index.config.nlist, nprobe=nprobe, bigk=k * k_factor, k=k,
        max_scan_local=max_scan_local, axes=axes, exec_mode=exec_mode,
        query_tile=query_tile)
    spec_sharded = P(axes)
    spec_rep = P()
    fn = shard_map(
        serve, mesh=mesh,
        in_specs=(spec_sharded, spec_sharded, spec_sharded, spec_rep,
                  spec_rep, spec_rep, spec_rep, spec_rep, spec_rep,
                  spec_rep, spec_sharded, spec_sharded, spec_sharded,
                  spec_rep),
        out_specs=DistSearchResult(ids=spec_rep, dists=spec_rep,
                                   local_dco=spec_rep))
    return fn(codes, bids, both, arrays.owned, jnp.asarray(owned_other),
              arrays.refs, arrays.refs_other, arrays.misc, index.centroids,
              index.codebook.codebooks, vecs, vec_lo, block_lo, queries)
