"""Distributed RAIRS index: shard_map serving step for billion-vector
corpora (the paper's SIFT1B regime on the production mesh).

Sharding scheme (DESIGN.md §4):
  * flat block arrays shard over the ("pod","data") axes by block-id
    range — balanced by construction (straggler mitigation is
    structural: every device owns TB/ndev blocks and scans at most the
    same static budget per query);
  * centroids + per-list block tables replicate (nlist x maxb int32 —
    MBs, not GBs);
  * refine vectors shard by vector-id range over the same axes.

Per query batch each device: selects lists (replicated compute),
builds the deduplicated candidate block set (identical on every
device), masks it to its local block range, scans locally (the same
SEIL semantics as core/search.py), and produces a local top-bigK.
One `all_gather` of (bigK ids, dists) per device merges candidates;
refinement scores each candidate on its owner device and a `pmin`
reduces exact distances — two small collectives per batch instead of
moving vector data.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .kmeans import pairwise_sq_l2
from .pq import pq_lut
from .search import BIG, _rank_table, SearchResult


class DistSearchResult(NamedTuple):
    ids: jnp.ndarray
    dists: jnp.ndarray
    local_dco: jnp.ndarray     # (B,) per-device approx DCO (psum'd)


def _local_scan(arrays_local, block_lo, lut, cand, cand_rank, rank_of,
                bq, blk, max_scan_local):
    """Scan candidate blocks that live in [block_lo, block_lo+TBl)."""
    tbl = arrays_local["block_ids"].shape[0]
    rel = cand - block_lo
    mine = (cand >= 0) & (rel >= 0) & (rel < tbl)
    rel = jnp.where(mine, rel, -1)
    # compact to the local static budget
    max_scan_local = min(max_scan_local, rel.shape[1])
    pos = jnp.arange(rel.shape[1], dtype=jnp.int32)
    key = jnp.where(mine, BIG - pos, -1 - pos)
    _, take = jax.lax.top_k(key, max_scan_local)
    blocks = jnp.take_along_axis(rel, take, axis=1)
    branks = jnp.take_along_axis(cand_rank, take, axis=1)
    bvalid = blocks >= 0
    safe = jnp.maximum(blocks, 0)

    codes = arrays_local["block_codes"][safe]            # (B, S, BLK, M)
    g = jnp.take_along_axis(
        lut[:, None, None, :, :], codes.astype(jnp.int32)[..., None],
        axis=-1)
    dists = jnp.sum(g[..., 0], axis=-1)
    ids = arrays_local["block_ids"][safe]
    other = arrays_local["block_other"][safe]
    o_rank = jnp.take_along_axis(
        rank_of, jnp.maximum(other, 0).reshape(bq, -1), axis=1
    ).reshape(other.shape)
    dup = (other >= 0) & (o_rank < branks[:, :, None])
    ok = (ids >= 0) & bvalid[:, :, None]
    keep = ok & ~dup
    dco = ok.sum(axis=(1, 2)).astype(jnp.int32)
    return jnp.where(keep, dists, jnp.inf).reshape(bq, -1), \
        ids.reshape(bq, -1), dco


def make_distributed_serve_step(nlist: int, nprobe: int, bigk: int, k: int,
                                max_scan_local: int, axes=("data",)):
    """Returns serve(arrays, tables, centroids, codebook_dec, vectors,
    queries) for use inside shard_map (see distributed_search)."""

    def serve(block_codes, block_ids, block_other, owned, owned_other,
              refs, refs_other, misc, centroids, lut_codebooks, vectors,
              vec_lo, block_lo, queries):
        bq = queries.shape[0]
        blk = block_ids.shape[1]
        # -- replicated control path: list selection + dedup (identical
        # on every device; no collective needed)
        cd = pairwise_sq_l2(queries, centroids)
        _, sel = jax.lax.top_k(-cd, nprobe)
        sel = sel.astype(jnp.int32)
        rank_of = _rank_table(sel, nlist)
        ow = owned[sel]
        rf = refs[sel]
        ro = refs_other[sel]
        mi = misc[sel]
        t = jnp.arange(nprobe, dtype=jnp.int32)[None, :, None]

        def visited_earlier(other_list):
            r = jnp.take_along_axis(
                rank_of, jnp.maximum(other_list, 0).reshape(bq, -1), axis=1
            ).reshape(other_list.shape)
            return (other_list >= 0) & (r < t)

        rf = jnp.where(visited_earlier(ro), -1, rf)
        # home shared blocks: skip if co-list scanned earlier (its ref
        # entry already computed the cell) — same as core/search.py
        oo = owned_other[sel]
        ow = jnp.where(visited_earlier(oo), -1, ow)
        cand = jnp.concatenate([ow.reshape(bq, -1), rf.reshape(bq, -1),
                                mi.reshape(bq, -1)], axis=1)
        cand_rank = jnp.concatenate(
            [jnp.broadcast_to(t, ow.shape).reshape(bq, -1),
             jnp.broadcast_to(t, rf.shape).reshape(bq, -1),
             jnp.broadcast_to(t, mi.shape).reshape(bq, -1)], axis=1)

        # -- local scan over owned block range
        lut = pq_lut_from_tables(lut_codebooks, queries)
        arrays_local = {"block_codes": block_codes, "block_ids": block_ids,
                        "block_other": block_other}
        flat_d, flat_i, dco = _local_scan(
            arrays_local, block_lo[0], lut, cand, cand_rank, rank_of, bq,
            blk, max_scan_local)

        # -- local top-bigK, then one all_gather to merge
        neg, pos = jax.lax.top_k(-flat_d, min(bigk, flat_d.shape[1]))
        l_ids = jnp.take_along_axis(flat_i, pos, axis=1)
        l_d = -neg
        g_ids = jax.lax.all_gather(l_ids, axes, axis=1, tiled=True)
        g_d = jax.lax.all_gather(l_d, axes, axis=1, tiled=True)
        negg, posg = jax.lax.top_k(-g_d, bigk)
        cand_ids = jnp.take_along_axis(g_ids, posg, axis=1)
        cand_ok = jnp.isfinite(-negg)
        cand_ids = jnp.where(cand_ok, cand_ids, -1)

        # -- distributed refine: owner device scores, pmin reduces
        nloc = vectors.shape[0]
        rel = cand_ids - vec_lo[0]
        mine = cand_ok & (rel >= 0) & (rel < nloc)
        cv = vectors[jnp.clip(rel, 0, nloc - 1)]
        diff = cv - queries[:, None, :]
        exact = jnp.where(mine, jnp.sum(diff * diff, -1), jnp.inf)
        exact = jax.lax.pmin(exact, axes)
        negk, posk = jax.lax.top_k(-exact, k)
        out_ids = jnp.take_along_axis(cand_ids, posk, axis=1)
        out_d = -negk
        out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
        return DistSearchResult(ids=out_ids, dists=out_d,
                                local_dco=jax.lax.psum(dco, axes))

    return serve


def pq_lut_from_tables(codebooks, queries):
    """(M, ksub, dsub) f32 codebooks -> per-query LUTs (B, M, ksub)."""
    b, d = queries.shape
    m, ksub, dsub = codebooks.shape
    qs = queries.reshape(b, m, dsub)
    diff = qs[:, :, None, :] - codebooks[None]
    return jnp.sum(diff * diff, axis=-1)


def distributed_search(index, mesh: Mesh, queries, *, nprobe: int, k: int,
                       k_factor: int = 10, max_scan_local: int = 512,
                       axes=("data",)):
    """Host-callable wrapper: pads + shards a RairsIndex over `axes` and
    runs the shard_map serve step (used by tests and launch/serve)."""
    import numpy as np
    nd = 1
    for a in axes:
        nd *= mesh.shape[a]
    arrays = index.arrays
    owned_np = np.asarray(arrays.owned)
    bo_np = np.asarray(arrays.block_other)
    owned_other = np.where(owned_np >= 0,
                           bo_np[np.maximum(owned_np, 0), 0], -1
                           ).astype(np.int32)
    tb = arrays.block_codes.shape[0]
    pad = (-tb) % nd

    def padb(x, fill):
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill)

    codes = padb(arrays.block_codes, 0)
    bids = padb(arrays.block_ids, -1)
    both = padb(arrays.block_other, -1)
    n = index.vectors.shape[0]
    vpad = (-n) % nd
    vecs = jnp.pad(index.vectors, ((0, vpad), (0, 0)))
    tb_l = (tb + pad) // nd
    n_l = (n + vpad) // nd
    block_lo = jnp.arange(nd, dtype=jnp.int32) * tb_l
    vec_lo = jnp.arange(nd, dtype=jnp.int32) * n_l

    serve = make_distributed_serve_step(
        nlist=index.config.nlist, nprobe=nprobe, bigk=k * k_factor, k=k,
        max_scan_local=max_scan_local, axes=axes)
    spec_sharded = P(axes)
    spec_rep = P()
    fn = jax.shard_map(
        serve, mesh=mesh,
        in_specs=(spec_sharded, spec_sharded, spec_sharded, spec_rep,
                  spec_rep, spec_rep, spec_rep, spec_rep, spec_rep,
                  spec_rep, spec_sharded, spec_sharded, spec_sharded,
                  spec_rep),
        out_specs=DistSearchResult(ids=spec_rep, dists=spec_rep,
                                   local_dco=spec_rep),
        check_vma=False)
    return fn(codes, bids, both, arrays.owned, jnp.asarray(owned_other),
              arrays.refs, arrays.refs_other, arrays.misc, index.centroids,
              index.codebook.codebooks, vecs, vec_lo, block_lo, queries)
