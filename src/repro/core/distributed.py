"""Distributed lowering backend: the per-device shard_map serve step
behind ``ShardedIndex`` sessions (core/sharded.py, DESIGN.md §4).

Sharding scheme:
  * flat block arrays shard over the mesh axes by block-id range —
    balanced by construction (straggler mitigation is structural: every
    device owns TB/ndev blocks and scans at most the same static budget
    per query);
  * centroids + per-list block tables + PQ codebooks replicate
    (nlist x maxb int32 — MBs, not GBs);
  * refine vectors shard by vector-id range over the same axes;
  * streaming state replicates: the delta segment is tiny by
    construction (folded into the base at compaction) and the tombstone
    mask is one bit per id, so every device scans the full delta and
    masks with the full bitmap — but only the ``slot % ndev`` owner
    *contributes* each delta candidate to the merge, so SEIL-exact
    (dedup-free) result streams stay duplicate-free across shards.

Per query batch each device composes the SAME engine stages as the
single-host searcher (core/engine/, DESIGN.md §5): ``select_lists``
runs replicated, ``plan_blocks`` windows the deduplicated candidate
set to the device's block range, ``scan_blocks`` scans the local
``BlockStore`` in either exec mode, and the shared finalize tail is
split around two small collectives: a local stable top-fetch
(``preselect_candidates``) + one ``all_gather`` merges candidate
streams, then ``finalize_candidates`` refines owner-scored exact
distances with one ``pmin`` — two collectives per batch instead of
moving vector data.

``build_serve_step`` is the only lowering entry point; ``ShardedSearcher``
AOT-compiles it per batch bucket through the ``Searcher._lower`` hook.
``distributed_search`` remains as a thin session wrapper over the
unified API (the legacy ``make_distributed_serve_step`` shim is gone).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh  # noqa: F401  (re-export for callers)

from .engine import (BlockStore, ListTables, finalize_candidates,
                     plan_blocks, preselect_candidates, scan_blocks,
                     scan_blocks_topk, select_lists)
from .params import SearchParams
from .pq import PQCodebook, pq_lut, pq_lut_ip
from .search import SearchResult
from .stream.search import delta_adc


def build_serve_step(*, nprobe: int, bigk: int, k: int, max_scan_local: int,
                     metric: str = "l2", dedup_results: bool = False,
                     oversample: int = 2, exec_mode: str = "paged",
                     query_tile: int = 8, axes=("data",), ndev: int = 1,
                     streaming: bool = False, use_kernel: bool = False,
                     fused_topk: bool = False, stage: str = "all",
                     packed_codes: bool = False):
    """Build the per-device serve step for shard_map.

    Returns ``serve(block_codes, block_ids, block_other, owned,
    owned_other, refs, refs_other, misc, centroids, codebooks, vectors,
    vec_lo, block_lo, dev_rank, delta_codes, delta_ids, live, queries)
    -> SearchResult`` where the first three arrays and ``vectors`` are
    the device's shard, ``vec_lo/block_lo/dev_rank`` are per-device
    scalars (sharded (ndev,) arrays), and everything else replicates.
    With ``streaming=False`` the delta/live arguments are zero-width
    placeholders and the streaming merge is compiled out.

    ``stage`` is the tracing split (DESIGN.md §11): ``"all"`` (default)
    is the production fused program; ``"scan"`` runs everything through
    the local preselect and returns the per-device candidate streams
    ``(l_d, l_ids, approx_dco, scanned, dropped)`` (counters psum'd);
    ``"tail"`` takes ``(vectors, vec_lo, queries, l_d, l_ids)`` and runs
    the all_gather + shared finalize.  ``"scan"`` then ``"tail"``
    composes to exactly ``"all"`` — same per-device ops, same
    collectives — so results stay bitwise identical (asserted in
    tests/test_obs.py).
    """
    if stage not in ("all", "scan", "tail"):
        raise ValueError(f"stage must be all|scan|tail, got {stage!r}")
    fetch = bigk * (oversample if dedup_results else 1)
    axes = tuple(axes)

    def scan_half(block_codes, block_ids, block_other, owned, owned_other,
                  refs, refs_other, misc, centroids, codebooks, vectors,
                  vec_lo, block_lo, dev_rank, delta_codes, delta_ids, live,
                  queries):
        # -- replicated control path: list selection + dedup + local plan
        # (identical on every device; no collective needed)
        selection = select_lists(queries, centroids, nprobe=nprobe,
                                 metric=metric)
        tables = ListTables(owned=owned, owned_other=owned_other, refs=refs,
                            refs_other=refs_other, misc=misc)
        plan = plan_blocks(tables, selection, max_scan=max_scan_local,
                           local_lo=block_lo[0],
                           local_count=block_ids.shape[0])

        # -- local ADC scan over the device's block shard (either mode)
        cb = PQCodebook(codebooks)
        lut = pq_lut(cb, queries) if metric == "l2" else pq_lut_ip(cb, queries)
        store = BlockStore(block_codes=block_codes, block_ids=block_ids,
                           block_other=block_other)
        # sel feeds the clustered exec mode: the cluster order is derived
        # from the replicated selection, so every device permutes its
        # (locally windowed) plan identically — per-device plans ride the
        # same clustering with their own per-tile local unions
        if fused_topk:
            # the fused scan's width-fetch output IS the per-device
            # preselect — tombstones applied pre-selection via ``live``
            scan = scan_blocks_topk(
                store, plan, lut, selection.rank_of, fetch=fetch,
                exec_mode=exec_mode, use_kernel=use_kernel,
                query_tile=query_tile, sel=selection.sel,
                live=live if streaming else None, packed=packed_codes)
        else:
            scan = scan_blocks(store, plan, lut, selection.rank_of,
                               exec_mode=exec_mode, use_kernel=use_kernel,
                               query_tile=query_tile, sel=selection.sel,
                               packed=packed_codes)
        flat_d, flat_i = scan.flat_d, scan.flat_i
        approx_dco = scan.approx_dco

        if streaming:
            # delta scanned on every device (replicated compute, no extra
            # collective) but each slot has one owner (slot % ndev) so the
            # gathered candidate stream holds each delta id exactly once
            # — and logical DCO is counted exactly once per live slot.
            cap = delta_ids.shape[0]
            alive = delta_ids >= 0
            mine = alive & ((jnp.arange(cap, dtype=jnp.int32) % ndev)
                            == dev_rank[0])
            dd = jnp.where(mine[None, :], delta_adc(lut, delta_codes),
                           jnp.inf)
            di = jnp.broadcast_to(delta_ids[None, :], dd.shape)
            flat_d = jnp.concatenate([flat_d, dd], axis=1)
            flat_i = jnp.concatenate([flat_i, di], axis=1)
            # tombstone mask over the whole id space, replicated (the
            # fused base stream is already live-masked; re-masking it
            # here is idempotent, and the delta needs it either way)
            dead = (flat_i >= 0) & ~live[jnp.maximum(flat_i, 0)]
            flat_d = jnp.where(dead, jnp.inf, flat_d)
            approx_dco = approx_dco + jnp.sum(mine).astype(jnp.int32)

        # -- collective 1 (first half): local stable top-fetch.  (With
        # fused_topk + no streaming merge the stream is already the
        # stable top-fetch; the preselect is then a width-preserving
        # stable sort, harmless and shape-identical.)
        l_d, l_ids = preselect_candidates(flat_d, flat_i, fetch=fetch)
        return (l_d, l_ids,
                jax.lax.psum(approx_dco, axes),
                jax.lax.psum(scan.scanned_blocks, axes),
                jax.lax.psum(plan.dropped, axes))

    def tail_half(vectors, vec_lo, queries, l_d, l_ids):
        # -- collective 1 (second half): all_gather the candidate streams
        g_d = jax.lax.all_gather(l_d, axes, axis=1, tiled=True)
        g_ids = jax.lax.all_gather(l_ids, axes, axis=1, tiled=True)
        # -- shared finalize tail; collective 2: pmin of owner-scored
        # exact distances (vec_lo windows the row shard)
        return finalize_candidates(
            g_d, g_ids, bigk=bigk, k=k, vectors=vectors, queries=queries,
            metric=metric, dedup_results=dedup_results,
            oversample=oversample, vec_lo=vec_lo[0], reduce_axes=axes)

    if stage == "scan":
        return scan_half
    if stage == "tail":
        return tail_half

    def serve(block_codes, block_ids, block_other, owned, owned_other,
              refs, refs_other, misc, centroids, codebooks, vectors,
              vec_lo, block_lo, dev_rank, delta_codes, delta_ids, live,
              queries):
        l_d, l_ids, approx_dco, scanned, dropped = scan_half(
            block_codes, block_ids, block_other, owned, owned_other,
            refs, refs_other, misc, centroids, codebooks, vectors,
            vec_lo, block_lo, dev_rank, delta_codes, delta_ids, live,
            queries)
        out_ids, out_d, refine_dco = tail_half(vectors, vec_lo, queries,
                                               l_d, l_ids)
        return SearchResult(
            ids=out_ids, dists=out_d, approx_dco=approx_dco,
            refine_dco=refine_dco, scanned_blocks=scanned,
            dropped_blocks=dropped)

    return serve


# ---------------------------------------------------------------------------
# compat session wrapper (pre-ShardedIndex entry point)
# ---------------------------------------------------------------------------

def distributed_search(index, mesh, queries, *,
                       params: SearchParams = None,
                       nprobe: int = None, k: int = None,
                       k_factor: int = None, max_scan_local: int = 512,
                       axes=("data",), exec_mode: str = None,
                       query_tile: int = None):
    """Deprecated host-callable wrapper, now a thin shim: shards `index`
    over `mesh` via ``index.shard(...)`` and serves one batch through a
    ``ShardedIndex`` session.  Prefer holding the session::

        sharded  = index.shard(mesh, axes=axes, max_scan_local=...)
        searcher = sharded.searcher(SearchParams(...))
        result   = searcher(queries)

    Query-side knobs come from `params` (individual kwargs override its
    fields); without `params`, `nprobe` and `k` are required.
    ``max_scan_local`` stays separate — it is the per-device plan
    budget, a property of the shard layout rather than of the query.
    Returns the unified ``SearchResult`` (the legacy ``local_dco`` field
    is ``approx_dco``)."""
    import dataclasses as _dc
    if params is None:
        if nprobe is None or k is None:
            raise TypeError(
                "distributed_search requires nprobe= and k= when no "
                "params=SearchParams(...) is given")
        params = SearchParams()
    over = {name: v for name, v in (("nprobe", nprobe), ("k", k),
                                    ("k_factor", k_factor),
                                    ("exec_mode", exec_mode),
                                    ("query_tile", query_tile))
            if v is not None}
    if over:
        params = _dc.replace(params, **over)
    if params.max_scan is not None:
        # the wrapper always pins a per-device budget, which would
        # silently override the per-query field — refuse instead
        raise ValueError(
            "distributed_search does not support SearchParams.max_scan; "
            "use max_scan_local= for the per-device plan budget (or hold "
            "a session: index.shard(mesh, max_scan_local=...)"
            ".searcher(params))")
    sharded = index.shard(mesh, axes=axes, max_scan_local=max_scan_local)
    return sharded.searcher(params)(queries)
