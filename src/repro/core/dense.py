"""Dense (GEMM) scoring path — mathematically identical to the blocked
SEIL scan, ~50x faster on CPU hosts, and the basis of the TPU roofline
serving step.

Key identity (tested in test_pq_kmeans.py::test_pq_adc_identity): with
``by_residual=False``, the ADC estimate ``sum_m LUT[m, code_m]`` equals
the exact squared distance to the PQ-decoded vector.  So scoring every
*stored item* against a query batch is one GEMM against the decoded
item matrix, and SEIL semantics (which blocks are scanned, cell-level
dedup, misc-item dedup, DCO counts) reduce to per-item masks:

  * a shared full block of cell_{i,j} is scanned iff i or j is probed,
    at effective rank min(rank_i, rank_j) — exactly once (Alg. 5);
  * a misc/owned block is scanned iff its home list is probed;
  * a misc item with co-assigned list o is discarded (after counting its
    DCO) iff rank(o) < scan rank of its block.

The blocked path (search.py) remains the deployment layout; equality of
the two paths is asserted in tests/test_dense.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BIG, finalize_candidates
from .engine.select import rank_table as _rank_table
from .kmeans import pairwise_sq_l2
from .pq import PQCodebook, pq_decode
from .search import SearchResult
from .seil import SeilArrays


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DenseAux:
    dec: jnp.ndarray          # (TB*BLK, D) decoded items (0 where invalid)
    dec_norm2: jnp.ndarray    # (TB*BLK,)
    ids: jnp.ndarray          # (TB*BLK,) int32, -1 invalid
    other: jnp.ndarray        # (TB*BLK,) int32 co-assigned list, -1 none
    block_l1: jnp.ndarray     # (TB,) home list, -1 unused block
    block_l2: jnp.ndarray     # (TB,) co-list for shared full blocks, -1 else


def make_dense_aux(arrays: SeilArrays, codebook: PQCodebook) -> DenseAux:
    tb, blk, m = arrays.block_codes.shape
    codes = np.asarray(arrays.block_codes).reshape(tb * blk, m)
    dec = np.array(pq_decode(codebook, jnp.asarray(codes)))
    ids = np.asarray(arrays.block_ids).reshape(-1)
    dec[ids < 0] = 0.0
    other = np.asarray(arrays.block_other).reshape(-1)

    block_l1 = np.full(tb, -1, np.int32)
    block_l2 = np.full(tb, -1, np.int32)
    owned = np.asarray(arrays.owned)
    misc = np.asarray(arrays.misc)
    bo = np.asarray(arrays.block_other)
    for l in range(owned.shape[0]):
        for b in owned[l][owned[l] >= 0]:
            block_l1[b] = l
            oth = bo[b]
            oth = oth[oth >= 0]
            if len(oth):  # shared full block: uniform co-list
                block_l2[b] = oth[0]
        for b in misc[l][misc[l] >= 0]:
            block_l1[b] = l  # misc: item-level others only
    return DenseAux(
        dec=jnp.asarray(dec),
        dec_norm2=jnp.asarray((dec * dec).sum(-1)),
        ids=jnp.asarray(ids),
        other=jnp.asarray(other),
        block_l1=jnp.asarray(block_l1),
        block_l2=jnp.asarray(block_l2),
    )


@functools.partial(jax.jit,
                   static_argnames=("nprobes", "bigk", "k", "metric",
                                    "dedup_results", "blk", "oversample"))
def _dense_chunk(aux: DenseAux, centroids, vectors, queries, *,
                 nprobes: tuple, bigk: int, k: int, metric: str,
                 dedup_results: bool, blk: int, oversample: int = 2):
    bq = queries.shape[0]
    nlist = centroids.shape[0]
    if metric == "l2":
        scores = (queries * queries).sum(-1)[:, None] \
            - 2.0 * (queries @ aux.dec.T) + aux.dec_norm2[None, :]
        cd = pairwise_sq_l2(queries, centroids)
    else:
        scores = -(queries @ aux.dec.T)
        cd = -(queries @ centroids.T)
    pmax = max(nprobes)
    _, sel_full = jax.lax.top_k(-cd, pmax)
    sel_full = sel_full.astype(jnp.int32)
    item_valid = aux.ids >= 0

    outs = []
    for p in nprobes:
        rank_of = _rank_table(sel_full[:, :p], nlist)        # (B, nlist)
        r1 = jnp.where(aux.block_l1 >= 0,
                       rank_of[:, jnp.maximum(aux.block_l1, 0)], BIG)
        r2 = jnp.where(aux.block_l2 >= 0,
                       rank_of[:, jnp.maximum(aux.block_l2, 0)], BIG)
        scan_rank = jnp.minimum(r1, r2)                      # (B, TB)
        scanned = scan_rank < BIG
        scan_rank_i = jnp.repeat(scan_rank, blk, axis=1)     # (B, TB*BLK)
        scanned_i = jnp.repeat(scanned, blk, axis=1)
        computed = scanned_i & item_valid[None, :]
        o_rank = jnp.where(aux.other >= 0,
                           rank_of[:, jnp.maximum(aux.other, 0)], BIG)
        dup = (aux.other >= 0)[None, :] & (o_rank < scan_rank_i)
        keep = computed & ~dup
        approx_dco = computed.sum(1).astype(jnp.int32)
        flat_d = jnp.where(keep, scores, jnp.inf)
        out_ids, out_d, refine_dco = finalize_candidates(
            flat_d, jnp.broadcast_to(aux.ids[None, :], flat_d.shape),
            bigk=bigk, k=k, vectors=vectors, queries=queries, metric=metric,
            dedup_results=dedup_results, oversample=oversample)
        outs.append(SearchResult(
            ids=out_ids, dists=out_d, approx_dco=approx_dco,
            refine_dco=refine_dco,
            scanned_blocks=scanned.sum(1).astype(jnp.int32),
            dropped_blocks=jnp.zeros(bq, jnp.int32)))
    return tuple(outs)


def dense_search_multi(index, queries, *, nprobes: Sequence[int], k: int,
                       k_factor: int = 10, chunk: int = 128
                       ) -> List[SearchResult]:
    """Score once per chunk, slice per-nprobe — shares the GEMM across the
    whole nprobe sweep (used by benchmark curves)."""
    if getattr(index, "_dense_aux", None) is None:
        index._dense_aux = make_dense_aux(index.arrays, index.codebook)
    aux = index._dense_aux
    nprobes = tuple(int(p) for p in nprobes)
    bigk = k * k_factor
    nq = queries.shape[0]
    per_probe = [[] for _ in nprobes]
    for s in range(0, nq, chunk):
        qc = queries[s:s + chunk]
        outs = _dense_chunk(
            aux, index.centroids, index.vectors, qc, nprobes=nprobes,
            bigk=bigk, k=k, metric=index.config.metric,
            dedup_results=index.needs_result_dedup,
            blk=index.arrays.block_size,
            oversample=index.result_oversample)
        for i, r in enumerate(outs):
            per_probe[i].append(jax.tree.map(np.asarray, r))
    return [jax.tree.map(lambda *a: np.concatenate(a, 0), *rs)
            for rs in per_probe]


def dense_search(index, queries, *, nprobe: int, k: int, k_factor: int = 10,
                 chunk: int = 128) -> SearchResult:
    return dense_search_multi(index, queries, nprobes=(nprobe,), k=k,
                              k_factor=k_factor, chunk=chunk)[0]
