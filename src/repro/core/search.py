"""SEIL-optimized ANNS query pipeline (paper Alg. 2 + Alg. 5), static-shape.

Pipeline per query batch:
  1. score list centroids, take top-nprobe (ranked) lists;
  2. gather each selected list's owned / referenced / misc block tables;
     apply cell-level deduplication to reference entries: the entry of
     the list at probe-rank t pointing to physical home `o` is skipped
     iff rank(o) < t (the vectorized ``listVisited`` probe);
  3. compact candidate blocks to a static scan budget;
  4. ADC distances for every surviving block (Pallas kernel on TPU,
     jnp oracle elsewhere); item-level masks: invalid ids, misc items
     whose co-assigned list was scanned earlier;
  5. top-bigK candidates (+ id-dedup for layouts without SEIL);
  6. refine with exact distances over the original vectors, top-K.

DCO accounting is paper-faithful: every *valid* item in a scanned block
counts one distance computation (misc duplicates included — SEIL cannot
avoid them, Alg. 5 L15), skipped reference blocks count zero, refine
adds one exact DCO per unique candidate.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .kmeans import pairwise_sq_l2
from .pq import PQCodebook, pq_lut, pq_lut_ip
from .seil import SeilArrays

BIG = jnp.int32(2 ** 30)


class SearchResult(NamedTuple):
    ids: jnp.ndarray          # (B, K) int32 final ids (-1 pad)
    dists: jnp.ndarray        # (B, K) f32 exact distances
    approx_dco: jnp.ndarray   # (B,) int32 ADC distance computations
    refine_dco: jnp.ndarray   # (B,) int32 exact distance computations
    scanned_blocks: jnp.ndarray  # (B,) int32
    dropped_blocks: jnp.ndarray  # (B,) int32 budget overflow (should be 0)


def _rank_table(sel: jnp.ndarray, nlist: int) -> jnp.ndarray:
    """(B, P) ranked selected lists -> (B, nlist) rank (BIG if unselected)."""
    b, p = sel.shape
    ranks = jnp.broadcast_to(jnp.arange(p, dtype=jnp.int32), (b, p))
    table = jnp.full((b, nlist), BIG, jnp.int32)
    return table.at[jnp.arange(b)[:, None], sel].min(ranks)


def finalize_candidates(flat_d, flat_i, *, bigk, k, vectors, queries,
                        metric, dedup_results, oversample: int = 2):
    """Shared tail of both search paths: top-bigK (+ optional id-dedup for
    duplicated layouts), exact-distance refinement, top-K packing.

    Duplicated layouts (no SEIL / m-assignment) retrieve `oversample*bigK`
    candidates before id-dedup so duplicate copies cannot displace unique
    candidates (a dedup-on-insert result queue), then truncate to bigK."""
    bq = flat_d.shape[0]
    fetch = bigk * (oversample if dedup_results else 1)
    fetch = min(fetch, flat_d.shape[1])
    neg, pos = jax.lax.top_k(-flat_d, fetch)
    cand_ids = jnp.take_along_axis(flat_i, pos, axis=1)      # (B, fetch)
    cand_d = -neg                                            # ascending
    cand_ok = jnp.isfinite(cand_d)
    if dedup_results:  # needed for layouts without SEIL (duplicated storage)
        order = jnp.argsort(jnp.where(cand_ok, cand_ids, BIG), axis=1)
        sid = jnp.take_along_axis(cand_ids, order, axis=1)
        rep = jnp.concatenate(
            [jnp.zeros((bq, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1)
        inv = jnp.argsort(order, axis=1)
        cand_ok &= ~jnp.take_along_axis(rep, inv, axis=1)
        cand_ok &= jnp.cumsum(cand_ok, axis=1) <= bigk       # truncate
    cand_ids = jnp.where(cand_ok, cand_ids, -1)

    cv = vectors[jnp.maximum(cand_ids, 0)]                   # (B, bigK, D)
    if metric == "l2":
        diff = cv - queries[:, None, :]
        exact = jnp.sum(diff * diff, axis=-1)
    else:
        exact = -jnp.einsum("bkd,bd->bk", cv, queries)
    exact = jnp.where(cand_ok, exact, jnp.inf)
    refine_dco = jnp.sum(cand_ok, axis=1).astype(jnp.int32)
    negk, posk = jax.lax.top_k(-exact, k)
    out_ids = jnp.take_along_axis(cand_ids, posk, axis=1)
    out_d = -negk
    out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
    return out_ids, out_d, refine_dco


@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "bigk", "k", "max_scan", "metric",
                     "dedup_results", "use_kernel", "oversample"))
def seil_search(
    arrays: SeilArrays,
    centroids: jnp.ndarray,       # (nlist, D)
    codebook: PQCodebook,
    vectors: jnp.ndarray,         # (n, D) refine store
    queries: jnp.ndarray,         # (B, D)
    *,
    nprobe: int,
    bigk: int,
    k: int,
    max_scan: int,                # static per-query block budget
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
) -> SearchResult:
    bq, d = queries.shape
    nlist = centroids.shape[0]
    blk = arrays.block_size

    # -- 1. select lists ----------------------------------------------------
    cd = (pairwise_sq_l2(queries, centroids) if metric == "l2"
          else -(queries @ centroids.T))
    _, sel = jax.lax.top_k(-cd, nprobe)            # (B, P) ascending distance
    sel = sel.astype(jnp.int32)
    rank_of = _rank_table(sel, nlist)              # (B, nlist)

    # -- 2. gather block tables + cell-level dedup ---------------------------
    owned = arrays.owned[sel]                      # (B, P, MO)
    refs = arrays.refs[sel]                        # (B, P, MR)
    refs_other = arrays.refs_other[sel]            # (B, P, MR)
    misc = arrays.misc[sel]                        # (B, P, MM)
    t = jnp.arange(nprobe, dtype=jnp.int32)[None, :, None]

    def visited_earlier(other_list):
        r = jnp.take_along_axis(
            rank_of, jnp.maximum(other_list, 0).reshape(bq, -1), axis=1
        ).reshape(other_list.shape)
        return (other_list >= 0) & (r < t)

    # reference entries: skip if the home list was scanned earlier (Alg. 5 L7)
    refs = jnp.where(visited_earlier(refs_other), -1, refs)
    # home shared blocks: skip if the co-assigned list was scanned earlier —
    # its reference entry already computed this cell.  (Alg. 5's pseudocode
    # only checks the ref->home direction and would re-compute the cell when
    # the referencing list is probed first; we implement the stated
    # cell-level compute-once semantics in both directions. See DESIGN.md.)
    owned_other = arrays.block_other[jnp.maximum(owned, 0), 0]
    owned_other = jnp.where(owned >= 0, owned_other, -1)
    owned = jnp.where(visited_earlier(owned_other), -1, owned)

    def flat(tbl):
        return tbl.reshape(bq, -1)
    cand = jnp.concatenate([flat(owned), flat(refs), flat(misc)], axis=1)
    cand_rank = jnp.concatenate([
        flat(jnp.broadcast_to(t, owned.shape)),
        flat(jnp.broadcast_to(t, refs.shape)),
        flat(jnp.broadcast_to(t, misc.shape))], axis=1)

    # -- 3. compact to the static scan budget --------------------------------
    max_scan = min(max_scan, cand.shape[1])    # static shapes; safe under jit
    valid = cand >= 0
    n_valid = jnp.sum(valid, axis=1).astype(jnp.int32)
    dropped = jnp.maximum(n_valid - max_scan, 0)
    # stable compaction: valid blocks first, preserving position order
    # (positions already run owned->refs->misc, each rank-ascending)
    pos = jnp.arange(cand.shape[1], dtype=jnp.int32)
    key = jnp.where(valid, BIG - pos, -1 - pos)
    _, take = jax.lax.top_k(key, max_scan)
    blocks = jnp.take_along_axis(cand, take, axis=1)        # (B, S)
    branks = jnp.take_along_axis(cand_rank, take, axis=1)   # (B, S)
    bvalid = jnp.take_along_axis(valid, take, axis=1)

    safe_blocks = jnp.maximum(blocks, 0)

    # -- 4. ADC distances -----------------------------------------------------
    lut = (pq_lut(codebook, queries) if metric == "l2"
           else pq_lut_ip(codebook, queries))                # (B, M, 16)
    if use_kernel:
        from ..kernels.ops import pq_scan_paged
        dists = pq_scan_paged(lut, arrays.block_codes, safe_blocks)
    else:
        codes = arrays.block_codes[safe_blocks]              # (B, S, BLK, M)
        g = jnp.take_along_axis(
            lut[:, None, None, :, :], codes.astype(jnp.int32)[..., None],
            axis=-1)
        dists = jnp.sum(g[..., 0], axis=-1)                  # (B, S, BLK)

    ids = arrays.block_ids[safe_blocks]                      # (B, S, BLK)
    other = arrays.block_other[safe_blocks]
    o_rank = jnp.take_along_axis(
        rank_of, jnp.maximum(other, 0).reshape(bq, -1), axis=1
    ).reshape(other.shape)
    dup_item = (other >= 0) & (o_rank < branks[:, :, None])
    item_ok = (ids >= 0) & bvalid[:, :, None]
    keep = item_ok & ~dup_item
    # DCO: SEIL computes misc duplicates then discards them (Alg.5 L15-16)
    approx_dco = jnp.sum(item_ok, axis=(1, 2)).astype(jnp.int32)

    # -- 5/6. top-bigK candidates + refine ------------------------------------
    flat_d = jnp.where(keep, dists, jnp.inf).reshape(bq, -1)
    flat_i = ids.reshape(bq, -1)
    out_ids, out_d, refine_dco = finalize_candidates(
        flat_d, flat_i, bigk=bigk, k=k, vectors=vectors, queries=queries,
        metric=metric, dedup_results=dedup_results, oversample=oversample)

    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=approx_dco,
        refine_dco=refine_dco,
        scanned_blocks=jnp.sum(bvalid, axis=1).astype(jnp.int32),
        dropped_blocks=dropped.astype(jnp.int32))
