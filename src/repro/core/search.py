"""SEIL-optimized ANNS query pipeline (paper Alg. 2 + Alg. 5), static-shape.

``seil_search`` is a thin composition of the staged query engine
(core/engine/, DESIGN.md §5):

  1. ``select_lists``  — score list centroids, take top-nprobe (ranked);
  2. ``plan_blocks``   — gather owned / referenced / misc block tables,
     apply cell-level dedup (the vectorized ``listVisited`` probe) and
     compact candidates to a static scan budget;
  3. ``scan_blocks``   — ADC distances for every surviving block (Pallas
     kernel on TPU, jnp oracle elsewhere) + item-level masks, in either
     ``exec_mode="paged"`` (per-query paging) or ``"grouped"`` (the
     paper's §5.3 list-major batch mode: one batch-union block plan,
     each block fetched once per query tile);
  4. ``finalize_candidates`` — top-bigK (+ id-dedup for layouts without
     SEIL), exact refinement over the original vectors, top-K.

DCO accounting is paper-faithful: every *valid* item in a scanned block
counts one distance computation (misc duplicates included — SEIL cannot
avoid them, Alg. 5 L15), skipped reference blocks count zero, refine
adds one exact DCO per unique candidate.  Both exec modes produce
bitwise-identical results and counters (tests/test_engine.py).

The distributed serving step (core/distributed.py) composes the same
stages over a sharded ``BlockStore`` — improvements to any stage apply
to both paths.

``seil_search`` is the unit Searcher sessions compile: a session AOT-
lowers this exact jitted function per batch-size bucket
(``seil_search.lower(...).compile()``, core/searcher.py), which is why
session results are bitwise identical to direct calls.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .engine import (finalize_candidates, plan_blocks, scan_blocks,
                     select_lists, store_from_arrays, tables_from_arrays)
from .pq import PQCodebook, pq_lut, pq_lut_ip
from .seil import SeilArrays


class SearchResult(NamedTuple):
    ids: jnp.ndarray          # (B, K) int32 final ids (-1 pad)
    dists: jnp.ndarray        # (B, K) f32 exact distances
    approx_dco: jnp.ndarray   # (B,) int32 ADC distance computations
    refine_dco: jnp.ndarray   # (B,) int32 exact distance computations
    scanned_blocks: jnp.ndarray  # (B,) int32
    dropped_blocks: jnp.ndarray  # (B,) int32 budget overflow (should be 0)


@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "bigk", "k", "max_scan", "metric",
                     "dedup_results", "use_kernel", "oversample",
                     "exec_mode", "query_tile"))
def seil_search(
    arrays: SeilArrays,
    centroids: jnp.ndarray,       # (nlist, D)
    codebook: PQCodebook,
    vectors: jnp.ndarray,         # (n, D) refine store
    queries: jnp.ndarray,         # (B, D)
    *,
    nprobe: int,
    bigk: int,
    k: int,
    max_scan: int,                # static per-query block budget
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
    exec_mode: str = "paged",
    query_tile: int = 8,
) -> SearchResult:
    selection = select_lists(queries, centroids, nprobe=nprobe, metric=metric)
    plan = plan_blocks(tables_from_arrays(arrays), selection,
                       max_scan=max_scan)
    lut = (pq_lut(codebook, queries) if metric == "l2"
           else pq_lut_ip(codebook, queries))                # (B, M, 16)
    scan = scan_blocks(store_from_arrays(arrays), plan, lut,
                       selection.rank_of, exec_mode=exec_mode,
                       use_kernel=use_kernel, query_tile=query_tile)
    out_ids, out_d, refine_dco = finalize_candidates(
        scan.flat_d, scan.flat_i, bigk=bigk, k=k, vectors=vectors,
        queries=queries, metric=metric, dedup_results=dedup_results,
        oversample=oversample)
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=scan.approx_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=plan.dropped)
