"""SEIL-optimized ANNS query pipeline (paper Alg. 2 + Alg. 5), static-shape.

``seil_search`` is a thin composition of the staged query engine
(core/engine/, DESIGN.md §5):

  1. ``select_lists``  — score list centroids, take top-nprobe (ranked);
  2. ``plan_blocks``   — gather owned / referenced / misc block tables,
     apply cell-level dedup (the vectorized ``listVisited`` probe) and
     compact candidates to a static scan budget;
  3. ``scan_blocks``   — ADC distances for every surviving block (Pallas
     kernel on TPU, jnp oracle elsewhere) + item-level masks, in either
     ``exec_mode="paged"`` (per-query paging) or ``"grouped"`` (the
     paper's §5.3 list-major batch mode: one batch-union block plan,
     each block fetched once per query tile);
  4. ``finalize_candidates`` — top-bigK (+ id-dedup for layouts without
     SEIL), exact refinement over the original vectors, top-K.

DCO accounting is paper-faithful: every *valid* item in a scanned block
counts one distance computation (misc duplicates included — SEIL cannot
avoid them, Alg. 5 L15), skipped reference blocks count zero, refine
adds one exact DCO per unique candidate.  Both exec modes produce
bitwise-identical results and counters (tests/test_engine.py).

The distributed serving step (core/distributed.py) composes the same
stages over a sharded ``BlockStore`` — improvements to any stage apply
to both paths.

``seil_search`` is the unit Searcher sessions compile: a session AOT-
lowers this exact jitted function per batch-size bucket
(``seil_search.lower(...).compile()``, core/searcher.py), which is why
session results are bitwise identical to direct calls.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from .engine import (PlanProbe, cluster_order, finalize_candidates,
                     plan_blocks, scan_blocks, scan_blocks_topk,
                     select_lists, store_from_arrays, tables_from_arrays,
                     tile_unions, union_dims)
from .pq import PQCodebook, pq_lut, pq_lut_ip
from .seil import SeilArrays


def finalize_fetch(bigk: int, oversample: int, dedup_results: bool) -> int:
    """The candidate width ``finalize_candidates`` selects before exact
    refinement — the budget a fused scan must deliver for bitwise parity
    (``preselect_candidates``' covering-width invariant)."""
    return bigk * (oversample if dedup_results else 1)


class SearchResult(NamedTuple):
    ids: jnp.ndarray          # (B, K) int32 final ids (-1 pad)
    dists: jnp.ndarray        # (B, K) f32 exact distances
    approx_dco: jnp.ndarray   # (B,) int32 ADC distance computations
    refine_dco: jnp.ndarray   # (B,) int32 exact distance computations
    scanned_blocks: jnp.ndarray  # (B,) int32
    dropped_blocks: jnp.ndarray  # (B,) int32 budget overflow (should be 0)


@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "bigk", "k", "max_scan", "metric",
                     "dedup_results", "use_kernel", "oversample",
                     "exec_mode", "query_tile", "fused_topk",
                     "packed_codes"))
def seil_search(
    arrays: SeilArrays,
    centroids: jnp.ndarray,       # (nlist, D)
    codebook: PQCodebook,
    vectors: jnp.ndarray,         # (n, D) refine store
    queries: jnp.ndarray,         # (B, D)
    *,
    nprobe: int,
    bigk: int,
    k: int,
    max_scan: int,                # static per-query block budget
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
    exec_mode: str = "paged",
    query_tile: int = 8,
    fused_topk: bool = False,
    packed_codes: bool = False,   # arrays carry a nibble-packed quant plane
) -> SearchResult:
    selection = select_lists(queries, centroids, nprobe=nprobe, metric=metric)
    plan = plan_blocks(tables_from_arrays(arrays), selection,
                       max_scan=max_scan)
    lut = (pq_lut(codebook, queries) if metric == "l2"
           else pq_lut_ip(codebook, queries))                # (B, M, 16)
    if fused_topk:
        scan = scan_blocks_topk(
            store_from_arrays(arrays), plan, lut, selection.rank_of,
            fetch=finalize_fetch(bigk, oversample, dedup_results),
            exec_mode=exec_mode, use_kernel=use_kernel,
            query_tile=query_tile, sel=selection.sel, packed=packed_codes)
    else:
        scan = scan_blocks(store_from_arrays(arrays), plan, lut,
                           selection.rank_of, exec_mode=exec_mode,
                           use_kernel=use_kernel, query_tile=query_tile,
                           sel=selection.sel, packed=packed_codes)
    out_ids, out_d, refine_dco = finalize_candidates(
        scan.flat_d, scan.flat_i, bigk=bigk, k=k, vectors=vectors,
        queries=queries, metric=metric, dedup_results=dedup_results,
        oversample=oversample)
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=scan.approx_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=plan.dropped)


# ---------------------------------------------------------------------------
# traced pipeline — seil_search cut at its four stage boundaries
# (DESIGN.md §11).
#
# With a tracer active (repro/obs/) sessions dispatch through
# ``seil_search_traced`` instead of the monolithic executable: the same
# four engine stages, one jitted program each, with an obs span + device
# fence at every boundary so each span's duration covers that stage's
# device time.  Splitting at jit boundaries preserves bitwise results —
# the same invariant the plan_reuse split (probe_plan + scan_finalize)
# already relies on — asserted against seil_search in tests/test_obs.py.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("nprobe", "metric"))
def _stage_select(centroids, queries, *, nprobe, metric):
    return select_lists(queries, centroids, nprobe=nprobe, metric=metric)


@functools.partial(jax.jit, static_argnames=("max_scan", "metric"))
def _stage_plan(arrays, codebook, selection, queries, *, max_scan, metric):
    plan = plan_blocks(tables_from_arrays(arrays), selection,
                       max_scan=max_scan)
    lut = (pq_lut(codebook, queries) if metric == "l2"
           else pq_lut_ip(codebook, queries))
    return plan, lut


@functools.partial(
    jax.jit,
    static_argnames=("fetch", "exec_mode", "use_kernel", "query_tile",
                     "fused_topk", "has_live", "packed_codes"))
def _stage_scan(arrays, plan, lut, selection, live, *, fetch, exec_mode,
                use_kernel, query_tile, fused_topk, has_live,
                packed_codes=False):
    if fused_topk:
        return scan_blocks_topk(
            store_from_arrays(arrays), plan, lut, selection.rank_of,
            fetch=fetch, exec_mode=exec_mode, use_kernel=use_kernel,
            query_tile=query_tile, sel=selection.sel,
            live=live if has_live else None, packed=packed_codes)
    return scan_blocks(store_from_arrays(arrays), plan, lut,
                       selection.rank_of, exec_mode=exec_mode,
                       use_kernel=use_kernel, query_tile=query_tile,
                       sel=selection.sel, packed=packed_codes)


@functools.partial(
    jax.jit,
    static_argnames=("bigk", "k", "metric", "dedup_results", "oversample"))
def _stage_finalize(vectors, queries, flat_d, flat_i, *, bigk, k, metric,
                    dedup_results, oversample):
    return finalize_candidates(
        flat_d, flat_i, bigk=bigk, k=k, vectors=vectors, queries=queries,
        metric=metric, dedup_results=dedup_results, oversample=oversample)


def seil_search_traced(
    arrays: SeilArrays,
    centroids: jnp.ndarray,
    codebook: PQCodebook,
    vectors: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    nprobe: int,
    bigk: int,
    k: int,
    max_scan: int,
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
    exec_mode: str = "paged",
    query_tile: int = 8,
    fused_topk: bool = False,
    packed_codes: bool = False,
) -> SearchResult:
    """Stage-fenced ``seil_search`` for tracing: identical composition,
    one program per stage, span + fence at each boundary."""
    with obs.span("stage.select_lists", cat="device", nprobe=nprobe):
        selection = obs.fence(_stage_select(centroids, queries,
                                            nprobe=nprobe, metric=metric))
    with obs.span("stage.plan_blocks", cat="device", max_scan=max_scan):
        plan, lut = obs.fence(_stage_plan(arrays, codebook, selection,
                                          queries, max_scan=max_scan,
                                          metric=metric))
    name = "stage.scan_blocks_topk" if fused_topk else "stage.scan_blocks"
    with obs.span(name, cat="device", exec_mode=exec_mode) as sp:
        scan = obs.fence(_stage_scan(
            arrays, plan, lut, selection, lut,   # live unused (has_live=F)
            fetch=finalize_fetch(bigk, oversample, dedup_results),
            exec_mode=exec_mode, use_kernel=use_kernel,
            query_tile=query_tile, fused_topk=fused_topk, has_live=False,
            packed_codes=packed_codes))
        sp.add(approx_dco=int(np.sum(np.asarray(scan.approx_dco))),
               scanned_blocks=int(np.sum(np.asarray(scan.scanned_blocks))))
    with obs.span("stage.finalize", cat="device") as sp:
        out_ids, out_d, refine_dco = obs.fence(_stage_finalize(
            vectors, queries, scan.flat_d, scan.flat_i, bigk=bigk, k=k,
            metric=metric, dedup_results=dedup_results,
            oversample=oversample))
        sp.add(refine_dco=int(np.sum(np.asarray(refine_dco))))
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=scan.approx_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=plan.dropped)


# ---------------------------------------------------------------------------
# split pipeline — the incremental planner's two halves (DESIGN.md §5).
#
# With ``SearchParams(plan_reuse=True)`` a Searcher session dispatches
# each batch as probe -> host plan-cache merge -> scan: ``probe_plan``
# runs stages 1-2 plus union construction, the session merges this
# batch's tile unions with its cached ones (engine/cluster.py) and picks
# the smallest geometric width bucket covering the live entries, and
# ``scan_finalize`` runs stages 3-4 against the provided unions.  Both
# halves together perform exactly the stages of ``seil_search`` once, so
# results stay bitwise identical (tests/test_plan.py).
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "max_scan", "metric", "exec_mode",
                     "query_tile"))
def probe_plan(
    arrays: SeilArrays,
    centroids: jnp.ndarray,
    codebook: PQCodebook,
    queries: jnp.ndarray,
    *,
    nprobe: int,
    max_scan: int,
    metric: str = "l2",
    exec_mode: str = "grouped",
    query_tile: int = 8,
) -> PlanProbe:
    """Stages 1-2 + cluster order + this batch's own tile unions."""
    b = queries.shape[0]
    selection = select_lists(queries, centroids, nprobe=nprobe, metric=metric)
    plan = plan_blocks(tables_from_arrays(arrays), selection,
                       max_scan=max_scan)
    lut = (pq_lut(codebook, queries) if metric == "l2"
           else pq_lut_ip(codebook, queries))
    if exec_mode == "clustered":
        perm = cluster_order(selection.sel)
    else:
        perm = jnp.arange(b, dtype=jnp.int32)
    t, w = union_dims(b, plan.blocks.shape[1],
                      arrays.block_codes.shape[0], exec_mode, query_tile)
    unions = tile_unions(plan.blocks[perm], plan.valid[perm], t, w)
    return PlanProbe(sel=selection.sel, rank_of=selection.rank_of, lut=lut,
                     plan=plan, perm=perm, unions=unions)


@functools.partial(
    jax.jit,
    static_argnames=("bigk", "k", "metric", "dedup_results", "use_kernel",
                     "oversample", "exec_mode", "query_tile", "fused_topk",
                     "packed_codes"))
def scan_finalize(
    arrays: SeilArrays,
    vectors: jnp.ndarray,
    queries: jnp.ndarray,
    probe: PlanProbe,
    unions: jnp.ndarray,          # (T, W') width-bucketed unions to scan
    *,
    bigk: int,
    k: int,
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
    exec_mode: str = "grouped",
    query_tile: int = 8,
    fused_topk: bool = False,
    packed_codes: bool = False,
) -> SearchResult:
    """Stages 3-4 against caller-provided (possibly reused) unions."""
    if fused_topk:
        scan = scan_blocks_topk(
            store_from_arrays(arrays), probe.plan, probe.lut, probe.rank_of,
            fetch=finalize_fetch(bigk, oversample, dedup_results),
            exec_mode=exec_mode, use_kernel=use_kernel,
            query_tile=query_tile, perm=probe.perm, unions=unions,
            packed=packed_codes)
    else:
        scan = scan_blocks(store_from_arrays(arrays), probe.plan, probe.lut,
                           probe.rank_of, exec_mode=exec_mode,
                           use_kernel=use_kernel, query_tile=query_tile,
                           perm=probe.perm, unions=unions,
                           packed=packed_codes)
    out_ids, out_d, refine_dco = finalize_candidates(
        scan.flat_d, scan.flat_i, bigk=bigk, k=k, vectors=vectors,
        queries=queries, metric=metric, dedup_results=dedup_results,
        oversample=oversample)
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=scan.approx_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=probe.plan.dropped)
