"""SearchParams — the frozen, validated query-side parameter object.

All knobs of the four-stage query pipeline live here (DESIGN.md §7).
A ``SearchParams`` is hashable, so it keys compiled-searcher caches:
``RairsIndex.searcher(params)`` returns a long-lived session that
AOT-compiles the pipeline once per batch-size bucket and is reused for
every identical params object.

``max_scan=None`` means "derive the per-query block budget from the
index" (``RairsIndex.default_max_scan``); ``resolve`` pins it so a
session never re-derives per call.

Mutable indexes key on params too: ``StreamingIndex.searcher(params)``
(core/stream/, DESIGN.md §8) caches sessions per params object and
shares compiled streaming executables keyed by ``(params, delta
capacity)``, so the same hashability contract lets churn-driven session
turnover reuse executables instead of recompiling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .engine import EXEC_MODES

# default pad-and-dispatch buckets: powers of two up to this cap; larger
# batches are chunked so the executable set stays small and bounded.
MAX_AUTO_BUCKET = 1024

# compact-plane backends the quant subsystem implements, plus "full":
# scan the full-width codes but keep the widened survivor set — the
# pure-widening ablation (monotone-recall baseline, tests/test_refine.py)
REFINE_PLANES = ("pq4", "binary", "full")


@dataclasses.dataclass(frozen=True)
class RefineParams:
    """Two-tier scan knobs (quantization ladder, DESIGN.md §12).

    plane          tier-1 code plane: "pq4" (coarse 4-bit PQ, packed two
                   codes per byte), "binary" (RaBitQ-style sign codes
                   behind the same interface), or "full" (no compact
                   plane — widen the survivor set over the full-width
                   codes; the recall-monotone ablation)
    refine_factor  survivor widening: tier-1 keeps ``bigk * refine_factor``
                   candidates for tier-2's exact re-rank.  A factor of 1
                   leaves no margin for a coarser tier, so the ladder
                   degenerates to the exact single-tier program —
                   bitwise-identical to ``refine=None`` (asserted in
                   tests/test_refine.py).
    """
    plane: str = "pq4"
    refine_factor: int = 4

    def __post_init__(self):
        if self.plane not in REFINE_PLANES:
            raise ValueError(
                f"plane must be one of {REFINE_PLANES}, got {self.plane!r}")
        if self.refine_factor < 1:
            raise ValueError(
                f"refine_factor must be >= 1, got {self.refine_factor}")


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Validated query parameters (paper Alg. 2 knobs + engine controls).

    k            final neighbours per query
    nprobe       probed lists (Alg. 2 L1)
    k_factor     refinement oversampling: bigK = k * k_factor
    max_scan     static per-query block budget (None -> index default)
    exec_mode    "paged" (per-query) | "grouped" (§5.3 list-major batch)
                 | "clustered" (grouped with query-tile clustering:
                 per-tile block unions in probe-overlap order)
    use_kernel   route the ADC scan through the Pallas kernel
    fused_topk   fuse the scan with the stable top-fetch selection: the
                 scan stage emits only ``bigk * oversample`` candidates
                 per query instead of the full (S, BLK) score tensor.
                 With use_kernel=True the selection runs inside the
                 Pallas kernel (a VMEM-resident bitonic top-k
                 accumulator — candidates never round-trip HBM); with
                 use_kernel=False it is a stage-level jnp fusion.
                 Results are bitwise identical either way (the fused
                 selection reproduces ``preselect_candidates``' stable
                 tie order; tests/test_fused.py).
    query_tile   grouped/clustered query tile (VMEM residency per fetch;
                 the clustered union granularity)
    plan_reuse   incremental plans (grouped/clustered only): the session
                 splits each dispatch into probe -> plan-cache merge ->
                 scan, reusing/extending the previous batch's block
                 unions when adjacent batches probe overlapping lists,
                 and scanning at the smallest geometric width bucket
                 covering the live entries.  Results stay bitwise
                 identical; ``compile_stats()['plan']`` exposes
                 hit/extend/miss counters and union sizes.
    batch_buckets  optional ascending pad-and-dispatch bucket sizes;
                 None -> powers of two up to MAX_AUTO_BUCKET
    refine       two-tier scan (``RefineParams``): tier-1 scans the
                 compact code plane keeping ``bigk * refine_factor``
                 survivors, tier-2 exactly re-ranks them in finalize.
                 None (default) is the single-tier exact path.
    """
    k: int = 10
    nprobe: int = 16
    k_factor: int = 10
    max_scan: Optional[int] = None
    exec_mode: str = "paged"
    use_kernel: bool = False
    fused_topk: bool = False
    query_tile: int = 8
    plan_reuse: bool = False
    batch_buckets: Optional[Tuple[int, ...]] = None
    refine: Optional[RefineParams] = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.nprobe < 1:
            raise ValueError(f"nprobe must be >= 1, got {self.nprobe}")
        if self.k_factor < 1:
            raise ValueError(f"k_factor must be >= 1, got {self.k_factor}")
        if self.max_scan is not None and self.max_scan < 1:
            raise ValueError(f"max_scan must be >= 1 or None, got {self.max_scan}")
        if self.exec_mode not in EXEC_MODES:
            raise ValueError(
                f"exec_mode must be one of {EXEC_MODES}, got {self.exec_mode!r}")
        if self.query_tile < 1:
            raise ValueError(f"query_tile must be >= 1, got {self.query_tile}")
        if self.plan_reuse and self.exec_mode == "paged":
            raise ValueError(
                "plan_reuse needs a union-based exec_mode ('grouped' or "
                "'clustered'); paged scans have no batch union to reuse")
        if self.batch_buckets is not None:
            bb = tuple(int(b) for b in self.batch_buckets)
            if not bb or any(b < 1 for b in bb) or list(bb) != sorted(set(bb)):
                raise ValueError(
                    "batch_buckets must be a non-empty ascending tuple of "
                    f"positive sizes, got {self.batch_buckets!r}")
            object.__setattr__(self, "batch_buckets", bb)
        if self.refine is not None and not isinstance(self.refine,
                                                      RefineParams):
            raise ValueError(
                f"refine must be a RefineParams or None, got "
                f"{self.refine!r}")

    @property
    def bigk(self) -> int:
        return self.k * self.k_factor

    @property
    def bigk_eff(self) -> int:
        """Tier-1 survivor budget: bigK widened by the refine factor."""
        if self.refine is None:
            return self.bigk
        return self.bigk * self.refine.refine_factor

    @property
    def active_plane(self) -> Optional[str]:
        """The compact-plane backend the scan substitutes, or None when
        the program is the plain single-tier one (no refine, the "full"
        widening ablation, or refine_factor=1 — which degenerates to the
        exact path bitwise)."""
        r = self.refine
        if r is None or r.plane == "full" or r.refine_factor == 1:
            return None
        return r.plane

    def resolve(self, index) -> "SearchParams":
        """Pin index-dependent defaults and cross-check against the index."""
        nlist = index.config.nlist
        if self.nprobe > nlist:
            raise ValueError(
                f"nprobe={self.nprobe} exceeds the index's nlist={nlist}")
        if self.max_scan is not None:
            return self
        return dataclasses.replace(
            self, max_scan=index.default_max_scan(self.nprobe))

    def bucket_for(self, batch: int) -> int:
        """Smallest dispatch bucket that fits `batch` (after chunking)."""
        if self.batch_buckets is not None:
            for b in self.batch_buckets:
                if b >= batch:
                    return b
            return self.batch_buckets[-1]
        if batch >= MAX_AUTO_BUCKET:
            return MAX_AUTO_BUCKET
        b = 1
        while b < batch:
            b *= 2
        return b

    @property
    def max_chunk(self) -> int:
        """Largest batch a single executable handles; bigger batches chunk."""
        if self.batch_buckets is not None:
            return self.batch_buckets[-1]
        return MAX_AUTO_BUCKET
