"""Streaming mutable index subsystem (DESIGN.md §8).

``StreamingIndex`` wraps an immutable ``RairsIndex`` base epoch with an
append-only delta segment, a tombstone bitmap, threshold/explicit
compaction, and (epoch, version)-pinned searcher sessions.
"""
from .delta import DeltaSegment  # noqa: F401
from .search import delta_adc, streaming_search  # noqa: F401
from .streaming import (PendingCompaction, StaleSessionError,  # noqa: F401
                        StreamConfig, StreamingIndex, StreamingSearcher,
                        StreamStats)
