"""Delta segment — the append-only mutable tail of a ``StreamingIndex``.

Holds everything a post-epoch insert needs to be searchable and later
foldable into a fresh SEIL base: raw vectors (exact refinement), PQ
codes (ADC scan), and strategy-registry assignments (compaction input).
The buffers are host-side numpy; ``StreamingIndex`` owns the device
mirrors.

Capacity grows in fixed geometric buckets (``pad * 2**j``), so the
padded device views keep a small bounded set of shapes and the compiled
streaming executables never retrace on steady-state appends.  Slots are
never reused: a deleted delta item keeps its slot with ``live=False``
until the next compaction discards the whole segment — ids therefore
stay append-ordered and dense in ``[0, count)``.
"""
from __future__ import annotations

import numpy as np


class DeltaSegment:
    """Padded append-only buffers for one epoch's inserts."""

    def __init__(self, dim: int, m_pq: int, m_assign: int, pad: int = 256):
        if pad < 1:
            raise ValueError(f"pad must be >= 1, got {pad}")
        self.dim = int(dim)
        self.m_pq = int(m_pq)
        self.m_assign = int(m_assign)
        self.pad = int(pad)
        self.count = 0         # slots ever used (monotonic)
        self.capacity = 0      # allocated slots (bucketed)
        self.vectors = np.zeros((0, self.dim), np.float32)
        self.codes = np.zeros((0, self.m_pq), np.uint8)
        self.assigns = np.zeros((0, self.m_assign), np.int32)
        self.live = np.zeros((0,), bool)

    def _cap_for(self, n: int) -> int:
        if n <= 0:
            return 0
        cap = self.pad
        while cap < n:
            cap *= 2
        return cap

    @property
    def n_live(self) -> int:
        return int(self.live[:self.count].sum())

    @property
    def n_dead(self) -> int:
        return self.count - self.n_live

    def append(self, vectors: np.ndarray, codes: np.ndarray,
               assigns: np.ndarray):
        """Append a batch; returns ``(slots, grew)`` where `slots` are the
        newly used slot indices and `grew` flags a capacity-bucket jump
        (device mirrors must be rebuilt rather than patched)."""
        b = vectors.shape[0]
        s0 = self.count
        need = s0 + b
        grew = need > self.capacity
        if grew:
            cap = self._cap_for(need)

            def regrow(old, shape, dtype):
                out = np.zeros(shape, dtype)
                out[:s0] = old[:s0]
                return out

            self.vectors = regrow(self.vectors, (cap, self.dim), np.float32)
            self.codes = regrow(self.codes, (cap, self.m_pq), np.uint8)
            self.assigns = regrow(self.assigns, (cap, self.m_assign), np.int32)
            self.live = regrow(self.live, (cap,), bool)
            self.capacity = cap
        self.vectors[s0:need] = vectors
        self.codes[s0:need] = codes
        self.assigns[s0:need] = assigns
        self.live[s0:need] = True
        self.count = need
        return np.arange(s0, need, dtype=np.int64), grew

    def mark_dead(self, slots: np.ndarray) -> int:
        """Tombstone `slots`; returns how many were live until now."""
        slots = np.asarray(slots, np.int64).ravel()
        if slots.size == 0:
            return 0
        if (slots < 0).any() or (slots >= self.count).any():
            raise ValueError(
                f"delta slots out of range [0, {self.count}): {slots}")
        newly = int(self.live[slots].sum())
        self.live[slots] = False
        return newly
