"""Delta segment — the append-only mutable tail of a ``StreamingIndex``.

Holds everything a post-epoch insert needs to be searchable and later
foldable into a fresh SEIL base: raw vectors (exact refinement), PQ
codes (ADC scan), and strategy-registry assignments (compaction input).
The buffers are host-side numpy; ``StreamingIndex`` owns the device
mirrors.

Capacity grows in fixed geometric buckets (``pad * 2**j``), so the
padded device views keep a small bounded set of shapes and the compiled
streaming executables never retrace on steady-state appends.  Slots are
never reused: a deleted delta item keeps its slot with ``live=False``
until the next compaction discards the whole segment — ids therefore
stay append-ordered and dense in ``[0, count)``.

The segment also maintains a **per-list posting map** (``post``,
``post_n``): for each IVF list, the delta slots assigned to it — the
routing directory that lets the query path scan only the delta items
reachable through the probed lists once the segment outgrows the
exhaustive-scan fast path (``IndexConfig.delta_route_min``, DESIGN.md
§8).  Postings are maintained incrementally on append (each slot posted
once per *distinct* assigned list), padded to a power-of-two per-list
width so the device mirror keeps a bounded set of shapes, and never
pruned on delete — liveness is checked through ``delta_ids`` at query
time, exactly like the exhaustive path.
"""
from __future__ import annotations

import numpy as np

_POST_MIN_WIDTH = 16


class DeltaSegment:
    """Padded append-only buffers for one epoch's inserts."""

    def __init__(self, dim: int, m_pq: int, m_assign: int, pad: int = 256,
                 nlist: int = 0):
        if pad < 1:
            raise ValueError(f"pad must be >= 1, got {pad}")
        self.dim = int(dim)
        self.m_pq = int(m_pq)
        self.m_assign = int(m_assign)
        self.pad = int(pad)
        self.nlist = int(nlist)
        self.count = 0         # slots ever used (monotonic)
        self.capacity = 0      # allocated slots (bucketed)
        self.vectors = np.zeros((0, self.dim), np.float32)
        self.codes = np.zeros((0, self.m_pq), np.uint8)
        self.assigns = np.zeros((0, self.m_assign), np.int32)
        self.live = np.zeros((0,), bool)
        # per-list routing directory: slot ids per assigned list, -1 pad
        self.post_width = 0
        self.post = np.full((self.nlist, 0), -1, np.int32)
        self.post_n = np.zeros(self.nlist, np.int32)
        # (lists, cols, slots) written by the latest append — the device
        # mirror patches exactly these coordinates instead of rebuilding
        self.last_post_update = (np.zeros(0, np.int64),) * 3

    def _cap_for(self, n: int) -> int:
        if n <= 0:
            return 0
        cap = self.pad
        while cap < n:
            cap *= 2
        return cap

    @property
    def n_live(self) -> int:
        return int(self.live[:self.count].sum())

    @property
    def n_dead(self) -> int:
        return self.count - self.n_live

    def append(self, vectors: np.ndarray, codes: np.ndarray,
               assigns: np.ndarray):
        """Append a batch; returns ``(slots, grew)`` where `slots` are the
        newly used slot indices and `grew` flags a capacity-bucket or
        posting-width jump (device mirrors must be rebuilt rather than
        patched)."""
        b = vectors.shape[0]
        s0 = self.count
        need = s0 + b
        grew = need > self.capacity
        if grew:
            cap = self._cap_for(need)

            def regrow(old, shape, dtype):
                out = np.zeros(shape, dtype)
                out[:s0] = old[:s0]
                return out

            self.vectors = regrow(self.vectors, (cap, self.dim), np.float32)
            self.codes = regrow(self.codes, (cap, self.m_pq), np.uint8)
            self.assigns = regrow(self.assigns, (cap, self.m_assign), np.int32)
            self.live = regrow(self.live, (cap,), bool)
            self.capacity = cap
        self.vectors[s0:need] = vectors
        self.codes[s0:need] = codes
        self.assigns[s0:need] = assigns
        self.live[s0:need] = True
        self.count = need
        slots = np.arange(s0, need, dtype=np.int64)
        grew |= self._append_postings(slots, np.asarray(assigns, np.int64))
        return slots, grew

    def _append_postings(self, slots: np.ndarray, assigns: np.ndarray
                         ) -> bool:
        """Post each new slot under its distinct assigned lists; returns
        whether the per-list width grew (device mirror rebuild)."""
        if self.nlist == 0 or slots.size == 0:
            return False
        m = assigns.shape[1]
        dup = np.zeros(assigns.shape, bool)
        for j in range(1, m):    # drop repeated lists within one row
            dup[:, j] = (assigns[:, :j] == assigns[:, j:j + 1]).any(axis=1)
        keep = ~dup
        lists = assigns[keep]
        srep = np.broadcast_to(slots[:, None], assigns.shape)[keep]
        order = np.argsort(lists, kind="stable")
        lists, srep = lists[order], srep[order]
        within = np.arange(len(lists)) - np.searchsorted(lists, lists)
        cols = self.post_n[lists].astype(np.int64) + within
        need = int(cols.max()) + 1 if len(cols) else 0
        grew = need > self.post_width
        if grew:
            w = max(_POST_MIN_WIDTH, self.post_width or _POST_MIN_WIDTH)
            while w < need:
                w *= 2
            post = np.full((self.nlist, w), -1, np.int32)
            post[:, :self.post_width] = self.post
            self.post, self.post_width = post, w
        self.post[lists, cols] = srep
        self.post_n += np.bincount(lists, minlength=self.nlist
                                   ).astype(np.int32)
        self.last_post_update = (lists, cols, srep)
        return grew

    def mark_dead(self, slots: np.ndarray) -> int:
        """Tombstone `slots`; returns how many were live until now."""
        slots = np.asarray(slots, np.int64).ravel()
        if slots.size == 0:
            return 0
        if (slots < 0).any() or (slots >= self.count).any():
            raise ValueError(
                f"delta slots out of range [0, {self.count}): {slots}")
        newly = int(self.live[slots].sum())
        self.live[slots] = False
        return newly
