"""Streaming query pipeline: base engine stages + delta scan + tombstones.

``streaming_search`` runs the same four engine stages as ``seil_search``
over the immutable base layout, then extends the candidate stream with
the mutable epoch state before the shared finalize stage:

  * the **delta segment** is scanned exhaustively — every live slot of
    the padded flat code buffer gets one ADC distance per query (no IVF
    routing; the segment is small by construction and is folded into the
    base at compaction).  Delta candidates enter ``finalize_candidates``
    through its ``extra_d/extra_i`` merge, so they compete with base
    candidates under the exact same top-bigK / refinement rules;
  * the **tombstone mask** (``live``, over the whole id space base +
    delta) is applied inside finalize — deleted items are forced to
    +inf before selection instead of being rewritten out of the layout.

DCO accounting stays paper-faithful: every live delta slot costs one
ADC distance computation per query (added to ``approx_dco``); dead slots
cost nothing; refinement counts once per surviving unique candidate.

All shapes are static given (batch bucket, delta capacity): the delta
buffers are padded to fixed capacity buckets (stream/delta.py), so
steady-state churn dispatches to cached executables without retracing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..engine import (finalize_candidates, plan_blocks, scan_blocks,
                      select_lists, store_from_arrays, tables_from_arrays)
from ..pq import PQCodebook, pq_lut, pq_lut_ip
from ..search import SearchResult
from ..seil import SeilArrays


def delta_adc(lut: jnp.ndarray, delta_codes: jnp.ndarray) -> jnp.ndarray:
    """ADC distances of every delta slot: (B, M, K) lut x (C, M) codes
    -> (B, C).  d[b, c] = sum_m lut[b, m, codes[c, m]]."""
    m = delta_codes.shape[1]
    g = lut[:, jnp.arange(m)[None, :], delta_codes.astype(jnp.int32)]
    return jnp.sum(g, axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "bigk", "k", "max_scan", "metric",
                     "dedup_results", "use_kernel", "oversample",
                     "exec_mode", "query_tile"))
def streaming_search(
    arrays: SeilArrays,
    centroids: jnp.ndarray,       # (nlist, D)
    codebook: PQCodebook,
    vectors: jnp.ndarray,         # (n_base + cap, D) refine store, id-aligned
    delta_codes: jnp.ndarray,     # (cap, M) uint8 padded delta buffer
    delta_ids: jnp.ndarray,       # (cap,) int32 global ids, -1 dead/unused
    live: jnp.ndarray,            # (n_base + cap,) bool tombstone mask
    queries: jnp.ndarray,         # (B, D)
    *,
    nprobe: int,
    bigk: int,
    k: int,
    max_scan: int,
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
    exec_mode: str = "paged",
    query_tile: int = 8,
) -> SearchResult:
    selection = select_lists(queries, centroids, nprobe=nprobe, metric=metric)
    plan = plan_blocks(tables_from_arrays(arrays), selection,
                       max_scan=max_scan)
    lut = (pq_lut(codebook, queries) if metric == "l2"
           else pq_lut_ip(codebook, queries))                # (B, M, 16)
    scan = scan_blocks(store_from_arrays(arrays), plan, lut,
                       selection.rank_of, exec_mode=exec_mode,
                       use_kernel=use_kernel, query_tile=query_tile)
    alive = delta_ids >= 0                                   # (cap,)
    dd = jnp.where(alive[None, :], delta_adc(lut, delta_codes), jnp.inf)
    di = jnp.broadcast_to(delta_ids[None, :], dd.shape)
    out_ids, out_d, refine_dco = finalize_candidates(
        scan.flat_d, scan.flat_i, bigk=bigk, k=k, vectors=vectors,
        queries=queries, metric=metric, dedup_results=dedup_results,
        oversample=oversample, extra_d=dd, extra_i=di, live=live)
    approx_dco = scan.approx_dco + jnp.sum(alive).astype(jnp.int32)
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=approx_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=plan.dropped)
