"""Streaming query pipeline: base engine stages + delta scan + tombstones.

``streaming_search`` runs the same four engine stages as ``seil_search``
over the immutable base layout, then extends the candidate stream with
the mutable epoch state before the shared finalize stage:

  * the **delta segment** is scanned in one of two ways.  While it is
    small (capacity <= the routing threshold, ``IndexConfig.
    delta_route_min``, default ``nlist * block``) every live slot of the
    padded flat code buffer gets one ADC distance per query — no
    routing, the exhaustive fast path.  Once capacity outgrows the
    threshold the scan is **routed**: each probed list contributes only
    the delta slots assigned to it (the per-list posting map maintained
    on append, stream/delta.py), deduplicated to the lowest-ranked
    probed assigned list — the delta-side analogue of Alg. 5's
    ``listVisited`` probe — so the per-query cost drops from O(capacity)
    to O(nprobe x list occupancy).  Routing narrows reach to the probed
    lists (exactly the base layout's semantics, i.e. what the same items
    get after compaction); with every assigned list probed the candidate
    set — and the results — are identical to the exhaustive path
    (asserted in tests/test_plan.py).  Either way delta candidates enter
    ``finalize_candidates`` through its ``extra_d/extra_i`` merge and
    compete with base candidates under the exact same top-bigK /
    refinement rules;
  * the **tombstone mask** (``live``, over the whole id space base +
    delta) is applied inside finalize — deleted items are forced to
    +inf before selection instead of being rewritten out.

DCO accounting stays paper-faithful: the exhaustive path counts one ADC
distance per live slot per query; the routed path counts one per live
slot *reachable through the probed lists* (computed once, at its
lowest-ranked probed list).  Dead slots cost nothing; refinement counts
once per surviving unique candidate.

All shapes are static given (batch bucket, delta capacity, posting
width): the delta buffers are padded to fixed capacity buckets and the
posting map to power-of-two per-list widths (stream/delta.py), so
steady-state churn dispatches to cached executables without retracing.

``scan_finalize_stream`` is the streaming scan half of the split
(incremental-plan) pipeline — the counterpart of
``core/search.py::scan_finalize`` dispatched by ``StreamingSearcher``
sessions with ``SearchParams(plan_reuse=True)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ..engine import (PlanProbe, finalize_candidates, plan_blocks,
                      scan_blocks, scan_blocks_topk, select_lists,
                      store_from_arrays, tables_from_arrays)
from ..pq import PQCodebook, pq_lut, pq_lut_ip
from ..search import (SearchResult, _stage_plan, _stage_scan, _stage_select,
                      finalize_fetch)
from ..seil import SeilArrays


def delta_adc(lut: jnp.ndarray, delta_codes: jnp.ndarray) -> jnp.ndarray:
    """ADC distances of every delta slot: (B, M, K) lut x (C, M) codes
    -> (B, C).  d[b, c] = sum_m lut[b, m, codes[c, m]]."""
    m = delta_codes.shape[1]
    g = lut[:, jnp.arange(m)[None, :], delta_codes.astype(jnp.int32)]
    return jnp.sum(g, axis=-1)


def routed_delta_candidates(lut, delta_codes, delta_ids, delta_post,
                            delta_assigns, sel, rank_of):
    """Delta candidates reached through the probed lists only.

    lut (B, M, K); delta_post (nlist, L) slot ids (-1 pad);
    delta_assigns (cap, m); sel (B, P) ranked probed lists; rank_of
    (B, nlist).  Returns ``(dd, di, dco)``: (B, P*L) distances/ids and
    the per-query routed DCO.  A slot assigned to several probed lists
    is computed exactly once — at its lowest-ranked probed assigned
    list (the delta-side ``listVisited``), so SEIL-exact result streams
    stay duplicate-free.
    """
    b, p = sel.shape
    slots = delta_post[sel]                               # (B, P, L)
    s0 = jnp.maximum(slots, 0)
    sids = jnp.where(slots >= 0, delta_ids[s0], -1)       # (B, P, L)
    al = delta_assigns[s0]                                # (B, P, L, m)
    r = jnp.take_along_axis(rank_of, al.reshape(b, -1), axis=1
                            ).reshape(al.shape)
    min_rank = jnp.min(r, axis=-1)                        # (B, P, L)
    keep = (sids >= 0) & (min_rank
                          == jnp.arange(p, dtype=jnp.int32)[None, :, None])
    codes = delta_codes[s0]                               # (B, P, L, M)
    g = jnp.take_along_axis(lut[:, None, None, :, :],
                            codes.astype(jnp.int32)[..., None], axis=-1)
    d = jnp.sum(g[..., 0], axis=-1)                       # (B, P, L)
    dd = jnp.where(keep, d, jnp.inf).reshape(b, -1)
    di = jnp.where(keep, sids, -1).reshape(b, -1)
    return dd, di, jnp.sum(keep, axis=(1, 2)).astype(jnp.int32)


def _delta_candidates(lut, delta_codes, delta_ids, delta_post,
                      delta_assigns, sel, rank_of, route_delta: bool):
    """(dd, di, per-query delta DCO) via the routed or exhaustive path."""
    if route_delta:
        return routed_delta_candidates(lut, delta_codes, delta_ids,
                                       delta_post, delta_assigns, sel,
                                       rank_of)
    alive = delta_ids >= 0                                # (cap,)
    dd = jnp.where(alive[None, :], delta_adc(lut, delta_codes), jnp.inf)
    di = jnp.broadcast_to(delta_ids[None, :], dd.shape)
    dco = jnp.broadcast_to(jnp.sum(alive).astype(jnp.int32),
                           (lut.shape[0],))
    return dd, di, dco


@functools.partial(
    jax.jit,
    static_argnames=("nprobe", "bigk", "k", "max_scan", "metric",
                     "dedup_results", "use_kernel", "oversample",
                     "exec_mode", "query_tile", "route_delta",
                     "fused_topk", "packed_codes"))
def streaming_search(
    arrays: SeilArrays,
    centroids: jnp.ndarray,       # (nlist, D)
    codebook: PQCodebook,
    vectors: jnp.ndarray,         # (n_base + cap, D) refine store, id-aligned
    delta_codes: jnp.ndarray,     # (cap, M) uint8 padded delta buffer
    delta_ids: jnp.ndarray,       # (cap,) int32 global ids, -1 dead/unused
    delta_post: jnp.ndarray,      # (nlist, L) int32 slot postings, -1 pad
    delta_assigns: jnp.ndarray,   # (cap, m) int32 assigned lists per slot
    live: jnp.ndarray,            # (n_base + cap,) bool tombstone mask
    queries: jnp.ndarray,         # (B, D)
    *,
    nprobe: int,
    bigk: int,
    k: int,
    max_scan: int,
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
    exec_mode: str = "paged",
    query_tile: int = 8,
    route_delta: bool = False,
    fused_topk: bool = False,
    packed_codes: bool = False,   # arrays carry a nibble-packed quant plane
) -> SearchResult:
    selection = select_lists(queries, centroids, nprobe=nprobe, metric=metric)
    plan = plan_blocks(tables_from_arrays(arrays), selection,
                       max_scan=max_scan)
    lut = (pq_lut(codebook, queries) if metric == "l2"
           else pq_lut_ip(codebook, queries))                # (B, M, 16)
    if fused_topk:
        # live is applied pre-selection so tombstoned base candidates
        # cannot occupy top-fetch slots; finalize's re-mask is idempotent
        scan = scan_blocks_topk(
            store_from_arrays(arrays), plan, lut, selection.rank_of,
            fetch=finalize_fetch(bigk, oversample, dedup_results),
            exec_mode=exec_mode, use_kernel=use_kernel,
            query_tile=query_tile, sel=selection.sel, live=live,
            packed=packed_codes)
    else:
        scan = scan_blocks(store_from_arrays(arrays), plan, lut,
                           selection.rank_of, exec_mode=exec_mode,
                           use_kernel=use_kernel, query_tile=query_tile,
                           sel=selection.sel, packed=packed_codes)
    dd, di, delta_dco = _delta_candidates(
        lut, delta_codes, delta_ids, delta_post, delta_assigns,
        selection.sel, selection.rank_of, route_delta)
    out_ids, out_d, refine_dco = finalize_candidates(
        scan.flat_d, scan.flat_i, bigk=bigk, k=k, vectors=vectors,
        queries=queries, metric=metric, dedup_results=dedup_results,
        oversample=oversample, extra_d=dd, extra_i=di, live=live)
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=scan.approx_dco + delta_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=plan.dropped)


# ---------------------------------------------------------------------------
# traced pipeline — streaming_search cut at its stage boundaries
# (DESIGN.md §11): the base stage programs from core/search.py plus a
# separate delta-scan stage, so the delta-vs-base scan split shows up
# directly as span counters.  Bitwise-identical to streaming_search
# (asserted in tests/test_obs.py).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("route_delta",))
def _stage_delta(lut, delta_codes, delta_ids, delta_post, delta_assigns,
                 sel, rank_of, *, route_delta):
    return _delta_candidates(lut, delta_codes, delta_ids, delta_post,
                             delta_assigns, sel, rank_of, route_delta)


@functools.partial(
    jax.jit,
    static_argnames=("bigk", "k", "metric", "dedup_results", "oversample"))
def _stage_finalize_stream(vectors, queries, flat_d, flat_i, dd, di, live,
                           *, bigk, k, metric, dedup_results, oversample):
    return finalize_candidates(
        flat_d, flat_i, bigk=bigk, k=k, vectors=vectors, queries=queries,
        metric=metric, dedup_results=dedup_results, oversample=oversample,
        extra_d=dd, extra_i=di, live=live)


def streaming_search_traced(
    arrays, centroids, codebook, vectors, delta_codes, delta_ids,
    delta_post, delta_assigns, live, queries, *, nprobe, bigk, k, max_scan,
    metric="l2", dedup_results=True, use_kernel=False, oversample=2,
    exec_mode="paged", query_tile=8, route_delta=False, fused_topk=False,
    packed_codes=False,
) -> SearchResult:
    """Stage-fenced ``streaming_search`` for tracing: identical
    composition, span + fence per stage, delta DCO on its own span."""
    with obs.span("stage.select_lists", cat="device", nprobe=nprobe):
        selection = obs.fence(_stage_select(centroids, queries,
                                            nprobe=nprobe, metric=metric))
    with obs.span("stage.plan_blocks", cat="device", max_scan=max_scan):
        plan, lut = obs.fence(_stage_plan(arrays, codebook, selection,
                                          queries, max_scan=max_scan,
                                          metric=metric))
    name = "stage.scan_blocks_topk" if fused_topk else "stage.scan_blocks"
    with obs.span(name, cat="device", exec_mode=exec_mode) as sp:
        # fused applies the tombstone mask pre-selection (has_live)
        scan = obs.fence(_stage_scan(
            arrays, plan, lut, selection, live,
            fetch=finalize_fetch(bigk, oversample, dedup_results),
            exec_mode=exec_mode, use_kernel=use_kernel,
            query_tile=query_tile, fused_topk=fused_topk,
            has_live=fused_topk, packed_codes=packed_codes))
        sp.add(approx_dco=int(np.sum(np.asarray(scan.approx_dco))),
               scanned_blocks=int(np.sum(np.asarray(scan.scanned_blocks))))
    with obs.span("stage.delta_scan", cat="device",
                  routed=bool(route_delta)) as sp:
        dd, di, delta_dco = obs.fence(_stage_delta(
            lut, delta_codes, delta_ids, delta_post, delta_assigns,
            selection.sel, selection.rank_of, route_delta=route_delta))
        sp.add(delta_dco=int(np.sum(np.asarray(delta_dco))))
    with obs.span("stage.finalize", cat="device") as sp:
        out_ids, out_d, refine_dco = obs.fence(_stage_finalize_stream(
            vectors, queries, scan.flat_d, scan.flat_i, dd, di, live,
            bigk=bigk, k=k, metric=metric, dedup_results=dedup_results,
            oversample=oversample))
        sp.add(refine_dco=int(np.sum(np.asarray(refine_dco))))
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=scan.approx_dco + delta_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=plan.dropped)


@functools.partial(
    jax.jit,
    static_argnames=("bigk", "k", "metric", "dedup_results", "use_kernel",
                     "oversample", "exec_mode", "query_tile", "route_delta",
                     "fused_topk", "packed_codes"))
def scan_finalize_stream(
    arrays: SeilArrays,
    vectors: jnp.ndarray,
    delta_codes: jnp.ndarray,
    delta_ids: jnp.ndarray,
    delta_post: jnp.ndarray,
    delta_assigns: jnp.ndarray,
    live: jnp.ndarray,
    queries: jnp.ndarray,
    probe: PlanProbe,
    unions: jnp.ndarray,          # (T, W') width-bucketed unions to scan
    *,
    bigk: int,
    k: int,
    metric: str = "l2",
    dedup_results: bool = True,
    use_kernel: bool = False,
    oversample: int = 2,
    exec_mode: str = "grouped",
    query_tile: int = 8,
    route_delta: bool = False,
    fused_topk: bool = False,
    packed_codes: bool = False,
) -> SearchResult:
    """Streaming stages 3-4 against caller-provided (reused) unions —
    the probe half is the base ``probe_plan`` (the delta needs no block
    planning), so incremental plans compose with churn unchanged."""
    if fused_topk:
        scan = scan_blocks_topk(
            store_from_arrays(arrays), probe.plan, probe.lut, probe.rank_of,
            fetch=finalize_fetch(bigk, oversample, dedup_results),
            exec_mode=exec_mode, use_kernel=use_kernel,
            query_tile=query_tile, perm=probe.perm, unions=unions,
            live=live, packed=packed_codes)
    else:
        scan = scan_blocks(store_from_arrays(arrays), probe.plan, probe.lut,
                           probe.rank_of, exec_mode=exec_mode,
                           use_kernel=use_kernel, query_tile=query_tile,
                           perm=probe.perm, unions=unions,
                           packed=packed_codes)
    dd, di, delta_dco = _delta_candidates(
        probe.lut, delta_codes, delta_ids, delta_post, delta_assigns,
        probe.sel, probe.rank_of, route_delta)
    out_ids, out_d, refine_dco = finalize_candidates(
        scan.flat_d, scan.flat_i, bigk=bigk, k=k, vectors=vectors,
        queries=queries, metric=metric, dedup_results=dedup_results,
        oversample=oversample, extra_d=dd, extra_i=di, live=live)
    return SearchResult(
        ids=out_ids, dists=out_d, approx_dco=scan.approx_dco + delta_dco,
        refine_dco=refine_dco, scanned_blocks=scan.scanned_blocks,
        dropped_blocks=probe.plan.dropped)
