"""StreamingIndex — a mutable, epoch-versioned view over a frozen base.

The paper builds SEIL once over a static corpus; production corpora
churn.  ``StreamingIndex`` makes insert/delete first-class (DESIGN.md
§8) without giving up the static-shape query engine:

  * the **base** is an ordinary immutable ``RairsIndex`` (one *epoch*);
  * inserts go to an append-only **delta segment** (stream/delta.py):
    assigned through the strategy registry and PQ-encoded exactly like
    the base, then scanned through a padded flat buffer that merges into
    the shared finalize stage (stream/search.py) — no layout rebuild.
    Small deltas scan exhaustively; once capacity outgrows
    ``IndexConfig.delta_route_min`` (default ``nlist * block``) the scan
    is *routed* through the probed lists via the per-list posting map
    maintained on append (stream/search.py docstring);
  * deletes flip bits in a **tombstone mask** over the whole id space;
    dead items are masked at query time, never rewritten out;
  * **compaction** folds survivors (base minus tombstones, plus live
    delta) into a fresh ``build_seil`` base, renumbers ids densely
    (``last_remap`` maps old -> new, -1 = deleted) and bumps ``epoch``.
    ``begin_compact`` is the zero-downtime variant (DESIGN.md §10): it
    snapshots the epoch so the O(n) fold can run on a worker thread
    while the stream keeps serving and mutating, and ``install`` swaps
    the new epoch in atomically, replaying whatever mutations arrived
    after the snapshot;
  * **external ids** are stable handles: the id first issued for an
    item never changes even though compaction renumbers the internal
    id space — ``resolve_ids`` / ``external_ids`` translate through a
    composed map that chains every ``last_remap``, so gateway clients
    holding result ids survive epoch handovers;
  * **sessions** (``StreamingSearcher``) pin the (epoch, version) they
    compiled against: any mutation bumps ``version``, and a stale
    session raises ``StaleSessionError`` instead of silently serving
    pre-mutation state — the failure mode of the old layout-level
    ``seil.delete_ids`` path.  Fresh sessions share compiled executables
    through a stream-level cache keyed by (params, delta capacity), so
    steady-state churn never recompiles.

Mutation costs: insert is O(batch) (assign + encode + buffer patch),
delete is O(batch) (scatter into the mask), compaction is the one O(n)
operation — amortized by thresholds (``StreamConfig``) or triggered
explicitly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

# module (not symbol) imports: the insert path must observe monkeypatched
# index_mod.pq_encode / compute_assignments exactly like build_index does
from .. import index as index_mod
from ..params import SearchParams
from ..search import SearchResult
from ..searcher import Searcher
from ..seil import build_seil
from ...errors import RairsError
from .delta import DeltaSegment
from .search import (scan_finalize_stream, streaming_search,
                     streaming_search_traced)


class StaleSessionError(RairsError, RuntimeError):
    """A searcher session outlived the index state it compiled against."""


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Streaming-side knobs (query knobs stay in ``SearchParams``).

    delta_pad           delta capacity bucket quantum: buffers are padded
                        to ``delta_pad * 2**j`` slots so compiled shapes
                        stay bounded under churn
    compact_delta_frac  auto-compact when the delta segment exceeds this
                        fraction of the base size (None = manual only)
    compact_dead_frac   auto-compact when tombstoned items exceed this
                        fraction of the id space (None = manual only)
    """
    delta_pad: int = 256
    compact_delta_frac: Optional[float] = None
    compact_dead_frac: Optional[float] = None

    def __post_init__(self):
        if self.delta_pad < 1:
            raise ValueError(f"delta_pad must be >= 1, got {self.delta_pad}")
        for name in ("compact_delta_frac", "compact_dead_frac"):
            v = getattr(self, name)
            if v is not None and not v > 0:
                raise ValueError(f"{name} must be > 0 or None, got {v!r}")


@dataclasses.dataclass
class StreamStats:
    """Mutation / session accounting for one StreamingIndex."""
    inserts: int = 0           # vectors appended
    deletes: int = 0           # items newly tombstoned
    compactions: int = 0
    auto_compactions: int = 0  # subset of compactions (threshold-triggered)
    sessions: int = 0          # StreamingSearcher objects created
    invalidations: int = 0     # cached sessions dropped as stale

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _DeviceState:
    """Device mirrors of the mutable state, patched in O(batch) between
    capacity-bucket / posting-width jumps (which rebuild them wholesale)."""
    vectors_full: jnp.ndarray   # (n_base + cap, D) id-aligned refine store
    delta_codes: jnp.ndarray    # (cap, M) uint8
    delta_ids: jnp.ndarray      # (cap,) int32 global ids, -1 dead/unused
    delta_post: jnp.ndarray     # (nlist, L) int32 per-list slot postings
    delta_assigns: jnp.ndarray  # (cap, m) int32 assigned lists per slot
    live_full: jnp.ndarray      # (n_base + cap,) bool
    capacity: int


def _fold_epoch(base, base_live: np.ndarray, d_vectors: np.ndarray,
                d_codes: np.ndarray, d_assigns: np.ndarray,
                d_live: np.ndarray):
    """Pure epoch fold: survivors of (base, delta) -> a fresh
    ``RairsIndex`` plus the dense old->new remap over the snapshot id
    space (-1 = deleted).  Touches only its arguments and the immutable
    base, so it is safe to run off-thread against snapshot copies while
    the owning ``StreamingIndex`` keeps serving (``begin_compact``)."""
    cfg = base.config
    codes_base = base.codes
    if codes_base is None:     # pre-cache bundle: encode once
        codes_base = np.asarray(
            index_mod.pq_encode(base.codebook, base.vectors))
    vec = np.concatenate(
        [np.asarray(base.vectors)[base_live], d_vectors[d_live]], axis=0)
    codes = np.concatenate(
        [np.asarray(codes_base)[base_live], d_codes[d_live]], axis=0)
    assigns = np.concatenate(
        [np.asarray(base.assigns)[base_live], d_assigns[d_live]], axis=0)
    n = vec.shape[0]
    shared = cfg.seil and cfg.multi_m == 2
    t1 = time.perf_counter()
    arrays, seil_stats = build_seil(
        assigns, codes, np.arange(n, dtype=np.int32), cfg.nlist,
        block=cfg.block, shared=shared, code_bits=cfg.nbits)
    t_layout = time.perf_counter() - t1
    alive_full = np.concatenate([base_live, d_live])
    remap = np.full(alive_full.shape[0], -1, np.int64)
    remap[np.nonzero(alive_full)[0]] = np.arange(n)
    new_base = index_mod.RairsIndex(
        config=cfg, centroids=base.centroids, codebook=base.codebook,
        arrays=arrays, vectors=jnp.asarray(vec), stats=seil_stats,
        assigns=assigns, codes=codes,
        build_seconds={"layout": t_layout})
    return new_base, remap, t_layout


class PendingCompaction:
    """A two-phase zero-downtime compaction (``begin_compact``).

    ``fold()`` builds the next epoch from a snapshot taken at
    ``begin_compact`` time; it reads only the snapshot copies and the
    immutable base, so a worker thread can run it while the stream keeps
    answering queries and absorbing mutations.  ``install()`` then swaps
    the folded epoch in atomically and *replays* everything that arrived
    after the snapshot: tail inserts re-append with their already-
    computed codes/assignments (no re-encoding), post-snapshot deletes
    re-tombstone through the remap.  The combined remap over the full
    pre-install id space lands in ``stream.last_remap`` and chains into
    the external-id map exactly like a synchronous ``compact()``.

    Thread contract: ``fold()`` may run on any thread; ``install()``
    mutates the stream and must be serialized against every other use of
    the index — the gateway's dispatcher thread calls it between
    dispatched batches (DESIGN.md §10 handover state machine).
    """

    def __init__(self, stream: "StreamingIndex", reason: str):
        self.stream = stream
        self.reason = reason
        self.state = "folding"
        d = stream._delta
        self._epoch0 = stream.epoch
        self._n_base0 = stream.n_base
        self._count0 = d.count
        self._base_live0 = stream._base_live.copy()
        self._d_vectors0 = d.vectors[:d.count].copy()
        self._d_codes0 = d.codes[:d.count].copy()
        self._d_assigns0 = d.assigns[:d.count].copy()
        self._d_live0 = d.live[:d.count].copy()
        self._folded = None
        self._fold_seconds = 0.0

    def fold(self) -> "PendingCompaction":
        """The O(n) rebuild — run this off-thread; chainable."""
        if self.state != "folding":
            raise RuntimeError(f"fold() on a {self.state} compaction")
        t0 = time.perf_counter()
        self._folded = _fold_epoch(
            self.stream.base, self._base_live0, self._d_vectors0,
            self._d_codes0, self._d_assigns0, self._d_live0)
        self._fold_seconds = time.perf_counter() - t0
        self.state = "ready"
        return self

    def abort(self) -> None:
        """Drop the pending fold; the stream stays on its current epoch."""
        self.state = "aborted"
        if self.stream._pending_compact is self:
            self.stream._pending_compact = None

    def install(self) -> dict:
        """Atomically swap the folded epoch in and replay the mutation
        tail.  Must not race any other use of the stream (see class
        docstring); sessions become stale exactly as under ``compact``."""
        st = self.stream
        if self.state != "ready":
            raise RuntimeError(
                f"install() on a {self.state} compaction (fold() first)")
        if st.epoch != self._epoch0:
            self.abort()
            raise RuntimeError(
                "a competing compaction landed while this one folded; "
                "the snapshot is stale")
        t0 = time.perf_counter()
        new_base, remap0, t_layout = self._folded
        d = st._delta
        # mutations that arrived after the snapshot
        tail_vec = d.vectors[self._count0:d.count].copy()
        tail_codes = d.codes[self._count0:d.count].copy()
        tail_assigns = d.assigns[self._count0:d.count].copy()
        tail_live = d.live[self._count0:d.count].copy()
        dead_base = self._base_live0 & ~st._base_live
        dead_delta = self._d_live0 & ~d.live[:self._count0]
        n_total_old = self._n_base0 + d.count
        # swap epochs (sessions stale from here on)
        st.base = new_base
        st.epoch += 1
        st.version += 1
        st.stats.compactions += 1
        st._retire_sessions()
        st._reset_epoch_state()
        # remap over the full pre-install id space: snapshot ids fold
        # through remap0, live tail inserts re-append under fresh ids
        remap = np.full(n_total_old, -1, np.int64)
        remap[:remap0.size] = remap0
        if tail_live.any():
            lv = np.nonzero(tail_live)[0]
            slots, _ = st._delta.append(
                tail_vec[lv], tail_codes[lv], tail_assigns[lv])
            remap[self._n_base0 + self._count0 + lv] = st.n_base + slots
        # post-snapshot deletes: their victims folded in as live (the
        # snapshot predates them) — re-tombstone through the remap.
        # Stats/version stay put: these mutations were already counted
        # when the caller issued them.
        dead_old = np.concatenate(
            [np.nonzero(dead_base)[0],
             self._n_base0 + np.nonzero(dead_delta)[0]])
        if dead_old.size:
            st._apply_tombstones(remap[dead_old])
        st._apply_remap(remap)
        st._pending_compact = None
        self.state = "installed"
        return {"epoch": st.epoch, "reason": self.reason,
                "n_live": st.n_live,
                "dropped": int((remap < 0).sum()),
                "seconds": self._fold_seconds + time.perf_counter() - t0,
                "layout_seconds": t_layout,
                "replayed_inserts": int(tail_live.sum()),
                "replayed_deletes": int(dead_old.size),
                "id_remap": remap}


class StreamingIndex:
    """Mutable index: an immutable ``RairsIndex`` base epoch plus delta
    segment, tombstone mask, and versioned searcher sessions.

    Duck-type compatible with the read side of ``RairsIndex`` (config /
    centroids / codebook / vectors / stats / searcher / search), so
    existing call sites — including the ``insert_batch`` compat wrapper —
    keep working unchanged.
    """

    def __init__(self, base, config: Optional[StreamConfig] = None):
        if isinstance(base, StreamingIndex):
            raise TypeError("base must be an immutable RairsIndex, not a "
                            "StreamingIndex (nest epochs via compact())")
        self.base = base
        self.stream_config = config or StreamConfig()
        self.epoch = 0
        self.version = 0            # bumps on every insert/delete/compact
        self.stats = StreamStats()
        self.last_remap = None      # old id -> new id after last compact
        self._retired: Dict[str, int] = {}   # folded stats of dead sessions
        self._pending_compact: Optional[PendingCompaction] = None
        # stable external ids: the handle first issued for an item never
        # changes; _ext_to_int chains every compaction remap (-1 = dead)
        # and _int_to_ext is its inverse over the current id space
        self._ext_to_int = np.arange(self.n_base, dtype=np.int64)
        self._int_to_ext = np.arange(self.n_base, dtype=np.int64)
        # compact-plane codecs outlive epochs: train once, re-encode
        # every rebuilt base with the carried codec (quant/plane.py)
        self._plane_codecs: Dict[str, object] = {}
        self._reset_epoch_state()

    def _reset_epoch_state(self):
        base = self.base
        self._delta = DeltaSegment(
            dim=int(base.vectors.shape[1]), m_pq=int(base.codebook.m),
            m_assign=int(base.assigns.shape[1]),
            pad=self.stream_config.delta_pad,
            nlist=int(base.config.nlist))
        self._base_live = np.ones(self.n_base, bool)
        self._dead_base = 0
        self._dev: Optional[_DeviceState] = None
        self._sessions: Dict[SearchParams, "StreamingSearcher"] = {}
        self._exec_cache: Dict[tuple, dict] = {}
        # plan_reuse probe-half executables: they consume only the base
        # arrays, so they survive delta capacity/posting bucket jumps
        # (keyed per params; dropped with the epoch like everything here)
        self._probe_cache: Dict[SearchParams, dict] = {}
        # per-backend device mirrors of the delta's compact-plane codes,
        # keyed by (version, capacity) — dropped with the epoch
        self._plane_delta: Dict[str, tuple] = {}

    # ------------------------------------------------------------------
    # sizes / views
    # ------------------------------------------------------------------
    @property
    def n_base(self) -> int:
        return int(self.base.vectors.shape[0])

    @property
    def n_total(self) -> int:
        """Size of the id space (base + every delta slot ever used)."""
        return self.n_base + self._delta.count

    @property
    def n_delta(self) -> int:
        """Live items in the delta segment."""
        return self._delta.n_live

    @property
    def n_dead(self) -> int:
        return self._dead_base + self._delta.n_dead

    @property
    def n_live(self) -> int:
        return self.n_total - self.n_dead

    @property
    def has_mutations(self) -> bool:
        """Any insert/delete since the current epoch's base was built."""
        return self._delta.count > 0 or self._dead_base > 0

    @property
    def delta_route_threshold(self) -> int:
        """Delta capacity above which the scan routes through the probed
        lists (``IndexConfig.delta_route_min``; default ``nlist *
        block`` — the point where the exhaustive delta costs as much per
        query as scanning every list's worth of one block)."""
        cfg = self.base.config
        if cfg.delta_route_min is not None:
            return cfg.delta_route_min
        return cfg.nlist * cfg.block

    @property
    def delta_routed(self) -> bool:
        """Whether the current delta capacity bucket scans routed.
        Keyed on *capacity* (not live count) so the choice is a static
        property of the compiled shapes."""
        return self._delta.capacity > self.delta_route_threshold

    def routes_at(self, nprobe: int) -> bool:
        """Session-level routing decision.  An explicit
        ``delta_route_min`` is the caller's final word; under the auto
        threshold the routed path must also be cheaper than the scan it
        replaces: the padded routed gather costs ~``nprobe x
        post_width`` ADC rows per query, and a hot-list-skewed delta
        can grow the posting width until that exceeds the exhaustive
        ``capacity`` — then the exhaustive fast path stays in force."""
        if not self.delta_routed:
            return False
        if self.base.config.delta_route_min is not None:
            return True
        return nprobe * self._delta.post_width < self._delta.capacity

    # read-side duck typing with RairsIndex --------------------------------
    @property
    def config(self):
        return self.base.config

    @property
    def centroids(self):
        return self.base.centroids

    @property
    def codebook(self):
        return self.base.codebook

    @property
    def arrays(self):
        return self.base.arrays

    @property
    def seil_stats(self):
        return self.base.stats

    # RairsIndex exposes `.stats` as SeilStats; StreamingIndex.stats is the
    # mutation counter, so the layout stats keep their own accessor above.

    @property
    def needs_result_dedup(self) -> bool:
        return self.base.needs_result_dedup

    @property
    def result_oversample(self) -> int:
        return self.base.result_oversample

    def default_max_scan(self, nprobe: int, slack: float = 1.3) -> int:
        return self.base.default_max_scan(nprobe, slack)

    def plane(self, backend: str, codec=None):
        """The stream-level compact plane (DESIGN.md §12): delegates to
        the current base epoch but *pins the codec* across compactions —
        the first epoch trains it, every rebuilt base re-encodes its
        surviving corpus with the carried codec (deterministic, so the
        folded plane is bitwise what a reload would derive).  An
        explicit ``codec=`` (bundle restore) takes precedence."""
        if codec is None:
            codec = self._plane_codecs.get(backend)
        pp = self.base.plane(backend, codec=codec)
        self._plane_codecs[backend] = pp.codec
        return pp

    def _plane_delta_codes(self, backend: str) -> jnp.ndarray:
        """(capacity, Mc) uint8 compact codes over the delta buffer.

        Deliberately *unpacked*: the delta scan is a per-slot gather-ADC
        (stream/search.py), which composes with the plane codec's LUT
        as-is — nibble packing only pays inside the blocked base scan.
        Recomputed lazily per (version, capacity); the delta is small by
        construction, so the O(capacity) encode rides the mutation
        budget, never the steady-state query path."""
        key = (self.version, self._delta.capacity)
        hit = self._plane_delta.get(backend)
        if hit is not None and hit[0] == key:
            return hit[1]
        from ...quant import encode_plane
        codes = jnp.asarray(encode_plane(self.plane(backend).codec,
                                         self._delta.vectors))
        self._plane_delta[backend] = (key, codes)
        return codes

    @property
    def vectors(self) -> jnp.ndarray:
        """(n_total, D) id-aligned vector view (tombstoned rows included)."""
        d = self._delta
        if d.count == 0:
            return self.base.vectors
        return jnp.concatenate(
            [self.base.vectors, jnp.asarray(d.vectors[:d.count])], axis=0)

    @property
    def assigns(self) -> np.ndarray:
        """(n_total, m) id-aligned assignment view (analysis benches)."""
        d = self._delta
        if d.count == 0:
            return self.base.assigns
        return np.concatenate(
            [np.asarray(self.base.assigns), d.assigns[:d.count]], axis=0)

    @property
    def codes(self) -> Optional[np.ndarray]:
        """(n_total, M) id-aligned cached-PQ-code view (None only for a
        pre-code-cache base that was never mutated)."""
        d = self._delta
        base_codes = self.base.codes
        if d.count == 0:
            return base_codes
        if base_codes is None:   # pre-cache bundle: encode once, like compact
            base_codes = np.asarray(
                index_mod.pq_encode(self.base.codebook, self.base.vectors))
        return np.concatenate(
            [np.asarray(base_codes), d.codes[:d.count]], axis=0)

    def live_mask(self) -> np.ndarray:
        """(n_total,) host bool: True where the id is still live."""
        return np.concatenate(
            [self._base_live, self._delta.live[:self._delta.count]])

    def live_ids(self) -> np.ndarray:
        return np.nonzero(self.live_mask())[0].astype(np.int64)

    def live_vectors(self) -> jnp.ndarray:
        """(n_live, D) surviving vectors in id order (oracle / recall)."""
        d = self._delta
        host = np.concatenate(
            [np.asarray(self.base.vectors), d.vectors[:d.count]], axis=0)
        return jnp.asarray(host[self.live_mask()])

    # ------------------------------------------------------------------
    # device mirrors
    # ------------------------------------------------------------------
    def _device_state(self) -> _DeviceState:
        if self._dev is None:
            d = self._delta
            nb = self.n_base
            vec = np.concatenate(
                [np.asarray(self.base.vectors), d.vectors], axis=0)
            ids = np.full(d.capacity, -1, np.int32)
            used = np.arange(d.count)
            live_used = used[d.live[:d.count]]
            ids[live_used] = nb + live_used
            live_full = np.concatenate([self._base_live, d.live])
            self._dev = _DeviceState(
                vectors_full=jnp.asarray(vec),
                delta_codes=jnp.asarray(d.codes),
                delta_ids=jnp.asarray(ids),
                delta_post=jnp.asarray(d.post),
                delta_assigns=jnp.asarray(d.assigns),
                live_full=jnp.asarray(live_full),
                capacity=d.capacity)
        return self._dev

    # ------------------------------------------------------------------
    # mutations
    # ------------------------------------------------------------------
    def insert(self, x) -> np.ndarray:
        """Append vectors through the delta path; returns their global ids.

        O(batch): strategy-registry assignment + PQ encoding of the new
        rows and buffer patches — never a layout rebuild (asserted via
        ``seil.build_seil_call_count`` in tests and BENCH_stream.json).
        """
        x = np.asarray(x, np.float32)
        if x.ndim != 2 or x.shape[1] != self.base.vectors.shape[1]:
            raise ValueError(
                f"insert batch must be (B, {self.base.vectors.shape[1]}), "
                f"got {x.shape}")
        if x.shape[0] == 0:
            return np.zeros(0, np.int64)
        base = self.base
        xj = jnp.asarray(x)
        assigns = np.asarray(index_mod.compute_assignments(
            xj, base.centroids, base.config), np.int32)
        codes = np.asarray(index_mod.pq_encode(base.codebook, xj))
        nb = self.n_base
        slots, grew = self._delta.append(x, codes, assigns)
        ids = nb + slots
        # issue permanent external handles (identical to the internal id
        # at insert time; compaction remaps chain through _apply_remap)
        ext = np.arange(self._ext_to_int.size,
                        self._ext_to_int.size + ids.size, dtype=np.int64)
        self._ext_to_int = np.concatenate([self._ext_to_int, ids])
        self._int_to_ext = np.concatenate([self._int_to_ext, ext])
        if self._dev is not None and not grew:
            dv = self._dev
            s0 = int(slots[0])
            dv.vectors_full = jax.lax.dynamic_update_slice(
                dv.vectors_full, xj, (jnp.int32(nb + s0), jnp.int32(0)))
            dv.delta_codes = jax.lax.dynamic_update_slice(
                dv.delta_codes, jnp.asarray(codes),
                (jnp.int32(s0), jnp.int32(0)))
            dv.delta_ids = jax.lax.dynamic_update_slice(
                dv.delta_ids, jnp.asarray(ids, jnp.int32), (jnp.int32(s0),))
            dv.delta_assigns = jax.lax.dynamic_update_slice(
                dv.delta_assigns, jnp.asarray(assigns),
                (jnp.int32(s0), jnp.int32(0)))
            pl, pc, ps = self._delta.last_post_update
            if len(pl):
                dv.delta_post = dv.delta_post.at[
                    jnp.asarray(pl), jnp.asarray(pc)].set(
                    jnp.asarray(ps, jnp.int32))
            dv.live_full = jax.lax.dynamic_update_slice(
                dv.live_full, jnp.ones(len(slots), bool),
                (jnp.int32(nb + s0),))
        else:
            self._dev = None   # capacity/posting bucket jump: rebuild lazily
        self.version += 1
        self.stats.inserts += x.shape[0]
        epoch_before = self.epoch
        self._maybe_auto_compact()
        if self.epoch != epoch_before:
            # compaction renumbered the id space; the fresh inserts are
            # alive by construction, so the remap covers all of them
            ids = self.last_remap[ids]
        return ids

    def delete(self, ids) -> int:
        """Tombstone `ids` (base and/or delta); returns how many were
        live until now.  Dead/duplicate ids are a no-op; out-of-range
        ids raise.  O(batch): bitmap scatter, no layout rewrite."""
        ids = np.unique(np.asarray(ids, np.int64).ravel())
        if ids.size == 0:
            return 0
        if ids[0] < 0 or ids[-1] >= self.n_total:
            raise ValueError(
                f"delete ids out of range [0, {self.n_total})")
        nb = self.n_base
        bids = ids[ids < nb]
        dslots = ids[ids >= nb] - nb
        newly_base = int(self._base_live[bids].sum())
        newly = newly_base + int(self._delta.live[dslots].sum())
        if newly == 0:
            return 0        # idempotent retry: nothing changed, nothing stales
        self._base_live[bids] = False
        self._dead_base += newly_base
        self._delta.mark_dead(dslots)
        if self._dev is not None:
            dv = self._dev
            dv.live_full = dv.live_full.at[jnp.asarray(ids)].set(False)
            if dslots.size:
                dv.delta_ids = dv.delta_ids.at[jnp.asarray(dslots)].set(-1)
        self.version += 1
        self.stats.deletes += newly
        self._maybe_auto_compact()
        return newly

    def compact(self, reason: str = "manual") -> dict:
        """Fold delta + tombstones into a fresh base epoch.

        Survivors keep their relative (id) order — base first, then delta
        — and are renumbered densely, so the new base is exactly what
        ``build_index`` would produce over the surviving corpus with the
        same frozen centroids/codebook (asserted in tests/test_stream.py).
        ``last_remap[old_id] -> new_id`` (-1 = deleted) records the
        renumbering; every open session becomes stale.
        """
        if self._pending_compact is not None:
            raise RuntimeError(
                "a background compaction is pending (begin_compact); "
                "install() or abort() it before compacting synchronously")
        t0 = time.perf_counter()
        d = self._delta
        new_base, remap, t_layout = _fold_epoch(
            self.base, self._base_live, d.vectors[:d.count],
            d.codes[:d.count], d.assigns[:d.count], d.live[:d.count])
        n = int((remap >= 0).sum())
        self.base = new_base
        self.epoch += 1
        self.version += 1
        self.stats.compactions += 1
        self._retire_sessions()
        self._reset_epoch_state()
        self._apply_remap(remap)
        return {"epoch": self.epoch, "reason": reason, "n_live": n,
                "dropped": int(remap.size - n),
                "seconds": time.perf_counter() - t0,
                "layout_seconds": t_layout, "id_remap": remap}

    def begin_compact(self, reason: str = "background") -> PendingCompaction:
        """Start a zero-downtime compaction: snapshot this epoch and
        return a ``PendingCompaction`` whose ``fold()`` can run on a
        worker thread while searches and mutations keep flowing, and
        whose ``install()`` swaps the new epoch in atomically (replaying
        the post-snapshot mutation tail).  Only one may be pending;
        threshold auto-compaction stands down while it is."""
        if self._pending_compact is not None:
            raise RuntimeError("a background compaction is already pending")
        p = PendingCompaction(self, reason)
        self._pending_compact = p
        return p

    # ------------------------------------------------------------------
    # stable external ids (survive compaction renumbering)
    # ------------------------------------------------------------------
    def _apply_remap(self, remap: np.ndarray) -> None:
        """Record a compaction renumbering and chain it into the
        composed external-id map (external handles never change)."""
        self.last_remap = remap
        e2i = self._ext_to_int
        valid = e2i >= 0
        nxt = np.full(e2i.shape, -1, np.int64)
        nxt[valid] = remap[e2i[valid]]
        self._ext_to_int = nxt
        i2e = np.full(self.n_total, -1, np.int64)
        ext = np.nonzero(nxt >= 0)[0]
        i2e[nxt[ext]] = ext
        self._int_to_ext = i2e

    def resolve_ids(self, external_ids) -> np.ndarray:
        """Map stable external handles (gateway responses,
        ``external_ids``) to current internal ids; -1 for handles that
        were deleted or never issued.  Handles survive any number of
        compactions — the map chains every ``last_remap``."""
        e = np.asarray(external_ids, np.int64)
        flat = e.ravel()
        out = np.full(flat.shape, -1, np.int64)
        ok = (flat >= 0) & (flat < self._ext_to_int.size)
        ints = self._ext_to_int[flat[ok]]
        live = self.live_mask()
        out[ok] = np.where(
            (ints >= 0) & live[np.clip(ints, 0, live.size - 1)], ints, -1)
        return out.reshape(e.shape)

    def external_ids(self, internal_ids) -> np.ndarray:
        """Map current internal ids (e.g. ``SearchResult.ids``) to their
        stable external handles; -1 pads pass through."""
        i = np.asarray(internal_ids, np.int64)
        flat = i.ravel()
        out = np.full(flat.shape, -1, np.int64)
        ok = (flat >= 0) & (flat < self._int_to_ext.size)
        out[ok] = self._int_to_ext[flat[ok]]
        return out.reshape(i.shape)

    def _apply_tombstones(self, ids: np.ndarray) -> None:
        """Install-time tombstone scatter: no version bump, stats, or
        auto-compaction — the replayed deletes were already counted when
        the caller issued them (``PendingCompaction.install``)."""
        ids = np.asarray(ids, np.int64).ravel()
        ids = np.unique(ids[ids >= 0])
        if ids.size == 0:
            return
        nb = self.n_base
        bids = ids[ids < nb]
        dslots = ids[ids >= nb] - nb
        self._dead_base += int(self._base_live[bids].sum())
        self._base_live[bids] = False
        self._delta.mark_dead(dslots)
        if self._dev is not None:
            dv = self._dev
            dv.live_full = dv.live_full.at[jnp.asarray(ids)].set(False)
            if dslots.size:
                dv.delta_ids = dv.delta_ids.at[jnp.asarray(dslots)].set(-1)

    def restore_state(self, *, epoch: int, version: int,
                      base_live: np.ndarray, delta_vectors: np.ndarray,
                      delta_codes: np.ndarray, delta_assigns: np.ndarray,
                      delta_live: np.ndarray) -> None:
        """Rehydrate persisted epoch state (bundle v2 load, core/io.py)
        into a freshly wrapped base — exact codes/assigns/liveness are
        restored, nothing is recomputed.  Only valid before any
        mutation."""
        if self.version != 0 or self._delta.count != 0:
            raise RuntimeError("restore_state requires a pristine "
                               "StreamingIndex")
        if delta_vectors.shape[0]:
            self._delta.append(delta_vectors, delta_codes, delta_assigns)
            self._delta.mark_dead(np.nonzero(~delta_live)[0])
        if base_live.shape[0] != self.n_base:
            raise ValueError(
                f"base_live has {base_live.shape[0]} bits for a base of "
                f"{self.n_base} vectors")
        self._base_live[:] = base_live
        self._dead_base = int((~base_live).sum())
        self._dev = None
        self.epoch = int(epoch)
        self.version = int(version)
        # external-id state is not persisted (v2 bundles predate it): a
        # restored stream re-issues identity handles over its id space
        self._ext_to_int = np.arange(self.n_total, dtype=np.int64)
        self._int_to_ext = np.arange(self.n_total, dtype=np.int64)

    def _maybe_auto_compact(self):
        if self._pending_compact is not None:
            return      # the background fold owns this epoch's compaction
        sc = self.stream_config
        if (sc.compact_delta_frac is not None
                and self._delta.count > sc.compact_delta_frac
                * max(1, self.n_base)):
            self.stats.auto_compactions += 1
            self.compact(reason="delta_threshold")
        elif (sc.compact_dead_frac is not None
                and self.n_dead > sc.compact_dead_frac
                * max(1, self.n_total)):
            self.stats.auto_compactions += 1
            self.compact(reason="dead_threshold")

    def shard(self, mesh, axes=("data",), max_scan_local=None):
        """Deploy this mutable index over `mesh` as a ``ShardedIndex``
        (core/sharded.py): the base epoch shards by block/vector range,
        the delta segment and tombstone mask replicate (the delta is
        tiny by construction), and compaction re-shards the fresh base
        lazily.  Mutations keep flowing through this StreamingIndex
        (the sharded view forwards insert/delete/compact); sessions on
        the mesh pin (epoch, version) exactly like single-host ones.
        Cached per (mesh, axes, max_scan_local)."""
        from ..sharded import shard_index
        return shard_index(self, mesh, axes=axes,
                           max_scan_local=max_scan_local)

    # ------------------------------------------------------------------
    # sessions
    # ------------------------------------------------------------------
    def searcher(self, params: Optional[SearchParams] = None,
                 **kwargs) -> "StreamingSearcher":
        """Create (or fetch) a session pinned to the current version.

        A cached session is returned only while the index has not
        mutated past it; otherwise its stats are folded into the
        aggregate, it is dropped as stale, and a fresh session — sharing
        this stream's compiled-executable cache — replaces it.
        """
        if params is None:
            params = SearchParams(**kwargs)
        elif kwargs:
            params = dataclasses.replace(params, **kwargs)
        sess = self._sessions.get(params)
        if sess is not None and sess.version == self.version:
            return sess
        if sess is not None:
            self._fold_session(sess)
            self.stats.invalidations += 1
        sess = StreamingSearcher(self, params)
        self._sessions[params] = sess
        self.stats.sessions += 1
        return sess

    def search(self, queries: jnp.ndarray, k: int, nprobe: int,
               k_factor: int = 10, max_scan: Optional[int] = None,
               use_kernel: bool = False, exec_mode: str = "paged",
               query_tile: int = 8) -> SearchResult:
        """Convenience kwarg path mirroring ``RairsIndex.search`` —
        always dispatches through a current (never stale) session."""
        return self.searcher(SearchParams(
            k=k, nprobe=nprobe, k_factor=k_factor, max_scan=max_scan,
            use_kernel=use_kernel, exec_mode=exec_mode,
            query_tile=query_tile))(queries)

    def _fold_session(self, sess: "Searcher"):
        for key, v in sess.stats.as_dict().items():
            self._retired[key] = self._retired.get(key, 0) + v

    def _retire_sessions(self):
        for sess in self._sessions.values():
            self._fold_session(sess)
        self._sessions.clear()

    def searcher_stats(self) -> dict:
        """Aggregate compile-cache stats over live + retired sessions,
        extending the RairsIndex accessor with mutation/epoch fields."""
        live = list(self._sessions.values())
        out = {
            "sessions": self.stats.sessions,
            "invalidations": self.stats.invalidations,
            "epoch": self.epoch,
            "version": self.version,
        }
        for key in ("compiles", "cache_hits"):
            out[key] = (self._retired.get(key, 0)
                        + sum(getattr(s.stats, key) for s in live))
        out["base"] = self.base.searcher_stats()
        return out


class StreamingSearcher(Searcher):
    """An (epoch, version)-pinned session over a ``StreamingIndex``.

    A pristine epoch (no mutations yet) delegates to the wrapped base
    index's own session, so an unmutated ``StreamingIndex`` searches
    bitwise-identically to its ``RairsIndex``.  Once mutated, the
    session dispatches ``streaming_search`` (base stages + exhaustive
    delta scan + tombstone mask) per batch bucket; executables live in a
    stream-level cache keyed by (params, delta capacity), so the session
    churn caused by version pinning never recompiles.
    """

    def __init__(self, stream: StreamingIndex, params: SearchParams):
        self.stream = stream
        self.version = stream.version
        ap = params.active_plane
        if ap is not None:
            # pin the carried codec on the base's plane cache *before*
            # Searcher.__init__ resolves it, so a post-compaction epoch
            # re-encodes with the stream's codec instead of retraining
            stream.plane(ap)
        super().__init__(stream.base, params)
        self.epoch = stream.epoch
        # pinned at session creation: a mutation that changes the answer
        # also bumps the version, which stales the session anyway
        self._route_delta = stream.routes_at(self.params.nprobe)
        if stream.has_mutations:
            self._delegate = None
            # executables depend on (params, delta shapes) only.  The
            # posting width joins the key only once this session routes
            # (the routed gather width is a compiled shape); on the
            # exhaustive path the posting map is replaced by a
            # zero-width placeholder, so steady-state appends growing
            # the postings never recompile the exhaustive executables.
            post_w = stream._delta.post_width if self._route_delta else 0
            self._compiled = stream._exec_cache.setdefault(
                (self.params, stream._delta.capacity, post_w), {})
        else:
            self._delegate = stream.base.searcher(params)

    def _probe_exe_store(self) -> dict:
        """Probe-half executables consume only base arrays — share them
        across delta capacity/posting bucket jumps (same epoch)."""
        return self.stream._probe_cache.setdefault(self.params, {})

    def _check_current(self):
        st = self.stream
        if self.version != st.version:
            raise StaleSessionError(
                f"searcher session pinned (epoch {self.epoch}, version "
                f"{self.version}) but the StreamingIndex is at (epoch "
                f"{st.epoch}, version {st.version}); mutations invalidate "
                f"sessions — re-fetch via stream.searcher(params)")

    def _stream_state(self) -> tuple:
        """Streaming analogue of ``Searcher._scan_state``: when a refine
        tier is active, substitute the plane-packed base block codes,
        the plane codec (LUT source), and the plane's *unpacked* delta
        codes — the delta gather-ADC and the blocked base scan then both
        score tier-1 distances against the same codec, and tier-2 stays
        the shared exact finalize over ``vectors_full``."""
        idx = self.stream.base
        dev = self.stream._device_state()
        if self._plane is None:
            return idx.arrays, idx.codebook, dev.delta_codes, False
        return (dataclasses.replace(idx.arrays,
                                    block_codes=self._plane.block_codes),
                self._plane.codec,
                self.stream._plane_delta_codes(self._plane.backend),
                True)

    def _lower(self, bucket: int):
        p = self.params
        idx = self.stream.base
        dev = self.stream._device_state()
        arrays, codebook, delta_codes, packed = self._stream_state()
        q_spec = jax.ShapeDtypeStruct(
            (bucket, idx.vectors.shape[1]), jnp.float32)
        return streaming_search.lower(
            arrays, idx.centroids, codebook, dev.vectors_full,
            delta_codes, dev.delta_ids, self._post_arg(dev),
            dev.delta_assigns, dev.live_full, q_spec,
            nprobe=p.nprobe, bigk=p.bigk_eff, k=p.k, max_scan=p.max_scan,
            metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            use_kernel=p.use_kernel, oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile,
            route_delta=self._route_delta, fused_topk=p.fused_topk,
            packed_codes=packed)

    def _dispatch_traced(self, bucket: int, qc):
        """Stage-fenced streaming dispatch (repro/obs/): the base stage
        programs plus a separate delta-scan span, so a trace shows the
        delta-vs-base DCO split directly.  A pristine session never
        reaches this — ``__call__`` delegates to the base session."""
        p = self.params
        idx = self.stream.base
        dev = self.stream._device_state()
        arrays, codebook, delta_codes, packed = self._stream_state()
        return streaming_search_traced(
            arrays, idx.centroids, codebook, dev.vectors_full,
            delta_codes, dev.delta_ids, self._post_arg(dev),
            dev.delta_assigns, dev.live_full, qc,
            nprobe=p.nprobe, bigk=p.bigk_eff, k=p.k, max_scan=p.max_scan,
            metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            use_kernel=p.use_kernel, oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile,
            route_delta=self._route_delta, fused_topk=p.fused_topk,
            packed_codes=packed)

    def _post_arg(self, dev) -> jnp.ndarray:
        """The posting-map argument: real directory when routed, a
        zero-width placeholder otherwise (keeps exhaustive-path
        executable signatures independent of posting growth)."""
        if self._route_delta:
            return dev.delta_post
        return jnp.zeros((self.stream.base.config.nlist, 0), jnp.int32)

    def _call_inputs(self) -> tuple:
        idx = self.stream.base
        dev = self.stream._device_state()
        arrays, codebook, delta_codes, _ = self._stream_state()
        return (arrays, idx.centroids, codebook, dev.vectors_full,
                delta_codes, dev.delta_ids, self._post_arg(dev),
                dev.delta_assigns, dev.live_full)

    # -- incremental-plan hooks: the probe half is the base index's own
    # (inherited — self.index IS stream.base), only the scan half swaps
    # in the streaming tail (delta merge + tombstones) ------------------
    def _lower_scan(self, bucket: int, probe_spec, unions_spec):
        p = self.params
        idx = self.stream.base
        dev = self.stream._device_state()
        arrays, _, delta_codes, packed = self._stream_state()
        q_spec = jax.ShapeDtypeStruct(
            (bucket, idx.vectors.shape[1]), jnp.float32)
        return scan_finalize_stream.lower(
            arrays, dev.vectors_full, delta_codes, dev.delta_ids,
            self._post_arg(dev), dev.delta_assigns, dev.live_full, q_spec,
            probe_spec, unions_spec,
            bigk=p.bigk_eff, k=p.k, metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            use_kernel=p.use_kernel, oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile,
            route_delta=self._route_delta, fused_topk=p.fused_topk,
            packed_codes=packed)

    def _scan_inputs(self) -> tuple:
        idx = self.stream.base
        dev = self.stream._device_state()
        arrays, _, delta_codes, _ = self._stream_state()
        return (arrays, dev.vectors_full, delta_codes,
                dev.delta_ids, self._post_arg(dev), dev.delta_assigns,
                dev.live_full)

    def __call__(self, queries: jnp.ndarray) -> SearchResult:
        if self._delegate is not None:
            self._check_current()
            return self._delegate(queries)
        return super().__call__(queries)

    search = __call__
