"""Stage 2 — candidate block gathering, cell-level dedup, compaction.

This is the vectorized form of Alg. 5's ``listVisited`` probe: a
reference (or home shared) block is skipped iff the cell's other list
was scanned at an earlier probe rank.  The surviving candidates are
compacted to a static scan budget, preserving owned -> refs -> misc
order (each rank-ascending), so downstream shapes are jit-static.

``plan_blocks`` optionally windows the candidate set to a contiguous
physical block range and rebases ids — that is the whole difference
between the single-host and the shard_map execution of the pipeline.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .types import BIG, ListSelection, ListTables, QueryPlan


def gather_candidates(tables: ListTables, selection: ListSelection
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query candidate block ids + scan ranks, after cell-level dedup.

    Returns (cand, cand_rank), both (B, P*(MO+MR+MM)); skipped / padded
    entries are -1 in ``cand``.
    """
    sel, rank_of = selection.sel, selection.rank_of
    bq, nprobe = sel.shape
    owned = tables.owned[sel]                      # (B, P, MO)
    owned_other = tables.owned_other[sel]
    refs = tables.refs[sel]                        # (B, P, MR)
    refs_other = tables.refs_other[sel]
    misc = tables.misc[sel]                        # (B, P, MM)
    t = jnp.arange(nprobe, dtype=jnp.int32)[None, :, None]

    def visited_earlier(other_list):
        r = jnp.take_along_axis(
            rank_of, jnp.maximum(other_list, 0).reshape(bq, -1), axis=1
        ).reshape(other_list.shape)
        return (other_list >= 0) & (r < t)

    # reference entries: skip if the home list was scanned earlier (Alg. 5 L7)
    refs = jnp.where(visited_earlier(refs_other), -1, refs)
    # home shared blocks: skip if the co-assigned list was scanned earlier —
    # its reference entry already computed this cell.  (Alg. 5's pseudocode
    # only checks the ref->home direction and would re-compute the cell when
    # the referencing list is probed first; we implement the stated
    # cell-level compute-once semantics in both directions. See DESIGN.md.)
    owned = jnp.where(visited_earlier(owned_other), -1, owned)

    def flat(tbl):
        return tbl.reshape(bq, -1)
    cand = jnp.concatenate([flat(owned), flat(refs), flat(misc)], axis=1)
    cand_rank = jnp.concatenate([
        flat(jnp.broadcast_to(t, owned.shape)),
        flat(jnp.broadcast_to(t, refs.shape)),
        flat(jnp.broadcast_to(t, misc.shape))], axis=1)
    return cand, cand_rank


def compact_plan(cand: jnp.ndarray, cand_rank: jnp.ndarray, max_scan: int
                 ) -> QueryPlan:
    """Stable compaction of valid candidates to a static budget: valid
    blocks first, preserving position order (positions already run
    owned -> refs -> misc, each rank-ascending)."""
    max_scan = min(max_scan, cand.shape[1])    # static shapes; safe under jit
    valid = cand >= 0
    n_valid = jnp.sum(valid, axis=1).astype(jnp.int32)
    dropped = jnp.maximum(n_valid - max_scan, 0).astype(jnp.int32)
    pos = jnp.arange(cand.shape[1], dtype=jnp.int32)
    key = jnp.where(valid, BIG - pos, -1 - pos)
    _, take = jax.lax.top_k(key, max_scan)
    blocks = jnp.take_along_axis(cand, take, axis=1)        # (B, S)
    ranks = jnp.take_along_axis(cand_rank, take, axis=1)    # (B, S)
    bvalid = jnp.take_along_axis(valid, take, axis=1)
    return QueryPlan(blocks=jnp.maximum(blocks, 0), ranks=ranks,
                     valid=bvalid, dropped=dropped)


def plan_blocks(tables: ListTables, selection: ListSelection, *,
                max_scan: int, local_lo: Optional[jnp.ndarray] = None,
                local_count: Optional[int] = None) -> QueryPlan:
    """Gather + dedup + compact.  With ``local_lo``/``local_count`` the
    candidate set is windowed to physical blocks [lo, lo+count) and ids
    are rebased to the local store (the shard_map path)."""
    cand, cand_rank = gather_candidates(tables, selection)
    if local_lo is not None:
        rel = cand - local_lo
        mine = (cand >= 0) & (rel >= 0) & (rel < local_count)
        cand = jnp.where(mine, rel, -1)
    return compact_plan(cand, cand_rank, max_scan)
