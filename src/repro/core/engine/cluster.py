"""Query-tile clustering + tile unions — the locality-aware planner core.

The paper's §5.3 throughput win comes from fetching each shared cell
once and scoring it for many queries while resident.  The batch-union
form (``exec_mode="grouped"``) realizes that over the *whole* batch:
one stray query inflates every tile's union, and the B x U redundant
compute eats the win (DESIGN.md §5 cost model).  This module shrinks
the union toward each tile's own working set:

* ``cluster_order`` buckets the batch by probed-list overlap — a greedy
  prefix clustering of the ranked probe signature (queries sharing the
  longest ranked-probe prefix are co-tiled), implemented as a stable
  lexicographic sort over the first ``CLUSTER_DEPTH`` probe ranks so it
  is jittable and deterministic: equal signatures keep their original
  batch order, which makes the permutation reproducible across runs and
  replicas (the shard_map serve step runs it replicated).
* ``tile_unions`` builds one sorted, duplicate-free block union per
  query tile (static width ``min(tile * S, TB)``), so the clustered
  scan pays ``B x U_tile`` instead of ``B x U_batch``.
* ``merge_unions_host`` / ``plan_width`` implement the *incremental*
  side (host-side numpy, driven by ``Searcher``): adjacent serving
  batches probing overlapping lists reuse the previous unions (hit),
  extend them while they stay tight (extend), or replace them (miss) —
  and the scan executable is dispatched at the smallest geometric width
  bucket covering the live entries, so steady-state skewed traffic
  scans tight unions instead of the worst-case static width.
* ``tile_signatures`` names each tile by *what it probes* (its leading
  probed list + run index) instead of its position in the batch, so the
  plan cache survives tile-boundary shifts: when the popularity mix
  moves a hot query group from tile 3 to tile 4 between batches, the
  group still finds the union cached under its own hot list.

Correctness invariant (asserted in tests/test_plan.py): every valid
planned block of a query is contained in its tile's union, so the
sorted-union ``searchsorted`` scatter recovers exactly the paged
distances — clustering, reuse, and width bucketing never change
results, only the access schedule.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .types import BIG

# probe ranks participating in the cluster signature: deep enough to
# separate working sets, shallow enough to keep the sort key tiny
CLUSTER_DEPTH = 4

# incremental-plan cache tightness: a cached union may outgrow the
# batch's own working set by at most this factor (x own live entries)
# before it is rebuilt — unbounded extension would creep the scanned
# width toward the static worst case and forfeit the clustering win
EXTEND_SLACK = 2.0
_MIN_UNION = 32


def fit_tile(b: int, query_tile: int) -> int:
    """Largest tile size <= query_tile that divides the batch."""
    qt = max(1, min(query_tile, b))
    while b % qt:
        qt -= 1
    return qt


def union_dims(b: int, s: int, total_blocks: int, exec_mode: str,
               query_tile: int) -> Tuple[int, int]:
    """Static (n_tiles, width) of the union tensor for one batch shape.

    grouped:   one batch-wide union, width min(B*S, TB);
    clustered: one union per query tile, width min(tile*S, TB).
    """
    if exec_mode == "grouped":
        return 1, min(b * s, total_blocks)
    qt = fit_tile(b, query_tile)
    return b // qt, min(qt * s, total_blocks)


def cluster_order(sel: jnp.ndarray) -> jnp.ndarray:
    """Stable locality permutation of the batch from its probe signature.

    sel (B, P) ranked probed lists -> perm (B,) such that queries with
    equal probe-rank prefixes are adjacent (greedy prefix clustering).
    Stable: ties keep original batch order.  Jittable (one lexsort).
    """
    depth = min(CLUSTER_DEPTH, sel.shape[1])
    # jnp.lexsort is stable; last key is primary -> rank-0 list dominates
    return jnp.lexsort(tuple(sel[:, d] for d in reversed(range(depth)))
                       ).astype(jnp.int32)


def tile_unions(blocks: jnp.ndarray, valid: jnp.ndarray, n_tiles: int,
                width: int) -> jnp.ndarray:
    """Per-tile sorted unions of valid planned blocks.

    blocks/valid (B, S) (already in cluster order) -> (n_tiles, width)
    ascending unique block ids, BIG-padded.  ``width`` must be
    >= min(tile*S, TB) so no valid block can be dropped.
    """
    b, s = blocks.shape
    allb = jnp.where(valid, blocks, BIG).reshape(n_tiles, (b // n_tiles) * s)
    srt = jnp.sort(allb, axis=1)
    first = jnp.concatenate(
        [jnp.ones((n_tiles, 1), bool), srt[:, 1:] != srt[:, :-1]], axis=1)
    uniq = jnp.where(first & (srt < BIG), srt, BIG)
    return jnp.sort(uniq, axis=1)[:, :width]


def union_live(unions: np.ndarray) -> np.ndarray:
    """(T, W) BIG-padded unions -> (T,) live entry counts (host or jnp)."""
    return (unions < int(BIG)).sum(axis=1)


def plan_width(live_max: int, width_cap: int) -> int:
    """Smallest width bucket covering ``live_max`` entries (the scan
    executable's dispatch width), capped at the static worst case.
    Buckets grow geometrically by 1.5x: fine enough that the scanned
    width tracks the traffic's working set (a power-of-two ladder can
    overshoot by 2x, which is the whole clustering margin), coarse
    enough that the executable set stays small and bounded."""
    w = _MIN_UNION
    while w < live_max:
        w = w * 3 // 2
    return min(w, width_cap)


def width_buckets(width_cap: int) -> list:
    """Every dispatch width ``plan_width`` can produce for one static
    ``width_cap`` — the 1.5x geometric ladder clipped to the cap.  The
    gateway pre-compiles a scan executable per bucket at startup
    (``Searcher.warmup_widths``) so a cold start or epoch swap never
    pays compile latency on the serving path."""
    out = set()
    w = _MIN_UNION
    while w < width_cap:
        out.add(w)
        w = w * 3 // 2
    out.add(width_cap)
    return sorted(out)


def tile_signatures(lead_lists: np.ndarray, deep=None) -> list:
    """Stable identity keys for a batch's tiles, from the rank-0 probed
    list of each tile's first query (in cluster order).

    A tile is named ``(lead list, run index)`` — the run index separates
    consecutive tiles anchored on the same hot list.  Position-keyed
    caches die the moment popularity drift moves a tile boundary; these
    keys follow the working set instead (``Searcher`` keys its plan
    cache with them).

    ``deep`` (T, P) — the full ranked probe row of each tile-lead query
    — widens the key with the probe prefix beyond the lead (ranks
    1..CLUSTER_DEPTH-1): at large nprobe many tiles anchor on the same
    hot list, and a lead-only key then separates them only by run index
    — which is positional, so drift reshuffles their cached unions into
    each other.  The deep key ``(lead, prefix, run)`` weights the tile
    identity by probed-list overlap instead; distinct working sets
    sharing a lead stop colliding and the hit rate stops collapsing as
    nprobe outgrows the lead-rank window (reported per dispatch as
    ``sig_deep_split`` in ``compile_stats()["plan"]``).
    """
    leads = np.asarray(lead_lists).tolist()
    if deep is not None:
        d = np.asarray(deep)
        depth = min(CLUSTER_DEPTH, d.shape[1])
        fps = [tuple(r) for r in d[:, 1:depth].tolist()]
        sig = []
        run = 0
        for i, key in enumerate(zip(leads, fps)):
            run = run + 1 if i and key == sig[-1][:2] else 0
            sig.append((key[0], key[1], run))
        return sig
    sig = []
    run = 0
    for i, lst in enumerate(leads):
        run = run + 1 if i and lst == sig[-1][0] else 0
        sig.append((lst, run))
    return sig


def merge_unions_host(cached: Optional[np.ndarray], own: np.ndarray,
                      present: Optional[np.ndarray] = None
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Incremental-plan merge (host-side numpy, per dispatch bucket).

    cached/own: (T, W) sorted BIG-padded unions.  Per tile:
      * hit    — own ⊆ cached and the cache is still *tight* (within
        ``EXTEND_SLACK`` x this batch's own working set): reuse it;
      * extend — merged live entries fit both the width and the
        tightness bound: the cache grows;
      * miss   — cold cache, width overflow, or a cache that bloated
        past the tightness bound: replace with this batch's own union.
    ``present`` masks rows that actually had a cached union (signature-
    keyed callers align a ragged cache into (T, W) with BIG-filled rows
    for first-seen tiles; those must classify as misses, not extends).
    The tightness bound is what keeps the scanned width tracking the
    traffic instead of creeping toward the static worst case under
    drift.  Returns ``(used, hit, extend)`` with used (T, W) the unions
    to scan *and* cache; every path keeps own ⊆ used, the correctness
    invariant.
    """
    t, w = own.shape
    big = int(BIG)
    if cached is None:
        return own, np.zeros(t, bool), np.zeros(t, bool)
    cat = np.concatenate([cached, own], axis=1)
    srt = np.sort(cat, axis=1)
    keep = srt < big
    keep[:, 1:] &= srt[:, 1:] != srt[:, :-1]
    live_merged = keep.sum(axis=1)
    tight = live_merged <= np.maximum(
        (union_live(own) * EXTEND_SLACK).astype(np.int64), _MIN_UNION)
    hit = (live_merged == union_live(cached)) & tight  # own added nothing
    fits = (live_merged <= w) & tight
    if present is not None:
        hit &= present
        fits &= present
    merged = np.full((t, w), big, srt.dtype)
    rows = np.nonzero(keep)[0]
    cols = (np.cumsum(keep, axis=1) - 1)[keep]
    sel = cols < w                                    # overflow rows ignored
    merged[rows[sel], cols[sel]] = srt[keep][sel]
    used = np.where(hit[:, None], cached,
                    np.where(fits[:, None], merged, own))
    return used, hit, fits & ~hit
