"""Staged ANN query engine (DESIGN.md §5).

Composable, individually-jittable stages over static-shape pytrees:

    select_lists  -> ListSelection   centroid top-nprobe
    plan_blocks   -> QueryPlan       cell-level dedup + budget compaction
    scan_blocks   -> ScanOut         ADC scan, exec_mode "paged"|"grouped"
    finalize_candidates              top-bigK + id-dedup + exact refine

``core/search.py`` (single host) and ``core/distributed.py`` (the
shard_map serve step behind ``core/sharded.py``) are thin compositions
of these stages; they differ only in which ``BlockStore`` they scan and
in the plan's block-range window.
"""
from .cluster import (cluster_order, fit_tile, merge_unions_host,  # noqa: F401
                      plan_width, tile_signatures, tile_unions, union_dims,
                      union_live, width_buckets)
from .finalize import finalize_candidates, preselect_candidates  # noqa: F401
from .fused import plan_slot_maps, scan_blocks_topk  # noqa: F401
from .plan import compact_plan, gather_candidates, plan_blocks  # noqa: F401
from .scan import EXEC_MODES, batch_union, scan_blocks  # noqa: F401
from .select import rank_table, select_lists  # noqa: F401
from .types import (BIG, BlockStore, ListSelection, ListTables,  # noqa: F401
                    PlanProbe, QueryPlan, ScanOut, store_from_arrays,
                    tables_from_arrays)
