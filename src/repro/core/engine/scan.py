"""Stage 3 — ADC scan of the planned blocks, in one of two exec modes.

``paged``   : every query pages its own scan list — one (block, query)
              fetch per plan entry.  This is the classic per-query IVF
              scan (kernel: ``pq_scan_paged`` with query_tile=1).

``grouped`` : the paper's §5.3 cache optimization ("group tasks by
              list"), batch-union form.  The union of all blocks planned
              by *any* query in the batch is materialized once, sorted
              by physical block id; each union block is fetched once per
              query tile and scored for the whole tile while resident
              (kernel: ``pq_scan_grouped``).  Per-query distances are
              then scattered back into the plan layout via a sorted-
              union ``searchsorted``, so everything downstream —
              item masks, DCO counters, top-K — is byte-for-byte the
              same computation as paged mode.  HBM traffic drops from
              sum_q |plan_q| block fetches to |union_batch| * ceil(B/QT);
              logical DCO accounting is unchanged by construction.

``clustered``: the locality-aware refinement of grouped (engine/
              cluster.py).  Queries are permuted into probe-overlap
              order (stable signature sort), the union is built *per
              query tile* instead of per batch, each tile scans only
              its own working set (kernel: ``pq_scan_tiled``), and the
              per-query distances are scattered back through the same
              sorted-union ``searchsorted`` and un-permuted.  The
              redundant-compute term shrinks from B x U_batch to
              B x U_tile; on skewed traffic U_tile -> |plan_q| and the
              mode matches paged compute while keeping grouped's
              amortized fetches.  Callers holding incremental plans
              (core/searcher.py) pass ``perm``/``unions`` explicitly —
              possibly width-bucketed and extended with a previous
              batch's unions — otherwise both are derived here.

The union budget is ``min(B*S, TB)`` (``min(tile*S, TB)`` per clustered
tile) — an upper bound on the number of distinct planned blocks — so
neither mode can drop a block the paged plan would scan: results are
bitwise identical (asserted in tests/test_engine.py, tests/test_plan.py).

Item-level masks (shared by both modes): invalid slots, and misc items
whose co-assigned list was scanned at an earlier rank (their cell was
already computed — Alg. 5 L15-16; the DCO is still counted, SEIL cannot
avoid computing a misc duplicate before discarding it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels.ref import adc_gather as _adc_gather
from ...quant.nibbles import unpack_nibbles
from .cluster import cluster_order, fit_tile, tile_unions, union_dims
from .types import BIG, BlockStore, QueryPlan, ScanOut

EXEC_MODES = ("paged", "grouped", "clustered")


def _codes_for(codes: jnp.ndarray, m: int, packed: bool) -> jnp.ndarray:
    """Gathered code tiles -> scannable codes.  A packed store (quant
    plane: two 4-bit codes per byte) is unpacked in-register to the LUT
    width ``m`` right after the gather; full-width stores pass through."""
    return unpack_nibbles(codes, m) if packed else codes


def batch_union(plan: QueryPlan, total_blocks: int) -> jnp.ndarray:
    """Sorted union of all valid planned block ids across the batch,
    padded with BIG.  Static width min(B*S, TB) >= |union| always.
    The one-tile case of ``tile_unions`` — shared so the monolithic
    grouped scan and the plan_reuse probe half can never diverge."""
    b, s = plan.blocks.shape
    u = min(b * s, total_blocks)
    return tile_unions(plan.blocks, plan.valid, 1, u)[0]


def _scan_paged(store: BlockStore, plan: QueryPlan, lut, use_kernel: bool,
                packed: bool = False):
    if use_kernel:
        from ...kernels.ops import pq_scan_paged
        return pq_scan_paged(lut, store.block_codes, plan.blocks,
                             packed=packed)
    codes = _codes_for(store.block_codes[plan.blocks], lut.shape[1],
                       packed)                     # (B, S, BLK, M)
    return _adc_gather(lut, codes)


def _scan_grouped(store: BlockStore, plan: QueryPlan, lut,
                  use_kernel: bool, query_tile: int, union=None,
                  packed: bool = False):
    b, s = plan.blocks.shape
    if union is None:
        union = batch_union(plan, store.block_codes.shape[0])   # (U,)
    safe_union = jnp.where(union < BIG, union, 0)
    if use_kernel:
        from ...kernels.ops import pq_scan_grouped
        qt = fit_tile(b, query_tile)
        dists_u = pq_scan_grouped(lut, store.block_codes, safe_union,
                                  query_tile=qt,
                                  packed=packed)             # (B, U, BLK)
    else:
        codes_u = _codes_for(store.block_codes[safe_union], lut.shape[1],
                             packed)                        # (U, BLK, M)
        dists_u = _adc_gather(
            lut, jnp.broadcast_to(codes_u[None], (b,) + codes_u.shape))
    # scatter back to the plan layout: every valid plan block is in the
    # sorted union, so searchsorted finds its exact position
    pos = jnp.searchsorted(union, plan.blocks.reshape(-1)).reshape(b, s)
    pos = jnp.minimum(pos, union.shape[0] - 1)
    return jnp.take_along_axis(dists_u, pos[:, :, None], axis=1)


def _scan_clustered(store: BlockStore, plan: QueryPlan, lut,
                    use_kernel: bool, query_tile: int, sel=None,
                    perm=None, unions=None, packed: bool = False):
    """Per-tile-union scan in cluster order; returns (B, S, BLK) dists
    in the *original* batch order — byte-for-byte the paged values."""
    b, s = plan.blocks.shape
    if perm is None:
        perm = cluster_order(sel)
    pb = plan.blocks[perm]                                  # (B, S)
    if unions is None:
        t, w = union_dims(b, s, store.block_codes.shape[0], "clustered",
                          query_tile)
        unions = tile_unions(pb, plan.valid[perm], t, w)    # (T, W)
    t, w = unions.shape
    qt = b // t
    safe_u = jnp.where(unions < BIG, unions, 0)
    lut_p = lut[perm]
    if use_kernel:
        from ...kernels.ops import pq_scan_tiled
        d_u = pq_scan_tiled(lut_p, store.block_codes, safe_u,
                            query_tile=qt,
                            packed=packed)                  # (B, W, BLK)
    else:
        codes_u = _codes_for(store.block_codes[safe_u], lut.shape[1],
                             packed)                       # (T, W, BLK, M)
        m, k = lut.shape[1], lut.shape[2]
        g = jnp.take_along_axis(
            lut_p.reshape(t, qt, 1, 1, m, k),
            codes_u[:, None].astype(jnp.int32)[..., None], axis=-1)
        d_u = jnp.sum(g[..., 0], axis=-1).reshape(b, w, -1)  # (B, W, BLK)
    # per-tile sorted-union scatter: exact positions, then un-permute
    pos = jax.vmap(jnp.searchsorted)(unions, pb.reshape(t, qt * s))
    pos = jnp.minimum(pos.reshape(b, s), w - 1)
    dists_p = jnp.take_along_axis(d_u, pos[:, :, None], axis=1)
    return dists_p[jnp.argsort(perm)]


def scan_blocks(store: BlockStore, plan: QueryPlan, lut: jnp.ndarray,
                rank_of: jnp.ndarray, *, exec_mode: str = "paged",
                use_kernel: bool = False, query_tile: int = 8,
                sel=None, perm=None, unions=None,
                packed: bool = False) -> ScanOut:
    """ADC distances + item masks + DCO for the planned blocks.

    lut: (B, M, K) per-query subspace tables; rank_of: (B, nlist).
    ``sel`` (the stage-1 ranked probed lists) is required by
    ``exec_mode="clustered"`` unless ``perm``/``unions`` are provided by
    a caller holding incremental plans (core/searcher.py); ``unions``
    alone also overrides the batch union of ``"grouped"`` ((1, U) row).
    ``packed`` marks ``store.block_codes`` as a nibble-packed quant
    plane (two 4-bit codes per byte) — the tier-1 compact scan; the
    LUT width stays the logical M and ids/masks/DCO are untouched.
    """
    assert exec_mode in EXEC_MODES, exec_mode
    bq = plan.blocks.shape[0]
    if exec_mode == "grouped":
        dists = _scan_grouped(store, plan, lut, use_kernel, query_tile,
                              union=None if unions is None else unions[0],
                              packed=packed)
    elif exec_mode == "clustered":
        dists = _scan_clustered(store, plan, lut, use_kernel, query_tile,
                                sel=sel, perm=perm, unions=unions,
                                packed=packed)
    else:
        dists = _scan_paged(store, plan, lut, use_kernel, packed=packed)

    ids = store.block_ids[plan.blocks]             # (B, S, BLK)
    other = store.block_other[plan.blocks]
    o_rank = jnp.take_along_axis(
        rank_of, jnp.maximum(other, 0).reshape(bq, -1), axis=1
    ).reshape(other.shape)
    dup_item = (other >= 0) & (o_rank < plan.ranks[:, :, None])
    item_ok = (ids >= 0) & plan.valid[:, :, None]
    keep = item_ok & ~dup_item
    # DCO: SEIL computes misc duplicates then discards them (Alg.5 L15-16)
    approx_dco = jnp.sum(item_ok, axis=(1, 2)).astype(jnp.int32)
    return ScanOut(
        flat_d=jnp.where(keep, dists, jnp.inf).reshape(bq, -1),
        flat_i=ids.reshape(bq, -1),
        approx_dco=approx_dco,
        scanned_blocks=jnp.sum(plan.valid, axis=1).astype(jnp.int32))
