"""Stage 3 — ADC scan of the planned blocks, in one of two exec modes.

``paged``   : every query pages its own scan list — one (block, query)
              fetch per plan entry.  This is the classic per-query IVF
              scan (kernel: ``pq_scan_paged`` with query_tile=1).

``grouped`` : the paper's §5.3 cache optimization ("group tasks by
              list"), batch-union form.  The union of all blocks planned
              by *any* query in the batch is materialized once, sorted
              by physical block id; each union block is fetched once per
              query tile and scored for the whole tile while resident
              (kernel: ``pq_scan_grouped``).  Per-query distances are
              then scattered back into the plan layout via a sorted-
              union ``searchsorted``, so everything downstream —
              item masks, DCO counters, top-K — is byte-for-byte the
              same computation as paged mode.  HBM traffic drops from
              sum_q |plan_q| block fetches to |union_batch| * ceil(B/QT);
              logical DCO accounting is unchanged by construction.

The union budget is ``min(B*S, TB)`` — an upper bound on the number of
distinct planned blocks — so grouped mode can never drop a block the
paged plan would scan: results are bitwise identical (asserted in
tests/test_engine.py).

Item-level masks (shared by both modes): invalid slots, and misc items
whose co-assigned list was scanned at an earlier rank (their cell was
already computed — Alg. 5 L15-16; the DCO is still counted, SEIL cannot
avoid computing a misc duplicate before discarding it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import BIG, BlockStore, QueryPlan, ScanOut

EXEC_MODES = ("paged", "grouped")


def _adc_gather(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """lut (B, M, K), codes (B, S, BLK, M) -> (B, S, BLK) ADC distances."""
    g = jnp.take_along_axis(
        lut[:, None, None, :, :], codes.astype(jnp.int32)[..., None],
        axis=-1)
    return jnp.sum(g[..., 0], axis=-1)


def _fit_query_tile(b: int, query_tile: int) -> int:
    qt = max(1, min(query_tile, b))
    while b % qt:
        qt -= 1
    return qt


def batch_union(plan: QueryPlan, total_blocks: int) -> jnp.ndarray:
    """Sorted union of all valid planned block ids across the batch,
    padded with BIG.  Static width min(B*S, TB) >= |union| always."""
    b, s = plan.blocks.shape
    u = min(b * s, total_blocks)
    allb = jnp.where(plan.valid, plan.blocks, BIG).reshape(-1)
    srt = jnp.sort(allb)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), srt[1:] != srt[:-1]])
    uniq = jnp.where(first & (srt < BIG), srt, BIG)
    return jnp.sort(uniq)[:u]                      # ascending unique + pad


def _scan_paged(store: BlockStore, plan: QueryPlan, lut, use_kernel: bool):
    if use_kernel:
        from ...kernels.ops import pq_scan_paged
        return pq_scan_paged(lut, store.block_codes, plan.blocks)
    codes = store.block_codes[plan.blocks]         # (B, S, BLK, M)
    return _adc_gather(lut, codes)


def _scan_grouped(store: BlockStore, plan: QueryPlan, lut,
                  use_kernel: bool, query_tile: int):
    b, s = plan.blocks.shape
    union = batch_union(plan, store.block_codes.shape[0])   # (U,)
    safe_union = jnp.where(union < BIG, union, 0)
    if use_kernel:
        from ...kernels.ops import pq_scan_grouped
        qt = _fit_query_tile(b, query_tile)
        dists_u = pq_scan_grouped(lut, store.block_codes, safe_union,
                                  query_tile=qt)            # (B, U, BLK)
    else:
        codes_u = store.block_codes[safe_union]             # (U, BLK, M)
        dists_u = _adc_gather(
            lut, jnp.broadcast_to(codes_u[None], (b,) + codes_u.shape))
    # scatter back to the plan layout: every valid plan block is in the
    # sorted union, so searchsorted finds its exact position
    pos = jnp.searchsorted(union, plan.blocks.reshape(-1)).reshape(b, s)
    pos = jnp.minimum(pos, union.shape[0] - 1)
    return jnp.take_along_axis(dists_u, pos[:, :, None], axis=1)


def scan_blocks(store: BlockStore, plan: QueryPlan, lut: jnp.ndarray,
                rank_of: jnp.ndarray, *, exec_mode: str = "paged",
                use_kernel: bool = False, query_tile: int = 8) -> ScanOut:
    """ADC distances + item masks + DCO for the planned blocks.

    lut: (B, M, K) per-query subspace tables; rank_of: (B, nlist).
    """
    assert exec_mode in EXEC_MODES, exec_mode
    bq = plan.blocks.shape[0]
    if exec_mode == "grouped":
        dists = _scan_grouped(store, plan, lut, use_kernel, query_tile)
    else:
        dists = _scan_paged(store, plan, lut, use_kernel)

    ids = store.block_ids[plan.blocks]             # (B, S, BLK)
    other = store.block_other[plan.blocks]
    o_rank = jnp.take_along_axis(
        rank_of, jnp.maximum(other, 0).reshape(bq, -1), axis=1
    ).reshape(other.shape)
    dup_item = (other >= 0) & (o_rank < plan.ranks[:, :, None])
    item_ok = (ids >= 0) & plan.valid[:, :, None]
    keep = item_ok & ~dup_item
    # DCO: SEIL computes misc duplicates then discards them (Alg.5 L15-16)
    approx_dco = jnp.sum(item_ok, axis=(1, 2)).astype(jnp.int32)
    return ScanOut(
        flat_d=jnp.where(keep, dists, jnp.inf).reshape(bq, -1),
        flat_i=ids.reshape(bq, -1),
        approx_dco=approx_dco,
        scanned_blocks=jnp.sum(plan.valid, axis=1).astype(jnp.int32))
