"""Stage 1 — centroid scoring and top-nprobe list selection (Alg. 2 L1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kmeans import pairwise_sq_l2
from .types import BIG, ListSelection


def rank_table(sel: jnp.ndarray, nlist: int) -> jnp.ndarray:
    """(B, P) ranked selected lists -> (B, nlist) rank (BIG if unselected)."""
    b, p = sel.shape
    ranks = jnp.broadcast_to(jnp.arange(p, dtype=jnp.int32), (b, p))
    table = jnp.full((b, nlist), BIG, jnp.int32)
    return table.at[jnp.arange(b)[:, None], sel].min(ranks)


def select_lists(queries: jnp.ndarray, centroids: jnp.ndarray, *,
                 nprobe: int, metric: str = "l2") -> ListSelection:
    """Score list centroids, keep the top-nprobe per query (rank-ordered)."""
    cd = (pairwise_sq_l2(queries, centroids) if metric == "l2"
          else -(queries @ centroids.T))
    _, sel = jax.lax.top_k(-cd, nprobe)            # ascending distance
    sel = sel.astype(jnp.int32)
    return ListSelection(sel=sel, rank_of=rank_table(sel, centroids.shape[0]))
