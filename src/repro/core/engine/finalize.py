"""Stage 4 — top-bigK candidate selection, id-dedup, exact refinement."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import BIG


def preselect_candidates(flat_d, flat_i, *, fetch: int):
    """Stable top-``fetch`` over a flat candidate stream: returns
    ``(cand_d, cand_ids)`` sorted ascending by distance, ties broken by
    flat position (``jax.lax.top_k`` is stable).

    This is the per-device half of the distributed merge (core/sharded.py):
    each shard preselects its local top-fetch, the shards ``all_gather``,
    and ``finalize_candidates`` runs over the union.  Because the
    selection is stable, ``finalize_candidates(preselect(x)) ==
    finalize_candidates(x)`` bitwise whenever the preselect width covers
    the finalize fetch — the 1-device parity invariant asserted in
    tests/test_sharded.py.
    """
    fetch = min(fetch, flat_d.shape[1])
    neg, pos = jax.lax.top_k(-flat_d, fetch)
    return -neg, jnp.take_along_axis(flat_i, pos, axis=1)


def finalize_candidates(flat_d, flat_i, *, bigk, k, vectors, queries,
                        metric, dedup_results, oversample: int = 2,
                        extra_d=None, extra_i=None, live=None,
                        vec_lo=None, reduce_axes=None):
    """Shared tail of all search paths: top-bigK (+ optional id-dedup for
    duplicated layouts), exact-distance refinement, top-K packing.

    Duplicated layouts (no SEIL / m-assignment) retrieve `oversample*bigK`
    candidates before id-dedup so duplicate copies cannot displace unique
    candidates (a dedup-on-insert result queue), then truncate to bigK.

    Streaming hooks (core/stream/, both default-off and bitwise inert
    when unused):
      extra_d/extra_i  (B, C) ADC distances + ids of delta-segment
                       candidates, merged ahead of the top-bigK so fresh
                       inserts compete with base-layout candidates;
      live             (n_total,) bool tombstone mask over the id space —
                       dead candidates (deleted base or delta items) are
                       forced to +inf before selection, so they can
                       neither be returned nor displace live candidates.

    Sharded hooks (core/sharded.py, inert when unused): with ``vec_lo``
    the refine store is a row shard covering global ids
    [vec_lo, vec_lo + len(vectors)); each device scores only the
    candidates it owns (+inf elsewhere) and ``reduce_axes`` pmin-merges
    exact distances across the mesh, so refinement never moves vector
    data.  On one device (vec_lo=0, full store) the owner mask equals
    ``cand_ok`` and the pmin is the identity — bitwise the single-host
    path.
    """
    if extra_d is not None:
        flat_d = jnp.concatenate([flat_d, extra_d], axis=1)
        flat_i = jnp.concatenate([flat_i, extra_i], axis=1)
    if live is not None:
        dead = (flat_i >= 0) & ~live[jnp.maximum(flat_i, 0)]
        flat_d = jnp.where(dead, jnp.inf, flat_d)
    bq = flat_d.shape[0]
    fetch = bigk * (oversample if dedup_results else 1)
    fetch = min(fetch, flat_d.shape[1])
    neg, pos = jax.lax.top_k(-flat_d, fetch)
    cand_ids = jnp.take_along_axis(flat_i, pos, axis=1)      # (B, fetch)
    cand_d = -neg                                            # ascending
    cand_ok = jnp.isfinite(cand_d)
    if dedup_results:  # needed for layouts without SEIL (duplicated storage)
        order = jnp.argsort(jnp.where(cand_ok, cand_ids, BIG), axis=1)
        sid = jnp.take_along_axis(cand_ids, order, axis=1)
        rep = jnp.concatenate(
            [jnp.zeros((bq, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1)
        inv = jnp.argsort(order, axis=1)
        cand_ok &= ~jnp.take_along_axis(rep, inv, axis=1)
        cand_ok &= jnp.cumsum(cand_ok, axis=1) <= bigk       # truncate
    cand_ids = jnp.where(cand_ok, cand_ids, -1)

    if vec_lo is None:
        cv = vectors[jnp.maximum(cand_ids, 0)]               # (B, bigK, D)
        score_ok = cand_ok
    else:
        nloc = vectors.shape[0]
        rel = cand_ids - vec_lo
        score_ok = cand_ok & (rel >= 0) & (rel < nloc)       # owner mask
        cv = vectors[jnp.clip(rel, 0, nloc - 1)]
    if metric == "l2":
        diff = cv - queries[:, None, :]
        exact = jnp.sum(diff * diff, axis=-1)
    else:
        exact = -jnp.einsum("bkd,bd->bk", cv, queries)
    exact = jnp.where(score_ok, exact, jnp.inf)
    if reduce_axes is not None:
        exact = jax.lax.pmin(exact, reduce_axes)
    refine_dco = jnp.sum(cand_ok, axis=1).astype(jnp.int32)
    negk, posk = jax.lax.top_k(-exact, k)
    out_ids = jnp.take_along_axis(cand_ids, posk, axis=1)
    out_d = -negk
    out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
    return out_ids, out_d, refine_dco
