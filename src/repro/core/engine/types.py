"""Pytrees exchanged between the engine stages (all shapes static).

The engine decomposes a query batch into four stages (DESIGN.md §5):

    select_lists -> ListSelection      (which lists, at which probe rank)
    plan_blocks  -> QueryPlan          (which physical blocks, deduplicated,
                                        compacted to a static scan budget)
    scan_blocks  -> ScanOut            (ADC distance per surviving item)
    finalize_candidates                (top-bigK, id-dedup, exact refine)

Each stage is a pure jittable function over these containers, so the
single-host searcher (core/search.py) and the shard_map serving step
(core/distributed.py) are thin compositions of the same code — the
distributed path only swaps in a locally-sharded ``BlockStore`` and a
block-range window on the plan.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

BIG = jnp.int32(2 ** 30)


class ListSelection(NamedTuple):
    """Stage-1 output: ranked probed lists per query."""
    sel: jnp.ndarray       # (B, P) int32 list ids, ascending centroid distance
    rank_of: jnp.ndarray   # (B, nlist) int32 probe rank, BIG if unselected


class ListTables(NamedTuple):
    """Replicated per-list block tables (the SEIL directory, seil.py)."""
    owned: jnp.ndarray        # (nlist, MO) int32 block ids, -1 pad
    owned_other: jnp.ndarray  # (nlist, MO) int32 co-list of shared owned blocks
    refs: jnp.ndarray         # (nlist, MR) int32 referenced block ids, -1 pad
    refs_other: jnp.ndarray   # (nlist, MR) int32 physical-home list, -1 pad
    misc: jnp.ndarray         # (nlist, MM) int32 misc block ids, -1 pad


class BlockStore(NamedTuple):
    """Flat physical block storage — global on one host, a shard under
    shard_map (block ids inside a QueryPlan are relative to this store)."""
    block_codes: jnp.ndarray  # (TB, BLK, M) uint8
    block_ids: jnp.ndarray    # (TB, BLK) int32, -1 invalid
    block_other: jnp.ndarray  # (TB, BLK) int32 co-assigned list, -1 none


class QueryPlan(NamedTuple):
    """Stage-2 output: per-query scan list, compacted to a static budget."""
    blocks: jnp.ndarray    # (B, S) int32 store-relative block ids (pad -> 0)
    ranks: jnp.ndarray     # (B, S) int32 probe rank of each block's scan
    valid: jnp.ndarray     # (B, S) bool
    dropped: jnp.ndarray   # (B,) int32 candidates lost to the budget


class PlanProbe(NamedTuple):
    """Probe-half output of the split pipeline (incremental plans,
    core/searcher.py): everything the scan+finalize executable consumes,
    plus this batch's own tile unions for the host-side plan cache."""
    sel: jnp.ndarray       # (B, P) int32 ranked probed lists
    rank_of: jnp.ndarray   # (B, nlist) int32 probe ranks
    lut: jnp.ndarray       # (B, M, K) f32 per-query ADC tables
    plan: "QueryPlan"
    perm: jnp.ndarray      # (B,) int32 cluster order (identity for grouped)
    unions: jnp.ndarray    # (T, W) int32 sorted tile unions, BIG pad


class ScanOut(NamedTuple):
    """Stage-3 output: flat per-item candidate distances (inf = masked)."""
    flat_d: jnp.ndarray          # (B, S*BLK) f32
    flat_i: jnp.ndarray          # (B, S*BLK) int32 vector ids
    approx_dco: jnp.ndarray      # (B,) int32 ADC distance computations
    scanned_blocks: jnp.ndarray  # (B,) int32


def tables_from_arrays(arrays) -> ListTables:
    """Build ListTables from SeilArrays, deriving ``owned_other`` (the
    co-assigned list of each owned shared block) from block metadata.
    Safe under jit; the distributed driver precomputes it host-side
    instead because its block arrays are sharded."""
    owned = arrays.owned
    owned_other = arrays.block_other[jnp.maximum(owned, 0), 0]
    owned_other = jnp.where(owned >= 0, owned_other, -1)
    return ListTables(owned=owned, owned_other=owned_other, refs=arrays.refs,
                      refs_other=arrays.refs_other, misc=arrays.misc)


def store_from_arrays(arrays) -> BlockStore:
    return BlockStore(block_codes=arrays.block_codes,
                      block_ids=arrays.block_ids,
                      block_other=arrays.block_other)
