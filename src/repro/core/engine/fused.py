"""Stage 3+ — fused ADC scan -> stable partial top-``fetch``.

``scan_blocks_topk`` is the drop-in fused alternative to
``scan_blocks`` + ``preselect_candidates``: it returns a ``ScanOut``
whose candidate stream is already the stable top-``fetch`` of the plan
layout — width ``fetch`` instead of ``S * BLK`` — so the scan stage
stops writing a (B, S, BLK) score tensor to HBM just for finalize to
re-read and discard.  Contract (both paths, bitwise):

  * ``flat_d``/``flat_i`` are the ascending stable selection of the
    unfused stream — exactly ``preselect_candidates`` over
    ``scan_blocks``' output with ties broken by flat plan position —
    with masked/overflow entries normalized to ``(+inf, -1)``;
  * ``approx_dco``/``scanned_blocks`` keep the logical accounting of
    ``scan_blocks`` unchanged (masked misc duplicates still count one
    ADC computation each, Alg. 5 L15-16);
  * with ``live`` (streaming tombstones) dead candidates are forced out
    *before* selection — they can neither be returned nor displace live
    candidates — matching the distributed serve step's ordering; the
    idempotent re-mask in ``finalize_candidates`` keeps end results
    bitwise identical to the unfused live-in-finalize path.

``use_kernel=True`` routes through the fused Pallas kernel
(``kernels/pq_scan.py::pq_scan_topk_kernel``): the keep mask moves
in-kernel (``rank_of`` rides the query-tile prefetch, ``block_ids`` /
``block_other`` tiles are DMA'd alongside the code tiles) and the
top-``fetch`` accumulator lives in VMEM across the scan grid.  The
kernel iterates *scan positions* (per-query plan slots in paged mode,
sorted-union positions in grouped/clustered), so the plan layout is
carried in as two (B, S) sidecars built here: ``slot_of`` (the plan
slot scanned at that position, -1 if the query does not plan it) and
``rank_u`` (that slot's probe rank) — one scatter through the same
sorted-union ``searchsorted`` the unfused modes use, which is exact
because SEIL plans are per-query duplicate-free.

``use_kernel=False`` is the stage-level fusion oracle: the jnp scan
plus an in-stage stable preselect.  Identical output contract, no
kernel — the shard_map serve path and CPU tests run this by default.
Distances are assumed finite (a +/-inf ADC distance would be
indistinguishable from a masked slot in the oracle's normalization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .cluster import cluster_order, fit_tile, tile_unions, union_dims
from .finalize import preselect_candidates
from .scan import EXEC_MODES, batch_union, scan_blocks
from .types import BIG, BlockStore, QueryPlan, ScanOut


def plan_slot_maps(blocks: jnp.ndarray, ranks: jnp.ndarray,
                   valid: jnp.ndarray, unions: jnp.ndarray):
    """Invert the sorted-union scatter: which plan slot does scan
    position ``w`` of query ``b`` correspond to?

    blocks/ranks/valid: (B, S) plan rows, already tiled in the same row
    order as ``unions`` (T, W) with B == T * qt.  Returns ``slot_of`` /
    ``rank_u`` (B, W): the plan slot index (-1 if the union position is
    not in that query's plan) and its probe rank.  Exact because every
    valid plan block is present in its tile's sorted union and SEIL
    plans are per-query duplicate-free, so the scatter is injective.
    """
    b, s = blocks.shape
    t, w = unions.shape
    qt = b // t
    pos = jax.vmap(jnp.searchsorted)(unions, blocks.reshape(t, qt * s))
    pos = pos.reshape(b, s)
    # invalid slots scatter out of bounds (w) and are dropped
    posc = jnp.where(valid, jnp.minimum(pos, w - 1), w)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    slots = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    slot_of = jnp.full((b, w), -1, jnp.int32).at[rows, posc].set(
        slots, mode="drop")
    rank_u = jnp.zeros((b, w), jnp.int32).at[rows, posc].set(
        ranks, mode="drop")
    return slot_of, rank_u


def _fused_kernel_scan(store: BlockStore, plan: QueryPlan, lut, rank_of,
                       *, fetch: int, exec_mode: str, query_tile: int,
                       sel, perm, unions, dead, packed: bool = False):
    """Per-exec-mode kernel dispatch: build (tile_idx, slot_of, rank_u)
    and run the fused Pallas kernel.  Returns (flat_d, flat_i, dco)."""
    from ...kernels.ops import pq_scan_topk
    b, s = plan.blocks.shape
    if exec_mode == "paged":
        # scan position == plan slot; every query pages its own list
        slots = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
        slot_of = jnp.where(plan.valid, slots, -1)
        d, _, ids, dco = pq_scan_topk(
            lut, store.block_codes, store.block_ids, store.block_other,
            plan.blocks, rank_of, slot_of, plan.ranks, dead,
            fetch=fetch, query_tile=1, packed=packed)
        return d, ids, dco

    if exec_mode == "grouped":
        qt = fit_tile(b, query_tile)
        union = (batch_union(plan, store.block_codes.shape[0])
                 if unions is None else unions[0])           # (U,)
        safe_union = jnp.where(union < BIG, union, 0)
        tile_idx = jnp.broadcast_to(safe_union[None, :],
                                    (b // qt, union.shape[0]))
        slot_of, rank_u = plan_slot_maps(plan.blocks, plan.ranks,
                                         plan.valid, union[None, :])
        d, _, ids, dco = pq_scan_topk(
            lut, store.block_codes, store.block_ids, store.block_other,
            tile_idx, rank_of, slot_of, rank_u, dead,
            fetch=fetch, query_tile=qt, packed=packed)
        return d, ids, dco

    # clustered: per-tile unions in probe-overlap order, then un-permute
    if perm is None:
        perm = cluster_order(sel)
    pb, pr, pv = plan.blocks[perm], plan.ranks[perm], plan.valid[perm]
    if unions is None:
        t, w = union_dims(b, s, store.block_codes.shape[0], "clustered",
                          query_tile)
        unions = tile_unions(pb, pv, t, w)
    t, w = unions.shape
    qt = b // t
    safe_u = jnp.where(unions < BIG, unions, 0)
    slot_of, rank_u = plan_slot_maps(pb, pr, pv, unions)
    d, _, ids, dco = pq_scan_topk(
        lut[perm], store.block_codes, store.block_ids, store.block_other,
        safe_u, rank_of[perm], slot_of, rank_u, dead,
        fetch=fetch, query_tile=qt, packed=packed)
    inv = jnp.argsort(perm)
    return d[inv], ids[inv], dco[inv]


def scan_blocks_topk(store: BlockStore, plan: QueryPlan, lut: jnp.ndarray,
                     rank_of: jnp.ndarray, *, fetch: int,
                     exec_mode: str = "paged", use_kernel: bool = False,
                     query_tile: int = 8, sel=None, perm=None, unions=None,
                     live=None, packed: bool = False) -> ScanOut:
    """Fused scan + stable top-``fetch`` selection (see module docstring).

    Same signature and semantics as ``scan_blocks`` plus ``fetch`` (the
    candidate budget finalize needs: ``bigk * oversample`` for
    dedup-required layouts, ``bigk`` otherwise) and ``live`` (optional
    tombstone mask over the id space, applied pre-selection).
    ``packed`` marks the code store as a nibble-packed quant plane,
    exactly as in ``scan_blocks``.
    """
    assert exec_mode in EXEC_MODES, exec_mode
    b, s = plan.blocks.shape
    blk = store.block_codes.shape[1]
    fetch = min(fetch, s * blk)
    if not use_kernel:
        out = scan_blocks(store, plan, lut, rank_of, exec_mode=exec_mode,
                          use_kernel=False, query_tile=query_tile, sel=sel,
                          perm=perm, unions=unions, packed=packed)
        d = out.flat_d
        if live is not None:
            dead = (out.flat_i >= 0) & ~live[jnp.maximum(out.flat_i, 0)]
            d = jnp.where(dead, jnp.inf, d)
        ids = jnp.where(jnp.isfinite(d), out.flat_i, -1)
        cd, ci = preselect_candidates(d, ids, fetch=fetch)
        return ScanOut(flat_d=cd, flat_i=ci, approx_dco=out.approx_dco,
                       scanned_blocks=out.scanned_blocks)

    dead = None
    if live is not None:
        # per-block tombstone tiles, DMA'd alongside the code tiles —
        # the (TB, BLK) analogue of finalize's id-space lookup
        dead = ((store.block_ids >= 0)
                & ~live[jnp.maximum(store.block_ids, 0)]).astype(jnp.uint8)
    d, ids, dco = _fused_kernel_scan(
        store, plan, lut, rank_of, fetch=fetch, exec_mode=exec_mode,
        query_tile=query_tile, sel=sel, perm=perm, unions=unions, dead=dead,
        packed=packed)
    return ScanOut(
        flat_d=d, flat_i=ids, approx_dco=dco,
        scanned_blocks=jnp.sum(plan.valid, axis=1).astype(jnp.int32))
