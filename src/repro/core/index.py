"""RairsIndex — the public index object tying RAIR + PQ + SEIL together.

`build_index` is paper Alg. 1 (AddVectors) for a bulk batch:
RairAssign -> PQEncoding -> SeilInsert; querying is Alg. 2 through a
compiled searcher session: ``index.searcher(SearchParams(...))`` (see
DESIGN.md §7; ``RairsIndex.search`` is a thin kwarg wrapper over the
same sessions).  ``save_index``/``load_index`` (core/io.py) persist the
built index so serving restarts skip the train+build phase.

Strategy presets (paper §6.1 "Solutions to Compare", extensible via
``assign.register_strategy``):
  single  -> IVFPQfs   (baseline single assignment)
  naive   -> NaiveRA   (2nd-nearest list, strict)
  soar    -> SOARL2    (orthogonal residual, strict)
  rair    -> RAIR      (AIR, primary may win -> single)
  srair   -> SRAIR     (AIR, strictly two lists)
`seil=True` adds the shared-cell layout (RAIRS = rair+seil, etc.).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .assign import (AGGRS, STRATEGY_REGISTRY, available_strategies,
                     get_strategy, rair_assign_multi)
from .kmeans import kmeans_fit
from .params import SearchParams
from .pq import PQCodebook, pq_encode, pq_train
from .search import SearchResult
from .searcher import Searcher
from .seil import SeilArrays, SeilStats, build_seil

# kept for callers that enumerate the paper's preset strategies; the
# authoritative (extensible) set is assign.STRATEGY_REGISTRY
STRATEGIES = ("single", "naive", "soar", "rair", "srair")


@dataclasses.dataclass
class IndexConfig:
    nlist: int = 256
    m_pq: Optional[int] = None        # default D // 2 (paper: dsub = 2)
    nbits: int = 4
    block: int = 32
    strategy: str = "rair"
    seil: bool = True
    lam: float = 0.5
    n_cands: int = 10
    metric: str = "l2"
    multi_m: int = 2                  # >2 enables m-assignment (strict, aggr)
    aggr: str = "max"
    kmeans_iters: int = 15
    pq_iters: int = 12
    train_sample: int = 131072
    # streaming: delta capacity above which the delta scan routes through
    # the probed lists instead of scanning exhaustively (DESIGN.md §8).
    # None -> auto: nlist * block (where exhaustive costs one block per
    # list), plus a per-session cost guard (StreamingIndex.routes_at)
    # that keeps the exhaustive path when a skewed delta makes routing
    # dearer; an explicit value (0 forces routing from the first
    # insert) is final.
    delta_route_min: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in STRATEGY_REGISTRY:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; registered: "
                f"{available_strategies()}")
        if self.metric not in ("l2", "ip"):
            raise ValueError(f"metric must be 'l2' or 'ip', got {self.metric!r}")
        if not 1 <= self.nbits <= 8:
            raise ValueError(
                f"nbits must be in [1, 8] (codes are uint8), got {self.nbits}")
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")
        if self.nlist < 1:
            raise ValueError(f"nlist must be >= 1, got {self.nlist}")
        if self.multi_m < 2:
            raise ValueError(f"multi_m must be >= 2, got {self.multi_m}")
        if self.aggr not in AGGRS:
            raise ValueError(f"aggr must be one of {AGGRS}, got {self.aggr!r}")
        if self.n_cands < 2:
            raise ValueError(
                f"n_cands must be >= 2 (primary + alternates), got {self.n_cands}")
        if self.m_pq is not None and self.m_pq < 1:
            raise ValueError(f"m_pq must be >= 1 or None, got {self.m_pq}")
        if self.lam < 0:
            raise ValueError(f"lam must be >= 0, got {self.lam}")
        if self.delta_route_min is not None and self.delta_route_min < 0:
            raise ValueError(
                f"delta_route_min must be >= 0 or None (auto), got "
                f"{self.delta_route_min}")


@dataclasses.dataclass
class RairsIndex:
    config: IndexConfig
    centroids: jnp.ndarray            # (nlist, D)
    codebook: PQCodebook
    arrays: SeilArrays
    vectors: jnp.ndarray              # (n, D) refine store
    stats: SeilStats
    assigns: np.ndarray               # (n, m) — kept for analysis benches
    codes: Optional[np.ndarray] = None  # (n, M) cached PQ codes (append path)
    build_seconds: dict = dataclasses.field(default_factory=dict)

    @property
    def needs_result_dedup(self) -> bool:
        # duplicated layouts (no SEIL) can surface the same id twice
        return (not self.config.seil) and self.config.strategy != "single"

    @property
    def result_oversample(self) -> int:
        # max copies of one id = assignment multiplicity
        return max(int(self.assigns.shape[1]), 2)

    def default_max_scan(self, nprobe: int, slack: float = 1.3) -> int:
        avg_blocks = self.stats.n_blocks / self.config.nlist
        mo, mr, mm = (self.arrays.owned.shape[1], self.arrays.refs.shape[1],
                      self.arrays.misc.shape[1])
        cap = nprobe * (mo + mr + mm)
        want = int(nprobe * max(avg_blocks * slack, 4.0)) + 8
        return min(cap, max(want, 16))

    def searcher(self, params: Optional[SearchParams] = None,
                 **kwargs) -> Searcher:
        """Create (or fetch) a compiled search session for `params`.

        Sessions are cached per params object on this index, so repeated
        requests for the same parameters share AOT-compiled executables.
        Keyword arguments build (or override fields of) the params:
        ``index.searcher(k=10, nprobe=16)``.
        """
        if params is None:
            params = SearchParams(**kwargs)
        elif kwargs:
            params = dataclasses.replace(params, **kwargs)
        cache = getattr(self, "_searcher_cache", None)
        if cache is None:
            cache = {}
            self._searcher_cache = cache
        if params not in cache:
            cache[params] = Searcher(self, params)
        return cache[params]

    def plane(self, backend: str, codec=None):
        """Attach (or fetch) a compact code plane — the tier-1 side of
        the quantization ladder (repro/quant/, DESIGN.md §12).

        Planes are derived lazily on first use and cached per backend:
        the codec is trained (pq4) or closed-form (binary) from the
        refine store, every id is encoded, and the codes are gathered
        into this index's exact SEIL block layout, nibble-packed.  Pass
        ``codec=`` to carry a trained codec across a rebuild
        (compaction) — re-encoding is deterministic, so the carried
        plane is bitwise the retrained one would be on identical data.
        """
        from ..quant import PLANE_BACKENDS, build_plane
        if backend not in PLANE_BACKENDS:
            raise ValueError(f"unknown plane backend {backend!r}; "
                             f"choose from {PLANE_BACKENDS}")
        cache = getattr(self, "_planes", None)
        if cache is None:
            cache = {}
            self._planes = cache
        hit = cache.get(backend)
        if hit is not None and (codec is None or codec is hit.codec):
            return hit
        key = jax.random.fold_in(jax.random.PRNGKey(17),
                                 PLANE_BACKENDS.index(backend))
        cache[backend] = build_plane(
            backend, key, np.asarray(self.vectors),
            np.asarray(self.arrays.block_ids), codec=codec,
            iters=self.config.pq_iters)
        return cache[backend]

    def streaming(self, config=None):
        """Wrap this (immutable) index as the base epoch of a mutable
        ``StreamingIndex`` (core/stream/, DESIGN.md §8): inserts go to a
        delta segment, deletes to a tombstone mask, ``compact()`` folds
        both into a fresh base.  `config` is an optional StreamConfig."""
        from .stream import StreamingIndex
        return StreamingIndex(self, config)

    def shard(self, mesh, axes=("data",), max_scan_local=None):
        """Deploy this index over `mesh` as a ``ShardedIndex``
        (core/sharded.py, DESIGN.md §4): block arrays and refine vectors
        shard by id range, centroids/tables/codebooks replicate, and
        ``.searcher(params)`` sessions lower shard_map executables with
        the same bucket/cache machinery as the single-host path.
        Cached per (mesh, axes, max_scan_local) so repeated shards of
        one index share placed arrays and compiled executables."""
        from .sharded import shard_index
        return shard_index(self, mesh, axes=axes,
                           max_scan_local=max_scan_local)

    def searcher_stats(self) -> dict:
        """Aggregate compile-cache stats over every cached session (the
        public accessor — benchmarks/serving should not reach into the
        session cache)."""
        sessions = list(getattr(self, "_searcher_cache", {}).values())
        return {
            "sessions": len(sessions),
            "compiles": sum(s.stats.compiles for s in sessions),
            "cache_hits": sum(s.stats.cache_hits for s in sessions),
        }

    def search(self, queries: jnp.ndarray, k: int, nprobe: int,
               k_factor: int = 10, max_scan: Optional[int] = None,
               use_kernel: bool = False, exec_mode: str = "paged",
               query_tile: int = 8) -> SearchResult:
        """Convenience kwarg path: builds/reuses a Searcher session.

        Prefer ``index.searcher(SearchParams(...))`` for serving loops —
        it makes the compiled session (and its cache stats) explicit.
        See DESIGN.md §7 for the migration note.
        """
        return self.searcher(SearchParams(
            k=k, nprobe=nprobe, k_factor=k_factor, max_scan=max_scan,
            use_kernel=use_kernel, exec_mode=exec_mode,
            query_tile=query_tile))(queries)


def compute_assignments(x: jnp.ndarray, centroids: jnp.ndarray,
                        cfg: IndexConfig) -> np.ndarray:
    """Dispatch to the registered assignment strategy (m-assignment,
    paper §4.3, overrides the pairwise strategies when multi_m > 2)."""
    if cfg.multi_m > 2:
        return np.asarray(rair_assign_multi(
            x, centroids, m=cfg.multi_m, aggr=cfg.aggr, lam=cfg.lam,
            n_cands=cfg.n_cands))
    return np.asarray(get_strategy(cfg.strategy)(x, centroids, cfg))


def build_index(key: jax.Array, x: jnp.ndarray, cfg: IndexConfig,
                centroids: Optional[jnp.ndarray] = None,
                codebook: Optional[PQCodebook] = None) -> RairsIndex:
    """Train (k-means + PQ) and add all vectors (Alg. 1)."""
    n, d = x.shape
    m_pq = cfg.m_pq or d // 2
    k1, k2 = jax.random.split(key)
    times = {}
    t0 = time.perf_counter()
    if centroids is None:
        centroids = kmeans_fit(k1, x, cfg.nlist, iters=cfg.kmeans_iters,
                               sample=cfg.train_sample)
    if codebook is None:
        codebook = pq_train(k2, x, m_pq, nbits=cfg.nbits, iters=cfg.pq_iters,
                            sample=cfg.train_sample)
    jax.block_until_ready((centroids, codebook.codebooks))
    times["train"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    assigns = compute_assignments(x, centroids, cfg)
    times["assign"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    codes = np.asarray(pq_encode(codebook, x))
    times["encode"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    shared = cfg.seil and cfg.multi_m == 2
    arrays, stats = build_seil(
        assigns, codes, np.arange(n, dtype=np.int32), cfg.nlist,
        block=cfg.block, shared=shared, code_bits=cfg.nbits)
    times["layout"] = time.perf_counter() - t0

    return RairsIndex(config=cfg, centroids=centroids, codebook=codebook,
                      arrays=arrays, vectors=jnp.asarray(x), stats=stats,
                      assigns=assigns, codes=codes, build_seconds=times)


def insert_batch(index, x_new: jnp.ndarray):
    """Append a batch through the streaming delta path (paper Fig. 12,
    DESIGN.md §8) — compat wrapper over ``StreamingIndex``.

    Historically this re-ran ``build_seil`` over the pooled corpus on
    every append (O(n) per batch).  It now wraps `index` in (or reuses)
    a ``StreamingIndex`` and appends to its delta segment in O(batch);
    the result is read-side compatible with ``RairsIndex`` (vectors /
    search / searcher), new ids continue the old numbering, and
    ``.compact()`` folds the delta into a base whose search results are
    bitwise equal to the old pooled rebuild (tests/test_stream.py)."""
    from .stream import StreamingIndex   # local: stream imports this module
    stream = (index if isinstance(index, StreamingIndex)
              else index.streaming())
    stream.insert(x_new)
    return stream
