"""ShardedIndex — one index, any topology (DESIGN.md §4).

``index.shard(mesh, axes=...)`` wraps a ``RairsIndex`` *or* a
``StreamingIndex`` as a mesh-resident view that serves through the
exact same session protocol as the single-host path::

    sharded  = index.shard(mesh)                  # deployment detail
    searcher = sharded.searcher(SearchParams(k=10, nprobe=16))
    result   = searcher(queries)                  # pad-and-dispatch buckets
    searcher.compile_stats()                      # same counters

``ShardedSearcher`` reuses all of ``Searcher``'s machinery (batch-size
buckets, chunking, compile/cache stats, (epoch, version) pinning) and
only swaps the ``_lower`` / ``_call_inputs`` hooks: lowering produces a
``shard_map`` executable of the serve step built by
``core/distributed.py::build_serve_step`` instead of a single-device
``seil_search`` program.

Data placement happens once per index state, not per call: block
arrays/refine vectors are padded to the device count and committed with
a block-id/vector-id range ``NamedSharding``; centroids, the SEIL list
tables, PQ codebooks, the delta segment, and the tombstone mask are
committed replicated.  Placement is two-tier: the base layout (block
store + tables) is placed once per *epoch*, the mutable pieces
(vectors incl. delta rows, delta buffers, tombstone mask) once per
*version* — so insert/delete never re-transfer the block store, only
compaction does.  A mutated ``StreamingIndex`` base invalidates the
per-version state and every open session exactly like the single-host
``StreamingSearcher`` (``StaleSessionError``); compiled executables are
shared through a shape-keyed cache, so steady-state churn on the mesh
never recompiles.

On a 1-device mesh the whole pipeline — plan window, local scan,
stable top-fetch preselect, identity collectives, owner refinement —
is bitwise identical to the plain ``Searcher`` (asserted in
tests/test_sharded.py for both exec modes, frozen and streaming).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import obs
from ..dist import shard_map
from .distributed import build_serve_step
from .params import SearchParams
from .search import SearchResult
from .searcher import Searcher
from .stream import StaleSessionError, StreamingIndex


@dataclasses.dataclass
class _BasePlacement:
    """Mesh-resident arrays of one *epoch* of the base layout.

    The block arrays are row shards (block-id range); the list tables,
    centroids, and codebooks replicate.  Nothing here changes on
    insert/delete — only compaction (a new epoch) invalidates it — so
    the expensive block-store transfer happens once per epoch, not once
    per mutation.
    """
    block_codes: jnp.ndarray        # (TBp, BLK, M) sharded
    block_ids: jnp.ndarray          # (TBp, BLK)    sharded
    block_other: jnp.ndarray        # (TBp, BLK)    sharded
    block_lo: jnp.ndarray           # (ndev,)       sharded, per-device scalar
    dev_rank: jnp.ndarray           # (ndev,)       sharded, per-device scalar
    owned: jnp.ndarray              # replicated list tables …
    owned_other: jnp.ndarray
    refs: jnp.ndarray
    refs_other: jnp.ndarray
    misc: jnp.ndarray
    centroids: jnp.ndarray
    codebooks: jnp.ndarray


@dataclasses.dataclass
class _PlacedState:
    """Full per-*version* state: the epoch base plus the mutable pieces
    (refine vectors incl. delta rows, delta buffers, tombstone mask).

    ``signature`` keys the compiled-executable cache: two states with
    equal shapes can share every executable because arrays are runtime
    arguments, never baked into the program.
    """
    base: _BasePlacement
    vectors: jnp.ndarray            # (Np, D)   sharded by vector-id range
    vec_lo: jnp.ndarray             # (ndev,)   sharded, per-device scalar
    delta_codes: jnp.ndarray        # (cap, M)  replicated ((0, M) frozen)
    delta_ids: jnp.ndarray          # (cap,)    replicated
    live: jnp.ndarray               # (n_total,) replicated ((0,) frozen)
    signature: Tuple

    def serve_args(self) -> tuple:
        b = self.base
        return (b.block_codes, b.block_ids, b.block_other,
                b.owned, b.owned_other, b.refs, b.refs_other, b.misc,
                b.centroids, b.codebooks, self.vectors, self.vec_lo,
                b.block_lo, b.dev_rank,
                self.delta_codes, self.delta_ids, self.live)


class _Placement:
    """Placed arrays + executable cache shared by every ShardedIndex of
    one (index, mesh, axes) — views differing only in ``max_scan_local``
    must not place the index twice."""

    def __init__(self):
        self.state: Optional[_PlacedState] = None
        self.version = None
        self.base: Optional[_BasePlacement] = None
        self.base_epoch = None
        self.exec_cache: Dict[tuple, dict] = {}
        self.budget_cache: Dict[tuple, int] = {}   # derived max_scan_local
        # compact-plane placements (DESIGN.md §12): per-epoch sharded
        # packed block codes + replicated codec books, and per-version
        # replicated delta plane codes — placed lazily on first refine
        # session, dropped with the epoch exactly like the base
        self.plane_base: Dict[str, tuple] = {}
        self.plane_delta: Dict[str, tuple] = {}


def shard_index(index, mesh, axes=("data",),
                max_scan_local: Optional[int] = None) -> "ShardedIndex":
    """Cached ``ShardedIndex`` factory — the implementation behind
    ``RairsIndex.shard`` / ``StreamingIndex.shard``.  Cached per
    (mesh, axes, max_scan_local) on the index (``Mesh`` is hashable, so
    equal meshes hit the same entry); views differing only in
    ``max_scan_local`` additionally share one placement + executable
    cache through ``_Placement``, so no configuration ever places the
    arrays twice."""
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    key = (mesh, axes, max_scan_local)
    cache = getattr(index, "_shard_cache", None)
    if cache is None:
        cache = {}
        index._shard_cache = cache
    if key not in cache:
        cache[key] = ShardedIndex(index, mesh, axes=axes,
                                  max_scan_local=max_scan_local)
    return cache[key]


def _pad_rows(x: np.ndarray, mult: int, fill) -> np.ndarray:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    widths = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return np.pad(x, widths, constant_values=fill)


class ShardedIndex:
    """A mesh deployment of an index, serving through ``Searcher`` sessions.

    Duck-type compatible with the read side of ``RairsIndex`` /
    ``StreamingIndex`` (config / centroids / codebook / vectors /
    searcher / search / searcher_stats), and — over a streaming base —
    with the mutation side too (insert / delete / compact), so call
    sites written against the single-host API run unchanged on a mesh.
    """

    def __init__(self, index, mesh, axes=("data",),
                 max_scan_local: Optional[int] = None):
        if isinstance(index, ShardedIndex):
            raise TypeError("index is already a ShardedIndex")
        self.index = index
        self.mesh = mesh
        self.axes = (axes,) if isinstance(axes, str) else tuple(axes)
        for a in self.axes:
            if a not in mesh.shape:
                raise ValueError(
                    f"mesh has no axis {a!r} (axes: {tuple(mesh.shape)})")
        ndev = 1
        for a in self.axes:
            ndev *= mesh.shape[a]
        self.ndev = ndev
        self.max_scan_local = max_scan_local
        self.streaming = isinstance(index, StreamingIndex)
        pcache = getattr(index, "_placement_cache", None)
        if pcache is None:
            pcache = {}
            index._placement_cache = pcache
        self._placement: _Placement = pcache.setdefault(
            (mesh, self.axes), _Placement())
        self._sessions: Dict[SearchParams, "ShardedSearcher"] = {}
        self._retired: Dict[str, int] = {}
        self._n_invalidations = 0

    # ------------------------------------------------------------------
    # read-side duck typing
    # ------------------------------------------------------------------
    @property
    def config(self):
        return self.index.config

    @property
    def centroids(self):
        return self.index.centroids

    @property
    def codebook(self):
        return self.index.codebook

    @property
    def vectors(self):
        return self.index.vectors

    @property
    def needs_result_dedup(self) -> bool:
        return self.index.needs_result_dedup

    @property
    def result_oversample(self) -> int:
        return self.index.result_oversample

    def default_max_scan(self, nprobe: int, slack: float = 1.3) -> int:
        return self.index.default_max_scan(nprobe, slack)

    @property
    def epoch(self) -> int:
        return getattr(self.index, "epoch", 0)

    @property
    def version(self) -> int:
        return getattr(self.index, "version", 0)

    # mutation passthrough (streaming base only) ------------------------
    def _stream(self) -> StreamingIndex:
        if not self.streaming:
            raise TypeError(
                "mutations need a streaming base: shard a StreamingIndex "
                "(index.streaming().shard(mesh)) instead of a frozen "
                "RairsIndex")
        return self.index

    def insert(self, x) -> np.ndarray:
        """Append through the base's delta path; placed state and open
        sessions refresh lazily on the next ``searcher()`` fetch."""
        return self._stream().insert(x)

    def delete(self, ids) -> int:
        return self._stream().delete(ids)

    def compact(self, reason: str = "manual") -> dict:
        """Fold delta + tombstones on the base; the fresh epoch's block
        arrays are re-sharded over the mesh on the next session fetch."""
        return self._stream().compact(reason=reason)

    def live_ids(self) -> np.ndarray:
        return self._stream().live_ids()

    def live_vectors(self):
        return self._stream().live_vectors()

    # ------------------------------------------------------------------
    # mesh placement
    # ------------------------------------------------------------------
    def _put(self, x, spec) -> jnp.ndarray:
        return jax.device_put(jnp.asarray(x), NamedSharding(self.mesh, spec))

    def _place_base(self, base) -> _BasePlacement:
        """Place one epoch's immutable base layout (the expensive part:
        the full block store crosses host->device once per epoch)."""
        nd = self.ndev
        sh, rep = P(self.axes), P()
        arrays = base.arrays
        owned_np = np.asarray(arrays.owned)
        bo_np = np.asarray(arrays.block_other)
        owned_other = np.where(owned_np >= 0,
                               bo_np[np.maximum(owned_np, 0), 0], -1
                               ).astype(np.int32)
        codes = _pad_rows(np.asarray(arrays.block_codes), nd, 0)
        bids = _pad_rows(np.asarray(arrays.block_ids), nd, -1)
        both = _pad_rows(np.asarray(arrays.block_other), nd, -1)
        lanes = np.arange(nd, dtype=np.int32)
        tb_l = codes.shape[0] // nd
        return _BasePlacement(
            block_codes=self._put(codes, sh),
            block_ids=self._put(bids, sh),
            block_other=self._put(both, sh),
            block_lo=self._put(lanes * tb_l, sh),
            dev_rank=self._put(lanes, sh),
            owned=self._put(arrays.owned, rep),
            owned_other=self._put(owned_other, rep),
            refs=self._put(arrays.refs, rep),
            refs_other=self._put(arrays.refs_other, rep),
            misc=self._put(arrays.misc, rep),
            centroids=self._put(base.centroids, rep),
            codebooks=self._put(base.codebook.codebooks, rep))

    def _build_state(self) -> _PlacedState:
        idx = self.index
        nd = self.ndev
        pl = self._placement
        sh, rep = P(self.axes), P()
        if self.streaming:
            dev = idx._device_state()      # id-aligned base+delta mirrors
            base = idx.base
            vectors_full = np.asarray(dev.vectors_full)
            delta_codes = self._put(dev.delta_codes, rep)
            delta_ids = self._put(dev.delta_ids, rep)
            live = self._put(dev.live_full, rep)
            cap = dev.capacity
        else:
            base = idx
            vectors_full = np.asarray(idx.vectors)
            delta_codes = self._put(
                np.zeros((0, base.codebook.m), np.uint8), rep)
            delta_ids = self._put(np.zeros((0,), np.int32), rep)
            live = self._put(np.zeros((0,), bool), rep)
            cap = 0
        if pl.base is None or pl.base_epoch != self.epoch:
            pl.base = self._place_base(base)
            pl.base_epoch = self.epoch
            pl.plane_base.clear()
            pl.plane_delta.clear()
        vecs = _pad_rows(vectors_full, nd, 0.0)
        n_l = vecs.shape[0] // nd
        lanes = np.arange(nd, dtype=np.int32)
        return _PlacedState(
            base=pl.base,
            vectors=self._put(vecs, sh),
            vec_lo=self._put(lanes * n_l, sh),
            delta_codes=delta_codes, delta_ids=delta_ids, live=live,
            signature=(pl.base.block_ids.shape[0], vecs.shape[0], cap, nd))

    def plane(self, backend: str, codec=None):
        """Forwarded plane accessor (``Searcher.__init__`` resolves the
        session's plane through it): the wrapped index owns the codec
        and the host-side layout; the mesh placement happens separately
        in ``_plane_args``."""
        return self.index.plane(backend, codec=codec)

    def _plane_args(self, plane) -> tuple:
        """Mesh placements of one compact plane: packed block codes
        padded and row-sharded exactly like the base block store (same
        padded TB, so per-device block-id windows line up), codec books
        and the delta's plane codes replicated.  Cached per epoch /
        version on the shared placement like their full-width twins."""
        pl = self._placement
        sh, rep = P(self.axes), P()
        hit = pl.plane_base.get(plane.backend)
        if hit is None:
            codes = _pad_rows(np.asarray(plane.block_codes), self.ndev, 0)
            hit = (self._put(codes, sh),
                   self._put(plane.codec.codebooks, rep))
            pl.plane_base[plane.backend] = hit
        key = (plane.backend, self.version)
        dhit = pl.plane_delta.get(plane.backend)
        if dhit is None or dhit[0] != key:
            if self.streaming:
                dcodes = self.index._plane_delta_codes(plane.backend)
            else:
                dcodes = np.zeros(
                    (0, int(plane.codec.codebooks.shape[0])), np.uint8)
            dhit = (key, self._put(dcodes, rep))
            pl.plane_delta[plane.backend] = dhit
        return hit[0], hit[1], dhit[1]

    def _ensure_state(self) -> _PlacedState:
        pl = self._placement
        v = self.version
        if pl.state is None or pl.version != v:
            pl.state = self._build_state()
            pl.version = v
        return pl.state

    def derived_max_scan_local(self, nprobe: int) -> int:
        """Per-device plan budget from per-shard list occupancy.

        For each device, every list contributes only the table entries
        (owned/refs/misc) whose block falls inside that device's
        block-id range; the worst query can select at most the
        ``nprobe`` fullest such lists, so the sum of their local counts
        is a safe upper bound on any local plan size — by construction
        the derived budget never truncates a plan, hence is
        recall-neutral (tests/test_plan.py).  Sessions use
        ``min(params.max_scan, derived)`` when ``max_scan_local`` is
        unset: strictly tighter padded scan bounds than replicating the
        full per-query budget on every shard, and on one device
        bitwise-identical to the plain Searcher in both regimes (either
        the old budget applies, or nothing truncates anywhere).
        Cached per (epoch, nprobe, ndev) on the shared placement."""
        pl = self._placement
        key = (self.epoch, nprobe, self.ndev)
        if key not in pl.budget_cache:
            base = self.index.base if self.streaming else self.index
            arrays = base.arrays
            nd = self.ndev
            tb = np.asarray(arrays.block_codes).shape[0]
            tb_l = (tb + (-tb) % nd) // nd        # padded rows per device
            nlist = base.config.nlist
            counts = np.zeros((nlist, nd), np.int64)
            for tbl in (arrays.owned, arrays.refs, arrays.misc):
                t = np.asarray(tbl)
                rows = np.repeat(np.arange(t.shape[0]), t.shape[1])
                blocks = t.ravel()
                ok = blocks >= 0
                np.add.at(counts, (rows[ok], blocks[ok] // tb_l), 1)
            top = np.sort(counts, axis=0)[::-1][:nprobe]
            pl.budget_cache[key] = max(int(top.sum(axis=0).max()), 1)
        return pl.budget_cache[key]

    # ------------------------------------------------------------------
    # sessions
    # ------------------------------------------------------------------
    def searcher(self, params: Optional[SearchParams] = None,
                 **kwargs) -> "ShardedSearcher":
        """Create (or fetch) a compiled mesh session for `params`.

        Same contract as the single-host ``searcher()``: sessions are
        cached per params object; over a streaming base a cached session
        is returned only while the index has not mutated past it —
        stale sessions are dropped (stats folded) and replaced, with
        executables shared through a shape-keyed cache.
        """
        if params is None:
            params = SearchParams(**kwargs)
        elif kwargs:
            params = dataclasses.replace(params, **kwargs)
        if params.plan_reuse:
            raise ValueError(
                "plan_reuse is a single-host session feature (the plan "
                "cache merges host-side between dispatches); mesh "
                "sessions support exec_mode='clustered' for per-device "
                "tile unions instead")
        sess = self._sessions.get(params)
        if sess is not None and sess.version == self.version:
            return sess
        if sess is not None:
            self._fold_session(sess)
            self._n_invalidations += 1
        sess = ShardedSearcher(self, params)
        self._sessions[params] = sess
        return sess

    def search(self, queries: jnp.ndarray, k: int, nprobe: int,
               k_factor: int = 10, max_scan: Optional[int] = None,
               exec_mode: str = "paged", query_tile: int = 8
               ) -> SearchResult:
        """Convenience kwarg path mirroring ``RairsIndex.search``."""
        return self.searcher(SearchParams(
            k=k, nprobe=nprobe, k_factor=k_factor, max_scan=max_scan,
            exec_mode=exec_mode, query_tile=query_tile))(queries)

    def _fold_session(self, sess: "Searcher"):
        for key, v in sess.stats.as_dict().items():
            self._retired[key] = self._retired.get(key, 0) + v

    def searcher_stats(self) -> dict:
        live = list(self._sessions.values())
        out = {
            "sessions": len(live) + self._n_invalidations,
            "invalidations": self._n_invalidations,
            "ndev": self.ndev,
            "epoch": self.epoch,
            "version": self.version,
        }
        for key in ("compiles", "cache_hits"):
            out[key] = (self._retired.get(key, 0)
                        + sum(getattr(s.stats, key) for s in live))
        return out


class ShardedSearcher(Searcher):
    """A compiled shard_map session over one ``ShardedIndex``.

    Identical outer machinery to ``Searcher`` (create via
    ``sharded.searcher(params)``): pad-and-dispatch batch buckets,
    chunking, compile/cache stats, and — over a streaming base —
    (epoch, version) pinning with deterministic ``StaleSessionError``.
    Only the two lowering hooks differ: ``_lower`` jits the
    ``build_serve_step`` shard_map program over the mesh, and
    ``_call_inputs`` feeds the placed shard arrays.
    """

    def __init__(self, sharded: ShardedIndex, params: SearchParams):
        self.sharded = sharded
        self.version = sharded.version
        state = sharded._ensure_state()
        super().__init__(sharded.index, params)
        self.epoch = sharded.epoch
        self._state = state
        # per-device plan budget: explicit max_scan_local, or derived
        # from per-shard list occupancy (never truncates, so tighter
        # padded bounds stay recall-neutral) capped by the per-query one
        self.max_scan_local = (
            sharded.max_scan_local if sharded.max_scan_local is not None
            else min(self.params.max_scan,
                     sharded.derived_max_scan_local(self.params.nprobe)))
        # executables depend on (params, per-device budget, shapes) only
        # — arrays are runtime args — so sibling views and later epochs
        # with equal shapes share them (the resolved budget keys the
        # cache: a new epoch may derive a different bound)
        self._compiled = sharded._placement.exec_cache.setdefault(
            (self.params, self.max_scan_local, state.signature), {})

    def _check_current(self) -> None:
        sh = self.sharded
        if self.version != sh.version:
            raise StaleSessionError(
                f"sharded session pinned (epoch {self.epoch}, version "
                f"{self.version}) but the index is at (epoch {sh.epoch}, "
                f"version {sh.version}); mutations invalidate sessions — "
                f"re-fetch via sharded.searcher(params)")

    def _serve_args(self) -> tuple:
        """Runtime serve-step arguments, with the compact-plane
        substitution applied when a refine tier is active: sharded
        packed block codes for the block store, the plane codec's books
        for the LUT source, the plane's delta codes for the delta scan.
        Everything else — vectors, tables, tombstones — is untouched;
        tier-2 owner refinement runs over the exact shard vectors."""
        args = self._state.serve_args()
        if self._plane is None:
            return args
        bc, cb, dc = self.sharded._plane_args(self._plane)
        args = list(args)
        args[0], args[9], args[14] = bc, cb, dc
        return tuple(args)

    def _build_step(self, stage: str):
        sh = self.sharded
        p = self.params
        idx = sh.index
        return build_serve_step(
            nprobe=p.nprobe, bigk=p.bigk_eff, k=p.k,
            max_scan_local=self.max_scan_local,
            metric=idx.config.metric,
            dedup_results=idx.needs_result_dedup,
            oversample=idx.result_oversample,
            exec_mode=p.exec_mode, query_tile=p.query_tile,
            axes=sh.axes, ndev=sh.ndev, streaming=sh.streaming,
            use_kernel=p.use_kernel, fused_topk=p.fused_topk, stage=stage,
            packed_codes=self._plane is not None)

    def _lower(self, bucket: int):
        sh = self.sharded
        serve = self._build_step("all")
        s, r = P(sh.axes), P()
        fn = jax.jit(shard_map(
            serve, mesh=sh.mesh,
            in_specs=(s, s, s,                 # block shard
                      r, r, r, r, r,           # list tables
                      r, r,                    # centroids, codebooks
                      s, s, s, s,              # vectors, vec_lo/block_lo/rank
                      r, r, r,                 # delta + tombstones
                      r),                      # queries
            out_specs=SearchResult(ids=r, dists=r, approx_dco=r,
                                   refine_dco=r, scanned_blocks=r,
                                   dropped_blocks=r)))
        q_spec = jax.ShapeDtypeStruct(
            (bucket, sh.index.vectors.shape[1]), jnp.float32)
        return fn.lower(*self._serve_args(), q_spec)

    def _call_inputs(self) -> tuple:
        return self._serve_args()

    # -- traced two-program split (DESIGN.md §11) ----------------------
    def _lower_stage_scan(self, bucket: int):
        """Lower the per-shard scan half: same in_specs as the fused
        program; the per-device candidate streams come out sharded on
        their fetch axis (global width fetch*ndev)."""
        sh = self.sharded
        s, r = P(sh.axes), P()
        cand = P(None, sh.axes)
        fn = jax.jit(shard_map(
            self._build_step("scan"), mesh=sh.mesh,
            in_specs=(s, s, s, r, r, r, r, r, r, r, s, s, s, s, r, r, r, r),
            out_specs=(cand, cand, r, r, r)))
        q_spec = jax.ShapeDtypeStruct(
            (bucket, sh.index.vectors.shape[1]), jnp.float32)
        return fn.lower(*self._serve_args(), q_spec)

    def _lower_stage_tail(self, bucket: int, l_d, l_ids):
        """Lower the gather/finalize tail against the scan half's
        candidate-stream shapes: each device slices its own fetch
        columns back out, all_gathers, and refines owner-scored exact
        distances — identical collectives to the fused program."""
        sh = self.sharded
        st = self._state
        s, r = P(sh.axes), P()
        cand = P(None, sh.axes)
        fn = jax.jit(shard_map(
            self._build_step("tail"), mesh=sh.mesh,
            in_specs=(s, s, r, cand, cand),
            out_specs=(r, r, r)))
        q_spec = jax.ShapeDtypeStruct(
            (bucket, sh.index.vectors.shape[1]), jnp.float32)
        spec = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), (l_d, l_ids))
        return fn.lower(st.vectors, st.vec_lo, q_spec, *spec)

    def _dispatch_traced(self, bucket: int, qc):
        """Stage-fenced mesh dispatch: the shard_map program split at
        the preselect/all_gather boundary into two AOT executables, so
        a trace separates per-shard scan time from the gather/merge
        tail — the two halves the multi-device regression hides in."""
        sh = self.sharded
        st = self._state
        scan_exe = self._get_exe(("tscan", bucket),
                                 lambda: self._lower_stage_scan(bucket))
        with obs.span("stage.shard_scan", cat="device", bucket=bucket,
                      ndev=sh.ndev) as sp:
            l_d, l_ids, approx_dco, scanned, dropped = obs.fence(
                scan_exe(*self._call_inputs(), qc))
            sp.add(approx_dco=int(np.sum(np.asarray(approx_dco))),
                   scanned_blocks=int(np.sum(np.asarray(scanned))))
        tail_exe = self._get_exe(
            ("ttail", bucket),
            lambda: self._lower_stage_tail(bucket, l_d, l_ids))
        with obs.span("stage.gather_finalize", cat="device", bucket=bucket,
                      ndev=sh.ndev) as sp:
            out_ids, out_d, refine_dco = obs.fence(
                tail_exe(st.vectors, st.vec_lo, qc, l_d, l_ids))
            sp.add(refine_dco=int(np.sum(np.asarray(refine_dco))))
        return SearchResult(
            ids=out_ids, dists=out_d, approx_dco=approx_dco,
            refine_dco=refine_dco, scanned_blocks=scanned,
            dropped_blocks=dropped)
