"""RAIRS core: redundant assignment (RAIR/AIR) + shared-cell lists (SEIL).

The paper's primary contribution as a composable JAX module: k-means IVF
training, product quantization, AIR-metric assignment, SEIL layout, and
the static-shape deduplicating searcher with exact refinement.
"""
from .assign import (rair_assign, rair_assign_multi, single_assign,  # noqa
                     candidate_lists, air_skip_fraction,
                     STRATEGY_REGISTRY, register_strategy, get_strategy,
                     available_strategies)
from .engine import (EXEC_MODES, BlockStore, ListSelection, ListTables,  # noqa
                     QueryPlan, ScanOut, plan_blocks, scan_blocks,
                     select_lists, finalize_candidates, cluster_order,
                     tile_signatures, tile_unions, union_dims, union_live,
                     merge_unions_host)
from .index import IndexConfig, RairsIndex, build_index, insert_batch  # noqa
from ..errors import CorruptBundleError  # noqa
from .io import (CHECKSUM_FORMAT_VERSION, INDEX_FORMAT,  # noqa
                 INDEX_FORMAT_VERSION, PLANE_FORMAT_VERSION,
                 SHARDED_FORMAT_VERSION, load_index, read_index_meta,
                 save_index)
from .params import (MAX_AUTO_BUCKET, REFINE_PLANES, RefineParams,  # noqa
                     SearchParams)
from .searcher import PlanStats, Searcher, SearcherStats  # noqa
from .sharded import ShardedIndex, ShardedSearcher, shard_index  # noqa
from .distributed import build_serve_step, distributed_search  # noqa
from .stream import (PendingCompaction, StaleSessionError,  # noqa
                     StreamConfig, StreamingIndex, StreamingSearcher,
                     StreamStats, streaming_search)
from .kmeans import kmeans_fit, kmeans_step_sharded, pairwise_sq_l2  # noqa
from .metrics import ground_truth, recall_at_k, per_query_recall, dco_summary  # noqa
from .pq import PQCodebook, pq_train, pq_encode, pq_lut, pq_adc, pq_decode  # noqa
from .search import seil_search, SearchResult  # noqa
from .seil import (SeilArrays, SeilStats, build_seil, build_seil_call_count,  # noqa
                   cell_stats, vectors_in_large_cells, build_id_map,
                   delete_ids)
