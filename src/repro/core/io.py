"""Index persistence — save/load a built index as one npz bundle.

The bundle holds every array the query path needs (centroids, PQ
codebooks, SEIL block store + per-list tables, refine vectors) plus the
build-side state that makes the index appendable (assignments, cached PQ
codes), so ``load_index`` returns an object equivalent to the one
``build_index`` produced: searches, ``insert_batch`` and ``searcher``
sessions all work without re-training (tests/test_searcher.py asserts
result equality).

Config / stats / provenance travel as a JSON document embedded in the
npz (as a uint8 array — no pickling), headed by a format name and
version so future layout changes stay detectable.

Format v2 (DESIGN.md §8) adds optional *streaming* state: a bundle may
carry a ``StreamingIndex`` — the base epoch arrays exactly as before,
plus the delta segment (vectors/codes/assigns/liveness) and the base
tombstone bitmap (bit-packed), with epoch/version counters in the JSON
meta.  ``save_index`` accepts either index type; ``load_index`` returns
whichever type the bundle holds.  v1 bundles (pre-streaming) load
unchanged — v1 is exactly "v2 with no streaming section".
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Union

import jax.numpy as jnp
import numpy as np

from .index import IndexConfig, RairsIndex
from .pq import PQCodebook
from .seil import SeilArrays, SeilStats
from .stream import StreamConfig, StreamingIndex

INDEX_FORMAT = "rairs-index"
INDEX_FORMAT_VERSION = 2
READ_FORMAT_VERSIONS = (1, 2)   # v1 = v2 without the streaming section

_SEIL_FIELDS = ("block_codes", "block_ids", "block_other", "owned",
                "refs", "refs_other", "misc")


def save_index(index: Union[RairsIndex, StreamingIndex],
               path: Union[str, os.PathLike], extra: dict = None) -> None:
    """Write `index` to `path` as a compressed npz bundle (exact path —
    no implicit .npz suffix is appended).  `extra` is a JSON-able dict
    of caller provenance (e.g. {"dataset": "sift1m"}) stored alongside
    the config and readable via ``read_index_meta``.  A StreamingIndex
    is persisted without compacting: the delta segment and tombstones
    round-trip as-is."""
    stream = index if isinstance(index, StreamingIndex) else None
    base = stream.base if stream is not None else index
    meta = {
        "format": INDEX_FORMAT,
        "format_version": INDEX_FORMAT_VERSION,
        "config": dataclasses.asdict(base.config),
        "stats": dataclasses.asdict(base.stats),
        "build_seconds": base.build_seconds,
        "has_codes": base.codes is not None,
        "extra": dict(extra or {}),
    }
    arrays = {
        "centroids": np.asarray(base.centroids),
        "codebooks": np.asarray(base.codebook.codebooks),
        "vectors": np.asarray(base.vectors),
        "assigns": np.asarray(base.assigns),
    }
    for f in _SEIL_FIELDS:
        arrays[f] = np.asarray(getattr(base.arrays, f))
    if base.codes is not None:
        arrays["codes"] = np.asarray(base.codes)
    if stream is not None:
        d = stream._delta
        meta["streaming"] = {
            "epoch": stream.epoch,
            "version": stream.version,
            "delta_count": int(d.count),
            "stream_config": dataclasses.asdict(stream.stream_config),
        }
        arrays["delta_vectors"] = d.vectors[:d.count]
        arrays["delta_codes"] = d.codes[:d.count]
        arrays["delta_assigns"] = d.assigns[:d.count]
        arrays["delta_live"] = d.live[:d.count]
        arrays["base_live"] = np.packbits(stream._base_live)
    arrays["meta_json"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), np.uint8)
    with open(path, "wb") as fh:
        np.savez_compressed(fh, **arrays)


def _check_meta(path, z) -> dict:
    if "meta_json" not in z:
        raise ValueError(f"{path}: not a {INDEX_FORMAT} bundle")
    meta = json.loads(bytes(z["meta_json"].tobytes()).decode("utf-8"))
    if meta.get("format") != INDEX_FORMAT:
        raise ValueError(
            f"{path}: format {meta.get('format')!r} != {INDEX_FORMAT!r}")
    version = meta.get("format_version")
    if version not in READ_FORMAT_VERSIONS:
        raise ValueError(
            f"{path}: unsupported format_version {version} "
            f"(this build reads versions {READ_FORMAT_VERSIONS})")
    return meta


def read_index_meta(path: Union[str, os.PathLike]) -> dict:
    """Read only the JSON metadata of a bundle (config / stats / extra
    provenance) without materializing the arrays."""
    with np.load(path, allow_pickle=False) as z:
        return _check_meta(path, z)


def load_index(path: Union[str, os.PathLike]
               ) -> Union[RairsIndex, StreamingIndex]:
    """Load a bundle written by ``save_index``.

    Returns a plain ``RairsIndex`` for frozen bundles (all v1 bundles,
    and v2 bundles saved from a RairsIndex) or a ``StreamingIndex`` —
    delta segment, tombstones and epoch/version counters restored —
    when the bundle carries streaming state."""
    with np.load(path, allow_pickle=False) as z:
        meta = _check_meta(path, z)
        cfg = IndexConfig(**meta["config"])
        arrays = SeilArrays(**{f: jnp.asarray(z[f]) for f in _SEIL_FIELDS})
        base = RairsIndex(
            config=cfg,
            centroids=jnp.asarray(z["centroids"]),
            codebook=PQCodebook(jnp.asarray(z["codebooks"])),
            arrays=arrays,
            vectors=jnp.asarray(z["vectors"]),
            stats=SeilStats(**meta["stats"]),
            assigns=np.asarray(z["assigns"]),
            codes=np.asarray(z["codes"]) if meta["has_codes"] else None,
            build_seconds=dict(meta.get("build_seconds", {})),
        )
        sm = meta.get("streaming")
        if sm is None:
            return base
        stream = StreamingIndex(base, StreamConfig(**sm["stream_config"]))
        stream.restore_state(
            epoch=sm["epoch"], version=sm["version"],
            base_live=np.unpackbits(
                z["base_live"], count=base.vectors.shape[0]).astype(bool),
            delta_vectors=np.asarray(z["delta_vectors"]),
            delta_codes=np.asarray(z["delta_codes"]),
            delta_assigns=np.asarray(z["delta_assigns"]),
            delta_live=np.asarray(z["delta_live"], bool),
        )
        return stream
