"""Index persistence — single-file npz bundles and sharded v3 bundles.

The bundle holds every array the query path needs (centroids, PQ
codebooks, SEIL block store + per-list tables, refine vectors) plus the
build-side state that makes the index appendable (assignments, cached PQ
codes), so ``load_index`` returns an object equivalent to the one
``build_index`` produced: searches, ``insert_batch`` and ``searcher``
sessions all work without re-training (tests/test_searcher.py asserts
result equality).

Config / stats / provenance travel as a JSON document embedded in the
npz (as a uint8 array — no pickling), headed by a format name and
version so future layout changes stay detectable.

Format v2 (DESIGN.md §8) adds optional *streaming* state: a bundle may
carry a ``StreamingIndex`` — the base epoch arrays exactly as before,
plus the delta segment (vectors/codes/assigns/liveness) and the base
tombstone bitmap (bit-packed), with epoch/version counters in the JSON
meta.  ``save_index`` accepts either index type; ``load_index`` returns
whichever type the bundle holds.  v1 bundles (pre-streaming) load
unchanged — v1 is exactly "v2 with no streaming section".

Format v3 (DESIGN.md §4) is the *sharded* layout for mesh deployments:
``save_index(index, path, shards=N)`` (or passing a ``ShardedIndex``)
writes a directory —

    path/MANIFEST.json   format header, shard row ranges, embedded meta
    path/common.npz      replicated arrays: centroids, codebooks, the
                         per-list SEIL tables, streaming state
    path/shard_0000.npz… row shards: block arrays by block-id range,
                         vectors/assigns/codes by vector-id range

Shard count in the file layout is independent of the serving mesh
(ranges are even splits of the unpadded arrays), so a 4-shard bundle
loads onto an 8-device mesh and vice versa.  ``load_index`` reassembles
and returns the same index type as the v1/v2 path — pass ``mesh=`` to
get a ``ShardedIndex`` back directly.  v1/v2 single-file bundles load
unchanged (asserted against golden fixtures in tests/test_io_compat.py).

Format v4 (DESIGN.md §12) adds the *quantization ladder*: attached
compact planes persist as their codec books plus the per-id codes —
the canonical pair from which the packed SEIL block layout re-derives
deterministically on load (``quant.plane_block_codes`` is a pure
gather), so the scan-form array never needs to travel.  A bundle is
written as v4 **only when planes are attached**; an index without
planes round-trips byte-identically to the v2/v3 writer, and v1-v3
bundles load exactly as before — v4 is strictly additive.

Format v5 (DESIGN.md §13) is *crash-safe, checksummed* persistence —
the layouts above, hardened:

  * every file is written to a temp name, fsynced, and atomically
    renamed into place (``os.replace``), so a crash mid-save never
    leaves a half-written file under the bundle's name;
  * sharded bundles write content-addressed member files
    (``shard_0000-<crc>.npz``) and commit by atomically replacing the
    manifest *last* — an interrupted save leaves the previous
    manifest, and therefore the previous complete bundle, loadable
    (stale members from the failed attempt are swept on the next
    successful commit);
  * the meta carries a per-array crc32 table; ``load_index`` verifies
    every array it materializes and rejects truncated or bit-flipped
    bundles with ``CorruptBundleError`` naming the bad member.

v1-v4 bundles predate the checksum table and load unchanged (no table
-> nothing to verify); every new save writes v5.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Callable, Optional, Union

import jax.numpy as jnp
import numpy as np

from .. import faults
from ..errors import CorruptBundleError
from .index import IndexConfig, RairsIndex
from .pq import PQCodebook
from .seil import SeilArrays, SeilStats
from .stream import StreamConfig, StreamingIndex

INDEX_FORMAT = "rairs-index"
INDEX_FORMAT_VERSION = 2          # single-file bundles without planes
SHARDED_FORMAT_VERSION = 3        # manifest + per-shard bundles
PLANE_FORMAT_VERSION = 4          # either layout + attached compact planes
CHECKSUM_FORMAT_VERSION = 5       # atomic writes + per-array crc32 table
READ_FORMAT_VERSIONS = (1, 2, 3, 4, 5)  # v1 = v2 minus the streaming section
MANIFEST_NAME = "MANIFEST.json"

_SEIL_FIELDS = ("block_codes", "block_ids", "block_other", "owned",
                "refs", "refs_other", "misc")
# v3 split of the SEIL arrays: block store shards by block-id range,
# the per-list directory replicates in common.npz
_BLOCK_FIELDS = ("block_codes", "block_ids", "block_other")
_TABLE_FIELDS = ("owned", "refs", "refs_other", "misc")
_VECTOR_FIELDS = ("vectors", "assigns", "codes")   # shard by vector-id range
_STREAM_FIELDS = ("delta_vectors", "delta_codes", "delta_assigns",
                  "delta_live", "base_live")


def _fsync_dir(dirname: str) -> None:
    """Best-effort directory fsync so the rename itself is durable
    (no-op on platforms/filesystems that refuse O_RDONLY dir opens)."""
    try:
        fd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write(path: Union[str, os.PathLike],
                  write: Callable) -> None:
    """Crash-safe file write: temp name in the same directory, fsync,
    then ``os.replace`` into place — readers only ever see the old
    complete file or the new complete file, never a torn one."""
    path = os.fspath(path)
    d = os.path.dirname(path) or "."
    tmp = os.path.join(d, f".{os.path.basename(path)}.tmp.{os.getpid()}")
    try:
        with open(tmp, "wb") as fh:
            write(fh)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    _fsync_dir(d)


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _checksums(arrays: dict) -> dict:
    return {name: _crc(a) for name, a in arrays.items()}


def _gather_arrays(index: Union[RairsIndex, StreamingIndex],
                   extra: Optional[dict]) -> tuple:
    """(meta, arrays) shared by the single-file and sharded writers."""
    stream = index if isinstance(index, StreamingIndex) else None
    base = stream.base if stream is not None else index
    meta = {
        "format": INDEX_FORMAT,
        "format_version": CHECKSUM_FORMAT_VERSION,
        "config": dataclasses.asdict(base.config),
        "stats": dataclasses.asdict(base.stats),
        "build_seconds": base.build_seconds,
        "has_codes": base.codes is not None,
        "extra": dict(extra or {}),
    }
    arrays = {
        "centroids": np.asarray(base.centroids),
        "codebooks": np.asarray(base.codebook.codebooks),
        "vectors": np.asarray(base.vectors),
        "assigns": np.asarray(base.assigns),
    }
    for f in _SEIL_FIELDS:
        arrays[f] = np.asarray(getattr(base.arrays, f))
    if base.codes is not None:
        arrays["codes"] = np.asarray(base.codes)
    if stream is not None:
        d = stream._delta
        meta["streaming"] = {
            "epoch": stream.epoch,
            "version": stream.version,
            "delta_count": int(d.count),
            "stream_config": dataclasses.asdict(stream.stream_config),
        }
        arrays["delta_vectors"] = d.vectors[:d.count]
        arrays["delta_codes"] = d.codes[:d.count]
        arrays["delta_assigns"] = d.assigns[:d.count]
        arrays["delta_live"] = d.live[:d.count]
        arrays["base_live"] = np.packbits(stream._base_live)
    # quantization-ladder planes (v4+): codec books + per-id codes only —
    # the packed block layout is a deterministic gather, re-derived on
    # load.
    planes = getattr(base, "_planes", None) or {}
    if planes:
        meta["planes"] = sorted(planes)
        for b in sorted(planes):
            pp = planes[b]
            arrays[f"plane_{b}_codebooks"] = np.asarray(
                pp.codec.codebooks, np.float32)
            arrays[f"plane_{b}_codes"] = np.asarray(pp.codes, np.uint8)
    return meta, arrays


def save_index(index, path: Union[str, os.PathLike], extra: dict = None,
               *, shards: Optional[int] = None) -> None:
    """Write `index` to `path`.

    Default: one compressed npz bundle at exactly `path` (no implicit
    .npz suffix).  With ``shards=N`` — or when `index` is a
    ``ShardedIndex``, defaulting N to its device count — `path` becomes
    a directory holding a v3 manifest + per-shard bundles (see module
    docstring).  `extra` is a JSON-able dict of caller provenance
    (e.g. {"dataset": "sift1m"}) readable via ``read_index_meta``.  A
    StreamingIndex is persisted without compacting: the delta segment
    and tombstones round-trip as-is."""
    from .sharded import ShardedIndex
    if isinstance(index, ShardedIndex):
        shards = shards or index.ndev
        index = index.index
    meta, arrays = _gather_arrays(index, extra)
    if shards is None:
        meta["checksums"] = _checksums(arrays)
        arrays["meta_json"] = np.frombuffer(
            json.dumps(meta).encode("utf-8"), np.uint8)
        _atomic_write(path,
                      lambda fh: np.savez_compressed(fh, **arrays))
        return
    _save_sharded(meta, arrays, path, int(shards))


def _splits(n: int, shards: int):
    """Even [lo, hi) row ranges (np.array_split semantics)."""
    bounds = np.linspace(0, n, shards + 1).astype(np.int64)
    return [(int(bounds[i]), int(bounds[i + 1])) for i in range(shards)]


def _member_token(checksums: dict) -> str:
    """Short content token for a member file, derived from its arrays'
    crc32 table — two saves of different content never collide on a
    member name, so a crashed save cannot tear a file the committed
    manifest still points at."""
    blob = json.dumps(checksums, sort_keys=True).encode()
    return f"{zlib.crc32(blob):08x}"


def _save_sharded(meta: dict, arrays: dict, path, shards: int) -> None:
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    os.makedirs(path, exist_ok=True)
    tb = arrays["block_ids"].shape[0]
    n = arrays["vectors"].shape[0]
    block_rows = _splits(tb, shards)
    vector_rows = _splits(n, shards)
    shard_files, checksums = [], {}
    for s in range(shards):
        blo, bhi = block_rows[s]
        vlo, vhi = vector_rows[s]
        payload = {f: arrays[f][blo:bhi] for f in _BLOCK_FIELDS}
        for f in _VECTOR_FIELDS:
            if f in arrays:
                payload[f] = arrays[f][vlo:vhi]
        crcs = _checksums(payload)
        fname = f"shard_{s:04d}-{_member_token(crcs)}.npz"
        _atomic_write(os.path.join(path, fname),
                      lambda fh, p=payload: np.savez_compressed(fh, **p))
        shard_files.append(fname)
        checksums[fname] = crcs
    common = {f: arrays[f] for f in ("centroids", "codebooks")}
    for f in _TABLE_FIELDS + _STREAM_FIELDS:
        if f in arrays:
            common[f] = arrays[f]
    # plane payloads are tiny (Mc << M) — they replicate with the tables
    for f in arrays:
        if f.startswith("plane_"):
            common[f] = arrays[f]
    common_crcs = _checksums(common)
    common_name = f"common-{_member_token(common_crcs)}.npz"
    _atomic_write(os.path.join(path, common_name),
                  lambda fh: np.savez_compressed(fh, **common))
    checksums[common_name] = common_crcs
    manifest = {
        "format": INDEX_FORMAT,
        "format_version": CHECKSUM_FORMAT_VERSION,
        "shards": shards,
        "common": common_name,
        "shard_files": shard_files,
        "block_rows": block_rows,
        "vector_rows": vector_rows,
        "checksums": checksums,
        "meta": dict(meta, format_version=CHECKSUM_FORMAT_VERSION),
    }
    # the manifest is the commit point: every member is already durable
    # under a content-addressed name, so atomically replacing the
    # manifest flips the whole bundle old -> new; a crash anywhere
    # before this line leaves the previous bundle fully loadable
    _atomic_write(os.path.join(path, MANIFEST_NAME),
                  lambda fh: fh.write(
                      (json.dumps(manifest, indent=1) + "\n").encode()))
    _sweep_orphans(path, {common_name, *shard_files})


def _sweep_orphans(path, live: set) -> None:
    """Post-commit cleanup: drop member files no manifest references
    any more (left by superseded saves or crashed attempts).  Strictly
    best-effort — the bundle is already committed."""
    try:
        entries = os.listdir(path)
    except OSError:
        return
    for fname in entries:
        if fname in live or not fname.endswith(".npz"):
            continue
        if fname.startswith(("shard_", "common")):
            try:
                os.remove(os.path.join(path, fname))
            except OSError:
                pass


def _manifest_path(path) -> Optional[str]:
    """Resolve `path` to a v3 manifest file, or None for single-file."""
    p = os.fspath(path)
    if os.path.isdir(p):
        return os.path.join(p, MANIFEST_NAME)
    if os.path.basename(p) == MANIFEST_NAME:
        return p
    return None


def _check_meta(path, meta: dict) -> dict:
    if meta.get("format") != INDEX_FORMAT:
        raise ValueError(
            f"{path}: format {meta.get('format')!r} != {INDEX_FORMAT!r}")
    version = meta.get("format_version")
    if version not in READ_FORMAT_VERSIONS:
        raise ValueError(
            f"{path}: unsupported format_version {version} "
            f"(this build reads versions {READ_FORMAT_VERSIONS})")
    return meta


def _load_npz_meta(path, z) -> dict:
    if "meta_json" not in z:
        raise ValueError(f"{path}: not a {INDEX_FORMAT} bundle")
    fname = os.path.basename(os.fspath(path))
    raw = _read_members(fname, z, skip=[k for k in z.files
                                        if k != "meta_json"])
    meta = json.loads(bytes(raw["meta_json"].tobytes()).decode("utf-8"))
    _check_meta(path, meta)
    if meta["format_version"] not in (1, INDEX_FORMAT_VERSION,
                                      PLANE_FORMAT_VERSION,
                                      CHECKSUM_FORMAT_VERSION):
        raise ValueError(
            f"{path}: single-file bundles carry format_version 1, "
            f"{INDEX_FORMAT_VERSION}, {PLANE_FORMAT_VERSION} or "
            f"{CHECKSUM_FORMAT_VERSION}, got "
            f"{meta['format_version']} (v{SHARDED_FORMAT_VERSION} bundles "
            f"are directories with a {MANIFEST_NAME})")
    return meta


def _read_manifest(mpath: str) -> dict:
    if not os.path.exists(mpath):
        raise ValueError(f"{mpath}: sharded bundle has no {MANIFEST_NAME}")
    with open(mpath) as fh:
        manifest = json.load(fh)
    _check_meta(mpath, manifest)
    if manifest.get("format_version") not in (SHARDED_FORMAT_VERSION,
                                              PLANE_FORMAT_VERSION,
                                              CHECKSUM_FORMAT_VERSION):
        raise ValueError(
            f"{mpath}: manifest version "
            f"{manifest.get('format_version')} not in "
            f"({SHARDED_FORMAT_VERSION}, {PLANE_FORMAT_VERSION}, "
            f"{CHECKSUM_FORMAT_VERSION})")
    return manifest


def _open_member(path: str):
    """np.load a bundle member, turning truncation / not-a-zip / torn
    header failures into ``CorruptBundleError`` naming the file."""
    import zipfile
    try:
        return np.load(path, allow_pickle=False)
    except (zipfile.BadZipFile, EOFError, OSError, ValueError) as e:
        if not os.path.exists(path):
            raise CorruptBundleError(
                f"{os.path.basename(path)}: bundle member missing") from e
        raise CorruptBundleError(
            f"{os.path.basename(path)}: unreadable "
            f"({type(e).__name__}: {e})") from e


def _read_members(fname: str, z, skip=()) -> dict:
    """Extract every array from an open npz, turning zip-stream decode
    failures (numpy reads members lazily, so a mid-file bitflip only
    surfaces here, not at ``_open_member``) into ``CorruptBundleError``
    naming the offending ``file:member``."""
    import zipfile
    import zlib
    out = {}
    for name in z.files:
        if name in skip:
            continue
        try:
            out[name] = z[name]
        except (zipfile.BadZipFile, zlib.error, EOFError, OSError,
                ValueError) as e:
            raise CorruptBundleError(
                f"{fname}:{name}: unreadable "
                f"({type(e).__name__}: {e})") from e
    return out


def _verify_members(fname: str, members: dict,
                    checksums: Optional[dict]) -> dict:
    """Apply the fault-injection read hook, then verify each array
    against the bundle's crc32 table (v5; earlier formats have no
    table and skip verification).  Raises ``CorruptBundleError``
    naming the offending ``file:member``."""
    out = {}
    for name, arr in members.items():
        arr = faults.corrupt_array("io.read_array", f"{fname}:{name}", arr)
        if checksums is not None:
            want = checksums.get(name)
            if want is None:
                raise CorruptBundleError(
                    f"{fname}:{name}: member absent from the bundle's "
                    f"checksum table")
            got = _crc(arr)
            if got != want:
                raise CorruptBundleError(
                    f"{fname}:{name}: crc32 mismatch "
                    f"(stored {want:#010x}, computed {got:#010x}) — "
                    f"bundle is truncated or bit-flipped")
        out[name] = arr
    return out


def read_index_meta(path: Union[str, os.PathLike]) -> dict:
    """Read only the JSON metadata of a bundle (config / stats / extra
    provenance) without materializing the arrays.  Works on single-file
    bundles and v3 sharded directories alike."""
    mpath = _manifest_path(path)
    if mpath is not None:
        manifest = _read_manifest(mpath)
        return dict(manifest["meta"], shards=manifest["shards"])
    with _open_member(os.fspath(path)) as z:
        return _load_npz_meta(path, z)


def _index_from(meta: dict, get):
    """Rebuild the index object from meta + an array accessor (shared by
    the single-file and sharded loaders)."""
    cfg = IndexConfig(**meta["config"])
    arrays = SeilArrays(**{f: jnp.asarray(get(f)) for f in _SEIL_FIELDS})
    base = RairsIndex(
        config=cfg,
        centroids=jnp.asarray(get("centroids")),
        codebook=PQCodebook(jnp.asarray(get("codebooks"))),
        arrays=arrays,
        vectors=jnp.asarray(get("vectors")),
        stats=SeilStats(**meta["stats"]),
        assigns=np.asarray(get("assigns")),
        codes=np.asarray(get("codes")) if meta["has_codes"] else None,
        build_seconds=dict(meta.get("build_seconds", {})),
    )
    if meta.get("planes"):
        from ..quant import PlanePack, plane_block_codes
        block_ids = np.asarray(arrays.block_ids)
        base._planes = {}
        for b in meta["planes"]:
            codec = PQCodebook(jnp.asarray(get(f"plane_{b}_codebooks")))
            codes = np.asarray(get(f"plane_{b}_codes"), np.uint8)
            base._planes[b] = PlanePack(
                backend=b, codec=codec, codes=codes,
                block_codes=plane_block_codes(codes, block_ids))
    sm = meta.get("streaming")
    if sm is None:
        return base
    stream = StreamingIndex(base, StreamConfig(**sm["stream_config"]))
    if meta.get("planes"):
        # restored codecs are the stream's carried ones: a later
        # compaction re-encodes with them instead of retraining
        stream._plane_codecs.update(
            {b: base._planes[b].codec for b in meta["planes"]})
    stream.restore_state(
        epoch=sm["epoch"], version=sm["version"],
        base_live=np.unpackbits(
            get("base_live"), count=base.vectors.shape[0]).astype(bool),
        delta_vectors=np.asarray(get("delta_vectors")),
        delta_codes=np.asarray(get("delta_codes")),
        delta_assigns=np.asarray(get("delta_assigns")),
        delta_live=np.asarray(get("delta_live"), bool),
    )
    return stream


def _load_sharded(mpath: str):
    manifest = _read_manifest(mpath)
    root = os.path.dirname(mpath)
    table = manifest.get("checksums")
    parts = []
    for fname in manifest["shard_files"] + [manifest["common"]]:
        with _open_member(os.path.join(root, fname)) as z:
            members = _verify_members(
                fname, _read_members(fname, z),
                table.get(fname) if table is not None else None)
        parts.append(members)
    common = parts.pop()

    def get(name):
        if name in common:
            return common[name]
        return np.concatenate([p[name] for p in parts], axis=0)

    meta = dict(manifest["meta"])
    return _index_from(meta, get)


def load_index(path: Union[str, os.PathLike], *, mesh=None, axes=("data",),
               max_scan_local: Optional[int] = None
               ) -> Union[RairsIndex, StreamingIndex]:
    """Load a bundle written by ``save_index`` (any readable version).

    Returns a plain ``RairsIndex`` for frozen bundles (all v1 bundles,
    and v2/v3 bundles saved from a RairsIndex) or a ``StreamingIndex``
    — delta segment, tombstones and epoch/version counters restored —
    when the bundle carries streaming state.  v3 sharded directories
    reassemble transparently.  With ``mesh=`` the loaded index is
    deployed immediately: returns ``loaded.shard(mesh, axes=...)``."""
    mpath = _manifest_path(path)
    if mpath is not None:
        index = _load_sharded(mpath)
    else:
        fname = os.path.basename(os.fspath(path))
        with _open_member(os.fspath(path)) as z:
            meta = _load_npz_meta(path, z)
            members = _verify_members(
                fname, _read_members(fname, z, skip=("meta_json",)),
                meta.get("checksums"))
        index = _index_from(meta, members.__getitem__)
    if mesh is not None:
        return index.shard(mesh, axes=axes, max_scan_local=max_scan_local)
    return index
