"""Index persistence — save/load a built RairsIndex as one npz bundle.

The bundle holds every array the query path needs (centroids, PQ
codebooks, SEIL block store + per-list tables, refine vectors) plus the
build-side state that makes the index appendable (assignments, cached PQ
codes), so ``load_index`` returns an object equivalent to the one
``build_index`` produced: searches, ``insert_batch`` and ``searcher``
sessions all work without re-training (tests/test_searcher.py asserts
result equality).

Config / stats / provenance travel as a JSON document embedded in the
npz (as a uint8 array — no pickling), headed by a format name and
version so future layout changes stay detectable.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Union

import jax.numpy as jnp
import numpy as np

from .index import IndexConfig, RairsIndex
from .pq import PQCodebook
from .seil import SeilArrays, SeilStats

INDEX_FORMAT = "rairs-index"
INDEX_FORMAT_VERSION = 1

_SEIL_FIELDS = ("block_codes", "block_ids", "block_other", "owned",
                "refs", "refs_other", "misc")


def save_index(index: RairsIndex, path: Union[str, os.PathLike],
               extra: dict = None) -> None:
    """Write `index` to `path` as a compressed npz bundle (exact path —
    no implicit .npz suffix is appended).  `extra` is a JSON-able dict
    of caller provenance (e.g. {"dataset": "sift1m"}) stored alongside
    the config and readable via ``read_index_meta``."""
    meta = {
        "format": INDEX_FORMAT,
        "format_version": INDEX_FORMAT_VERSION,
        "config": dataclasses.asdict(index.config),
        "stats": dataclasses.asdict(index.stats),
        "build_seconds": index.build_seconds,
        "has_codes": index.codes is not None,
        "extra": dict(extra or {}),
    }
    arrays = {
        "meta_json": np.frombuffer(json.dumps(meta).encode("utf-8"), np.uint8),
        "centroids": np.asarray(index.centroids),
        "codebooks": np.asarray(index.codebook.codebooks),
        "vectors": np.asarray(index.vectors),
        "assigns": np.asarray(index.assigns),
    }
    for f in _SEIL_FIELDS:
        arrays[f] = np.asarray(getattr(index.arrays, f))
    if index.codes is not None:
        arrays["codes"] = np.asarray(index.codes)
    with open(path, "wb") as fh:
        np.savez_compressed(fh, **arrays)


def _check_meta(path, z) -> dict:
    if "meta_json" not in z:
        raise ValueError(f"{path}: not a {INDEX_FORMAT} bundle")
    meta = json.loads(bytes(z["meta_json"].tobytes()).decode("utf-8"))
    if meta.get("format") != INDEX_FORMAT:
        raise ValueError(
            f"{path}: format {meta.get('format')!r} != {INDEX_FORMAT!r}")
    version = meta.get("format_version")
    if version != INDEX_FORMAT_VERSION:
        raise ValueError(
            f"{path}: unsupported format_version {version} "
            f"(this build reads version {INDEX_FORMAT_VERSION})")
    return meta


def read_index_meta(path: Union[str, os.PathLike]) -> dict:
    """Read only the JSON metadata of a bundle (config / stats / extra
    provenance) without materializing the arrays."""
    with np.load(path, allow_pickle=False) as z:
        return _check_meta(path, z)


def load_index(path: Union[str, os.PathLike]) -> RairsIndex:
    """Load an index bundle written by ``save_index``."""
    with np.load(path, allow_pickle=False) as z:
        meta = _check_meta(path, z)
        cfg = IndexConfig(**meta["config"])
        arrays = SeilArrays(**{f: jnp.asarray(z[f]) for f in _SEIL_FIELDS})
        return RairsIndex(
            config=cfg,
            centroids=jnp.asarray(z["centroids"]),
            codebook=PQCodebook(jnp.asarray(z["codebooks"])),
            arrays=arrays,
            vectors=jnp.asarray(z["vectors"]),
            stats=SeilStats(**meta["stats"]),
            assigns=np.asarray(z["assigns"]),
            codes=np.asarray(z["codes"]) if meta["has_codes"] else None,
            build_seconds=dict(meta.get("build_seconds", {})),
        )
