"""Redundant-assignment strategies: NaiveRA, SOAR(L2), AIR / RAIR / SRAIR.

The AIR metric (paper Theorem 4.1):   loss(c') = ||r'||^2 + lambda * r^T r'
with r = c1 - x (primary residual), r' = c' - x.  lambda=0 degenerates to
NaiveRA; SOAR uses ||r'||^2 + lambda*(r^T r' / ||r||)^2 (orthogonal
preference, inner-product-space original).

m-assignment (paper 4.3):  loss_m(c') = ||r'||^2 + lambda * aggr_i r_i^T r'
over previously selected residuals r_i, aggr in {max, min, avg}.

All functions are jittable and chunk over n; `rair_assign` is the
public entry used by the index builder.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans import pairwise_sq_l2

METRICS = ("naive", "soar", "air")
AGGRS = ("max", "min", "avg")


def candidate_lists(x: jnp.ndarray, centroids: jnp.ndarray, n_cands: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-n_cands nearest lists per vector (ascending distance).

    Returns (cand_ids (n, C) int32, cand_d2 (n, C) f32).
    This is the FindNearestLists of Alg. 3 (exhaustive variant; the
    sublinear-ANN variant is an implementation choice the paper allows).
    """
    d2 = pairwise_sq_l2(x, centroids)
    neg, idx = jax.lax.top_k(-d2, n_cands)
    return idx.astype(jnp.int32), -neg


def _second_loss(x, centroids, cand_ids, cand_d2, metric: str, lam: float):
    """AIR/SOAR/naive loss of every candidate as the 2nd list. (n, C)."""
    c = centroids[cand_ids]                       # (n, C, D)
    r = c - x[:, None, :]                         # residuals (n, C, D)
    r0 = r[:, 0, :]                               # primary residual (n, D)
    d2 = cand_d2                                  # ||r'||^2
    if metric == "naive":
        return d2
    dot = jnp.einsum("nd,ncd->nc", r0, r)         # r^T r'
    if metric == "air":
        return d2 + lam * dot
    if metric == "soar":
        nrm2 = jnp.maximum(jnp.sum(r0 * r0, axis=-1, keepdims=True), 1e-12)
        return d2 + lam * (dot * dot) / nrm2
    raise ValueError(f"unknown metric {metric!r}")


@functools.partial(jax.jit, static_argnames=("metric", "lam", "strict"))
def _assign2_chunk(x, centroids, cand_ids, cand_d2, metric, lam, strict):
    loss = _second_loss(x, centroids, cand_ids, cand_d2, metric, lam)
    if strict:
        # SRAIR: exclude the primary list from the 2nd-choice argmin.
        loss = loss.at[:, 0].set(jnp.inf)
    sec = jnp.take_along_axis(
        cand_ids, jnp.argmin(loss, axis=-1)[:, None], axis=-1)[:, 0]
    first = cand_ids[:, 0]
    lo = jnp.minimum(first, sec)
    hi = jnp.maximum(first, sec)
    return jnp.stack([lo, hi], axis=-1)           # (n, 2), lo==hi => single


def _chunked(fn, x, chunk, *args):
    n = x.shape[0]
    outs = []
    for s in range(0, n, chunk):
        outs.append(fn(x[s:s + chunk], *args))
    return jnp.concatenate(outs, axis=0)


def rair_assign(
    x: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    metric: str = "air",
    lam: float = 0.5,
    n_cands: int = 10,
    strict: bool = False,
    chunk: int = 8192,
) -> jnp.ndarray:
    """Assign each vector to (list1, list2), list1<=list2 (Alg. 3).

    metric='air' strict=False  -> RAIR (paper default)
    metric='air' strict=True   -> SRAIR
    metric='naive' strict=True -> NaiveRA   (2nd-nearest list)
    metric='soar'  strict=True -> SOARL2
    Single assignment baseline: use `single_assign`.
    """
    def fn(xb):
        cids, cd2 = candidate_lists(xb, centroids, n_cands)
        return _assign2_chunk(xb, centroids, cids, cd2, metric, lam, strict)
    return _chunked(fn, x, chunk)


def single_assign(x: jnp.ndarray, centroids: jnp.ndarray, chunk: int = 8192
                  ) -> jnp.ndarray:
    """Baseline: (n, 2) with both entries = nearest list (cell_{i,i})."""
    def fn(xb):
        cids, _ = candidate_lists(xb, centroids, 1)
        return jnp.concatenate([cids, cids], axis=-1)
    return _chunked(fn, x, chunk)


# ----------------------------------------------------------------------------
# m-assignment (paper §4.3): greedy selection with aggregated dot penalty
# ----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("m", "aggr", "lam"))
def _assign_m_chunk(x, centroids, cand_ids, cand_d2, m, aggr, lam):
    n, c = cand_ids.shape
    cand_c = centroids[cand_ids]                  # (n, C, D)
    r = cand_c - x[:, None, :]                    # (n, C, D)
    dots = jnp.einsum("ncd,nkd->nck", r, r)       # r_i^T r_j  (n, C, C)
    d2 = cand_d2

    chosen = jnp.zeros((n, m), jnp.int32)         # indices into candidates
    chosen = chosen.at[:, 0].set(0)               # primary = nearest
    taken = jnp.zeros((n, c), bool).at[:, 0].set(True)

    def pick(j, state):
        chosen, taken = state
        # aggr over previously chosen residual dots with each candidate
        sel = jax.vmap(lambda d, ch: d[ch])(dots, chosen)      # (n, m, C)
        prior = jnp.arange(m) < j                              # mask rows >= j
        if aggr == "max":
            agg = jnp.max(jnp.where(prior[None, :, None], sel, -jnp.inf), axis=1)
        elif aggr == "min":
            agg = jnp.min(jnp.where(prior[None, :, None], sel, jnp.inf), axis=1)
        else:  # avg
            agg = (jnp.sum(jnp.where(prior[None, :, None], sel, 0.0), axis=1)
                   / jnp.maximum(jnp.sum(prior), 1))
        loss = d2 + lam * agg
        loss = jnp.where(taken, jnp.inf, loss)                 # strict: no repeats
        nxt = jnp.argmin(loss, axis=-1).astype(jnp.int32)
        chosen = chosen.at[:, j].set(nxt)
        taken = jax.vmap(lambda t, i: t.at[i].set(True))(taken, nxt)
        return chosen, taken

    chosen, _ = jax.lax.fori_loop(1, m, pick, (chosen, taken))
    lists = jnp.take_along_axis(cand_ids, chosen, axis=-1)     # (n, m)
    return jnp.sort(lists, axis=-1)


def rair_assign_multi(x, centroids, *, m: int = 3, aggr: str = "max",
                      lam: float = 0.5, n_cands: int = 10, chunk: int = 8192):
    """Strict m-assignment (paper Fig. 14). Returns (n, m) sorted list ids."""
    assert aggr in AGGRS
    def fn(xb):
        cids, cd2 = candidate_lists(xb, centroids, n_cands)
        return _assign_m_chunk(xb, centroids, cids, cd2, m, aggr, lam)
    return _chunked(fn, x, chunk)


# ----------------------------------------------------------------------------
# Strategy registry (paper §6.1 "Solutions to Compare", pluggable)
# ----------------------------------------------------------------------------
# Maps a strategy name to an assignment function
#     fn(x (n, D), centroids (nlist, D), cfg: IndexConfig) -> np.ndarray (n, m)
# of sorted per-vector list ids.  ``IndexConfig`` validates its strategy
# against this registry at construction, and ``compute_assignments``
# dispatches through it — adding a SOAR-style variant is one decorated
# function, no core edits.
StrategyFn = Callable[[jnp.ndarray, jnp.ndarray, object], np.ndarray]
STRATEGY_REGISTRY: Dict[str, StrategyFn] = {}


def register_strategy(name: str, overwrite: bool = False):
    """Decorator: register an assignment strategy under `name`."""
    def deco(fn: StrategyFn) -> StrategyFn:
        if not overwrite and name in STRATEGY_REGISTRY:
            raise ValueError(f"strategy {name!r} already registered")
        STRATEGY_REGISTRY[name] = fn
        return fn
    return deco


def get_strategy(name: str) -> StrategyFn:
    try:
        return STRATEGY_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: "
            f"{available_strategies()}") from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(STRATEGY_REGISTRY))


@register_strategy("single")
def _strategy_single(x, centroids, cfg):
    """IVFPQfs baseline: one (duplicated) nearest-list assignment."""
    return np.asarray(single_assign(x, centroids))


def _rair_family(x, centroids, cfg, metric: str, strict: bool):
    return np.asarray(rair_assign(
        x, centroids, metric=metric, lam=cfg.lam, n_cands=cfg.n_cands,
        strict=strict))


@register_strategy("naive")
def _strategy_naive(x, centroids, cfg):
    """NaiveRA: strict 2nd-nearest list."""
    return _rair_family(x, centroids, cfg, metric="naive", strict=True)


@register_strategy("soar")
def _strategy_soar(x, centroids, cfg):
    """SOARL2: strict orthogonality-weighted residual."""
    return _rair_family(x, centroids, cfg, metric="soar", strict=True)


@register_strategy("rair")
def _strategy_rair(x, centroids, cfg):
    """RAIR: AIR metric, primary may win (single assignment kept)."""
    return _rair_family(x, centroids, cfg, metric="air", strict=False)


@register_strategy("srair")
def _strategy_srair(x, centroids, cfg):
    """SRAIR: AIR metric, strictly two distinct lists."""
    return _rair_family(x, centroids, cfg, metric="air", strict=True)


def air_skip_fraction(x, centroids, lam=0.5, n_cands=10, chunk=8192) -> float:
    """Fraction of vectors for which RAIR keeps single assignment
    (loss_min attained by the primary list: ||r'||^2+lam r^T r' >= (1+lam)||r||^2)."""
    a = rair_assign(x, centroids, metric="air", lam=lam, n_cands=n_cands,
                    strict=False, chunk=chunk)
    return float(jnp.mean(a[:, 0] == a[:, 1]))
