"""Logical-axis sharding: named dims -> mesh axes via a rule table.

Params and activations are annotated with *logical* dimension names
("batch", "heads", "vocab", ...); a rule table maps each name to mesh
axes ("data", "model", optionally "pod").  ``logical_spec`` resolves
names to a PartitionSpec under the active ``axis_rules`` context,
applying two guards:

  * axes absent from the mesh are pruned (the same rules serve the
    single-pod (data, model) and multi-pod (pod, data, model) meshes);
  * a dim whose size does not divide the mapped axis-size product is
    replicated instead (e.g. hubert's vocab=504 on a 16-wide model
    axis), and a mesh axis is never assigned to two dims of one spec.

``logical_shard`` is the in-graph annotation: a no-op unless an
``axis_rules`` context is active, so model code runs unchanged on a
single host (tests) and sharded under the production mesh (launch/).
"""
from __future__ import annotations

import contextlib
from types import SimpleNamespace
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical dim -> mesh axis (str), axes (tuple), or None (replicate)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "zero": ("pod", "data"),      # ZeRO-sharded replicated dims
    "expert": ("pod", "data"),    # expert parallelism over the data axes
    "lists": ("pod", "data"),     # IVF list / block pools (RAIRS caches)
    "heads": "model",
    "kv": "model",
    "ff": "model",
    "vocab": "model",
    "ssm_head": "model",
    "d_model": None,
    "seq": None,
    "state": None,
    "blk": None,
    "kv_head_dim": None,          # serve caches override to "model"
}

_state = SimpleNamespace(ctx=None)   # (mesh, rules) or None


@contextlib.contextmanager
def axis_rules(mesh, rules: Optional[dict] = None):
    """Activate (mesh, rules) for logical_spec/logical_shard resolution."""
    prev = _state.ctx
    _state.ctx = (mesh, dict(DEFAULT_RULES if rules is None else rules))
    try:
        yield
    finally:
        _state.ctx = prev


def zero1_rules() -> dict:
    """Rules for ZeRO-1/3 shardings (the "zero" dim consumes data axes)."""
    return dict(DEFAULT_RULES)


def _mesh_axes(mesh, rule) -> Tuple[str, ...]:
    if rule is None:
        return ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    return tuple(a for a in axes if a in tuple(mesh.axis_names))


def logical_spec(*names, shape: Tuple[int, ...]) -> P:
    """Resolve logical dim names to a PartitionSpec under the active
    context.  Requires ``axis_rules`` (or ``_state.ctx``) to be set."""
    assert _state.ctx is not None, "logical_spec needs an axis_rules context"
    mesh, rules = _state.ctx
    used = set()
    entries = []
    for i, name in enumerate(names):
        axes = _mesh_axes(mesh, rules.get(name)) if name else ()
        axes = tuple(a for a in axes if a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if not axes or size <= 1 or shape[i] % size != 0:
            entries.append(None)      # replicate: indivisible or unmapped
            continue
        used.update(axes)
        entries.append(axes[0] if len(axes) == 1 else axes)
    return P(*entries)


def logical_shard(x, *names):
    """In-graph sharding annotation; identity outside an axis_rules ctx."""
    if _state.ctx is None:
        return x
    mesh, _ = _state.ctx
    spec = logical_spec(*names, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_shardings(specs, mesh, rules: Optional[dict] = None, is_leaf=None,
                    logical_of=None):
    """Tree of NamedShardings for a ParamSpec tree (launch/train/serve)."""
    with axis_rules(mesh, rules=rules):
        def sh(s):
            names = tuple(logical_of(s)) if logical_of else tuple(s.logical)
            return NamedSharding(mesh, logical_spec(*names, shape=s.shape))
        return jax.tree.map(sh, specs, is_leaf=is_leaf)
