"""Elastic training: replan the mesh after node failures and rescale the
batch schedule so the optimizer sees the same global batch.

The policy is the standard one for synchronous data parallelism: keep
the per-device microbatch fixed (it was tuned for memory), shrink the
data axis to the surviving devices, and raise gradient accumulation so
``global_batch = data_size * microbatch * accum`` is preserved (rounded
up — a slightly larger global batch is preferred over a smaller one).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data_size: int
    model_size: int
    devices: tuple

    @property
    def n_devices(self) -> int:
        return self.data_size * self.model_size


def replan_mesh(devices: Sequence, model: int = 1, failed: Sequence = ()
                ) -> MeshPlan:
    """Largest (data, model) mesh over the surviving devices.  The model
    axis is fixed (tensor-parallel groups cannot shrink without a
    different parameter layout); data_size absorbs the loss."""
    failed = set(failed)
    alive = tuple(d for d in devices if d not in failed)
    if len(alive) < model:
        raise ValueError(f"only {len(alive)} devices left; "
                         f"model axis needs {model}")
    data = len(alive) // model
    return MeshPlan(data_size=data, model_size=model,
                    devices=alive[:data * model])


def rescale_batch(global_batch: int, accum: int, plan: MeshPlan,
                  orig_data_size: Optional[int] = None) -> Tuple[int, int]:
    """(new_global_batch, new_accum) preserving the per-device
    microbatch implied by the original schedule.  ``orig_data_size`` is
    the data-axis size the schedule was tuned on; it defaults to the
    new plan's (exact only when no data devices were lost — pass the
    old size after a failure so the microbatch stays fixed)."""
    orig = orig_data_size if orig_data_size is not None else plan.data_size
    micro = max(1, global_batch // max(orig * accum, 1))
    new_accum = max(accum, -(-global_batch // (plan.data_size * micro)))
    return plan.data_size * micro * new_accum, new_accum
