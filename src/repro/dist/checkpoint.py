"""Host checkpointing: one ``step_XXXXXXXX`` directory per step holding
the flattened pytree leaves (npz), with atomic publish (write to a tmp
dir, rename) and optional retention.  Restore rebuilds the caller's
template structure, so any registered pytree (params dict, OptState,
nested caches) round-trips.
"""
from __future__ import annotations

import os
import re
import shutil
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")
_LEAVES = "leaves.npz"


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.isfile(os.path.join(ckpt_dir, name, _LEAVES)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _steps(ckpt_dir)
    return steps[-1] if steps else None


def save_checkpoint(ckpt_dir: str, step: int, tree, keep: Optional[int] = None
                    ) -> str:
    """Write `tree` as checkpoint `step`; prune to the newest `keep`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = _step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = jax.tree.leaves(tree)
    np.savez(os.path.join(tmp, _LEAVES),
             **{f"leaf_{i:06d}": np.asarray(leaf)
                for i, leaf in enumerate(leaves)})
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    if keep is not None:
        for s in _steps(ckpt_dir)[:-keep]:
            shutil.rmtree(_step_dir(ckpt_dir, s), ignore_errors=True)
    return final


def restore_checkpoint(ckpt_dir: str, template, step: Optional[int] = None):
    """Load checkpoint `step` (default: latest) into `template`'s pytree
    structure.  Leaf count must match; dtypes/shapes come from disk."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    with np.load(os.path.join(_step_dir(ckpt_dir, step), _LEAVES),
                 allow_pickle=False) as z:
        loaded = [z[k] for k in sorted(z.files)]
    treedef = jax.tree.structure(template)
    n = treedef.num_leaves
    if len(loaded) != n:
        raise ValueError(f"checkpoint has {len(loaded)} leaves, "
                         f"template expects {n}")
    return jax.tree.unflatten(treedef, [jnp.asarray(v) for v in loaded])
