"""Distributed runtime layer: logical-axis sharding rules, host
checkpointing with retention, and elastic mesh replanning.

Also home of the ``shard_map`` version shim: ``jax.shard_map`` landed
after 0.4.x, where the same API lives in ``jax.experimental.shard_map``
with ``check_rep`` instead of ``check_vma``.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    def shard_map(f, *, mesh, in_specs, out_specs, **kw):
        kw.setdefault("check_vma", False)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, **kw):
        kw.pop("check_vma", None)
        kw.setdefault("check_rep", False)
        return _shard_map_04(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
