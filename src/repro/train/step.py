"""Production train step: microbatched gradient accumulation + remat +
AdamW, with GSPMD sharding derived from the model's logical axes.

ZeRO-1 (`zero1=True`): optimizer moments shard their first replicated
dimension over the data axis; GSPMD then emits reduce-scatter for the
moment update and all-gather for the param update — the standard
optimizer-state-sharding collective schedule.

Gradient compression (`grad_compress`): microbatch-accumulated grads are
cast to bf16/int8 before the optimizer applies them — with DP sharding
this compresses the cross-replica all-reduce wire format (see
optim/compress.py for the explicit shard_map variant used in tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..dist.sharding import (axis_rules, logical_spec, param_shardings,
                             zero1_rules)
from ..models.transformer import param_specs, train_loss, ParamSpec
from ..optim.adamw import AdamWConfig, OptState, adamw_init, adamw_update
from ..optim.compress import compress_tree, decompress_tree
from ..models.runtime_flags import scan_unroll_arg


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: AdamWConfig = AdamWConfig()
    accum: int = 8                   # gradient-accumulation microbatches
    remat: bool = True
    zero1: bool = True
    fsdp: bool = False               # ZeRO-3-style param sharding over data
    grad_compress: str = "none"      # none | bf16 | int8


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).
    Batch leaves have leading dim global_batch; accumulation reshapes to
    (accum, gb/accum, ...)."""

    def train_step(params, opt_state, batch):
        a = tcfg.accum

        def split(x):
            # batch dim is 0 except positions3-style (3, B, ...) leaves
            bdim = 1 if (x.ndim >= 2 and x.shape[0] == 3) else 0
            gb = x.shape[bdim]
            x = x.reshape(x.shape[:bdim] + (a, gb // a) + x.shape[bdim + 1:])
            return jnp.moveaxis(x, bdim, 0) if bdim else x

        mbs = jax.tree.map(split, batch)

        def loss_fn(p, mb):
            return train_loss(p, cfg, mb, remat=tcfg.remat)

        def acc(carry, mb):
            tot, g = carry
            l, gi = jax.value_and_grad(loss_fn)(params, mb)
            g = jax.tree.map(jnp.add, g, gi)
            return (tot + l, g), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros), mbs,
                                        unroll=scan_unroll_arg())
        grads = jax.tree.map(lambda g: g / a, grads)
        if tcfg.grad_compress != "none":
            c, scales = compress_tree(grads, tcfg.grad_compress)
            grads = decompress_tree(c, scales, tcfg.grad_compress)
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               tcfg.optim)
        return new_params, new_opt, {"loss": loss / a, **om}

    return train_step


def train_step_shardings(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig,
                         batch_specs):
    """(in_shardings, out_shardings) for jit(train_step)."""
    specs = param_specs(cfg)
    is_leaf = lambda x: isinstance(x, ParamSpec)
    if tcfg.fsdp:
        # ZeRO-3/FSDP: params themselves shard a replicated dim over data;
        # GSPMD all-gathers at each use point (the FSDP schedule).
        p_sh = None  # assigned after zero_logical is defined below
    else:
        p_sh = param_shardings(specs, mesh, is_leaf=is_leaf)

    # a dim is ZeRO-eligible if its logical name resolves to replicated
    REPLICATED = (None, "d_model", "seq", "state", "blk")

    def zero_logical(s: ParamSpec):
        names = list(s.logical)
        # expert/list dims already consume the data axis (2D EP sharding)
        if any(n in ("expert", "lists") for n in names):
            return tuple(names)
        for i, n in enumerate(names):
            if n in REPLICATED and s.shape[i] % mesh.shape["data"] == 0 \
                    and s.shape[i] >= mesh.shape["data"]:
                names[i] = "zero"
                break
        return tuple(names)

    def opt_logical(s: ParamSpec):
        return zero_logical(s) if tcfg.zero1 else s.logical

    o_leaf_sh = param_shardings(specs, mesh, rules=zero1_rules(),
                                is_leaf=is_leaf, logical_of=opt_logical)
    if tcfg.fsdp:
        p_sh = param_shardings(specs, mesh, rules=zero1_rules(),
                               is_leaf=is_leaf, logical_of=zero_logical)
    with axis_rules(mesh):
        scalar = NamedSharding(mesh, P())
        opt_sh = OptState(mu=o_leaf_sh, nu=o_leaf_sh, step=scalar)
        batch_sh = jax.tree.map(
            lambda s: NamedSharding(
                mesh, logical_spec("batch", *([None] * (len(s.shape) - 1)),
                                   shape=s.shape)), batch_specs)
        metrics_sh = {"loss": scalar, "grad_norm": scalar, "lr": scalar}
    return (p_sh, opt_sh, batch_sh), (p_sh, opt_sh, metrics_sh)


def init_all(key, cfg: ModelConfig):
    from ..models.transformer import init_params
    params = init_params(key, cfg)
    return params, adamw_init(params)
