from .step import make_train_step, TrainConfig, train_step_shardings  # noqa
