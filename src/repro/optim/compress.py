"""Gradient compression for cross-replica reduction.

Distributed-optimization trick for 1000+ node DP: gradients cross the
(slow) inter-pod links compressed.  Two codecs:
  * bf16 — 2x traffic cut, loses 16 mantissa bits (safe for grads);
  * int8 — 4x cut, per-tensor absmax scaling (error-prone for tiny
    grads; exposed for the perf pass, off by default).

Used by train/step.py's explicit-DP variant: per-shard grads are
compressed, `psum`'d over the data axes, then decompressed — the psum
of int8 is performed in int32 to avoid overflow across <= 2^23 replicas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_tree(tree, mode: str):
    if mode == "none":
        return tree, None
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree), None
    if mode == "int8":
        scales = jax.tree.map(
            lambda g: jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0, tree)
        q = jax.tree.map(
            lambda g, s: jnp.clip(jnp.round(g / s), -127, 127
                                  ).astype(jnp.int8), tree, scales)
        return q, scales
    raise ValueError(mode)


def decompress_tree(tree, scales, mode: str):
    if mode == "none":
        return tree
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), tree)
    if mode == "int8":
        return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                            tree, scales)
    raise ValueError(mode)


def compressed_psum(tree, axis_names, mode: str = "bf16"):
    """psum with on-the-wire compression (inside shard_map)."""
    c, scales = compress_tree(tree, mode)
    if mode == "int8":
        c = jax.tree.map(lambda q: q.astype(jnp.int32), c)
        c = jax.lax.psum(c, axis_names)
        scales = jax.tree.map(lambda s: jax.lax.pmax(s, axis_names), scales)
        return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                            c, scales)
    c = jax.lax.psum(c, axis_names)
    return decompress_tree(c, scales, mode)
