from .adamw import (AdamWConfig, adamw_init, adamw_update,  # noqa
                    cosine_schedule, global_norm)
from .compress import compress_tree, decompress_tree  # noqa
