"""AdamW with cosine schedule + global-norm clipping (pure pytree impl).

Optimizer state carries f32 master moments; ZeRO-1 sharding of the
moments over the data axis is applied at the step level (see
train/step.py + dist/sharding.zero1_logical).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    mu: object
    nu: object
    step: jnp.ndarray


def adamw_init(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(mu=z, nu=jax.tree.map(jnp.copy, z),
                    step=jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig):
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)
    lr = cosine_schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        return (p - lr * (u + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(mu=mu, nu=nu, step=step), \
        {"grad_norm": gn, "lr": lr}
