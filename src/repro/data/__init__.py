from .synthetic import make_dataset, DATASETS  # noqa: F401
