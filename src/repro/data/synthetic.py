"""Synthetic datasets statistically shaped like the paper's corpora.

The container is offline, so SIFT/GIST/MSong/OpenAI/T2I are replaced by
latent-manifold Gaussian mixtures: cluster structure in a low-dim latent
space (power-law mixture mass, anisotropic covariance) projected to the
ambient dimension plus small ambient noise.  This reproduces the two
properties that make the paper's setting meaningful and that uniform
data would destroy:

  * clusteredness — IVF lists, the Fig. 5 cell-size skew, AIR geometry;
  * low intrinsic dimension — real descriptors/embeddings concentrate
    near a manifold, which is what makes 4-bit PQ + refine reach high
    recall (on iid-dim data PQ error swamps NN distances and *no* IVF
    method reaches 0.9; calibrated in EXPERIMENTS.md §Datasets).

Queries are perturbed data points (in-distribution, as in SIFT/GIST);
the T2I stand-in (`modality_gap=True`) draws queries from a shifted
mixture sharing the projection, mimicking the text-vs-image gap, with
Zipf-ish data norms for inner-product skew.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    d: int
    n_queries: int
    n_components: int = 64
    latent: int = 24            # intrinsic dimension of the manifold
    zipf: float = 1.2           # power-law exponent of mixture weights
    spread: float = 0.35        # within-cluster sigma (latent space)
    query_noise: float = 1.0    # query perturbation scale
    metric: str = "l2"
    modality_gap: bool = False  # T2I-like: query distribution shifted


DATASETS = {
    # stand-ins mirroring paper Table 2 (scaled to 1-core CPU budget)
    "sift1m": DatasetSpec("sift1m", 100_000, 128, 2_000),
    "msong": DatasetSpec("msong", 60_000, 128, 1_000, n_components=48),
    "gist": DatasetSpec("gist", 50_000, 256, 1_000, n_components=48,
                        latent=32),
    "openai": DatasetSpec("openai", 60_000, 256, 1_000, n_components=96,
                          latent=40, zipf=1.0),
    "t2i": DatasetSpec("t2i", 80_000, 128, 2_000, metric="ip",
                       modality_gap=True),
    # tiny configs for tests
    "unit": DatasetSpec("unit", 6_000, 32, 200, n_components=16, latent=12),
    "unit_ip": DatasetSpec("unit_ip", 6_000, 32, 200, n_components=16,
                           latent=12, metric="ip", modality_gap=True),
}


def _latent_mixture(key, n, k, latent, zipf, spread):
    kc, kw, kx, ka = jax.random.split(key, 4)
    centers = jax.random.normal(kc, (k, latent))
    w = 1.0 / jnp.arange(1, k + 1) ** zipf
    w = w / w.sum()
    comp = jax.random.choice(kw, k, shape=(n,), p=w)
    scales = jax.random.uniform(ka, (k, latent), minval=0.4, maxval=1.6) * spread
    z = centers[comp] + jax.random.normal(kx, (n, latent)) * scales[comp]
    return z


def make_dataset(name: str, seed: int = 0
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, DatasetSpec]:
    """Returns (data (n,D), queries (nq,D), spec)."""
    spec = DATASETS[name]
    # crc32, NOT hash(): the builtin is salted per process, which would
    # regenerate a different corpus on every run and break index
    # persistence (save in one process, serve from another).
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2 ** 31) + seed)
    kd, kq, kp, ks, kw, kn = jax.random.split(key, 6)
    z = _latent_mixture(kd, spec.n, spec.n_components, spec.latent,
                        spec.zipf, spec.spread)
    proj = jax.random.normal(kw, (spec.latent, spec.d)) / jnp.sqrt(spec.latent)
    x = z @ proj + jax.random.normal(kn, (spec.n, spec.d)) * 0.02
    if spec.modality_gap:
        zq = _latent_mixture(kq, spec.n_queries, spec.n_components,
                             spec.latent, spec.zipf, spec.spread * 1.3)
        shift = jax.random.normal(ks, (spec.latent,)) * 0.3
        q = (zq + shift) @ proj
        if spec.metric == "ip":  # Zipf-ish norms on data side (MIPS skew)
            norms = 1.0 + jax.random.gamma(kp, 2.0, (spec.n, 1)) * 0.3
            x = x * norms
    else:
        base = jax.random.choice(kp, spec.n, shape=(spec.n_queries,))
        scale = spec.spread * spec.query_noise / jnp.sqrt(spec.d / spec.latent)
        q = x[base] + jax.random.normal(kq, (spec.n_queries, spec.d)) * scale
    return x.astype(jnp.float32), q.astype(jnp.float32), spec
