import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf hillclimb on the three selected cells (EXPERIMENTS.md §Perf).

Each iteration: hypothesis (napkin math over the analytic roofline
terms) -> change (sharding / compression / retrieval knob) -> measure
(recompute terms; re-lower+compile the variant on a 256-chip mesh to
prove the schedule) -> confirm/refute.  Stops when remaining ideas
predict <5%% on the dominant term.

Run: PYTHONPATH=src python -m repro.launch.hillclimb
"""
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402

from repro.launch.shapes import LONG_KNN_CFG, plan_cell  # noqa: E402
from repro.dist.sharding import axis_rules                # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "launch_results", "hillclimb.json")


def compile_variant(arch, shape, mesh_shape, **plan_kw):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    t0 = time.perf_counter()
    with mesh, axis_rules(mesh):
        plan = plan_cell(arch, shape, mesh, **plan_kw)
        jitted = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings)
        compiled = jitted.lower(*plan.args).compile()
    dt = time.perf_counter() - t0
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {"temp_bytes": int(getattr(ma, "temp_size_in_bytes", -1)),
               "arg_bytes": int(getattr(ma, "argument_size_in_bytes", -1))}
    except Exception:
        pass
    return {"compile_ok": True, "compile_s": round(dt, 1), **mem}


def terms(arch, shape, **kw):
    from benchmarks.roofline import (analytic_bytes,
                                     analytic_collective_bytes, CHIPS, PEAK,
                                     HBM, ICI)
    import json as _j
    cost = _j.load(open(os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "launch_results",
        "cost", f"{arch}__{shape}.json")))
    t_comp = cost["flops"] / (CHIPS * PEAK)
    t_mem = analytic_bytes(arch, shape, **{k: v for k, v in kw.items()
                                           if k in ("tp", "dp", "kv_bytes",
                                                    "knn_cfg")}) / HBM
    t_coll = analytic_collective_bytes(arch, shape, **kw) / ICI
    return {"t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "roofline_frac": t_comp / max(t_comp, t_mem, t_coll)}


def main():
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                    ".."))
    log = []

    def record(cell, it, hypothesis, predicted, measured, verdict,
               compile_info=None):
        entry = {"cell": cell, "iteration": it, "hypothesis": hypothesis,
                 "predicted": predicted, "measured": measured,
                 "verdict": verdict, "compile": compile_info}
        log.append(entry)
        print(json.dumps(entry, indent=1, default=str), flush=True)

    # ---------------- Cell A: arctic-480b / train_4k (most collective-bound)
    cell = "arctic-480b/train_4k"
    base = terms("arctic-480b", "train_4k")
    record(cell, 0, "baseline (TP16/DP16, f32 grad all-reduce)", None,
           base, "baseline")
    # It1: bf16 gradient compression halves the dominant DP-grad wire bytes
    t1 = terms("arctic-480b", "train_4k", grad_bytes=2)
    c1 = compile_variant("arctic-480b", "train_4k", (16, 16),
                         grad_compress="bf16")
    record(cell, 1, "bf16 grad compression: DP all-reduce bytes /2 "
           "(DP term was 9.0s of 16.5s)",
           {"t_collective": base["t_collective"] - 4.5}, t1,
           "confirmed" if t1["t_collective"] < base["t_collective"] * 0.75
           else "refuted", c1)
    # It2: TP 16->8, DP 16->32: halves per-device TP/EP payload
    t2 = terms("arctic-480b", "train_4k", tp=8, dp=32, grad_bytes=2)
    c2 = compile_variant("arctic-480b", "train_4k", (32, 8),
                         grad_compress="bf16")
    record(cell, 2, "TP16->8 (DP32): tok_local/2 => TP+EP terms /2; "
           "DP grads/TP x2 but bf16 keeps net flat",
           {"t_collective": 8.3}, t2,
           "confirmed" if t2["t_collective"] < t1["t_collective"] * 0.8
           else "refuted", c2)
    # It3: bucketed async DP all-reduce overlaps accumulation (schedule
    # model: exposed DP = DP/accum; no re-compile needed - exposure model)
    exposed = dict(t2)
    dp_term = 2 * (4.83e11 / 8) * 2 * (31 / 32) * 2 / 50e9
    exposed["t_collective_exposed"] = t2["t_collective"] - dp_term * (1 - 1 / 8)
    record(cell, 3, "bucketed async grad all-reduce: overlap DP reduction "
           "of microbatch i with compute of i+1 (accum=8) => exposed DP/8",
           {"t_collective_exposed": exposed["t_collective_exposed"]},
           exposed, "confirmed (schedule model; collective overlaps "
           "compute, roofline now compute-bound)")

    # ---------------- Cell B: olmoe-1b-7b / prefill_32k (worst roofline)
    cell = "olmoe-1b-7b/prefill_32k"
    base = terms("olmoe-1b-7b", "prefill_32k")
    record(cell, 0, "baseline (TP16/DP16): EP all-to-all of top-8 dispatch "
           "dominates (1.38s of 1.70s)", None, base, "baseline")
    t1 = terms("olmoe-1b-7b", "prefill_32k", tp=8, dp=32)
    c1 = compile_variant("olmoe-1b-7b", "prefill_32k", (32, 8))
    record(cell, 1, "TP16->8 (DP32, batch 32 => 1/replica): tok_local/2 "
           "=> EP and TP terms /2", {"t_collective": 0.85}, t1,
           "confirmed" if t1["t_collective"] < base["t_collective"] * 0.6
           else "refuted", c1)
    t2 = terms("olmoe-1b-7b", "prefill_32k", tp=4, dp=32)
    c2 = compile_variant("olmoe-1b-7b", "prefill_32k", (32, 4))
    record(cell, 2, "TP 8->4 on 128 chips (32x4; d_ff expert=1024 still "
           "divides): EP/TP per-device bytes /2 again at half the chips "
           "=> better perf *per chip*", {"t_collective": 0.43}, t2,
           "confirmed" if t2["t_collective"] < t1["t_collective"] * 0.6
           else "refuted", c2)
    record(cell, 3, "int8 MoE dispatch compression (wire-only, like grad "
           "compression): EP bytes /2 => ~0.22s; predicted gain on total "
           "<5% once compute-bound at TP4 => stop", None,
           {"note": "stopping: next ideas <5% on dominant term"}, "stop")

    # ------------- Cell C: qwen3-8b / long_500k (paper-technique cell)
    cell = "qwen3-8b/long_500k"
    base = terms("qwen3-8b", "long_500k")
    record(cell, 0, "baseline RAIRS-kNN paged attention (bf16 blocks, "
           "nprobe=16, maxb=24): cross-shard block gather dominates",
           None, base, "baseline")
    t1 = terms("qwen3-8b", "long_500k", kv_bytes=1)
    kc1 = dataclasses.replace(LONG_KNN_CFG, cache_dtype="int8")
    c1 = compile_variant("qwen3-8b", "long_500k", (16, 16), knn_cfg=kc1)
    record(cell, 1, "int8 K/V blocks w/ per-block absmax scales (the "
           "paper's quantize-then-refine insight applied to the KV cache; "
           "exact-window softmax refines): gather wire bytes /2",
           {"t_collective": base["t_collective"] / 2}, t1,
           "confirmed" if t1["t_collective"] < base["t_collective"] * 0.6
           else "refuted", c1)
    kc2 = dataclasses.replace(LONG_KNN_CFG, cache_dtype="int8", nprobe=12,
                              max_blocks_per_list=16)
    t2 = terms("qwen3-8b", "long_500k", kv_bytes=1, knn_cfg=kc2)
    c2 = compile_variant("qwen3-8b", "long_500k", (16, 16), knn_cfg=kc2)
    record(cell, 2, "RAIR lets us probe less for equal recall (CPU "
           "benches: RAIRS reaches target recall at ~0.6x the probes of "
           "single assignment - fig8): nprobe 16->12, maxb 24->16 => "
           "gathered bytes x0.5", {"t_collective": t1["t_collective"] * 0.5},
           t2, "confirmed" if t2["t_collective"] < t1["t_collective"] * 0.6
           else "refuted", c2)
    record(cell, 3, "head-local block placement (blocks of one kv-head on "
           "2 devices): napkin math REFUTES - cross bytes /3.75 but the 2 "
           "source devices serve 8x the volume => per-link time x2 worse. "
           "Keep balanced round-robin placement.", None,
           {"note": "refuted by napkin math before implementation"},
           "refuted")

    with open(RESULTS, "w") as f:
        json.dump(log, f, indent=1, default=str)
    print(f"wrote {RESULTS}")


if __name__ == "__main__":
    main()
