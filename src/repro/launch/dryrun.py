import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analyses.

The two lines above MUST precede any jax import (jax locks the device
count at first init) — this module is the only place the 512 placeholder
host devices exist; tests and benches see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --multi-pod
Results cache to launch_results/dryrun/<cell>.json; --force re-runs.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS                       # noqa: E402
from repro.configs.base import SHAPES                 # noqa: E402
from repro.dist.sharding import axis_rules            # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.launch.shapes import plan_cell, skip_reason  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "launch_results", "dryrun")

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=?\s*(\w+)\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str):
    """Sum operand bytes of collective ops in the (post-SPMD) HLO."""
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8,
                   "u16": 2, "s16": 2}
    per_kind = {}
    # lines look like:  %ag = f32[16,128]{...} all-gather(...)
    line_re = re.compile(
        r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)")
    for m in line_re.finditer(hlo_text):
        dt, dims, kind = m.groups()
        nbytes = dtype_bytes.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
    return per_kind


def run_cell(arch: str, shape: str, multi_pod: bool, force: bool = False):
    cell_id = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    reason = skip_reason(arch, shape)
    if reason:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.perf_counter()
    rec = {"cell": cell_id, "arch": arch, "shape": shape,
           "multi_pod": multi_pod}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh, axis_rules(mesh):
            plan = plan_cell(arch, shape, mesh)
            jitted = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                             out_shardings=plan.out_shardings)
            lowered = jitted.lower(*plan.args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        rec.update(status="ok", mode=plan.mode, note=plan.note,
                   lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    rec[k] = int(v)
        except Exception as e:  # CPU backend may lack pieces
            rec["memory_analysis_error"] = str(e)
        try:
            ca = compiled.cost_analysis()
            rec["flops"] = float(ca.get("flops", -1))
            rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
            rec["transcendentals"] = float(ca.get("transcendentals", -1))
        except Exception as e:
            rec["cost_analysis_error"] = str(e)
        try:
            hlo = compiled.as_text()
            rec["collective_bytes"] = parse_collective_bytes(hlo)
            rec["hlo_bytes"] = len(hlo)
        except Exception as e:
            rec["collective_parse_error"] = str(e)
    except Exception:
        rec.update(status="failed", error=traceback.format_exc()[-4000:],
                   seconds=round(time.perf_counter() - t0, 1))

    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_rairs_cell(multi_pod: bool, force: bool = False):
    """The paper's own workload at SIFT1B scale: the distributed RAIRS
    serve step (shard_map: local SEIL scan + all_gather merge + owner
    refine) lowered+compiled on the production mesh."""
    import jax.numpy as jnp
    from repro.configs.rairs import CONFIG as R
    from repro.core.distributed import build_serve_step
    from repro.core.search import SearchResult

    cell_id = f"rairs-sift1b__serve__{'pod2' if multi_pod else 'pod1'}"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    rec = {"cell": cell_id, "arch": "rairs-sift1b", "shape": "serve",
           "multi_pod": multi_pod}
    t0 = time.perf_counter()
    try:
        from jax.sharding import PartitionSpec as P
        mesh = make_production_mesh(multi_pod=multi_pod)
        # the index shards over EVERY mesh axis (flat block-range split)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        nd = 512 if multi_pod else 256
        blk, m = R.block, R.m_pq
        tb = ((int(R.n_vectors * 1.15) // blk) // nd + 1) * nd
        maxo, maxr, maxm = 560, 560, 64
        bq = 256   # serving batch sized to HBM (temp ~ bq x budget x blk x M)
        S = jax.ShapeDtypeStruct
        # the ShardedSearcher lowering backend, abstract-shape compiled
        # (no real index at dry-run time, so no ShardedIndex session)
        serve = build_serve_step(
            nprobe=R.nprobe, bigk=R.k * R.k_factor, k=R.k,
            max_scan_local=256, axes=axes, ndev=nd, streaming=False)
        sh, rep = P(axes), P()
        fn = jax.shard_map(
            serve, mesh=mesh,
            in_specs=(sh, sh, sh, rep, rep, rep, rep, rep, rep, rep, sh,
                      sh, sh, sh, rep, rep, rep, rep),
            out_specs=SearchResult(
                ids=rep, dists=rep, approx_dco=rep, refine_dco=rep,
                scanned_blocks=rep, dropped_blocks=rep),
            check_vma=False)
        args = (S((tb, blk, m), jnp.uint8), S((tb, blk), jnp.int32),
                S((tb, blk), jnp.int32), S((R.nlist, maxo), jnp.int32),
                S((R.nlist, maxo), jnp.int32),
                S((R.nlist, maxr), jnp.int32), S((R.nlist, maxr), jnp.int32),
                S((R.nlist, maxm), jnp.int32), S((R.nlist, R.d), jnp.float32),
                S((m, 16, R.d // m), jnp.float32),
                S((R.n_vectors, R.d), jnp.bfloat16), S((nd,), jnp.int32),
                S((nd,), jnp.int32), S((nd,), jnp.int32),
                S((0, m), jnp.uint8), S((0,), jnp.int32), S((0,), jnp.bool_),
                S((bq, R.d), jnp.float32))
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
        rec.update(status="ok", mode="rairs_serve",
                   lower_s=round(t_lower, 1),
                   compile_s=round(time.perf_counter() - t0 - t_lower, 1))
        try:
            ma = compiled.memory_analysis()
            for kk in ("argument_size_in_bytes", "temp_size_in_bytes"):
                v = getattr(ma, kk, None)
                if v is not None:
                    rec[kk] = int(v)
        except Exception as e:
            rec["memory_analysis_error"] = str(e)
        try:
            ca = compiled.cost_analysis()
            rec["flops"] = float(ca.get("flops", -1))
            rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
        except Exception as e:
            rec["cost_analysis_error"] = str(e)
        try:
            rec["collective_bytes"] = parse_collective_bytes(
                compiled.as_text())
        except Exception as e:
            rec["collective_parse_error"] = str(e)
    except Exception:
        rec.update(status="failed", error=traceback.format_exc()[-4000:],
                   seconds=round(time.perf_counter() - t0, 1))
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    n_ok = n_fail = n_skip = 0
    if args.all or args.arch == "rairs-sift1b":
        for mp in meshes:
            rec = run_rairs_cell(mp, force=args.force)
            st = rec.get("status")
            n_ok += st == "ok"
            n_fail += st == "failed"
            print(f"[{rec['cell']}] {st} "
                  f"compile={rec.get('compile_s', '-')}s", flush=True)
            if st == "failed":
                print(rec.get("error", "")[-800:])
        if args.arch == "rairs-sift1b":
            archs = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force)
                st = rec.get("status")
                n_ok += st == "ok"
                n_fail += st == "failed"
                n_skip += st == "skipped"
                msg = (f"[{rec['cell']}] {st} "
                       f"compile={rec.get('compile_s', '-')}s "
                       f"flops={rec.get('flops', '-')} ")
                if st == "failed":
                    msg += "\n" + rec.get("error", "")[-800:]
                print(msg, flush=True)
    print(f"done: ok={n_ok} failed={n_fail} skipped={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
