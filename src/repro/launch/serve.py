"""RAIRS ANN serving driver: build (or load) an index over a synthetic
corpus and serve batched queries through a compiled searcher session —
the paper's own workload end-to-end.

``PYTHONPATH=src python -m repro.launch.serve --dataset sift1m
--nprobe 16 --batches 4``

Persistence (skip the train+build phase on repeat runs):

``... --save /tmp/sift1m.npz``      # first run: build then save
``... --load /tmp/sift1m.npz``      # later runs: load, serve immediately

Streaming ops (corpus churn through the mutable-index subsystem,
DESIGN.md §8).  ``--insert N`` holds the last N corpus vectors out of
the build and appends them through the delta path; ``--delete N``
tombstones N random live ids; ``--compact`` folds delta + tombstones
into a fresh base epoch.  Saved bundles carry the streaming state
(format v2), so an insert->delete->save / load round-trip resumes with
the same delta segment and tombstones:

``... --insert 512 --delete 128 --compact --save /tmp/churned.npz``

``--load`` composes with the churn ops (resume churn from a bundle and
persist the result to a new path); bundles record how many corpus rows
they consumed, so repeated ``--insert`` runs keep appending fresh rows
instead of duplicating indexed ones.

Distributed serving (DESIGN.md §4) is a deployment flag, not a code
path: ``--ndev N`` shards the index (frozen or streaming) over an
N-device mesh and serves through the identical session API
(``index.shard(mesh).searcher(params)``).  ``--shards N`` makes
``--save`` write a v3 sharded bundle (manifest + per-shard npz) that
``--load`` reassembles transparently:

``... --ndev 8 --save /tmp/sift1m_sharded --shards 8``

On CPU hosts, virtual devices for smoke runs come from
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Gateway serving (DESIGN.md §10): ``--gateway`` swaps the closed-loop
batch loop for the async serving gateway — an open-loop synthetic
arrival generator submits single-query requests at ``--offered-qps``,
the gateway coalesces them into compiled batch buckets on a
``--max-delay-ms`` deadline (probe-signature admission keeps the
plan cache hot), and the run prints per-load-point p50/p95/p99
latency plus the gateway telemetry snapshot:

``... --gateway --offered-qps 200,400,800 --gateway-requests 512``

Combined with churn ops, ``--gateway --compact`` exercises the
zero-downtime epoch handover: the compaction folds on a background
thread while requests keep flowing, and the new epoch installs
between batches.

Observability (DESIGN.md §11): ``--trace out.json`` traces the serving
phase — stage spans from gateway flush down to the per-shard scan,
device work fenced at stage boundaries — and writes a Chrome/Perfetto
trace-event file (open in ui.perfetto.dev; validate offline with
``python -m repro.obs.export out.json``).  ``--stats-format prom|json``
prints the unified ``snapshot_all`` stats (compile/cache + plan +
gateway telemetry + modeled HBM traffic + per-stage trace aggregates)
after serving:

``... --gateway --trace /tmp/serve_trace.json --stats-format prom``
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro import obs
from repro.core import (IndexConfig, RefineParams, SearchParams,
                        StreamConfig, StreamingIndex, available_strategies,
                        build_index, dco_summary, ground_truth, load_index,
                        read_index_meta, recall_at_k, save_index)
from repro.data import make_dataset


def refine_params(args):
    """``RefineParams`` from --refine-plane/--refine-factor (None = off)."""
    if args.refine_plane is None:
        return None
    return RefineParams(plane=args.refine_plane,
                        refine_factor=args.refine_factor)


def apply_stream_ops(index, args, x, rows_used: int):
    """Wrap `index` for mutation and run the requested churn ops.

    `rows_used` is how many corpus rows the index has already consumed
    (build + prior inserts, tracked in bundle provenance), so --insert
    only ever appends genuinely fresh rows — re-inserting indexed rows
    would duplicate vectors and corrupt the reported recall.  Returns
    ``(stream, rows_used')``."""
    stream = (index if isinstance(index, StreamingIndex)
              else index.streaming(StreamConfig(delta_pad=args.delta_pad)))
    if args.insert:
        take = min(args.insert, x.shape[0] - rows_used)
        if take < args.insert:
            print(f"--insert {args.insert}: only {max(take, 0)} fresh corpus "
                  f"rows remain ({rows_used} already consumed)")
        if take > 0:
            t0 = time.perf_counter()
            ids = stream.insert(x[rows_used:rows_used + take])
            rows_used += take
            print(f"inserted {len(ids)} vectors (ids {ids[0]}..{ids[-1]}) "
                  f"via the delta path in {time.perf_counter() - t0:.2f}s "
                  f"(no layout rebuild)")
    if args.delete:
        rng = np.random.default_rng(0)
        live = stream.live_ids()
        victims = rng.choice(live, size=min(args.delete, len(live)),
                             replace=False)
        t0 = time.perf_counter()
        n = stream.delete(victims)
        print(f"tombstoned {n} ids in {time.perf_counter() - t0:.2f}s")
    if args.compact:
        info = stream.compact()
        print(f"compacted to epoch {info['epoch']}: n_live={info['n_live']} "
              f"dropped={info['dropped']} in {info['seconds']:.2f}s "
              f"(layout {info['layout_seconds']:.2f}s)")
    print(f"  stream: epoch={stream.epoch} version={stream.version} "
          f"live={stream.n_live} delta={stream.n_delta} "
          f"dead={stream.n_dead}")
    return stream, rows_used


def run_gateway(serving, args, q, compact_async: bool = False):
    """Serve an open-loop synthetic arrival stream through the async
    gateway at each offered load point; with ``compact_async``, kick a
    zero-downtime epoch handover mid-stream (streaming indexes)."""
    from repro.gateway import (Gateway, GatewayConfig, LogSink,
                               degrade_ladder, run_open_loop)

    params = SearchParams(
        k=args.k, nprobe=args.nprobe, max_scan=args.max_scan,
        exec_mode=args.exec_mode, use_kernel=args.use_kernel,
        fused_topk=args.fused_topk, plan_reuse=args.plan_reuse,
        refine=refine_params(args))
    ladder = (degrade_ladder(params, levels=args.degrade_levels)[1:]
              if args.degrade_levels else None)
    cfg = GatewayConfig(max_delay_ms=args.max_delay_ms,
                        max_batch=args.max_batch,
                        admission=args.admission,
                        max_queue=args.max_queue,
                        overload=args.overload,
                        drain_s=args.drain_s,
                        degrade=ladder,
                        telemetry_interval_s=args.telemetry_interval)
    sinks = (LogSink(),) if args.telemetry_interval > 0 else ()
    with Gateway(serving, params, config=cfg, sinks=sinks) as gw:
        for point, qps in enumerate(args.offered_qps):
            handover = None
            if compact_async and point == 0:
                # fire the handover after ~1/4 of the stream so it folds
                # under live traffic and installs between batches
                trigger = max(1, args.gateway_requests // 4)

                def on_request(i, gw=gw, trigger=trigger):
                    nonlocal handover
                    if i == trigger and handover is None:
                        handover = gw.compact_async("serve_cli")
            else:
                on_request = None
            out = run_open_loop(gw, np.asarray(q), qps,
                                args.gateway_requests, seed=point,
                                on_request=on_request)
            print(f"load {qps:g} qps: achieved={out['achieved_qps']:.0f} "
                  f"p50={out['p50_ms']:.2f}ms p95={out['p95_ms']:.2f}ms "
                  f"p99={out['p99_ms']:.2f}ms "
                  f"mean_batch={out['mean_batch']:.1f} "
                  f"shed={out['shed']} levels={out['levels']} "
                  f"errors={out['errors']}")
            if handover is not None:
                info = handover.wait(300)
                print(f"  handover installed: epoch={info['epoch']} "
                      f"replayed_inserts={info['replayed_inserts']} "
                      f"replayed_deletes={info['replayed_deletes']}")
        tel = gw.stats()["telemetry"]
        print(f"gateway: qps={tel['qps']:.0f} "
              f"batch_fill={tel['batch_fill']:.1f} "
              f"bucket_fill={tel['bucket_fill']:.2f} "
              f"p50={tel['latency']['p50_ms']:.2f}ms "
              f"p99={tel['latency']['p99_ms']:.2f}ms "
              f"counters={tel['counters']}")
        # snapshot while the gateway (and any tracer) is still live so
        # --stats-format can render one unified stack-wide view
        return obs.snapshot_all(gateway=gw, tracer=obs.tracer())


def main():
    ap = argparse.ArgumentParser(
        epilog="Async serving: --gateway runs the deadline-batched "
               "gateway (repro.gateway) behind an open-loop arrival "
               "generator instead of the closed-loop batch loop; see "
               "DESIGN.md §10 and `python -m repro.launch.serve "
               "--gateway --offered-qps 200,400 --gateway-requests 256`.")
    ap.add_argument("--dataset", default="sift1m")
    ap.add_argument("--strategy", default="rair",
                    choices=available_strategies())
    ap.add_argument("--no-seil", action="store_true")
    ap.add_argument("--nlist", type=int, default=256)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-scan", type=int, default=None,
                    help="per-query block budget (default: index-derived)")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--exec-mode", default="paged",
                    choices=("paged", "grouped", "clustered"),
                    help="engine scan mode: per-query paging, list-major "
                         "batched execution (paper §5.3), or locality-"
                         "clustered per-tile unions")
    ap.add_argument("--plan-reuse", action="store_true",
                    help="incremental plans: reuse block unions across "
                         "adjacent batches (grouped/clustered only) and "
                         "report plan-cache stats")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the ADC scan through the Pallas kernel")
    ap.add_argument("--fused-topk", action="store_true",
                    help="fuse candidate selection into the scan stage "
                         "(with --use-kernel: VMEM-resident top-k inside "
                         "the Pallas kernel, DESIGN.md §9)")
    ap.add_argument("--refine-plane", default=None,
                    choices=("pq4", "binary", "full"),
                    help="two-tier ladder (DESIGN.md §12): scan this "
                         "compact plane in tier-1 and exactly re-rank the "
                         "widened survivor set in tier-2 ('full' = "
                         "widening-only ablation)")
    ap.add_argument("--refine-factor", type=int, default=4, metavar="R",
                    help="tier-1 survivor widening: tier-2 re-ranks "
                         "bigk*R candidates (R=1 is bitwise the "
                         "single-tier path)")
    ap.add_argument("--save", metavar="PATH", default=None,
                    help="persist the index bundle (after any stream ops)")
    ap.add_argument("--load", metavar="PATH", default=None,
                    help="load an index bundle from PATH (skips train+build)")
    ap.add_argument("--insert", type=int, default=0, metavar="N",
                    help="hold N corpus vectors out of the build and insert "
                         "them through the streaming delta path")
    ap.add_argument("--delete", type=int, default=0, metavar="N",
                    help="tombstone N random live ids")
    ap.add_argument("--compact", action="store_true",
                    help="fold delta + tombstones into a fresh base epoch")
    ap.add_argument("--delta-pad", type=int, default=256,
                    help="delta-segment capacity bucket quantum")
    ap.add_argument("--ndev", type=int, default=0, metavar="N",
                    help="serve through a ShardedIndex over an N-device "
                         "mesh (same session API; 0 = single host)")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="with --save: write a v3 sharded bundle "
                         "(manifest + N per-shard npz files)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve an open-loop arrival stream through the "
                         "async deadline-batched gateway (DESIGN.md §10) "
                         "instead of the closed-loop batch loop")
    ap.add_argument("--offered-qps", default="200",
                    help="comma-separated open-loop load points "
                         "(requests/s) for --gateway")
    ap.add_argument("--gateway-requests", type=int, default=256,
                    metavar="N", help="requests per load point")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="gateway micro-batch flush deadline")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="gateway coalescing target (flushes early when "
                         "a full bucket accumulates)")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="bounded admission: cap the gateway queue at N "
                         "requests (default: unbounded; DESIGN.md §13)")
    ap.add_argument("--overload", default="reject",
                    choices=("reject", "block"),
                    help="policy when the bounded queue is full: reject "
                         "sheds typed (Overloaded), block applies "
                         "backpressure to producers")
    ap.add_argument("--drain-s", type=float, default=None, metavar="S",
                    help="close() grace window: drain queued requests "
                         "for up to S seconds, then fail leftovers with "
                         "GatewayClosed (default: drain fully; 0 = "
                         "fail-fast)")
    ap.add_argument("--degrade-levels", type=int, default=0, metavar="L",
                    help="arm a graceful-degradation ladder with L "
                         "reduced-effort rungs below the configured "
                         "params (halved nprobe/max_scan per rung; "
                         "needs --max-queue; 0 = off)")
    ap.add_argument("--admission", default="signature",
                    choices=("signature", "fifo"),
                    help="gateway admission: group requests by rank-0 "
                         "probed list, or plain arrival order")
    ap.add_argument("--telemetry-interval", type=float, default=0.0,
                    metavar="S", help="emit a structured gateway "
                         "telemetry line every S seconds (0 = off)")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="trace the serving phase (stage spans with "
                         "device fencing, DESIGN.md §11) and write a "
                         "Chrome/Perfetto trace-event JSON to FILE; "
                         "open in ui.perfetto.dev")
    ap.add_argument("--trace-sample", type=int, default=1, metavar="N",
                    help="with --trace: record one gateway request "
                         "exemplar per N requests")
    ap.add_argument("--stats-format", default=None,
                    choices=("json", "prom"),
                    help="print the unified snapshot_all() stats "
                         "(session + gateway + HBM model + trace "
                         "aggregates) after serving, as pretty JSON or "
                         "Prometheus text exposition")
    args = ap.parse_args()
    try:
        args.offered_qps = [float(v) for v in
                            str(args.offered_qps).split(",") if v]
    except ValueError:
        ap.error(f"--offered-qps must be comma-separated numbers, "
                 f"got {args.offered_qps!r}")
    if args.ndev:
        avail = len(jax.devices())
        if args.ndev > avail:
            ap.error(f"--ndev {args.ndev} exceeds the {avail} available "
                     f"device(s); on CPU set XLA_FLAGS="
                     f"--xla_force_host_platform_device_count={args.ndev}")
        if args.plan_reuse:
            ap.error("--plan-reuse is single-host only (the plan cache "
                     "merges host-side between dispatches)")
    if args.shards and not args.save:
        ap.error("--shards only applies to --save")
    if args.plan_reuse and args.exec_mode == "paged":
        ap.error("--plan-reuse needs --exec-mode grouped or clustered "
                 "(paged scans have no block union to reuse)")
    stream_ops = bool(args.insert or args.delete or args.compact)
    gateway_handover = bool(args.gateway and args.compact)
    if gateway_handover:
        if args.ndev:
            ap.error("--gateway --compact needs the un-sharded streaming "
                     "index (the handover folds a StreamingIndex epoch)")
        # the gateway runs the compaction as a zero-downtime handover
        # mid-stream instead of a blocking fold before serving starts
        args.compact = False
    if args.load and args.save and not stream_ops:
        ap.error("--save with --load needs stream ops (an unmutated "
                 "loaded bundle is never re-written); add "
                 "--insert/--delete/--compact to churn then persist")

    x, q, spec = make_dataset(args.dataset)
    rows_used = x.shape[0]
    if args.load:
        meta = read_index_meta(args.load)
        saved_ds = meta.get("extra", {}).get("dataset")
        if saved_ds is not None and saved_ds != args.dataset:
            ap.error(f"{args.load} was built over dataset {saved_ds!r}, "
                     f"not --dataset {args.dataset!r}; recall against the "
                     f"wrong corpus is meaningless")
        t0 = time.perf_counter()
        index = load_index(args.load)
        cfg = index.config
        if index.vectors.shape[1] != x.shape[1]:
            ap.error(f"{args.load} holds {index.vectors.shape[1]}-d vectors "
                     f"but --dataset {args.dataset} is {x.shape[1]}-d")
        rows_used = meta.get("extra", {}).get(
            "corpus_rows_used", index.vectors.shape[0])
        streaming = isinstance(index, StreamingIndex)
        print(f"loaded {cfg.strategy}{'+SEIL' if cfg.seil else ''} "
              f"{'streaming ' if streaming else ''}index over "
              f"{index.vectors.shape[0]} vectors from {args.load} "
              f"in {time.perf_counter() - t0:.1f}s (train+build skipped; "
              f"--strategy/--nlist/--no-seil come from the bundle)")
        if streaming:
            print(f"  restored stream: epoch={index.epoch} "
                  f"version={index.version} live={index.n_live} "
                  f"delta={index.n_delta} dead={index.n_dead}")
    else:
        cfg = IndexConfig(nlist=args.nlist, strategy=args.strategy,
                          seil=not args.no_seil, metric=spec.metric)
        # --insert serves held-out corpus rows so churned recall is honest
        holdout = min(args.insert, x.shape[0] // 2)
        x_build = x[:x.shape[0] - holdout] if holdout else x
        rows_used = x_build.shape[0]
        t0 = time.perf_counter()
        index = build_index(jax.random.PRNGKey(0), x_build, cfg)
        print(f"built {args.strategy}{'' if args.no_seil else '+SEIL'} index "
              f"over {x_build.shape[0]} vectors in {time.perf_counter() - t0:.1f}s "
              f"(phases: { {k: round(v, 1) for k, v in index.build_seconds.items()} })")

    if stream_ops or isinstance(index, StreamingIndex):
        index, rows_used = apply_stream_ops(index, args, x, rows_used)
    if args.save:
        t0 = time.perf_counter()
        save_index(index, args.save,
                   extra={"dataset": args.dataset,
                          "corpus_rows_used": int(rows_used)},
                   shards=args.shards or None)
        what = f"sharded ({args.shards}-way) bundle" if args.shards \
            else "index bundle"
        print(f"saved {what} to {args.save} "
              f"in {time.perf_counter() - t0:.1f}s")
    base = index.base if isinstance(index, StreamingIndex) else index
    print(f"  blocks={base.stats.n_blocks} items={base.stats.n_items_stored} "
          f"refs={base.stats.n_ref_entries} "
          f"logical={base.stats.logical_bytes / 1e6:.1f}MB")

    serving = index
    if args.ndev:
        mesh = Mesh(np.asarray(jax.devices()[:args.ndev]), ("data",))
        serving = index.shard(mesh)
        print(f"serving over a {args.ndev}-device mesh (block/vector "
              f"shards of ~{base.stats.n_blocks // args.ndev} blocks; "
              f"same session API)")
    if args.trace:
        obs.start(sample=args.trace_sample)
    if args.gateway:
        snap = run_gateway(serving, args, q, compact_async=gateway_handover)
        finish_obs(args, snap)
        return
    searcher = serving.searcher(SearchParams(
        k=args.k, nprobe=args.nprobe, max_scan=args.max_scan,
        exec_mode=args.exec_mode, use_kernel=args.use_kernel,
        fused_topk=args.fused_topk, plan_reuse=args.plan_reuse,
        refine=refine_params(args)))

    # score against the index's own live corpus (== x when freshly built;
    # under churn the oracle runs over survivors with ids mapped back)
    nq = args.batches * args.batch_size
    if isinstance(index, StreamingIndex):
        live = index.live_ids()
        gt = live[ground_truth(index.live_vectors(), q[:nq], args.k,
                               metric=index.config.metric)]
    else:
        gt = ground_truth(index.vectors, q[:nq], args.k,
                          metric=index.config.metric)
    for b in range(args.batches):
        qb = q[b * args.batch_size:(b + 1) * args.batch_size]
        t0 = time.perf_counter()
        res = searcher(qb)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.asarray(res.ids),
                          gt[b * args.batch_size:(b + 1) * args.batch_size])
        s = dco_summary(res)
        st = searcher.stats
        print(f"batch {b}: recall@{args.k}={rec:.4f} "
              f"dco/query={s['total_dco']:.0f} "
              f"qps={qb.shape[0] / dt:.0f} "
              f"compile[new={st.compiles} hit={st.cache_hits} "
              f"buckets={list(searcher.buckets)}]")
    if args.plan_reuse:
        print(f"plan-cache stats: {searcher.compile_stats()['plan']}")
    if isinstance(index, StreamingIndex):
        print(f"stream searcher stats: {index.searcher_stats()}")
    if args.ndev:
        print(f"sharded searcher stats: {serving.searcher_stats()}")
    finish_obs(args, obs.snapshot_all(searcher=searcher,
                                      tracer=obs.tracer()))


def finish_obs(args, snap):
    """Close out the observability surfaces after serving: stop the
    tracer and write the Perfetto trace-event file (``--trace``), then
    render the unified ``snapshot_all`` stats (``--stats-format``)."""
    if args.trace:
        tr = obs.stop()
        doc = obs.write_trace(tr, args.trace)
        print(f"trace: {len(doc['traceEvents'])} trace events "
              f"({tr.fences} fences, {tr.dropped} dropped) -> "
              f"{args.trace}")
    if args.stats_format == "prom":
        sys.stdout.write(obs.to_prometheus(snap))
    elif args.stats_format == "json":
        print(json.dumps(snap, indent=1, default=float))


if __name__ == "__main__":
    main()
