"""RAIRS ANN serving driver: build (or load) an index over a synthetic
corpus and serve batched queries through a compiled searcher session —
the paper's own workload end-to-end.

``PYTHONPATH=src python -m repro.launch.serve --dataset sift1m
--nprobe 16 --batches 4``

Persistence (skip the train+build phase on repeat runs):

``... --save /tmp/sift1m.npz``      # first run: build then save
``... --load /tmp/sift1m.npz``      # later runs: load, serve immediately
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import (IndexConfig, SearchParams, available_strategies,
                        build_index, dco_summary, ground_truth, load_index,
                        read_index_meta, recall_at_k, save_index)
from repro.data import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift1m")
    ap.add_argument("--strategy", default="rair",
                    choices=available_strategies())
    ap.add_argument("--no-seil", action="store_true")
    ap.add_argument("--nlist", type=int, default=256)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-scan", type=int, default=None,
                    help="per-query block budget (default: index-derived)")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--exec-mode", default="paged",
                    choices=("paged", "grouped"),
                    help="engine scan mode: per-query paging or list-major "
                         "batched execution (paper §5.3)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the ADC scan through the Pallas kernel")
    ap.add_argument("--save", metavar="PATH", default=None,
                    help="persist the built index bundle to PATH")
    ap.add_argument("--load", metavar="PATH", default=None,
                    help="load an index bundle from PATH (skips train+build)")
    args = ap.parse_args()
    if args.load and args.save:
        ap.error("--save and --load are mutually exclusive (a loaded "
                 "bundle is never re-written)")

    x, q, spec = make_dataset(args.dataset)
    if args.load:
        meta = read_index_meta(args.load)
        saved_ds = meta.get("extra", {}).get("dataset")
        if saved_ds is not None and saved_ds != args.dataset:
            ap.error(f"{args.load} was built over dataset {saved_ds!r}, "
                     f"not --dataset {args.dataset!r}; recall against the "
                     f"wrong corpus is meaningless")
        t0 = time.perf_counter()
        index = load_index(args.load)
        cfg = index.config
        if index.vectors.shape[1] != x.shape[1]:
            ap.error(f"{args.load} holds {index.vectors.shape[1]}-d vectors "
                     f"but --dataset {args.dataset} is {x.shape[1]}-d")
        print(f"loaded {cfg.strategy}{'+SEIL' if cfg.seil else ''} index "
              f"over {index.vectors.shape[0]} vectors from {args.load} "
              f"in {time.perf_counter() - t0:.1f}s (train+build skipped; "
              f"--strategy/--nlist/--no-seil come from the bundle)")
    else:
        cfg = IndexConfig(nlist=args.nlist, strategy=args.strategy,
                          seil=not args.no_seil, metric=spec.metric)
        t0 = time.perf_counter()
        index = build_index(jax.random.PRNGKey(0), x, cfg)
        print(f"built {args.strategy}{'' if args.no_seil else '+SEIL'} index "
              f"over {x.shape[0]} vectors in {time.perf_counter() - t0:.1f}s "
              f"(phases: { {k: round(v, 1) for k, v in index.build_seconds.items()} })")
        if args.save:
            t0 = time.perf_counter()
            save_index(index, args.save, extra={"dataset": args.dataset})
            print(f"saved index bundle to {args.save} "
                  f"in {time.perf_counter() - t0:.1f}s")
    print(f"  blocks={index.stats.n_blocks} items={index.stats.n_items_stored} "
          f"refs={index.stats.n_ref_entries} "
          f"logical={index.stats.logical_bytes / 1e6:.1f}MB")

    searcher = index.searcher(SearchParams(
        k=args.k, nprobe=args.nprobe, max_scan=args.max_scan,
        exec_mode=args.exec_mode, use_kernel=args.use_kernel))

    # score against the index's own corpus (== x when freshly built; under
    # --load it guards against dataset-generator drift since the save)
    gt = ground_truth(index.vectors, q[:args.batches * args.batch_size],
                      args.k, metric=index.config.metric)
    for b in range(args.batches):
        qb = q[b * args.batch_size:(b + 1) * args.batch_size]
        t0 = time.perf_counter()
        res = searcher(qb)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.asarray(res.ids),
                          gt[b * args.batch_size:(b + 1) * args.batch_size])
        s = dco_summary(res)
        st = searcher.stats
        print(f"batch {b}: recall@{args.k}={rec:.4f} "
              f"dco/query={s['total_dco']:.0f} "
              f"qps={qb.shape[0] / dt:.0f} "
              f"compile[new={st.compiles} hit={st.cache_hits} "
              f"buckets={list(searcher.buckets)}]")


if __name__ == "__main__":
    main()
