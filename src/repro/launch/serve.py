"""RAIRS ANN serving driver: build an index over a synthetic corpus and
serve batched queries — the paper's own workload end-to-end.

``PYTHONPATH=src python -m repro.launch.serve --dataset sift1m
--nprobe 16 --batches 4``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import (IndexConfig, build_index, dco_summary, ground_truth,
                        recall_at_k)
from repro.data import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift1m")
    ap.add_argument("--strategy", default="rair",
                    choices=("single", "naive", "soar", "rair", "srair"))
    ap.add_argument("--no-seil", action="store_true")
    ap.add_argument("--nlist", type=int, default=256)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--exec-mode", default="paged",
                    choices=("paged", "grouped"),
                    help="engine scan mode: per-query paging or list-major "
                         "batched execution (paper §5.3)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the ADC scan through the Pallas kernel")
    args = ap.parse_args()

    x, q, spec = make_dataset(args.dataset)
    cfg = IndexConfig(nlist=args.nlist, strategy=args.strategy,
                      seil=not args.no_seil, metric=spec.metric)
    t0 = time.perf_counter()
    index = build_index(jax.random.PRNGKey(0), x, cfg)
    print(f"built {args.strategy}{'' if args.no_seil else '+SEIL'} index "
          f"over {x.shape[0]} vectors in {time.perf_counter() - t0:.1f}s "
          f"(phases: { {k: round(v, 1) for k, v in index.build_seconds.items()} })")
    print(f"  blocks={index.stats.n_blocks} items={index.stats.n_items_stored} "
          f"refs={index.stats.n_ref_entries} "
          f"logical={index.stats.logical_bytes / 1e6:.1f}MB")

    gt = ground_truth(x, q[:args.batches * args.batch_size], args.k,
                      metric=spec.metric)
    for b in range(args.batches):
        qb = q[b * args.batch_size:(b + 1) * args.batch_size]
        t0 = time.perf_counter()
        res = index.search(qb, k=args.k, nprobe=args.nprobe,
                           exec_mode=args.exec_mode,
                           use_kernel=args.use_kernel)
        res.ids.block_until_ready()
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.asarray(res.ids),
                          gt[b * args.batch_size:(b + 1) * args.batch_size])
        s = dco_summary(res)
        print(f"batch {b}: recall@{args.k}={rec:.4f} "
              f"dco/query={s['total_dco']:.0f} "
              f"qps={args.batch_size / dt:.0f}")


if __name__ == "__main__":
    main()
