"""Roofline cost pass: exact global HLO FLOPs/bytes per cell.

XLA's cost analysis counts a ``while`` body once regardless of trip
count, so the production (scanned) lowering under-reports FLOPs by
~n_layers x accum.  This pass re-lowers each cell with every scan fully
unrolled on a single *abstract* device (no mesh, no allocation) and uses
``lowered.cost_analysis()`` — exact global FLOPs of the whole step
(validated against closed forms in tests/test_dryrun.py).  Division by
chip count happens in the roofline report.

Run: ``PYTHONPATH=src python -m repro.launch.costpass --all``
(safe to run in the normal 1-device process: no XLA_FLAGS needed).
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback

import jax

from repro.configs import ARCHS
from repro.configs.base import SHAPES
from repro.models.runtime_flags import unrolled

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "launch_results", "cost")


def run_cost(arch: str, shape: str, force: bool = False):
    from repro.launch.shapes import plan_cell, skip_reason
    cell_id = f"{arch}__{shape}"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    rec = {"cell": cell_id, "arch": arch, "shape": shape}
    reason = skip_reason(arch, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
    else:
        t0 = time.perf_counter()
        try:
            # mesh=None: plan with a host mesh purely for spec construction;
            # lowering happens UNSHARDED (global shapes, abstract).
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
            plan = plan_cell(arch, shape, mesh)
            with unrolled():
                lowered = jax.jit(plan.step_fn).lower(*plan.args)
                ca = lowered.cost_analysis()
            rec.update(status="ok",
                       flops=float(ca.get("flops", -1)),
                       bytes_accessed=float(ca.get("bytes accessed", -1)),
                       transcendentals=float(ca.get("transcendentals", -1)),
                       lower_s=round(time.perf_counter() - t0, 1))
        except Exception:
            rec.update(status="failed",
                       error=traceback.format_exc()[-3000:],
                       seconds=round(time.perf_counter() - t0, 1))
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    nf = 0
    for a in archs:
        for s in shapes:
            rec = run_cost(a, s, force=args.force)
            nf += rec.get("status") == "failed"
            print(f"[{rec['cell']}] {rec.get('status')} "
                  f"flops={rec.get('flops', '-'):{'.3e' if isinstance(rec.get('flops'), float) else ''}} "
                  f"t={rec.get('lower_s', '-')}s", flush=True)
    if nf:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
