"""Cell planner: (arch x input-shape) -> step fn + abstract inputs +
shardings.  The dry-run lowers/compiles exactly what this module plans;
nothing here allocates device memory (ShapeDtypeStructs only).

Skip policy (DESIGN.md §Arch-applicability):
  * hubert (encoder-only): decode_32k / long_500k skipped per spec.
  * long_500k on pure full-attention archs is NOT run as quadratic
    attention (skipped per spec) — instead it runs RAIRS-kNN paged
    attention (the paper's technique), marked mode="rairs_knn".
  * jamba/mamba2 run long_500k natively (O(S)-per-step / O(1)-state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ARCHS
from ..configs.base import SHAPES, ModelConfig
from ..dist.sharding import axis_rules, logical_spec, param_shardings
from ..models.retrieval import KnnAttnConfig
from ..models.transformer import ParamSpec, abstract_params, param_specs
from ..serve.step import (cache_shardings, cache_specs, knn_decode_cache_specs,
                          make_decode_step, make_long_decode_step,
                          make_prefill_step)
from ..train.step import TrainConfig, make_train_step, train_step_shardings

SDS = jax.ShapeDtypeStruct

LONG_KNN_CFG = KnnAttnConfig(nlist=512, nprobe=16, block=128,
                             max_blocks_per_list=24, window=1024)


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: str
    mode: str                     # train | prefill | decode | rairs_knn | ssm
    step_fn: Any
    args: Tuple                   # abstract args
    in_shardings: Tuple
    out_shardings: Any
    note: str = ""


def _batch_specs(cfg: ModelConfig, b: int, s: int, *, labels: bool):
    sp: Dict[str, SDS] = {}
    if cfg.frontend == "frame":
        sp["frames"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    else:
        sp["tokens"] = SDS((b, s), jnp.int32)
        if cfg.frontend == "patch":
            sp["patch_embeds"] = SDS((b, s // 4, cfg.patch_dim), jnp.bfloat16)
        if cfg.m_rope:
            sp["positions3"] = SDS((3, b, s), jnp.int32)
    if labels:
        sp["labels"] = SDS((b, s), jnp.int32)
    return sp


def _batch_shardings(mesh: Mesh, batch_specs):
    with axis_rules(mesh):
        def sh(s):
            names = [None] * len(s.shape)
            # batch dim is axis 0 except positions3 (3, B, S)
            bdim = 1 if len(s.shape) >= 2 and s.shape[0] == 3 else 0
            names[bdim] = "batch"
            return NamedSharding(mesh, logical_spec(*names, shape=s.shape))
        return jax.tree.map(sh, batch_specs)


def skip_reason(arch: str, shape: str) -> Optional[str]:
    cfg = ARCHS[arch]
    kind = SHAPES[shape]["kind"]
    if not cfg.has_decode and kind in ("decode", "long_decode"):
        return "encoder-only arch: no decode step (per spec)"
    return None


def plan_cell(arch: str, shape: str, mesh: Mesh,
              accum: int = 8, grad_compress: str = "none",
              knn_cfg: KnnAttnConfig = None) -> CellPlan:
    cfg = ARCHS[arch]
    info = SHAPES[shape]
    b, s = info["global_batch"], info["seq_len"]
    kind = info["kind"]
    knn_cfg = knn_cfg or LONG_KNN_CFG

    if kind == "train":
        # 400B+ models cannot replicate f32 master params over the data
        # axis (memory_analysis: 120+ GiB/chip) -> FSDP/ZeRO-3 sharding
        fsdp = arch in ("arctic-480b", "jamba-1.5-large-398b")
        tcfg = TrainConfig(accum=accum, grad_compress=grad_compress,
                           fsdp=fsdp)
        bs = _batch_specs(cfg, b, s, labels=True)
        params = abstract_params(cfg)
        from ..optim.adamw import OptState
        opt = OptState(
            mu=jax.tree.map(lambda x: SDS(x.shape, jnp.float32), params),
            nu=jax.tree.map(lambda x: SDS(x.shape, jnp.float32), params),
            step=SDS((), jnp.int32))
        (p_sh, o_sh, b_sh), out_sh = train_step_shardings(cfg, mesh, tcfg, bs)
        return CellPlan(arch, shape, "train", make_train_step(cfg, tcfg),
                        (params, opt, bs), (p_sh, o_sh, b_sh), out_sh)

    specs = param_specs(cfg)
    is_leaf = lambda x: isinstance(x, ParamSpec)
    p_sh = param_shardings(specs, mesh, is_leaf=is_leaf)
    params = abstract_params(cfg, dtype=jnp.bfloat16)

    if kind == "prefill":
        bs = _batch_specs(cfg, b, s, labels=False)
        b_sh = _batch_shardings(mesh, bs)
        step = make_prefill_step(cfg)
        return CellPlan(arch, shape, "prefill", step, (params, bs),
                        (p_sh, b_sh), None)

    if kind == "decode":
        cache = cache_specs(cfg, b, s)
        c_sh = cache_shardings(cfg, mesh, cache)
        toks = SDS((b, 1), jnp.int32)
        with axis_rules(mesh):
            t_sh = NamedSharding(mesh, logical_spec("batch", None,
                                                    shape=(b, 1)))
        step = make_decode_step(cfg)
        return CellPlan(arch, shape, "decode", step, (params, cache, toks),
                        (p_sh, c_sh, t_sh), (None, c_sh))

    # ---- long_500k ----
    assert kind == "long_decode"
    pure_attention = cfg.attn_every == 0  # every mixer is full attention
    if pure_attention:
        cache = knn_decode_cache_specs(cfg, knn_cfg, b)
        c_sh = cache_shardings(cfg, mesh, cache, long_context=True)
        toks = SDS((b, 1), jnp.int32)
        with axis_rules(mesh):
            t_sh = NamedSharding(mesh, P())
        step = make_long_decode_step(cfg, knn_cfg)
        return CellPlan(
            arch, shape, "rairs_knn", step, (params, cache, toks),
            (p_sh, c_sh, t_sh), (None, c_sh),
            note="full-attention arch at 524k: RAIRS-kNN paged attention "
                 "(quadratic exact attention skipped per spec)")
    # jamba: native long attention on its sparse attn layers; mamba2: state
    cache = cache_specs(cfg, b, s)
    c_sh = cache_shardings(cfg, mesh, cache, long_context=True)
    toks = SDS((b, 1), jnp.int32)
    with axis_rules(mesh):
        t_sh = NamedSharding(mesh, P())
    step = make_decode_step(cfg)
    return CellPlan(arch, shape, "ssm_long", step, (params, cache, toks),
                    (p_sh, c_sh, t_sh), (None, c_sh),
                    note="SSM/hybrid native long context")


def all_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape
