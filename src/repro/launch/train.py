"""Training driver: ``PYTHONPATH=src python -m repro.launch.train
--arch qwen3-8b --steps 100 [--reduced]``.

On this CPU container only ``--reduced`` configs are runnable; full
configs are exercised via the dry-run.  The loop is the production
skeleton: data pipeline -> sharded train step -> periodic checkpoint ->
elastic restore on restart.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.dist.checkpoint import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.dist.sharding import axis_rules
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init
from repro.train.step import TrainConfig, make_train_step


def synthetic_lm_batch(key, cfg, batch, seq):
    ks = jax.random.split(key, 2)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)}
    b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    if cfg.frontend == "frame":
        b["frames"] = jax.random.normal(ks[1], (batch, seq, cfg.d_model))
    if cfg.frontend == "patch":
        b["patch_embeds"] = jax.random.normal(ks[1],
                                              (batch, seq // 4, cfg.patch_dim))
    if cfg.m_rope:
        b["positions3"] = jnp.broadcast_to(
            jnp.arange(seq)[None, None], (3, batch, seq)).astype(jnp.int32)
    return b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    tcfg = TrainConfig(accum=args.accum)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = adamw_init(params)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        restored = restore_checkpoint(args.ckpt_dir,
                                      {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        start = int(opt.step)
        print(f"resumed from step {start}")

    with mesh, axis_rules(mesh):
        for i in range(start, args.steps):
            batch = synthetic_lm_batch(jax.random.PRNGKey(i), cfg,
                                       args.batch, args.seq)
            t0 = time.perf_counter()
            params, opt, m = step_fn(params, opt, batch)
            loss = float(m["loss"])
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {loss:7.4f} "
                      f"gnorm {float(m['grad_norm']):7.3f} "
                      f"{time.perf_counter() - t0:5.2f}s", flush=True)
            if (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i + 1,
                                {"params": params, "opt": opt})
    print("done")


if __name__ == "__main__":
    main()
