"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run sets
xla_force_host_platform_device_count before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Tiny mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
