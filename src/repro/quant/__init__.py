"""Quantization ladder (DESIGN.md §12): compact code planes for the
two-tier scan — tier-1 scans a 4-bit-packed compact plane through the
unchanged engine, tier-2 exactly re-ranks the widened survivor set in
``finalize_candidates``.

``nibbles`` is the packed code layout (dependency-free; the engine and
kernels import it directly); ``plane`` holds the backends (pq4 /
binary), the ``PlanePack`` attachment container, and the SEIL block-
layout derivation.  ``repro.core`` is only imported lazily inside
functions, so this package is import-safe from anywhere in the stack.
"""
from .nibbles import pack_nibbles, packed_width, unpack_nibbles
from .plane import (PLANE_BACKENDS, PlanePack, build_plane, compact_subdim,
                    encode_plane, plane_block_codes, train_plane)

__all__ = [
    "PLANE_BACKENDS", "PlanePack", "build_plane", "compact_subdim",
    "encode_plane", "pack_nibbles", "packed_width", "plane_block_codes",
    "train_plane", "unpack_nibbles",
]
