"""Packed 4-bit code layout shared by the quant planes and the scan.

A compact plane stores two 4-bit codes per byte (lo nibble = even
subquantizer, hi nibble = odd), so the tier-1 scan reads half the code
bytes of an unpacked plane with the same ksub<=16 codebook.  These
helpers are the single definition of that layout — `plane.py` packs
with them at attach time, and the engine/kernel scan paths unpack with
them in-register (core/engine/scan.py jnp fallback) or in-VMEM
(kernels/pq_scan.py), so packer and unpacker can never diverge.

Deliberately dependency-free (numpy/jnp only): imported from both the
kernels package and the engine without touching `repro.core`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def packed_width(m: int) -> int:
    """Code bytes per item for an m-subquantizer 4-bit plane."""
    return (m + 1) // 2


def pack_nibbles(codes: np.ndarray) -> np.ndarray:
    """(..., M) uint8 codes < 16 -> (..., ceil(M/2)) packed bytes.

    Odd M pads a zero code into the final hi nibble; the scan's LUT is
    zero-padded to 2*ceil(M/2) rows so that phantom code contributes 0.
    """
    codes = np.asarray(codes)
    if codes.size and int(codes.max()) >= 16:
        raise ValueError("pack_nibbles needs 4-bit codes (< 16)")
    m = codes.shape[-1]
    if m % 2:
        pad = np.zeros(codes.shape[:-1] + (1,), codes.dtype)
        codes = np.concatenate([codes, pad], axis=-1)
    lo = codes[..., 0::2].astype(np.uint8)
    hi = codes[..., 1::2].astype(np.uint8)
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_nibbles(packed: jnp.ndarray, m: int) -> jnp.ndarray:
    """(..., ceil(M/2)) packed bytes -> (..., m) int32 codes (jit-safe).

    Interleaves lo/hi nibbles back into subquantizer order and slices
    off the odd-M phantom column.  Works on numpy arrays too.
    """
    lo = (packed & 15).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    out = jnp.stack([lo, hi], axis=-1)
    out = out.reshape(packed.shape[:-1] + (2 * packed.shape[-1],))
    return out[..., :m]
