"""Compact code planes — the tier-1 side of the quantization ladder.

The two-tier scan (DESIGN.md §12) runs the existing four-stage engine
over a *compact plane*: a second, coarser set of per-item codes laid
out in the exact same SEIL block geometry as the full-width codes, so
every scan path (paged/grouped/clustered, jnp or Pallas, frozen/
streaming/sharded) executes unchanged with three substitutions — the
plane's packed block codes for ``arrays.block_codes``, the plane's
codebook for the ADC LUT, and a survivor budget widened to
``bigk * refine_factor``.  Tier-2 is the engine's own
``finalize_candidates`` exact re-rank over the untouched vector store.

Every backend reduces to a ``PQCodebook`` with ksub <= 16, so ADC LUT
construction, encoding and decoding reuse ``core/pq.py`` verbatim:

``pq4``     a coarser product quantizer trained with ``pq_train`` at
            dsub = 8 (falling back to 4 / 2 for small or odd dims) —
            Mc = D/8 vs the full plane's M = D/2, i.e. 4x fewer LUT
            lookups and 8x fewer code bytes per scanned item once
            nibble-packed.
``binary``  a RaBitQ-style sign code with a *virtual* codebook built in
            closed form (no k-means): per-dimension mean/scale over
            groups of 4 dims, corner c of group g reconstructing
            ``mean + scale * (2*bit_j(c) - 1)``.  Nearest-corner
            encoding of x is exactly ``x > mean`` per dimension (the
            sign bit), and the standard ADC LUT against the corners is
            the asymmetric query-to-corner distance.

Codecs are tiny (Mc * ksub * dsub floats) and deterministic given
(vectors, key), so compaction re-derives a plane bitwise by re-encoding
the surviving corpus with the carried-over codec.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .nibbles import pack_nibbles, packed_width

PLANE_BACKENDS: Tuple[str, ...] = ("pq4", "binary")


def compact_subdim(d: int) -> int:
    """Subspace width of the pq4 plane: as coarse as the dim allows."""
    if d % 8 == 0 and d >= 16:
        return 8
    if d % 4 == 0:
        return 4
    if d % 2 == 0:
        return 2
    raise ValueError(f"pq4 plane needs an even dimension, got d={d}")


@dataclasses.dataclass(frozen=True)
class PlanePack:
    """One attached compact plane: codec + per-id codes + block layout.

    ``codes`` are the unpacked per-id codes (n, Mc) — the persistence
    and delta-append form.  ``block_codes`` is the scan form: the SEIL
    block-id gather of ``codes``, nibble-packed to (TB, BLK, ceil(Mc/2))
    so a block tile carries half the bytes of an unpacked plane.
    """
    backend: str
    codec: object               # core.pq.PQCodebook, ksub <= 16
    codes: np.ndarray           # (n, Mc) uint8 per-id compact codes
    block_codes: jnp.ndarray    # (TB, BLK, ceil(Mc/2)) uint8, packed

    @property
    def m(self) -> int:
        return int(self.codec.codebooks.shape[0])

    @property
    def ksub(self) -> int:
        return int(self.codec.codebooks.shape[1])

    @property
    def bytes_per_item(self) -> int:
        return int(self.block_codes.shape[-1])


def train_plane(backend: str, key, vectors, *, iters: int = 10):
    """Train (pq4) or derive (binary) a compact-plane codec.

    Returns a ``PQCodebook``; encoding/LUT/decoding ride core/pq.py.
    """
    from repro.core.pq import PQCodebook, pq_train
    x = np.asarray(vectors, np.float32)
    d = x.shape[1]
    if backend == "pq4":
        dsub = compact_subdim(d)
        return pq_train(key, jnp.asarray(x), m=d // dsub, nbits=4,
                        iters=iters)
    if backend == "binary":
        group = 4 if d % 4 == 0 else (2 if d % 2 == 0 else 1)
        mc = d // group
        mean = x.mean(axis=0)
        scale = x.std(axis=0) + 1e-6
        bits = (np.arange(2 ** group)[:, None]
                >> np.arange(group)[None, :]) & 1          # (ksub, group)
        signs = 2.0 * bits.astype(np.float32) - 1.0
        books = (mean.reshape(mc, 1, group)
                 + scale.reshape(mc, 1, group) * signs[None, :, :])
        return PQCodebook(jnp.asarray(books, jnp.float32))
    raise ValueError(f"unknown plane backend {backend!r}; "
                     f"choose from {PLANE_BACKENDS}")


def encode_plane(codec, vectors) -> np.ndarray:
    """Encode vectors against a plane codec -> (n, Mc) uint8 (< ksub).

    For ``binary`` codecs the nearest corner separates per dimension
    into sign(x - mean), so this *is* the sign-bit extraction.
    """
    from repro.core.pq import pq_encode
    if np.asarray(vectors).shape[0] == 0:
        return np.zeros((0, int(codec.codebooks.shape[0])), np.uint8)
    return np.asarray(pq_encode(codec, jnp.asarray(vectors, jnp.float32)),
                      np.uint8)


def plane_block_codes(codes: np.ndarray, block_ids) -> jnp.ndarray:
    """Gather per-id plane codes into the SEIL block layout and pack.

    codes (n, Mc) uint8, block_ids (TB, BLK) int32 with -1 invalid ->
    (TB, BLK, ceil(Mc/2)) uint8.  Invalid slots carry zero codes; the
    scan masks them by id exactly as it does for the full plane, so the
    phantom values never surface.  Pure host-side gather — deterministic,
    so compaction and reload re-derive the identical array.
    """
    ids = np.asarray(block_ids)
    safe = np.maximum(ids, 0)
    per_block = np.asarray(codes)[safe] * (ids >= 0)[..., None].astype(np.uint8)
    return jnp.asarray(pack_nibbles(per_block))


def build_plane(backend: str, key, vectors, block_ids, *,
                codec=None, iters: int = 10) -> PlanePack:
    """Train (unless a codec is carried over) + encode + lay out a plane."""
    if codec is None:
        codec = train_plane(backend, key, vectors, iters=iters)
    codes = encode_plane(codec, vectors)
    return PlanePack(backend=backend, codec=codec, codes=codes,
                     block_codes=plane_block_codes(codes, block_ids))


__all__ = ["PLANE_BACKENDS", "PlanePack", "build_plane", "compact_subdim",
           "encode_plane", "pack_nibbles", "packed_width",
           "plane_block_codes", "train_plane"]
