from .transformer import (Model, init_params, train_loss, prefill,  # noqa
                          decode_step)
