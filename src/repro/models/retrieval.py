"""RAIRS-kNN paged attention — the paper's index serving a 500k-token
KV cache (the long_500k cell for full-attention archs).

Keys of each (batch, kv-head) are clustered into `nlist` IVF lists;
each key is redundantly assigned to up to two lists with the AIR metric
(RAIR).  SEIL-for-attention adaptation: every cell_{i,j}'s keys are
packed once into 128-wide blocks listed in BOTH lists' tables — unlike
ANN search, attention *must* be compute-once (softmax would double-count
a twice-scanned key), so cell-level deduplication is a correctness
requirement here, done by first-occurrence masking over the gathered
block ids (the vectorized ``listVisited``).  Partial cell blocks are
zero-padded instead of spilling to a misc area (masked lanes are free on
the VPU; DESIGN.md §3 records the trade).

Decode gathers the top-`nprobe` lists' K/V blocks per kv-head plus a
recent raw window, then does masked attention over ~nprobe·maxb·128
keys instead of 524288 — sub-quadratic decode, paged exactly like the
Pallas pq_scan kernel pages SEIL blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.assign import rair_assign
from ..core.kmeans import kmeans_fit
from .layers import COMPUTE_DTYPE


@dataclasses.dataclass(frozen=True)
class KnnAttnConfig:
    nlist: int = 512
    nprobe: int = 16
    block: int = 128
    max_blocks_per_list: int = 32   # maxb
    window: int = 1024              # recent raw-attention window
    lam: float = 0.5
    n_cands: int = 10
    cache_dtype: str = "bf16"       # bf16 | int8 (per-block absmax scales)


def knn_cache_specs(cfg, kcfg: KnnAttnConfig, batch: int, n_periods: int,
                    dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract per-attn-slot cache (leading period axis) for the dry-run."""
    kvh, hd = cfg.n_kv_heads, cfg.hd
    nb = kcfg.nlist * kcfg.max_blocks_per_list // 2  # RAIR <=2x, shared once
    S = jax.ShapeDtypeStruct
    if kcfg.cache_dtype == "int8":
        dtype = jnp.int8
    out = {
        "centroids": S((n_periods, batch, kvh, kcfg.nlist, hd), jnp.float32),
        "k_blocks": S((n_periods, batch, kvh, nb, kcfg.block, hd), dtype),
        "v_blocks": S((n_periods, batch, kvh, nb, kcfg.block, hd), dtype),
        "key_valid": S((n_periods, batch, kvh, nb, kcfg.block), jnp.bool_),
        "table": S((n_periods, batch, kvh, kcfg.nlist,
                    kcfg.max_blocks_per_list), jnp.int32),
        "win_k": S((n_periods, batch, kcfg.window, kvh, hd), jnp.bfloat16),
        "win_v": S((n_periods, batch, kcfg.window, kvh, hd), jnp.bfloat16),
    }
    if kcfg.cache_dtype == "int8":  # per-block absmax dequant scales
        out["k_scale"] = S((n_periods, batch, kvh, nb), jnp.float32)
        out["v_scale"] = S((n_periods, batch, kvh, nb), jnp.float32)
    return out


def rairs_attention_decode(q: jnp.ndarray, slot_cache: Dict, kv_len,
                           kcfg: KnnAttnConfig) -> jnp.ndarray:
    """q: (B, 1, H, hd) -> (B, 1, H, hd) attention over retrieved + window."""
    b, _, h, hd = q.shape
    cents = slot_cache["centroids"]                    # (B, kvH, L, hd)
    kvh = cents.shape[1]
    rep = h // kvh
    qg = q[:, 0].reshape(b, kvh, rep, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    # 1. probe lists (group-shared: mean query over the GQA group)
    qm = qg.mean(axis=2)                               # (B, kvH, hd)
    cs = jnp.einsum("bgd,bgld->bgl", qm, cents,
                    preferred_element_type=jnp.float32)
    _, sel = jax.lax.top_k(cs, kcfg.nprobe)            # (B, kvH, P)

    # 2. gather block tables; first-occurrence dedup (vectorized listVisited)
    table = slot_cache["table"]                        # (B,kvH,L,maxb)
    tb = jnp.take_along_axis(
        table, sel[..., None].repeat(table.shape[-1], -1), axis=2)
    ids = tb.reshape(b, kvh, -1)                       # (B,kvH,S)
    s = ids.shape[-1]
    eq = ids[..., :, None] == ids[..., None, :]        # (B,kvH,S,S)
    earlier = jnp.tril(jnp.ones((s, s), bool), k=-1)
    dup = (eq & earlier).any(-1)
    keep_block = (ids >= 0) & ~dup                     # (B,kvH,S)

    # 3. gather K/V blocks (paged; scalar-prefetch kernel on TPU)
    safe = jnp.maximum(ids, 0)
    def g(x):  # (B,kvH,NB,blk,hd) -> (B,kvH,S,blk,hd)
        return jnp.take_along_axis(
            x, safe[..., None, None].repeat(x.shape[-2], -2)
                 .repeat(x.shape[-1], -1), axis=2)
    kb = g(slot_cache["k_blocks"])
    vb = g(slot_cache["v_blocks"])
    if "k_scale" in slot_cache:     # int8 blocks: per-block absmax dequant
        def gs(x):
            return jnp.take_along_axis(x, safe, axis=2)
        kb = kb.astype(COMPUTE_DTYPE) * gs(slot_cache["k_scale"]
                                           )[..., None, None].astype(COMPUTE_DTYPE)
        vb = vb.astype(COMPUTE_DTYPE) * gs(slot_cache["v_scale"]
                                           )[..., None, None].astype(COMPUTE_DTYPE)
    valid = jnp.take_along_axis(
        slot_cache["key_valid"],
        safe[..., None].repeat(kcfg.block, -1), axis=2)
    item_mask = valid & keep_block[..., None]          # (B,kvH,S,blk)

    kf = kb.reshape(b, kvh, -1, hd)
    vf = vb.reshape(b, kvh, -1, hd)
    mask_r = item_mask.reshape(b, kvh, -1)

    # 4. retrieved-set scores + recent window scores, one softmax
    sr = jnp.einsum("bgrd,bgkd->bgrk", (qg * scale).astype(COMPUTE_DTYPE),
                    kf.astype(COMPUTE_DTYPE),
                    preferred_element_type=jnp.float32)
    sr = jnp.where(mask_r[:, :, None], sr, -jnp.inf)
    wk, wv = slot_cache["win_k"], slot_cache["win_v"]  # (B,W,kvH,hd)
    w = wk.shape[1]
    sw = jnp.einsum("bgrd,bwgd->bgrw", (qg * scale).astype(COMPUTE_DTYPE),
                    wk.astype(COMPUTE_DTYPE),
                    preferred_element_type=jnp.float32)
    wpos = jnp.arange(w)[None]
    wmask = wpos < jnp.minimum(kv_len[:, None], w)
    sw = jnp.where(wmask[:, None, None], sw, -jnp.inf)
    alls = jnp.concatenate([sr, sw], axis=-1)
    p = jax.nn.softmax(alls, axis=-1)
    pr, pw = p[..., :sr.shape[-1]], p[..., sr.shape[-1]:]
    out = jnp.einsum("bgrk,bgkd->bgrd", pr.astype(COMPUTE_DTYPE),
                     vf.astype(COMPUTE_DTYPE),
                     preferred_element_type=jnp.float32) \
        + jnp.einsum("bgrw,bwgd->bgrd", pw.astype(COMPUTE_DTYPE),
                     wv.astype(COMPUTE_DTYPE),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def update_window(slot_cache: Dict, k_new, v_new, kv_len) -> Dict:
    """Ring-buffer append of the new token's K/V (B,1,kvH,hd)."""
    w = slot_cache["win_k"].shape[1]
    pos = kv_len % w

    def upd(buf, val):
        return jax.vmap(
            lambda c, u, x: jax.lax.dynamic_update_slice(c, x, (u, 0, 0))
        )(buf, pos, val.astype(buf.dtype))

    return dict(slot_cache,
                win_k=upd(slot_cache["win_k"], k_new),
                win_v=upd(slot_cache["win_v"], v_new))


# ----------------------------------------------------------------------------
# Long-context decode step (the long_500k cell for full-attention archs)
# ----------------------------------------------------------------------------
def decode_step_long(params, cfg, cache, tokens, kcfg: KnnAttnConfig):
    """Like transformer.decode_step, but attention slots run RAIRS-kNN
    paged attention against the clustered cache + recent window.
    tokens: (B, 1); cache["blocks"][s_j] = knn slot dict (attn) or
    MambaState (ssm)."""
    from .layers import rms_norm, _dot, attention_proj, apply_rope
    from .transformer import _ssm_sublayer, _mlp_sublayer, _unembed_w

    h = params["embed"][tokens].astype(COMPUTE_DTYPE)
    kv_len = cache["len"]
    kinds = cfg.slot_kinds()

    def body(hh, xs):
        pparams, pcache = xs
        newc = {}
        for j, (mixer, mlp) in enumerate(kinds):
            slot = pparams[f"s{j}"]
            if mixer == "attn":
                x = rms_norm(hh, slot["ln1"])
                a = slot["attn"]
                q, k, v = attention_proj(
                    x, a["wq"], a["wk"], a["wv"], cfg.n_heads,
                    cfg.n_kv_heads, cfg.hd, a.get("q_norm"), a.get("k_norm"))
                pos = kv_len[:, None]
                q = apply_rope(q, pos, cfg.rope_theta)
                k = apply_rope(k, pos, cfg.rope_theta)
                sc = update_window(pcache[f"s{j}"], k, v, kv_len)
                o = rairs_attention_decode(q, sc, kv_len + 1, kcfg)
                b = o.shape[0]
                y = _dot(o.reshape(b, 1, cfg.n_heads * cfg.hd), a["wo"])
                hh = hh + y.astype(hh.dtype)
                newc[f"s{j}"] = sc
            else:
                hh, c = _ssm_sublayer(cfg, slot, hh, "decode",
                                      state=pcache[f"s{j}"])
                newc[f"s{j}"] = c
            if mlp != "none":
                hh = _mlp_sublayer(cfg, slot, hh, mlp)
        return hh, newc

    from .runtime_flags import scan_unroll_arg
    h, new_blocks = jax.lax.scan(body, h, (params["blocks"],
                                           cache["blocks"]),
                                 unroll=scan_unroll_arg())
    h = rms_norm(h, params["final_norm"])
    logits = _dot(h, _unembed_w(params, cfg))
    return logits, {"blocks": new_blocks, "len": kv_len + 1}


# ----------------------------------------------------------------------------
# Offline cache construction (tests/examples; production would build this
# at prefill time with the distributed kmeans of core/)
# ----------------------------------------------------------------------------
def build_knn_cache(keys: np.ndarray, values: np.ndarray,
                    kcfg: KnnAttnConfig, seed: int = 0) -> Dict:
    """keys/values: (B, S, kvH, hd) -> concrete single-period slot cache.
    Uses the paper's own machinery: k-means lists + RAIR (AIR) assignment
    + shared-cell packing."""
    b, s, kvh, hd = keys.shape
    blk = kcfg.block
    nb_cap = kcfg.nlist * kcfg.max_blocks_per_list // 2
    cents = np.zeros((b, kvh, kcfg.nlist, hd), np.float32)
    kb = np.zeros((b, kvh, nb_cap, blk, hd), np.float32)
    vb = np.zeros((b, kvh, nb_cap, blk, hd), np.float32)
    valid = np.zeros((b, kvh, nb_cap, blk), bool)
    table = np.full((b, kvh, kcfg.nlist, kcfg.max_blocks_per_list), -1,
                    np.int32)
    for bi in range(b):
        for g in range(kvh):
            kk = keys[bi, :, g, :]
            c = np.asarray(kmeans_fit(jax.random.PRNGKey(seed + 7 * g),
                                      jnp.asarray(kk), kcfg.nlist, iters=8))
            cents[bi, g] = c
            a = np.asarray(rair_assign(
                jnp.asarray(kk), jnp.asarray(c), lam=kcfg.lam,
                n_cands=min(kcfg.n_cands, kcfg.nlist)))
            # pack each cell once; register its blocks in both lists
            keys64 = a[:, 0].astype(np.int64) * kcfg.nlist + a[:, 1]
            order = np.argsort(keys64, kind="stable")
            cells, starts = np.unique(keys64[order], return_index=True)
            nxt = 0
            fill = np.zeros(kcfg.nlist, np.int32)
            bounds = np.append(starts, len(order))
            for ci, cell in enumerate(cells):
                l1, l2 = int(cell // kcfg.nlist), int(cell % kcfg.nlist)
                items = order[bounds[ci]:bounds[ci + 1]]
                for s0 in range(0, len(items), blk):
                    it = items[s0:s0 + blk]
                    bid = nxt
                    nxt += 1
                    kb[bi, g, bid, :len(it)] = kk[it]
                    vb[bi, g, bid, :len(it)] = values[bi, :, g, :][it]
                    valid[bi, g, bid, :len(it)] = True
                    for l in {l1, l2}:
                        if fill[l] < kcfg.max_blocks_per_list:
                            table[bi, g, l, fill[l]] = bid
                            fill[l] += 1
    win_k = np.zeros((b, kcfg.window, kvh, hd), np.float32)
    win_v = np.zeros((b, kcfg.window, kvh, hd), np.float32)
    return {
        "centroids": jnp.asarray(cents),
        "k_blocks": jnp.asarray(kb, COMPUTE_DTYPE),
        "v_blocks": jnp.asarray(vb, COMPUTE_DTYPE),
        "key_valid": jnp.asarray(valid),
        "table": jnp.asarray(table),
        "win_k": jnp.asarray(win_k, COMPUTE_DTYPE),
        "win_v": jnp.asarray(win_v, COMPUTE_DTYPE),
    }
