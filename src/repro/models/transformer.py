"""Unified LM stack covering all 10 assigned architectures.

One parameterization, six families:
  dense (llama3/qwen3/gemma), moe (olmoe/arctic), vlm (qwen2-vl, M-RoPE,
  stub patch frontend), audio (hubert encoder, stub frame frontend),
  hybrid (jamba: periods of 7 Mamba + 1 attention, alternating MoE),
  ssm (mamba2, attention-free).

Layers stack over `n_periods` for `jax.lax.scan` (small HLO, fast
compiles at 512 devices); each period applies `cfg.slot_kinds()`
sublayers.  `param_specs` is the single source of truth for parameter
shapes + logical sharding axes: `init_params` samples real arrays (smoke
tests), `abstract_params` gives ShapeDtypeStructs (the multi-pod
dry-run lowers against these; full-size weights are never allocated).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import logical_shard
from .layers import (COMPUTE_DTYPE, _dot, apply_m_rope, apply_rope,
                     attention_proj, decode_attention, flash_attention,
                     gated_mlp, rms_norm)
from .mamba2 import (MambaState, mamba2_block, mamba2_block_decode,
                     mamba2_init)
from .moe import moe_mlp
from .runtime_flags import scan_unroll_arg


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init_scale: Optional[float] = None  # None -> 1/sqrt(fan_in)


def _attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sp = {
        "wq": ParamSpec((d, h * hd), ("d_model", "heads")),
        "wk": ParamSpec((d, kvh * hd), ("d_model", "kv")),
        "wv": ParamSpec((d, kvh * hd), ("d_model", "kv")),
        "wo": ParamSpec((h * hd, d), ("heads", "d_model")),
    }
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), (None,), 1.0)
        sp["k_norm"] = ParamSpec((hd,), (None,), 1.0)
    return sp


def _mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, ff), ("d_model", "ff")),
        "w_up": ParamSpec((d, ff), ("d_model", "ff")),
        "w_down": ParamSpec((ff, d), ("ff", "d_model")),
    }


def _moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, e, ff = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff or cfg.d_ff
    # 2D expert sharding: expert dim over "data" (EP), within-expert ff
    # over "model" (TP) — 480B-scale expert tables cannot replicate over
    # the data axis (memory_analysis showed 120 GiB/chip with EP-on-model
    # only; see EXPERIMENTS.md §Dry-run).
    sp = {
        "router": ParamSpec((d, e), ("d_model", None)),
        "w_gate": ParamSpec((e, d, ff), ("expert", "d_model", "ff")),
        "w_up": ParamSpec((e, d, ff), ("expert", "d_model", "ff")),
        "w_down": ParamSpec((e, ff, d), ("expert", "ff", "d_model")),
    }
    if cfg.moe_dense_residual:
        for k, v in _mlp_specs(cfg).items():
            sp["dense_" + k] = v
    return sp


def _ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_inner = cfg.ssm_heads * cfg.ssm_head_dim
    n = cfg.ssm_state
    in_dim = 2 * d_inner + 2 * n + cfg.ssm_heads
    return {
        "w_in": ParamSpec((d, in_dim), ("d_model", "ssm_head")),
        "conv_w": ParamSpec((4, d_inner + 2 * n), (None, "ssm_head"), 0.2),
        "A_log": ParamSpec((cfg.ssm_heads,), ("ssm_head",), 1.0),
        "D": ParamSpec((cfg.ssm_heads,), ("ssm_head",), 1.0),
        "norm": ParamSpec((d_inner,), ("ssm_head",), 1.0),
        "w_out": ParamSpec((d_inner, d), ("ssm_head", "d_model")),
    }


def param_specs(cfg: ModelConfig):
    """Full parameter pytree of ParamSpec (period-stacked layer params)."""
    d = cfg.d_model
    np_ = cfg.n_periods

    def stacked(sp: Dict[str, ParamSpec]):
        return {k: ParamSpec((np_,) + v.shape, (None,) + v.logical,
                             v.init_scale) for k, v in sp.items()}

    blocks: Dict[str, Any] = {}
    for j, (mixer, mlp) in enumerate(cfg.slot_kinds()):
        slot: Dict[str, Any] = {
            "ln1": stacked({"s": ParamSpec((d,), (None,), 1.0)})["s"],
        }
        if mixer == "attn":
            slot["attn"] = stacked(_attn_specs(cfg))
        else:
            slot["ssm"] = stacked(_ssm_specs(cfg))
        if mlp != "none":
            slot["ln2"] = stacked({"s": ParamSpec((d,), (None,), 1.0)})["s"]
            slot["mlp" if mlp == "dense" else "moe"] = stacked(
                _mlp_specs(cfg) if mlp == "dense" else _moe_specs(cfg))
        blocks[f"s{j}"] = slot

    params: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "d_model"), 0.02),
        "blocks": blocks,
        "final_norm": ParamSpec((d,), (None,), 1.0),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = ParamSpec((d, cfg.vocab), ("d_model", "vocab"))
    if cfg.frontend == "patch":
        params["patch_proj"] = ParamSpec((cfg.patch_dim, d),
                                         (None, "d_model"))
    return params


def _is_spec(x):
    return isinstance(x, ParamSpec)


def init_params(key: jax.Array, cfg: ModelConfig):
    specs, treedef = jax.tree.flatten(param_specs(cfg), is_leaf=_is_spec)
    keys = jax.random.split(key, len(specs))

    def mk(k, s: ParamSpec):
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        scale = s.init_scale if s.init_scale is not None \
            else 1.0 / math.sqrt(fan_in)
        if s.shape[-1:] == s.shape and s.init_scale == 1.0:
            return jnp.ones(s.shape, jnp.float32)  # norm scales
        return jax.random.normal(k, s.shape, jnp.float32) * scale

    return jax.tree.unflatten(treedef, [mk(k, s) for k, s in
                                        zip(keys, specs)])


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStructs for dry-run lowering (serve steps pass bf16)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        param_specs(cfg), is_leaf=_is_spec)


def param_logical(cfg: ModelConfig):
    return jax.tree.map(lambda s: s.logical, param_specs(cfg),
                        is_leaf=_is_spec)


# ----------------------------------------------------------------------------
# Embedding / frontend
# ----------------------------------------------------------------------------
def embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    if cfg.frontend == "frame":
        h = batch["frames"]                      # (B, S, d) stub frontend
    else:
        h = params["embed"][batch["tokens"]]     # (B, S, d)
        if cfg.frontend == "patch":
            pe = _dot(batch["patch_embeds"], params["patch_proj"])
            p = pe.shape[1]
            h = jnp.concatenate([pe.astype(h.dtype), h[:, p:]], axis=1)
    return logical_shard(h.astype(COMPUTE_DTYPE), "batch", "seq", "d_model")


def _positions(cfg, batch, h):
    b, s = h.shape[:2]
    if cfg.m_rope:
        return batch["positions3"]               # (3, B, S)
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


# ----------------------------------------------------------------------------
# Sublayers
# ----------------------------------------------------------------------------
def _rope(cfg, x, pos):
    if cfg.m_rope:
        return apply_m_rope(x, pos, cfg.m_rope_sections, cfg.rope_theta)
    return apply_rope(x, pos, cfg.rope_theta)


def _attn_sublayer(cfg, p, h, pos, mode, cache_kv=None, cache_len=None):
    x = rms_norm(h, p["ln1"])
    a = p["attn"]
    q, k, v = attention_proj(x, a["wq"], a["wk"], a["wv"], cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd,
                             a.get("q_norm"), a.get("k_norm"))
    q = logical_shard(q, "batch", "seq", "heads", None)
    k = logical_shard(k, "batch", "seq", "kv", None)
    v = logical_shard(v, "batch", "seq", "kv", None)
    if mode == "decode":
        qpos = cache_len[:, None]
        q = _rope(cfg, q, qpos if not cfg.m_rope else
                  jnp.broadcast_to(qpos[None], (3,) + qpos.shape))
        k = _rope(cfg, k, qpos if not cfg.m_rope else
                  jnp.broadcast_to(qpos[None], (3,) + qpos.shape))
        kc, vc = cache_kv
        b, smax = kc.shape[:2]
        upd = jnp.minimum(cache_len, smax - 1)
        kc = jax.vmap(lambda c, u, val: jax.lax.dynamic_update_slice(
            c, val, (u, 0, 0)))(kc, upd, k.astype(kc.dtype))
        vc = jax.vmap(lambda c, u, val: jax.lax.dynamic_update_slice(
            c, val, (u, 0, 0)))(vc, upd, v.astype(vc.dtype))
        o = decode_attention(q, kc, vc, cache_len + 1)
        new_cache = (kc, vc)
    else:
        q = _rope(cfg, q, pos)
        k = _rope(cfg, k, pos)
        o = flash_attention(q, k, v, causal=cfg.causal,
                            chunk=min(cfg.flash_chunk, q.shape[1]))
        new_cache = (k, v)
    o = logical_shard(o, "batch", "seq", "heads", None)
    b, s = o.shape[:2]
    y = _dot(o.reshape(b, s, cfg.n_heads * cfg.hd), a["wo"])
    return h + y.astype(h.dtype), new_cache


def _mlp_sublayer(cfg, p, h, kind):
    x = rms_norm(h, p["ln2"])
    if kind == "dense":
        m = p["mlp"]
        y = gated_mlp(x, m["w_gate"], m["w_up"], m["w_down"], cfg.act)
        y = logical_shard(y.astype(h.dtype), "batch", "seq", "d_model")
        return h + y
    m = p["moe"]
    y, _load = moe_mlp(x, m["router"], m["w_gate"], m["w_up"], m["w_down"],
                       top_k=cfg.moe_top_k,
                       capacity_factor=cfg.capacity_factor, act=cfg.act)
    if cfg.moe_dense_residual:
        y = y + gated_mlp(x, m["dense_w_gate"], m["dense_w_up"],
                          m["dense_w_down"], cfg.act)
    y = logical_shard(y.astype(h.dtype), "batch", "seq", "d_model")
    return h + y


def _ssm_sublayer(cfg, p, h, mode, state: Optional[MambaState] = None):
    x = rms_norm(h, p["ln1"])
    if mode == "decode":
        y, new_state = mamba2_block_decode(
            p["ssm"], x, state, n_heads=cfg.ssm_heads,
            head_dim=cfg.ssm_head_dim, ssm_state=cfg.ssm_state)
    else:
        y, new_state = mamba2_block(
            p["ssm"], x, n_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
            ssm_state=cfg.ssm_state, chunk=min(cfg.ssm_chunk, x.shape[1]))
    return h + y.astype(h.dtype), new_state


# ----------------------------------------------------------------------------
# Stack
# ----------------------------------------------------------------------------
def _period_fn(cfg: ModelConfig, mode: str):
    kinds = cfg.slot_kinds()

    def run(h, pos, pparams, pcache, cache_len):
        new_cache = {}
        for j, (mixer, mlp) in enumerate(kinds):
            slot = pparams[f"s{j}"]
            if mixer == "attn":
                ck = pcache.get(f"s{j}") if pcache else None
                h, c = _attn_sublayer(cfg, slot, h, pos, mode,
                                      cache_kv=ck, cache_len=cache_len)
                new_cache[f"s{j}"] = c
            else:
                st = pcache.get(f"s{j}") if pcache else None
                h, c = _ssm_sublayer(cfg, slot, h, mode, state=st)
                new_cache[f"s{j}"] = c
            if mlp != "none":
                h = _mlp_sublayer(cfg, slot, h, mlp)
        return h, new_cache

    return run


def forward(params, cfg: ModelConfig, batch, mode: str = "train",
            remat: bool = True):
    """Runs the stack. Returns (hidden (B,S,d), per-period cache stack)."""
    h = embed_inputs(params, cfg, batch)
    pos = _positions(cfg, batch, h)
    run = _period_fn(cfg, mode)

    def body(hh, pparams):
        hh, cache = run(hh, pos, pparams, None, None)
        return hh, cache

    if remat and mode == "train":
        body = jax.checkpoint(body)
    h, cache = jax.lax.scan(body, h, params["blocks"],
                            unroll=scan_unroll_arg())
    return rms_norm(h, params["final_norm"]), cache


# ----------------------------------------------------------------------------
# Losses / serving entry points
# ----------------------------------------------------------------------------
def _chunked_ce(h, w_unembed, labels, chunk: int):
    """Cross entropy with sequence chunking (vocab stays shardable)."""
    b, s, d = h.shape
    nch = max(s // chunk, 1)
    hs = h.reshape(b, nch, s // nch, d)
    ls = labels.reshape(b, nch, s // nch)

    def body(carry, inp):
        hc, lc = inp                            # (b, c, d), (b, c)
        logits = _dot(hc, w_unembed)            # (b, c, V) f32
        logits = logical_shard(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss = ((lse - gold) * mask).sum()
        return carry + jnp.stack([loss, mask.sum()]), None

    (tot, _), _ = jax.lax.scan(body, jnp.zeros(2),
                               (jnp.moveaxis(hs, 1, 0),
                                jnp.moveaxis(ls, 1, 0)),
                               unroll=scan_unroll_arg()), None
    return tot


def _unembed_w(params, cfg):
    return params["unembed"] if not cfg.tie_embeddings else params["embed"].T


def train_loss(params, cfg: ModelConfig, batch, remat: bool = True):
    h, _ = forward(params, cfg, batch, mode="train", remat=remat)
    acc = _chunked_ce(h, _unembed_w(params, cfg), batch["labels"],
                      cfg.ce_chunk)
    return acc[0] / jnp.maximum(acc[1], 1.0)


def prefill(params, cfg: ModelConfig, batch, cache_slack: int = 0):
    """Returns (last-position logits, decode cache)."""
    h, cache = forward(params, cfg, batch, mode="prefill", remat=False)
    b, s = h.shape[:2]
    logits = _dot(h[:, -1:], _unembed_w(params, cfg))
    if cfg.has_decode:
        def pad_kv(x):
            if cache_slack:
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, cache_slack)     # (NP, B, S, kvH, hd)
                x = jnp.pad(x, pad)
            return x
        cache = {k: (jax.tree.map(pad_kv, v)
                     if isinstance(v, tuple) and not isinstance(v, MambaState)
                     else v)
                 for k, v in cache.items()}
        length = jnp.full((b,), s, jnp.int32)
        return logits, {"blocks": cache, "len": length}
    return logits, None


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """tokens: (B, 1) -> (logits (B,1,V), updated cache)."""
    h = params["embed"][tokens].astype(COMPUTE_DTYPE)
    h = logical_shard(h, "batch", "seq", "d_model")
    run = _period_fn(cfg, "decode")
    cache_len = cache["len"]

    def body(hh, xs):
        pparams, pcache = xs
        hh, newc = run(hh, None, pparams, pcache, cache_len)
        return hh, newc

    h, new_blocks = jax.lax.scan(body, h, (params["blocks"],
                                           cache["blocks"]),
                                 unroll=scan_unroll_arg())
    h = rms_norm(h, params["final_norm"])
    logits = _dot(h, _unembed_w(params, cfg))
    return logits, {"blocks": new_blocks, "len": cache_len + 1}


class Model:
    """Thin OO veneer used by examples/launchers."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return init_params(key, self.cfg)

    def loss(self, params, batch):
        return train_loss(params, self.cfg, batch)

    def prefill(self, params, batch, cache_slack=0):
        return prefill(params, self.cfg, batch, cache_slack)

    def decode(self, params, cache, tokens):
        return decode_step(params, self.cfg, cache, tokens)
