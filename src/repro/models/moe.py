"""Token-choice top-k MoE with static capacity (GShard-style), EP-shardable.

Routing keeps exact top-k semantics: each token picks its top-k experts;
per expert only the first ``capacity`` routed slots are kept (overflow
tokens drop that expert's contribution — standard capacity-factor
behaviour).  Dispatch/combine are gathers/segment-sums with fully static
shapes, so GSPMD can shard the expert dimension over the `model` axis
(expert parallelism) and insert the all-to-alls.

FLOPs are the *active* FLOPs (k of E experts), not E/k-times dense —
this keeps the roofline "useful compute" ratio honest for MoE archs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE, gated_mlp


def route_topk(router_logits: jnp.ndarray, k: int, capacity: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """router_logits: (T, E) -> slot assignment.

    Returns (slot_token (E, C) int32 token id or -1,
             slot_gate  (E, C) f32 combine weight,
             aux: load-balance fraction per expert (E,))."""
    t, e = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate, expert = jax.lax.top_k(probs, k)               # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renorm
    flat_expert = expert.reshape(-1)                     # (T*k,)
    flat_gate = gate.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    # position of each routed pair within its expert queue
    order = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(t * k) - starts[sorted_e]
    pos = jnp.zeros(t * k, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < capacity
    slot = jnp.where(keep, flat_expert * capacity + pos, e * capacity)
    slot_token = jnp.full((e * capacity + 1,), -1, jnp.int32
                          ).at[slot].set(jnp.where(keep, flat_token, -1))
    slot_gate = jnp.zeros((e * capacity + 1,), jnp.float32
                          ).at[slot].set(jnp.where(keep, flat_gate, 0.0))
    load = counts.astype(jnp.float32) / (t * k)
    return (slot_token[:-1].reshape(e, capacity),
            slot_gate[:-1].reshape(e, capacity), load)


def moe_mlp(x: jnp.ndarray, router_w: jnp.ndarray, w_gate: jnp.ndarray,
            w_up: jnp.ndarray, w_down: jnp.ndarray, *, top_k: int,
            capacity_factor: float = 1.25, act: str = "silu"
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d); expert weights (E, d, ff) / (E, ff, d).
    Returns (y (B, S, d) f32, router load (E,))."""
    b, s, d = x.shape
    e = w_gate.shape[0]
    xt = x.reshape(b * s, d)
    logits = xt.astype(COMPUTE_DTYPE) @ router_w.astype(COMPUTE_DTYPE)
    cap = int(max(top_k * b * s / e * capacity_factor, 4))
    slot_token, slot_gate, load = route_topk(logits.astype(jnp.float32),
                                             top_k, cap)
    xe = xt[jnp.maximum(slot_token, 0)]                  # (E, C, d)
    ye = jax.vmap(lambda xx, wg, wu, wd: gated_mlp(xx[None], wg, wu, wd, act)[0]
                  )(xe, w_gate, w_up, w_down)            # (E, C, d) f32
    ye = ye * slot_gate[..., None]
    flat_tok = jnp.where(slot_token >= 0, slot_token, b * s).reshape(-1)
    y = jax.ops.segment_sum(ye.reshape(-1, d), flat_tok,
                            num_segments=b * s + 1)[:-1]
    return y.reshape(b, s, d), load
