"""Runtime flags controlling lowering strategy.

UNROLL_SCANS: the production path keeps layer/microbatch/chunk loops as
``lax.scan`` (small HLO -> fast 512-device compiles).  XLA's cost
analysis counts a while-loop body ONCE regardless of trip count, so the
roofline cost pass re-lowers each cell with every scan fully unrolled on
a single abstract device — ``lowered.cost_analysis()`` then reports the
exact global FLOPs (validated in tests/test_dryrun.py).
"""
import contextlib
import threading

_state = threading.local()


def unroll_scans() -> bool:
    return getattr(_state, "unroll", False)


@contextlib.contextmanager
def unrolled():
    prev = unroll_scans()
    _state.unroll = True
    try:
        yield
    finally:
        _state.unroll = prev


def scan_unroll_arg():
    return True if unroll_scans() else 1
