"""Mamba-2 (SSD — state-space duality) block, chunked-scan formulation.

Implements the minimal SSD recurrence of arXiv:2405.21060:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t xᵀ_t        (per head)
    y_t = C_tᵀ h_t + D x_t
computed chunk-parallel: quadratic attention-like form within chunks,
associative state passing across chunks — O(S·P·N) work, O(S) memory.
Single-token recurrence (`mamba2_decode`) carries (h, conv window).

Shapes: d_inner = expand·d_model split into H heads of P=head_dim;
B/C shared across heads (ngroups=1), state size N = ssm_state.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE, _dot, rms_norm
from .runtime_flags import scan_unroll_arg


class MambaState(NamedTuple):
    h: jnp.ndarray       # (B, H, P, N) SSM state
    conv: jnp.ndarray    # (B, W-1, conv_channels) depthwise-conv tail


def _segsum(dtA):  # (..., T) -> (..., T, T) lower-tri cumulative sums
    t = dtA.shape[-1]
    x = jnp.cumsum(dtA, axis=-1)
    diff = x[..., :, None] - x[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A_log, B, C, D, chunk: int = 128):
    """x: (b, s, h, p); dt: (b, s, h); A_log: (h,); B, C: (b, s, n).
    Returns y: (b, s, h, p) and final state (b, h, p, n)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    s0 = s
    pad = (-s) % chunk if s > chunk else 0
    if s < chunk:
        chunk = s
    if pad:
        # dt -> -inf so softplus(dt)=0: pad steps leave state untouched
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e9)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    cs = chunk
    A = -jnp.exp(A_log.astype(jnp.float32))                  # (h,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32))             # (b, s, h)
    xr = x.reshape(b, nc, cs, h, p)
    dtr = dt.reshape(b, nc, cs, h)
    Br = B.reshape(b, nc, cs, n)
    Cr = C.reshape(b, nc, cs, n)
    dtA = dtr * A[None, None, None, :]                       # (b, nc, cs, h)

    # --- intra-chunk (quadratic within the chunk, SSD "attention" form)
    L = jnp.exp(_segsum(jnp.moveaxis(dtA, -1, -2)))          # (b,nc,h,cs,cs)
    scores = jnp.einsum("bctn,bcsn->bcts", Cr, Br)           # (b,nc,cs,cs)
    M = scores[:, :, None] * L                               # (b,nc,h,t,s)
    y_diag = jnp.einsum("bchts,bcsh,bcshp->bcthp",
                        M.astype(COMPUTE_DTYPE),
                        dtr.astype(COMPUTE_DTYPE),
                        xr.astype(COMPUTE_DTYPE),
                        preferred_element_type=jnp.float32)

    # --- chunk states: contribution of each chunk to its final state
    # decay from step t (exclusive) to the chunk end: sum_{j>t} dtA_j
    rev_incl = jnp.cumsum(dtA[:, :, ::-1], axis=2)[:, :, ::-1]
    decay_to_end = jnp.exp(rev_incl - dtA)                   # (b,nc,cs,h)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        Br.astype(COMPUTE_DTYPE),
                        (dtr * decay_to_end).astype(COMPUTE_DTYPE),
                        xr.astype(COMPUTE_DTYPE),
                        preferred_element_type=jnp.float32)  # (b,nc,h,p,n)

    # --- inter-chunk recurrence over chunk boundary states
    chunk_decay = jnp.exp(dtA.sum(axis=2))                   # (b, nc, h)

    def scan_fn(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hlast, hprevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=scan_unroll_arg())
    hprevs = jnp.moveaxis(hprevs, 0, 1)                      # (b,nc,h,p,n)

    # --- inter-chunk output: y += C_t · (decay_from_start * h_prev)
    decay_from_start = jnp.exp(jnp.cumsum(dtA, axis=2))      # (b,nc,cs,h)
    y_off = jnp.einsum("bctn,bcth,bchpn->bcthp",
                       Cr.astype(COMPUTE_DTYPE),
                       decay_from_start.astype(COMPUTE_DTYPE),
                       hprevs.astype(COMPUTE_DTYPE),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y[:, :s0].astype(x.dtype), hlast


def mamba2_step(x_t, state: MambaState, dt_t, A_log, B_t, C_t, D):
    """Single-token recurrence. x_t: (b, h, p); dt_t: (b, h); B/C: (b, n)."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    dt = jax.nn.softplus(dt_t.astype(jnp.float32))           # (b, h)
    decay = jnp.exp(dt * A[None, :])                         # (b, h)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, B_t.astype(jnp.float32),
                     x_t.astype(jnp.float32))
    h = state.h * decay[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C_t.astype(jnp.float32), h)
    y = y + x_t.astype(jnp.float32) * D[None, :, None]
    return y.astype(x_t.dtype), h


def causal_conv1d(x, w, cache=None):
    """Depthwise causal conv. x: (b, s, c); w: (w_len, c).
    If cache (b, w_len-1, c) given: single-step mode (s==1)."""
    wl = w.shape[0]
    if cache is not None:
        window = jnp.concatenate([cache, x], axis=1)         # (b, wl, c)
        y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None]
        return y.astype(x.dtype), window[:, 1:]
    xp = jnp.pad(x, ((0, 0), (wl - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(wl))
    return y.astype(x.dtype), xp[:, x.shape[1]:]  # tail for decode handoff


def mamba2_block(params, x, *, n_heads, head_dim, ssm_state, conv_w=4,
                 chunk=128):
    """Full Mamba-2 mixer: in-proj -> conv -> SSD -> gate -> out-proj.
    x: (b, s, d_model) -> (b, s, d_model), final MambaState."""
    b, s, d = x.shape
    d_inner = n_heads * head_dim
    n = ssm_state
    zxbcdt = _dot(x, params["w_in"])          # (b,s, 2*d_inner + 2n + h)
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_out, conv_tail = causal_conv1d(conv_in, params["conv_w"])
    conv_out = jax.nn.silu(conv_out)
    xs, Bs, Cs = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    y, hlast = ssd_chunked(
        xs.reshape(b, s, n_heads, head_dim), dt, params["A_log"], Bs, Cs,
        params["D"], chunk=chunk)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = _dot(y, params["w_out"])
    return out, MambaState(h=hlast, conv=conv_tail[:, -(conv_w - 1):])


def mamba2_block_decode(params, x, state: MambaState, *, n_heads, head_dim,
                        ssm_state, conv_w=4):
    """Single-token mixer step. x: (b, 1, d_model)."""
    b, _, d = x.shape
    d_inner = n_heads * head_dim
    n = ssm_state
    zxbcdt = _dot(x, params["w_in"])
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_out, new_conv = causal_conv1d(conv_in, params["conv_w"], state.conv)
    conv_out = jax.nn.silu(conv_out)
    xs, Bs, Cs = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    y, hnew = mamba2_step(
        xs[:, 0].reshape(b, n_heads, head_dim), state, dt[:, 0],
        params["A_log"], Bs[:, 0], Cs[:, 0], params["D"])
    y = y.reshape(b, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return _dot(y, params["w_out"]), MambaState(h=hnew, conv=new_conv)


def mamba2_init(key, d_model, n_heads, head_dim, ssm_state, conv_w=4):
    d_inner = n_heads * head_dim
    n = ssm_state
    in_dim = 2 * d_inner + 2 * n + n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_in": jax.random.normal(k1, (d_model, in_dim), jnp.float32)
                / jnp.sqrt(d_model),
        "conv_w": jax.random.normal(k2, (conv_w, d_inner + 2 * n),
                                    jnp.float32) * 0.2,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.float32),
        "w_out": jax.random.normal(k3, (d_inner, d_model), jnp.float32)
                 / jnp.sqrt(d_inner),
    }
