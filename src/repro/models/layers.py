"""Transformer building blocks: RMSNorm, RoPE / M-RoPE, GQA attention
(chunked-flash for train/prefill, single-token for decode), gated MLPs.

Pure-JAX pytree params (no flax).  All matmuls cast to bf16 for compute
with f32 accumulation (``preferred_element_type``), f32 master params —
the MaxText-style mixed-precision recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .runtime_flags import scan_unroll_arg

COMPUTE_DTYPE = jnp.bfloat16


def _dot(x, w):
    return jax.lax.dot_general(
        x.astype(COMPUTE_DTYPE), w.astype(COMPUTE_DTYPE),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ----------------------------------------------------------------------------
# RoPE / M-RoPE
# ----------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[:, :, None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_m_rope(x, positions3, sections, theta: float = 1e4):
    """Qwen2-VL multimodal RoPE.  positions3: (3, B, S) for (t, h, w);
    `sections` partitions hd/2 frequencies across the three axes."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])
    pos = positions3[sec]                               # (hd/2, B, S) mixed
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------------
def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def flash_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                    window: Optional[int] = None):
    """Online-softmax attention, scanned over KV chunks (O(S) memory).
    q: (B, Sq, H, hd); k, v: (B, Sk, KvH, hd) — KvH repeated to H here."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = (q * scale).astype(COMPUTE_DTYPE)
    nchunks = max(sk // chunk, 1)
    csize = sk // nchunks
    kc = k.reshape(b, nchunks, csize, h, hd)
    vc = v.reshape(b, nchunks, csize, h, hd)
    q_pos = jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj.astype(COMPUTE_DTYPE),
                       preferred_element_type=jnp.float32)
        kv_pos = j * csize + jnp.arange(csize)
        mask = jnp.ones((sq, csize), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(COMPUTE_DTYPE),
            vj.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(nchunks)), unroll=scan_unroll_arg())
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)      # (B, Sq, H, hd)


def decode_attention(q, k_cache, v_cache, kv_len):
    """Single-token attention against a (B, Smax, KvH, hd) cache.
    kv_len: (B,) current lengths (positions >= kv_len masked)."""
    b, smax, kvh, hd = k_cache.shape
    h = q.shape[2]
    n_rep = h // kvh
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qh = (q[:, 0] * scale).astype(COMPUTE_DTYPE)        # (B, H, hd)
    qg = qh.reshape(b, kvh, n_rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg,
                   k_cache.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)  # (B,KvH,rep,Smax)
    mask = jnp.arange(smax)[None] < kv_len[:, None]
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p.astype(COMPUTE_DTYPE),
                     v_cache.astype(COMPUTE_DTYPE),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ----------------------------------------------------------------------------
# Projections / MLP
# ----------------------------------------------------------------------------
def attention_proj(x, wq, wk, wv, n_heads, n_kv_heads, head_dim,
                   q_norm=None, k_norm=None):
    b, s, _ = x.shape
    q = _dot(x, wq).reshape(b, s, n_heads, head_dim)
    k = _dot(x, wk).reshape(b, s, n_kv_heads, head_dim)
    v = _dot(x, wv).reshape(b, s, n_kv_heads, head_dim)
    if q_norm is not None:                      # Qwen3 qk_norm (per head_dim)
        q = rms_norm(q, q_norm)
        k = rms_norm(k, k_norm)
    return q, k, v


def gated_mlp(x, w_gate, w_up, w_down, act: str = "silu"):
    g = _dot(x, w_gate)
    u = _dot(x, w_up)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    return _dot((a * u).astype(x.dtype), w_down)


# ----------------------------------------------------------------------------
# Init helpers
# ----------------------------------------------------------------------------
def dense_init(key, shape, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s)
