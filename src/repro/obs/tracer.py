"""Span-based tracer for the serving stack (DESIGN.md §11).

One ``Tracer`` records *complete* spans: name, category, thread, start
time, duration, nesting depth, and a free-form counter dict.  Spans are
opened with the module-level ``span(...)`` context manager, which
guarantees well-nesting per thread (a span closes before anything that
opened earlier on the same thread) — asserted in tests/test_obs.py
under concurrent gateway traffic.

Zero overhead when disabled is a hard contract: ``span()`` returns a
shared no-op singleton and ``fence()`` returns its argument untouched —
no lock, no allocation that grows, no device synchronization — so the
instrumented dispatch path is the production path.  The module keeps a
global work counter (``work_count()``) bumped on every recorded span,
raw event, and fence; tests assert it does not move while tracing is
off (a counter-based assertion, deliberately not a timing one).

``fence(x)`` is how device work becomes attributable: with a tracer
active it blocks until ``x``'s buffers are ready, so the enclosing
span's duration covers the device time of its stage instead of just the
dispatch cost of an async call.  Fencing changes *when* the host
observes values, never the values — traced results are bitwise
identical to untraced ones (asserted).

``Tracer.event`` records cross-thread exemplar events (e.g. one span
per sampled gateway request, spanning enqueue→fulfill) on virtual
request tracks; these carry no nesting contract.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

import jax

# virtual-track tids for cross-thread exemplar events (Tracer.event):
# requests overlap in time, so they rotate over a small pool of tracks
# instead of stacking on the recording thread's (well-nested) track.
_REQ_TID_BASE = 1_000_000
_REQ_TRACKS = 8

# module-global tracer work counter: spans + events + fences ever
# recorded.  The zero-overhead-when-disabled test pins this.
_WORK = 0
_ACTIVE: Optional["Tracer"] = None
_ACTIVE_LOCK = threading.Lock()


class _NoopSpan:
    """Shared do-nothing span returned by ``span()`` while disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def add(self, **counters):
        return self


_NOOP = _NoopSpan()


class _Span:
    """A live span; created by ``Tracer.span`` and recorded on exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0
        self.depth = 0

    def add(self, **counters) -> "_Span":
        """Attach counters to the span (merged into its args)."""
        self.args.update(counters)
        return self

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self.depth = len(stack)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._tracer._stack().pop()
        self._tracer._record(self.name, self.cat, threading.get_ident(),
                             self.t0, t1 - self.t0, self.depth, self.args,
                             kind="span")
        return False


class Tracer:
    """Thread-safe span/event recorder.

    ``sample`` thins exemplar events (``sampled()`` is true once every
    ``sample`` calls); ``max_events`` bounds memory — past it, records
    are counted in ``dropped`` instead of stored.
    """

    def __init__(self, sample: int = 1, max_events: int = 200_000):
        if sample < 1:
            raise ValueError(f"sample must be >= 1, got {sample}")
        self.t0 = time.perf_counter()
        self.sample = sample
        self.max_events = max_events
        self.records: List[Dict[str, Any]] = []
        self.fences = 0
        self.dropped = 0
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._sample_ctr = 0
        self._req_slot = 0

    # -- recording ------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _record(self, name, cat, tid, t0, dur, depth, args, kind) -> None:
        global _WORK
        rec = {"name": name, "cat": cat, "tid": tid,
               "ts": t0 - self.t0, "dur": dur, "depth": depth,
               "kind": kind, "args": args}
        with self._lock:
            _WORK += 1
            if len(self.records) >= self.max_events:
                self.dropped += 1
            else:
                self.records.append(rec)

    def span(self, name: str, cat: str = "host", **args) -> _Span:
        return _Span(self, name, cat, args)

    def event(self, name: str, t0: float, dur: float, cat: str = "request",
              tid: Optional[int] = None, **args) -> None:
        """Record a cross-thread complete event (no nesting contract).
        ``t0`` is an absolute ``time.perf_counter()`` timestamp.  Without
        an explicit ``tid`` the event lands on a rotating virtual
        request track so overlapping requests render side by side."""
        if tid is None:
            with self._lock:
                slot = self._req_slot
                self._req_slot = (slot + 1) % _REQ_TRACKS
            tid = _REQ_TID_BASE + slot
        self._record(name, cat, tid, t0, dur, 0, args, kind="event")

    def sampled(self) -> bool:
        """True once every ``sample`` calls (always true at sample=1)."""
        with self._lock:
            n = self._sample_ctr
            self._sample_ctr += 1
        return n % self.sample == 0

    # -- aggregation ----------------------------------------------------
    def stage_summary(self) -> Dict[str, Dict[str, Any]]:
        """Per-span-name aggregate: count, total/mean seconds, and the
        sum of every numeric counter the spans carried."""
        with self._lock:
            recs = list(self.records)
        out: Dict[str, Dict[str, Any]] = {}
        for r in recs:
            if r["kind"] != "span":
                continue
            agg = out.setdefault(r["name"], {"count": 0, "total_s": 0.0,
                                             "counters": {}})
            agg["count"] += 1
            agg["total_s"] += r["dur"]
            for k, v in r["args"].items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                agg["counters"][k] = agg["counters"].get(k, 0) + v
        for agg in out.values():
            agg["mean_ms"] = agg["total_s"] / agg["count"] * 1e3
        return out


# ---------------------------------------------------------------------------
# module-level API — the only names instrumentation sites use
# ---------------------------------------------------------------------------

def enabled() -> bool:
    """True while a tracer is active (``start()`` .. ``stop()``)."""
    return _ACTIVE is not None


def tracer() -> Optional[Tracer]:
    """The active tracer, or None."""
    return _ACTIVE


def work_count() -> int:
    """Total tracer work ever done in this process (spans + events +
    fences recorded).  Pinned by the zero-overhead-when-disabled test."""
    return _WORK


def span(name: str, cat: str = "host", **args):
    """Open a span on the active tracer, or a shared no-op when none."""
    t = _ACTIVE
    if t is None:
        return _NOOP
    return t.span(name, cat, **args)


def fence(x):
    """Block until ``x``'s device buffers are ready — only while tracing
    (the production path never synchronizes).  Returns ``x``."""
    t = _ACTIVE
    if t is not None:
        global _WORK
        jax.block_until_ready(x)
        with t._lock:
            t.fences += 1
            _WORK += 1
    return x


def start(sample: int = 1, max_events: int = 200_000) -> Tracer:
    """Install a fresh active tracer (errors if one is already active)."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a tracer is already active; stop() it first")
        _ACTIVE = Tracer(sample=sample, max_events=max_events)
        return _ACTIVE


def stop() -> Tracer:
    """Deactivate and return the active tracer (errors if none)."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        if _ACTIVE is None:
            raise RuntimeError("no active tracer")
        t = _ACTIVE
        _ACTIVE = None
        return t


class trace:
    """``with obs.trace() as tr: ...`` — start/stop scoped to a block."""

    def __init__(self, sample: int = 1, max_events: int = 200_000):
        self._kw = {"sample": sample, "max_events": max_events}

    def __enter__(self) -> Tracer:
        self._t = start(**self._kw)
        return self._t

    def __exit__(self, *exc) -> bool:
        stop()
        return False
