"""Unified stats schema + the modeled scan-stage HBM traffic.

``snapshot_all`` folds every counter surface the stack already computes
— session compile/cache stats, plan-cache hit/extend/miss and union
widths, gateway telemetry, streaming epoch state, per-stage time/DCO
from tracer span counters, and the analytic HBM traffic model of the
scan stage — into ONE dict with a documented layout (see the function
docstring; locked by tests/test_obs.py and rendered to Prometheus text
by ``repro.obs.to_prometheus``).

``scan_traffic_model`` is the single definition of the scan/finalize
boundary traffic model ``bench_fused`` introduced; ``benchmarks/
roofline.py`` re-exports it so benchmark reports and serving snapshots
use identical accounting.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from .tracer import Tracer

SNAPSHOT_SCHEMA_VERSION = 1


def scan_traffic_model(*, scan_width: int, fetch: int) -> Dict[str, float]:
    """Analytic minimum bytes/query exchanged with HBM around the
    scan/finalize boundary (DESIGN.md §9):

      unfused: the scan materializes the full ``scan_width`` candidate
        stream for finalize to re-read — 8 B each (f32 distance + i32
        id), written once and read once;
      fused:   only the top-``fetch`` accumulator leaves the scan —
        12 B written each (f32 distance + i32 flat position + i32 id),
        8 B of which finalize reads back.
    """
    unfused_write = scan_width * 8.0
    fused_write = fetch * 12.0
    return {
        "unfused_scan_write": unfused_write,
        "fused_scan_write": fused_write,
        "write_reduction_x": unfused_write / fused_write,
        "unfused_roundtrip": 2 * unfused_write,
        "fused_roundtrip": fused_write + fetch * 8.0,
        "roundtrip_reduction_x":
            2 * unfused_write / (fused_write + fetch * 8.0),
    }


def session_traffic_model(searcher) -> Dict[str, Any]:
    """The scan-stage traffic model at a live session's operating point
    (scan width from the resolved params, fetch from the index's
    finalize contract).

    When the session runs the two-tier ladder (``params.refine``,
    DESIGN.md §12) a ``refine`` sub-dict reports the tier split: the
    compact plane's geometry (m_compact LUT lookups and packed
    code bytes per scanned item vs the full plane's m_full), the
    widened ``bigk_eff`` survivor budget, the modeled per-query code
    read traffic of each tier-1 variant, and the weighted total-ops
    model (tier-1 LUT lookups + tier-2 exact dims) against the
    single-tier baseline — the same accounting ``bench_refine`` and
    ``check_regression`` gate on, so serving snapshots and committed
    benches can never disagree about the claimed reduction."""
    from ..core.search import finalize_fetch
    p = searcher.params
    idx = searcher.index
    base = getattr(idx, "base", idx)          # StreamingIndex -> base
    blk = int(base.arrays.block_codes.shape[1])
    scan_width = p.max_scan * blk
    fetch = min(finalize_fetch(p.bigk_eff, idx.result_oversample,
                               idx.needs_result_dedup), scan_width)
    out = {"scan_width": scan_width, "fetch": fetch, "block": blk,
           "max_scan": p.max_scan, "fused_topk": p.fused_topk,
           "bytes_per_query": scan_traffic_model(scan_width=scan_width,
                                                 fetch=fetch)}
    plane = getattr(searcher, "_plane", None)
    if plane is not None:
        m_full = int(base.codebook.m)
        dim = int(base.vectors.shape[1])
        tier1_ops = scan_width * plane.m
        tier2_ops = p.bigk_eff * dim
        single_ops = scan_width * m_full + p.bigk * dim
        out["refine"] = {
            "plane": plane.backend,
            "refine_factor": p.refine.refine_factor,
            "bigk": p.bigk, "bigk_eff": p.bigk_eff,
            "m_compact": plane.m, "m_full": m_full,
            "lookups_per_item": plane.m,
            "code_bytes_per_item": plane.bytes_per_item,
            "full_code_bytes_per_item": m_full,
            "tier1_code_read_bytes": scan_width * plane.bytes_per_item,
            "single_tier_code_read_bytes": scan_width * m_full,
            "tier1_ops": tier1_ops, "tier2_ops": tier2_ops,
            "total_ops": tier1_ops + tier2_ops,
            "single_tier_ops": single_ops,
            "total_ops_reduction_x": single_ops / (tier1_ops + tier2_ops),
        }
    return out


def _trace_section(tracer: Tracer) -> Dict[str, Any]:
    summary = tracer.stage_summary()
    stage_s = sum(v["total_s"] for name, v in summary.items()
                  if name.startswith("stage."))
    disp = summary.get("searcher.dispatch")
    section: Dict[str, Any] = {
        "spans": summary,
        "fences": tracer.fences,
        "dropped": tracer.dropped,
        "events": len(tracer.records),
    }
    if disp and disp["total_s"] > 0:
        # fraction of end-to-end dispatch wall time attributed to named
        # engine stages — the bench_trace acceptance metric
        section["stage_attribution"] = stage_s / disp["total_s"]
    # per-stage DCO: the delta-vs-base scan split plus refine, straight
    # from span counters
    dco = {}
    for name, v in summary.items():
        for key in ("approx_dco", "delta_dco", "refine_dco"):
            if key in v["counters"]:
                dco[f"{name}.{key}"] = v["counters"][key]
    if dco:
        section["dco"] = dco
    return section


def snapshot_all(*, gateway=None, gateway_stats: Optional[dict] = None,
                 searcher=None, tracer: Optional[Tracer] = None
                 ) -> Dict[str, Any]:
    """One coherent stats dict across the stack.  Schema (top-level
    keys, each present only when its source was supplied):

      schema_version  int — bump on layout changes.
      session   ``Searcher.compile_stats()``: compiles /
                warmup_compiles / calls / dispatches / cache_hits /
                padded_rows / buckets, plus ``plan`` (hit_rate,
                hits/extends/misses, mean_union_live / mean_own_live /
                mean_width) when the session runs plan_reuse.
      gateway   ``Gateway.stats()``: telemetry counters + gauges +
                derived rates (qps, batch_fill, bucket_fill,
                *_dco_per_query, result_fill_rate, mean_top1_dist) +
                latency/queue_wait/dispatch histograms, queue depth,
                handover + session + stream state.
      hbm_model ``session_traffic_model``: scan_width / fetch / block /
                max_scan / fused_topk + modeled bytes_per_query
                (unfused vs fused write and roundtrip, reductions);
                plus ``refine`` (tier geometry, per-tier ops and code
                read traffic, total_ops_reduction_x vs single-tier)
                when the session runs the two-tier ladder.
      trace     per-span-name aggregates (count / total_s / mean_ms /
                summed counters), fence + drop counts, and
                ``stage_attribution`` (stage time / dispatch time) and
                ``dco`` (per-stage DCO incl. the delta-vs-base scan
                split) when the trace carried them.
    """
    out: Dict[str, Any] = {"schema_version": SNAPSHOT_SCHEMA_VERSION}
    if gateway is not None and gateway_stats is None:
        gateway_stats = gateway.stats()
    if gateway_stats is not None:
        out["gateway"] = gateway_stats
    if searcher is None and gateway is not None:
        searcher = getattr(gateway, "_last_session", None)
    if searcher is not None:
        out["session"] = searcher.compile_stats()
        out["hbm_model"] = session_traffic_model(searcher)
    if tracer is not None:
        out["trace"] = _trace_section(tracer)
    return out
