"""Unified stats schema + the modeled scan-stage HBM traffic.

``snapshot_all`` folds every counter surface the stack already computes
— session compile/cache stats, plan-cache hit/extend/miss and union
widths, gateway telemetry, streaming epoch state, per-stage time/DCO
from tracer span counters, and the analytic HBM traffic model of the
scan stage — into ONE dict with a documented layout (see the function
docstring; locked by tests/test_obs.py and rendered to Prometheus text
by ``repro.obs.to_prometheus``).

``scan_traffic_model`` is the single definition of the scan/finalize
boundary traffic model ``bench_fused`` introduced; ``benchmarks/
roofline.py`` re-exports it so benchmark reports and serving snapshots
use identical accounting.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from .tracer import Tracer

SNAPSHOT_SCHEMA_VERSION = 1


def scan_traffic_model(*, scan_width: int, fetch: int) -> Dict[str, float]:
    """Analytic minimum bytes/query exchanged with HBM around the
    scan/finalize boundary (DESIGN.md §9):

      unfused: the scan materializes the full ``scan_width`` candidate
        stream for finalize to re-read — 8 B each (f32 distance + i32
        id), written once and read once;
      fused:   only the top-``fetch`` accumulator leaves the scan —
        12 B written each (f32 distance + i32 flat position + i32 id),
        8 B of which finalize reads back.
    """
    unfused_write = scan_width * 8.0
    fused_write = fetch * 12.0
    return {
        "unfused_scan_write": unfused_write,
        "fused_scan_write": fused_write,
        "write_reduction_x": unfused_write / fused_write,
        "unfused_roundtrip": 2 * unfused_write,
        "fused_roundtrip": fused_write + fetch * 8.0,
        "roundtrip_reduction_x":
            2 * unfused_write / (fused_write + fetch * 8.0),
    }


def session_traffic_model(searcher) -> Dict[str, Any]:
    """The scan-stage traffic model at a live session's operating point
    (scan width from the resolved params, fetch from the index's
    finalize contract)."""
    from ..core.search import finalize_fetch
    p = searcher.params
    idx = searcher.index
    base = getattr(idx, "base", idx)          # StreamingIndex -> base
    blk = int(base.arrays.block_codes.shape[1])
    scan_width = p.max_scan * blk
    fetch = min(finalize_fetch(p.bigk, idx.result_oversample,
                               idx.needs_result_dedup), scan_width)
    return {"scan_width": scan_width, "fetch": fetch, "block": blk,
            "max_scan": p.max_scan, "fused_topk": p.fused_topk,
            "bytes_per_query": scan_traffic_model(scan_width=scan_width,
                                                  fetch=fetch)}


def _trace_section(tracer: Tracer) -> Dict[str, Any]:
    summary = tracer.stage_summary()
    stage_s = sum(v["total_s"] for name, v in summary.items()
                  if name.startswith("stage."))
    disp = summary.get("searcher.dispatch")
    section: Dict[str, Any] = {
        "spans": summary,
        "fences": tracer.fences,
        "dropped": tracer.dropped,
        "events": len(tracer.records),
    }
    if disp and disp["total_s"] > 0:
        # fraction of end-to-end dispatch wall time attributed to named
        # engine stages — the bench_trace acceptance metric
        section["stage_attribution"] = stage_s / disp["total_s"]
    # per-stage DCO: the delta-vs-base scan split plus refine, straight
    # from span counters
    dco = {}
    for name, v in summary.items():
        for key in ("approx_dco", "delta_dco", "refine_dco"):
            if key in v["counters"]:
                dco[f"{name}.{key}"] = v["counters"][key]
    if dco:
        section["dco"] = dco
    return section


def snapshot_all(*, gateway=None, gateway_stats: Optional[dict] = None,
                 searcher=None, tracer: Optional[Tracer] = None
                 ) -> Dict[str, Any]:
    """One coherent stats dict across the stack.  Schema (top-level
    keys, each present only when its source was supplied):

      schema_version  int — bump on layout changes.
      session   ``Searcher.compile_stats()``: compiles /
                warmup_compiles / calls / dispatches / cache_hits /
                padded_rows / buckets, plus ``plan`` (hit_rate,
                hits/extends/misses, mean_union_live / mean_own_live /
                mean_width) when the session runs plan_reuse.
      gateway   ``Gateway.stats()``: telemetry counters + gauges +
                derived rates (qps, batch_fill, bucket_fill,
                *_dco_per_query, result_fill_rate, mean_top1_dist) +
                latency/queue_wait/dispatch histograms, queue depth,
                handover + session + stream state.
      hbm_model ``session_traffic_model``: scan_width / fetch / block /
                max_scan / fused_topk + modeled bytes_per_query
                (unfused vs fused write and roundtrip, reductions).
      trace     per-span-name aggregates (count / total_s / mean_ms /
                summed counters), fence + drop counts, and
                ``stage_attribution`` (stage time / dispatch time) and
                ``dco`` (per-stage DCO incl. the delta-vs-base scan
                split) when the trace carried them.
    """
    out: Dict[str, Any] = {"schema_version": SNAPSHOT_SCHEMA_VERSION}
    if gateway is not None and gateway_stats is None:
        gateway_stats = gateway.stats()
    if gateway_stats is not None:
        out["gateway"] = gateway_stats
    if searcher is None and gateway is not None:
        searcher = getattr(gateway, "_last_session", None)
    if searcher is not None:
        out["session"] = searcher.compile_stats()
        out["hbm_model"] = session_traffic_model(searcher)
    if tracer is not None:
        out["trace"] = _trace_section(tracer)
    return out
