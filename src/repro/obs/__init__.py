"""Observability: engine-deep tracing, unified stats, trace export.

The tracer (``repro/obs/tracer.py``) is a span recorder threaded through
the whole serving stack — ``Gateway`` flush → ``Searcher`` dispatch →
engine stages → sharded lowering.  It is **off by default**: every
instrumentation point goes through the module-level ``span()`` /
``fence()`` helpers, which are no-ops (shared singleton span, no device
sync, no recorded work) until ``start()`` installs an active tracer.
With a tracer active, device work is timed by fencing
(``jax.block_until_ready``) at stage boundaries and staged pipelines
(``seil_search_traced`` et al.) replace the monolithic executables —
bitwise-identical by construction and asserted in tests/test_obs.py.

Export paths (DESIGN.md §11):
  * ``write_trace`` — Chrome/Perfetto trace-event JSON (``--trace`` on
    launch/serve.py); ``validate_trace`` is the schema gate CI runs.
  * ``to_prometheus`` — text exposition of any nested stats dict.
  * ``snapshot_all`` — the one documented stats schema unifying session
    compile stats, plan-cache stats, per-stage DCO from span counters,
    gateway telemetry, and the modeled HBM traffic of the scan stage.
"""
from .export import (to_prometheus, to_trace_events, validate_trace,
                     write_trace)
from .stats import scan_traffic_model, session_traffic_model, snapshot_all
from .tracer import (Tracer, enabled, fence, span, start, stop, trace,
                     tracer, work_count)

__all__ = [
    "Tracer", "enabled", "fence", "span", "start", "stop", "trace",
    "tracer", "work_count",
    "to_trace_events", "write_trace", "validate_trace", "to_prometheus",
    "snapshot_all", "scan_traffic_model", "session_traffic_model",
]
