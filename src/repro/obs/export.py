"""Trace/stats exporters: Chrome/Perfetto trace-event JSON + Prometheus
text exposition (DESIGN.md §11).

The trace format is the Chrome trace-event *JSON object format*: a top
level ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` where every
event is a complete ("ph": "X") event with microsecond ``ts``/``dur``
plus thread-name metadata ("ph": "M") rows — loadable unmodified in
``chrome://tracing`` and https://ui.perfetto.dev.  ``validate_trace``
is the schema contract CI enforces on captured traces
(``python -m repro.obs.export FILE``).

``to_prometheus`` flattens any nested numeric stats dict (e.g.
``snapshot_all()`` or ``Gateway.stats()``) into ``rairs_*`` text
exposition lines for scrape-style consumption from the gateway sink.
"""
from __future__ import annotations

import json
import numbers
import re
from typing import Any, Dict

from .tracer import _REQ_TID_BASE, _REQ_TRACKS, Tracer

_PID = 1
_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def to_trace_events(tracer: Tracer) -> Dict[str, Any]:
    """Render a tracer's records as a Chrome trace-event JSON document.

    Real thread ids are remapped to small ints in first-seen order;
    virtual request tracks (``Tracer.event`` exemplars) keep their own
    named tracks after the real threads.
    """
    with tracer._lock:
        recs = list(tracer.records)
    tid_map: Dict[int, int] = {}
    events = [{"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
               "args": {"name": "rairs-serve"}}]
    body = []
    for r in recs:
        raw = r["tid"]
        if raw not in tid_map:
            tid_map[raw] = len(tid_map)
            # virtual request tracks occupy exactly the small reserved
            # band; real OS thread idents are arbitrary large ints
            virt = _REQ_TID_BASE <= raw < _REQ_TID_BASE + _REQ_TRACKS
            label = (f"requests-{raw - _REQ_TID_BASE}" if virt
                     else f"thread-{tid_map[raw]}")
            events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                           "tid": tid_map[raw], "args": {"name": label}})
        body.append({
            "name": r["name"], "cat": r["cat"], "ph": "X",
            "ts": r["ts"] * 1e6, "dur": r["dur"] * 1e6,
            "pid": _PID, "tid": tid_map[raw],
            "args": {k: v for k, v in r["args"].items()},
        })
    body.sort(key=lambda e: e["ts"])
    return {"traceEvents": events + body, "displayTimeUnit": "ms",
            "otherData": {"fences": tracer.fences,
                          "dropped": tracer.dropped}}


def write_trace(tracer_or_doc, path: str) -> Dict[str, Any]:
    """Serialize a tracer (or a pre-rendered document) to ``path``;
    returns the document written."""
    doc = (tracer_or_doc if isinstance(tracer_or_doc, dict)
           else to_trace_events(tracer_or_doc))
    validate_trace(doc)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return doc


def validate_trace(doc: Any) -> Dict[str, Any]:
    """Raise ``ValueError`` unless ``doc`` is a well-formed trace-event
    JSON object; returns the doc.  This is the CI schema gate."""
    if not isinstance(doc, dict):
        raise ValueError(f"trace root must be an object, got {type(doc)}")
    ev = doc.get("traceEvents")
    if not isinstance(ev, list) or not ev:
        raise ValueError("traceEvents must be a non-empty list")
    for i, e in enumerate(ev):
        if not isinstance(e, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        ph = e.get("ph")
        if ph not in ("X", "M"):
            raise ValueError(f"traceEvents[{i}]: unsupported ph {ph!r}")
        if not isinstance(e.get("name"), str):
            raise ValueError(f"traceEvents[{i}]: missing string name")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                raise ValueError(f"traceEvents[{i}]: {key} must be an int")
        if ph == "X":
            for key in ("ts", "dur"):
                v = e.get(key)
                if not isinstance(v, numbers.Real) or v < 0:
                    raise ValueError(
                        f"traceEvents[{i}]: {key} must be a number >= 0, "
                        f"got {v!r}")
        if "args" in e and not isinstance(e["args"], dict):
            raise ValueError(f"traceEvents[{i}]: args must be an object")
    return doc


def to_prometheus(stats: Dict[str, Any], prefix: str = "rairs") -> str:
    """Flatten the numeric leaves of a nested stats dict into Prometheus
    text exposition lines (``<prefix>_<dotted_path_with_underscores>
    <value>``).  Non-numeric leaves and list entries are skipped —
    counters, gauges, rates, and histogram summaries all survive."""
    lines = []

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (str(k),))
        elif isinstance(node, bool):
            lines.append((path, int(node)))
        elif isinstance(node, numbers.Real):
            lines.append((path, node))

    walk(stats, ())
    out = []
    for path, v in sorted(lines):
        name = _NAME_RE.sub("_", "_".join((prefix,) + path))
        out.append(f"{name} {float(v):g}")
    return "\n".join(out) + "\n"


def main(argv=None) -> int:
    """CLI schema gate: validate a captured trace file and print a
    one-line summary per span category."""
    import argparse
    ap = argparse.ArgumentParser(
        description="validate a Chrome/Perfetto trace-event JSON file")
    ap.add_argument("trace", help="path to a captured trace file")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    validate_trace(doc)
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    cats: Dict[str, int] = {}
    for e in spans:
        cats[e.get("cat", "?")] = cats.get(e.get("cat", "?"), 0) + 1
    by_cat = ", ".join(f"{k}={v}" for k, v in sorted(cats.items()))
    print(f"ok: {args.trace} — {len(spans)} spans ({by_cat})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
