"""Gemma-2B [arXiv:2403.08295]: 18L d=2048 8H MQA(kv=1) ff=16384
vocab=256000 — GeGLU activation, head_dim=256."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="gelu", rope_theta=1e4,
    tie_embeddings=True,
)
