"""OLMoE-1B-7B [arXiv:2409.02060]: 16L d=2048 16H ff(expert)=1024
vocab=50304, MoE 64 experts top-8 (every layer)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab=50304, rope_theta=1e4,
    moe_experts=64, moe_top_k=8, moe_d_ff=1024, moe_every=1,
)
