"""Jamba-1.5-Large (398B) [arXiv:2403.19887]: 72L d=8192 64H GQA(kv=8)
ff=24576 vocab=65536 — Mamba:attention 7:1 interleave (period 8, attn at
slot 4), MoE 16 experts top-2 on alternating layers.  The Mamba mixer is
implemented as Mamba-2/SSD (state-space duality) — see DESIGN.md
§Arch-applicability for the adaptation note."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536, rope_theta=1e4,
    attn_every=8, moe_experts=16, moe_top_k=2, moe_d_ff=24576, moe_every=2,
    ssm_heads=256, ssm_head_dim=64, ssm_state=128,
)
