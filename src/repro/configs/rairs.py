"""The paper's own workload: RAIRS ANN serving at production scale.

SIFT1B-like: 1B vectors, D=128, nlist=32768 (paper §6.1), PQ M=64
nbits=4, sharded over the ("pod","data") axes; a serve step scores a
query batch (centroid top-nprobe -> SEIL block scan -> refine)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RairsServeConfig:
    name: str = "rairs-sift1b"
    n_vectors: int = 1_000_000_000
    d: int = 128
    nlist: int = 32768
    m_pq: int = 64
    block: int = 128          # TPU-native block (lane width)
    nprobe: int = 64
    k: int = 10
    k_factor: int = 10
    query_batch: int = 4096
    max_scan_blocks: int = 4096   # per-query static scan budget


CONFIG = RairsServeConfig()
