"""Snowflake Arctic (480B) [hf:Snowflake/snowflake-arctic-base]: 35L
d=7168 56H GQA(kv=8) ff=4864 vocab=32000, MoE 128 experts top-2 with a
dense residual MLP in parallel (Arctic's dense-MoE hybrid)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000, rope_theta=1e4,
    moe_experts=128, moe_top_k=2, moe_d_ff=4864, moe_every=1,
    moe_dense_residual=True,
)
