"""ModelConfig — one dataclass describing every assigned architecture.

Heterogeneous stacks (Jamba) are expressed as a repeating *period* of
sublayers: `attn_every=8` means each period has 1 attention + 7 Mamba
mixers; `moe_every=2` alternates dense/MoE MLPs inside the period.  The
stack scans over `n_layers / period` identical periods, so per-kind
parameters stack cleanly for `jax.lax.scan`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|vlm|audio|hybrid|ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    act: str = "silu"              # silu | gelu (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 1e6
    m_rope: bool = False
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)
    causal: bool = True            # False => bidirectional encoder
    has_decode: bool = True        # False => encoder-only (no KV cache)
    tie_embeddings: bool = False
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1             # MoE MLP on every k-th layer of a period
    moe_dense_residual: bool = False  # Arctic: dense MLP in parallel w/ MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    attn_every: int = 0            # 0: all-attn; k>1: 1 attn per k layers;
    #                                -1: attention-free (pure SSM)
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_state: int = 128
    ssm_chunk: int = 128
    # --- modality frontend stubs ---
    frontend: str = "none"         # none | patch (VLM) | frame (audio)
    patch_dim: int = 1176          # raw patch embedding dim (Qwen2-VL)
    # --- execution knobs ---
    flash_chunk: int = 1024
    ce_chunk: int = 512            # sequence chunking for the CE loss

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        if self.attn_every > 1:
            return self.attn_every if self.moe_every <= 1 else \
                _lcm(self.attn_every, self.moe_every)
        return max(self.moe_every, 1)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    def slot_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """Per sublayer slot within a period: (mixer, mlp) kinds."""
        out = []
        for j in range(self.period):
            if self.attn_every == -1:
                mixer = "ssm"
            elif self.attn_every > 1:
                # Jamba: attention in the middle of the period (1:7 ratio)
                mixer = "attn" if j == self.attn_every // 2 else "ssm"
            else:
                mixer = "attn"
            if mixer == "ssm":
                mlp = "none" if self.family == "ssm" else \
                    ("moe" if (self.moe_experts and j % self.moe_every == 1)
                     else "dense")
            elif self.moe_experts and (self.moe_every <= 1
                                       or j % self.moe_every == 1):
                mlp = "moe"
            else:
                mlp = "dense"
            out.append((mixer, mlp))
        return tuple(out)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = self.period
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(period, 2 if period == 1 else period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            else 2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=64 if self.moe_experts else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_head_dim=8 if self.ssm_heads else 64,
            ssm_state=16 if self.ssm_heads else 128,
            ssm_chunk=8,
            m_rope_sections=(2, 3, 3),
            patch_dim=32,
            flash_chunk=64,
            ce_chunk=32,
        )


def _lcm(a, b):
    import math
    return a * b // math.gcd(a, b)


# ----------------------------------------------------------------------------
# Input shapes (the assigned shape set; see launch/shapes.py for specs)
# ----------------------------------------------------------------------------
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="long_decode", seq_len=524288, global_batch=1),
}
