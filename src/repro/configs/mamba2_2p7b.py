"""Mamba2-2.7B [arXiv:2405.21060]: 64L d=2560 attention-free, SSD
(state-space duality), d_inner=2*2560 -> 80 heads of 64, ssm_state=128,
vocab=50280."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280, attn_every=-1,
    ssm_heads=80, ssm_head_dim=64, ssm_state=128,
    tie_embeddings=True,
)
