"""HuBERT-XLarge [arXiv:2106.07447]: 48L d=1280 16H ff=5120 vocab=504 —
encoder-only (bidirectional, no decode shapes), conv feature extractor
is a STUB per spec (input_specs supplies frame embeddings)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504, rope_theta=1e4,
    causal=False, has_decode=False, frontend="frame",
)
