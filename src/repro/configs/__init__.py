"""Architecture registry: ``--arch <id>`` resolves here."""
from .base import ModelConfig, SHAPES  # noqa

from .qwen3_8b import CONFIG as qwen3_8b
from .gemma_2b import CONFIG as gemma_2b
from .llama3_8b import CONFIG as llama3_8b
from .qwen3_1p7b import CONFIG as qwen3_1p7b
from .qwen2_vl_7b import CONFIG as qwen2_vl_7b
from .olmoe_1b_7b import CONFIG as olmoe_1b_7b
from .arctic_480b import CONFIG as arctic_480b
from .hubert_xlarge import CONFIG as hubert_xlarge
from .jamba_1p5_large import CONFIG as jamba_1p5_large
from .mamba2_2p7b import CONFIG as mamba2_2p7b

ARCHS = {
    "qwen3-8b": qwen3_8b,
    "gemma-2b": gemma_2b,
    "llama3-8b": llama3_8b,
    "qwen3-1.7b": qwen3_1p7b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "arctic-480b": arctic_480b,
    "hubert-xlarge": hubert_xlarge,
    "jamba-1.5-large-398b": jamba_1p5_large,
    "mamba2-2.7b": mamba2_2p7b,
}


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name]
