"""Qwen2-VL-7B [arXiv:2409.12191]: 28L d=3584 28H GQA(kv=4) ff=18944
vocab=152064 — M-RoPE (t/h/w sections), dynamic-resolution vision
frontend is a STUB per spec (input_specs supplies patch embeddings)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, rope_theta=1e6,
    m_rope=True, m_rope_sections=(16, 24, 24),
    frontend="patch", patch_dim=1176,
)
